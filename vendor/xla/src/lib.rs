//! Offline stub of the `xla` PJRT bindings.
//!
//! Mirrors the exact type/method surface `runtime::engine` consumes.  The
//! native XLA runtime is not linked: `PjRtClient::cpu()` fails with
//! [`XlaError::Unavailable`], so `Engine::open` errors out cleanly and the
//! PJRT-dependent integration tests skip themselves.  Replace this path
//! dependency with the real bindings to execute AOT artifacts.

/// Stub error: the native runtime is not present in this build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum XlaError {
    Unavailable,
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "XLA/PJRT runtime unavailable (offline stub build)")
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Stub of the PJRT client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::Unavailable)
    }
}

/// Stub of a parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::Unavailable)
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a host literal.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(XlaError::Unavailable)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(XlaError::Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::Unavailable)
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::Unavailable)
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
