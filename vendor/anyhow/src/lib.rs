//! Minimal offline stand-in for the `anyhow` crate: a string-backed error
//! type, the `anyhow!` macro, and the `Context` extension trait — exactly
//! the subset this workspace uses.  `{e}`, `{e:?}` and `{e:#}` all render
//! the full context chain.

use std::fmt;

/// A string-backed error with an optional context chain.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a failing `Result`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn macro_formats() {
        let name = "x";
        let e = anyhow!("bad {name}: {}", 7);
        assert_eq!(e.to_string(), "bad x: 7");
    }

    #[test]
    fn question_mark_converts_io() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn with_context_chains() {
        let e = io_fail().with_context(|| "reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: gone");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }
}
