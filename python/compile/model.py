# L2: the paper's training workload — an L-layer MLP (Sec. III: 20 layers of
# 2048x2048 with mini-batch per node) — written as *layerwise* jax entry
# points so the Rust coordinator can interleave per-layer backward compute
# with per-layer non-blocking all-reduce exactly as in the paper's Fig. 3b
# execution trace.
#
# Every GEMM goes through the L1 Pallas matmul kernel; the gradient
# quantization goes through the L1 BFP kernel; the NIC adder through the L1
# chunk-add kernel — so all three kernels lower into the AOT'd HLO.
#
# Build-time only: aot.py lowers these with jax.jit(...).lower(...) to HLO
# text; Python never runs on the Rust request path.

import jax
import jax.numpy as jnp

from .kernels import bfp as kbfp
from .kernels import matmul as kmm
from .kernels import reduce as kred


def init_params(key, n_layers, hidden, scale=None):
    """He-initialized weights/biases for an `n_layers` MLP of width `hidden`.

    Matches the paper's symmetric M_l x M_l layer shape.
    """
    if scale is None:
        scale = (2.0 / hidden) ** 0.5
    ws, bs = [], []
    for i in range(n_layers):
        key, sub = jax.random.split(key)
        ws.append(jax.random.normal(sub, (hidden, hidden), jnp.float32) * scale)
        bs.append(jnp.zeros((hidden,), jnp.float32))
    return ws, bs


# ---------------------------------------------------------------------------
# Layerwise entry points (each is AOT-lowered per shape)
# ---------------------------------------------------------------------------

def layer_fwd(x, w, b):
    """Hidden layer forward: z = x @ w + b; y = relu(z).

    Returns (y, z); z is stashed by the coordinator for the backward pass.
    """
    z = kmm.matmul(x, w) + b[None, :]
    y = jnp.maximum(z, 0.0)
    return y, z


def layer_fwd_linear(x, w, b):
    """Output layer forward (no activation): y = x @ w + b."""
    y = kmm.matmul(x, w) + b[None, :]
    return (y,)


def layer_bwd(x, z, w, dy):
    """Hidden layer backward given upstream dy:
      dz = dy * relu'(z);  dw = x^T @ dz;  db = sum_rows(dz);  dx = dz @ w^T.

    The two GEMMs are the paper's T_B = 4 M^2 B / P term (2x the forward
    FLOPs).  Returns (dx, dw, db).
    """
    dz = jnp.where(z > 0.0, dy, 0.0)
    dw = kmm.matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    dx = kmm.matmul(dz, w.T)
    return dx, dw, db


def layer_bwd_linear(x, w, dy):
    """Output layer backward (identity activation)."""
    dw = kmm.matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    dx = kmm.matmul(dy, w.T)
    return dx, dw, db


def mse_loss_grad(y, target):
    """Mean-square-error loss (paper Sec. II-A) and its gradient wrt y.

    loss = mean_{i,j} (y - t)^2 ; dy = 2 (y - t) / (B * M).
    Returns (loss[scalar as (1,1)], dy).
    """
    b, m = y.shape
    diff = y - target
    loss = jnp.sum(diff * diff) / (b * m)
    dy = (2.0 / (b * m)) * diff
    return loss.reshape(1, 1), dy


def sgd_update(w, dw, lr):
    """Plain SGD weight update (the worker-side T_U term): w - lr * dw.

    lr arrives as a (1,1) tensor so one artifact serves any learning rate.
    """
    return (w - lr.reshape(()) * dw,)


def adam_update(w, dw, m, v, lr, b1t, b2t):
    """Adam (Kingma & Ba [3], the paper's cited alternative optimizer).

    beta1=0.9, beta2=0.999, eps=1e-8 baked in; `b1t`/`b2t` are beta^t
    bias-correction powers passed as (1,1) tensors so one artifact serves
    every step.  Returns (w', m', v').
    """
    beta1, beta2, eps = 0.9, 0.999, 1e-8
    m2 = beta1 * m + (1.0 - beta1) * dw
    v2 = beta2 * v + (1.0 - beta2) * dw * dw
    mhat = m2 / (1.0 - b1t.reshape(()))
    vhat = v2 / (1.0 - b2t.reshape(()))
    w2 = w - lr.reshape(()) * mhat / (jnp.sqrt(vhat) + eps)
    return w2, m2, v2


def bfp_roundtrip_grad(g):
    """Wire quantization of a (M, M) gradient tensor: flatten, BFP16
    compress+decompress (what the NIC does at Tx/Rx), reshape back."""
    m, n = g.shape
    flat = g.reshape(-1, kbfp.DEFAULT_BLOCK_SIZE)
    q = kbfp.bfp_roundtrip(flat)
    return (q.reshape(m, n),)


def nic_chunk_add(a, b):
    """The NIC reduction step over a flat chunk (rows, 128)."""
    return (kred.chunk_add(a, b),)


# ---------------------------------------------------------------------------
# Whole-model reference (used by tests and for HLO cost analysis, not AOT'd
# per-layer)
# ---------------------------------------------------------------------------

def mlp_forward(params, x):
    """Full forward pass: hidden layers with relu, linear output layer."""
    ws, bs = params
    acts = [x]
    zs = []
    h = x
    for i in range(len(ws) - 1):
        h, z = layer_fwd(h, ws[i], bs[i])
        acts.append(h)
        zs.append(z)
    (y,) = layer_fwd_linear(h, ws[-1], bs[-1])
    return y, acts, zs


def mlp_loss(params, x, target):
    y, _, _ = mlp_forward(params, x)
    loss, _ = mse_loss_grad(y, target)
    return loss.reshape(())


def mlp_loss_ref(params, x, target):
    """Pure-jnp twin of mlp_loss (no Pallas) — jax.grad-able; the autodiff
    oracle that mlp_grads' manual layerwise backward is tested against."""
    ws, bs = params
    h = x
    for i in range(len(ws) - 1):
        h = jnp.maximum(jnp.dot(h, ws[i]) + bs[i][None, :], 0.0)
    y = jnp.dot(h, ws[-1]) + bs[-1][None, :]
    b, m = y.shape
    diff = y - target
    return jnp.sum(diff * diff) / (b * m)


def mlp_grads(params, x, target):
    """Layerwise manual backward — the exact sequence the Rust coordinator
    replays step by step.  Tested against jax.grad(mlp_loss)."""
    ws, bs = params
    y, acts, zs = mlp_forward(params, x)
    loss, dy = mse_loss_grad(y, target)
    dws = [None] * len(ws)
    dbs = [None] * len(ws)
    dx, dws[-1], dbs[-1] = layer_bwd_linear(acts[-1], ws[-1], dy)
    for i in range(len(ws) - 2, -1, -1):
        dx, dws[i], dbs[i] = layer_bwd(acts[i], zs[i], ws[i], dx)
    return loss.reshape(()), dws, dbs
