# AOT compile path: lower every L2 entry point to HLO *text* + a manifest.
#
# HLO text (NOT lowered.compile()/.serialize()) is the interchange format:
# jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
# crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
# parser reassigns ids and round-trips cleanly.  Recipe follows
# /opt/xla-example/gen_hlo.py.
#
# Outputs (under --out-dir, default ../artifacts):
#   <name>.hlo.txt        one per (entry point, shape) pair
#   manifest.json         name -> file, entry, input/output shapes
#   golden/bfp_cases.json bit-exact BFP vectors for the Rust codec tests
#
# Run via `make artifacts` (no-op when inputs are unchanged).

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref as kref
from .kernels.bfp import DEFAULT_BLOCK_SIZE, DEFAULT_MANT_BITS

F32 = jnp.float32

# (hidden, batch) grid lowered by default.  The tiny 64/16 pair keeps the
# Rust integration tests fast; 256/32 and 512/64 are the e2e training
# shapes.  --full adds the paper-scale 2048/448 pair used for compute-time
# calibration of the simulator.
DEFAULT_SHAPES = [(64, 16), (256, 32), (512, 64)]
FULL_SHAPES = [(2048, 448)]


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True; the Rust
    side unwraps the tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_points(shapes):
    """Yield (name, fn, example_args, meta) for every artifact to build."""
    for m, b in shapes:
        tag = f"m{m}_b{b}"
        yield (f"layer_fwd_{tag}", model.layer_fwd,
               (spec(b, m), spec(m, m), spec(m)),
               {"entry": "layer_fwd", "hidden": m, "batch": b})
        yield (f"layer_fwd_linear_{tag}", model.layer_fwd_linear,
               (spec(b, m), spec(m, m), spec(m)),
               {"entry": "layer_fwd_linear", "hidden": m, "batch": b})
        yield (f"layer_bwd_{tag}", model.layer_bwd,
               (spec(b, m), spec(b, m), spec(m, m), spec(b, m)),
               {"entry": "layer_bwd", "hidden": m, "batch": b})
        yield (f"layer_bwd_linear_{tag}", model.layer_bwd_linear,
               (spec(b, m), spec(m, m), spec(b, m)),
               {"entry": "layer_bwd_linear", "hidden": m, "batch": b})
        yield (f"mse_loss_grad_{tag}", model.mse_loss_grad,
               (spec(b, m), spec(b, m)),
               {"entry": "mse_loss_grad", "hidden": m, "batch": b})
    hiddens = sorted({m for m, _ in shapes})
    for m in hiddens:
        yield (f"sgd_update_m{m}", model.sgd_update,
               (spec(m, m), spec(m, m), spec(1, 1)),
               {"entry": "sgd_update", "hidden": m})
        yield (f"sgd_update_vec_m{m}", model.sgd_update,
               (spec(1, m), spec(1, m), spec(1, 1)),
               {"entry": "sgd_update_vec", "hidden": m})
        yield (f"adam_update_m{m}", model.adam_update,
               (spec(m, m), spec(m, m), spec(m, m), spec(m, m),
                spec(1, 1), spec(1, 1), spec(1, 1)),
               {"entry": "adam_update", "hidden": m})
        yield (f"adam_update_vec_m{m}", model.adam_update,
               (spec(1, m), spec(1, m), spec(1, m), spec(1, m),
                spec(1, 1), spec(1, 1), spec(1, 1)),
               {"entry": "adam_update_vec", "hidden": m})
        yield (f"bfp_roundtrip_m{m}", model.bfp_roundtrip_grad,
               (spec(m, m),),
               {"entry": "bfp_roundtrip", "hidden": m})
        rows = max(m * m // 128, 1)
        yield (f"nic_chunk_add_m{m}", model.nic_chunk_add,
               (spec(rows, 128), spec(rows, 128)),
               {"entry": "nic_chunk_add", "hidden": m})


def lower_all(out_dir, shapes, verbose=True):
    manifest = {"format": 1,
                "bfp": {"block_size": DEFAULT_BLOCK_SIZE,
                        "mant_bits": DEFAULT_MANT_BITS,
                        "exp_bits": 8},
                "artifacts": []}
    for name, fn, args, meta in entry_points(shapes):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = [list(o.shape) for o in
                      jax.eval_shape(fn, *args)]
        manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "inputs": [list(a.shape) for a in args],
            "outputs": out_shapes,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            **meta,
        })
        if verbose:
            print(f"  lowered {name:32s} ({len(text)//1024} KiB)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


# ---------------------------------------------------------------------------
# Golden BFP vectors: the contract between kernels/bfp.py and rust/src/bfp.
# Inputs and decoded outputs are stored as u32 bit patterns (bit-exact).
# ---------------------------------------------------------------------------

def _bfp_case(name, x):
    x = np.asarray(x, np.float32).reshape(-1, DEFAULT_BLOCK_SIZE)
    e, s, m = kref.bfp_encode_ref(jnp.asarray(x))
    dec = kref.bfp_decode_ref(e, s, m)
    return {
        "name": name,
        "block_size": DEFAULT_BLOCK_SIZE,
        "mant_bits": DEFAULT_MANT_BITS,
        "x_bits": np.asarray(x).view(np.uint32).reshape(-1).tolist(),
        "e_shared": np.asarray(e).reshape(-1).tolist(),
        "sign": np.asarray(s).reshape(-1).tolist(),
        "mag": np.asarray(m).reshape(-1).tolist(),
        "decoded_bits": np.asarray(dec).view(np.uint32).reshape(-1).tolist(),
    }


def golden_bfp_cases():
    rng = np.random.default_rng(0xB1_0C)
    bs = DEFAULT_BLOCK_SIZE
    cases = [
        _bfp_case("randn_4blocks", rng.standard_normal(4 * bs)),
        _bfp_case("zeros", np.zeros(bs)),
        _bfp_case("mixed_zero_nonzero",
                  np.where(rng.random(2 * bs) < 0.5, 0.0,
                           rng.standard_normal(2 * bs))),
        _bfp_case("wide_dynamic_range",
                  rng.standard_normal(4 * bs) *
                  np.exp2(rng.integers(-40, 40, 4 * bs)).astype(np.float32)),
        _bfp_case("negatives", -np.abs(rng.standard_normal(2 * bs))),
        _bfp_case("denormals",
                  (rng.standard_normal(bs) * 1e-41).astype(np.float32)),
        _bfp_case("tiny_gradients",
                  (rng.standard_normal(4 * bs) * 1e-8).astype(np.float32)),
        _bfp_case("large_values",
                  (rng.standard_normal(2 * bs) * 1e30).astype(np.float32)),
        _bfp_case("powers_of_two",
                  np.exp2(np.arange(bs) - 8).astype(np.float32)),
        _bfp_case("single_dominant",
                  np.concatenate([[1e6], rng.standard_normal(bs - 1)])
                  .astype(np.float32)),
    ]
    return {"format": 1, "cases": cases}


def write_golden(out_dir):
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    with open(os.path.join(gdir, "bfp_cases.json"), "w") as f:
        json.dump(golden_bfp_cases(), f)
    print(f"  wrote golden/bfp_cases.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--full", action="store_true",
                    help="also lower the paper-scale (2048, 448) shapes")
    ap.add_argument("--shapes", default="",
                    help="extra hidden:batch pairs, comma separated")
    args = ap.parse_args()
    shapes = list(DEFAULT_SHAPES)
    if args.full:
        shapes += FULL_SHAPES
    for tok in args.shapes.split(","):
        if tok:
            m, b = tok.split(":")
            shapes.append((int(m), int(b)))
    os.makedirs(args.out_dir, exist_ok=True)
    print(f"AOT lowering {len(shapes)} shape pairs -> {args.out_dir}")
    manifest = lower_all(args.out_dir, shapes)
    write_golden(args.out_dir)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json")


if __name__ == "__main__":
    main()
