# L1 Pallas kernel: FP32 chunk adder — the smart NIC's reduction datapath.
#
# In the paper's NIC (Fig. 3a) the input FIFO (local gradients via PCIe) and
# the Rx FIFO (partial sums from the previous ring node) feed a bank of FP32
# adders.  The TPU restatement streams (ROW_TILE, LANES) VMEM tiles through
# a VPU add; the Pallas grid loop plays the role of the FIFO drain and the
# BlockSpec double-buffering plays the role of the FIFO itself.
# See DESIGN.md "Hardware-Adaptation".

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128    # VPU lane width (f32) — the analogue of the NIC's SIMD lanes
ROW_TILE = 8   # f32 sublane tiling


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def chunk_add(a, b):
    """Elementwise f32 add of two equal-shape 2-D (rows, LANES) chunks."""
    rows, lanes = a.shape
    assert a.shape == b.shape
    tile = ROW_TILE if rows % ROW_TILE == 0 else rows
    return pl.pallas_call(
        _add_kernel,
        grid=(rows // tile,),
        in_specs=[
            pl.BlockSpec((tile, lanes), lambda i: (i, 0)),
            pl.BlockSpec((tile, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
        interpret=True,
    )(a, b)


def chunk_add_flat(a, b):
    """Adder for arbitrary-length 1-D chunks: pad to (rows, LANES) tiles,
    add, slice back — the shape the ring all-reduce actually moves."""
    n = a.shape[0]
    padded = -(-n // LANES) * LANES
    ap = jnp.pad(a, (0, padded - n)).reshape(-1, LANES)
    bp = jnp.pad(b, (0, padded - n)).reshape(-1, LANES)
    return chunk_add(ap, bp).reshape(-1)[:n]
