# Pure-jnp correctness oracles for every L1 kernel.
#
# Same integer BFP specification as kernels/bfp.py (and rust/src/bfp/) but
# written as plain vectorized jnp with no Pallas — the ground truth the
# kernels (and the Rust codec, via golden vectors) are tested against.

import jax
import jax.numpy as jnp

from .bfp import DEFAULT_BLOCK_SIZE, DEFAULT_MANT_BITS, _exp2_exact


def bfp_encode_ref(x, mant_bits=DEFAULT_MANT_BITS):
    """Reference BFP encode of (rows, block) f32 -> (E, sign, mag) int32."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = (bits >> 31).astype(jnp.int32)
    e = ((bits >> 23) & 0xFF).astype(jnp.int32)
    frac = (bits & 0x7FFFFF).astype(jnp.uint32)
    sig = jnp.where(e > 0, frac | jnp.uint32(0x800000), jnp.uint32(0))
    e_shared = jnp.max(e, axis=-1, keepdims=True)
    shift = jnp.minimum((e_shared - e) + (24 - mant_bits), 31).astype(jnp.uint32)
    bias = (jnp.uint32(1) << (shift - 1)).astype(jnp.uint32)
    mag = (sig + bias) >> shift
    mag = jnp.minimum(mag, jnp.uint32((1 << mant_bits) - 1)).astype(jnp.int32)
    return e_shared, sign, mag


def bfp_decode_ref(e_shared, sign, mag, mant_bits=DEFAULT_MANT_BITS):
    """Reference BFP decode -> f32 (exact power-of-two scale, matching the
    Rust codec bit for bit)."""
    scale = _exp2_exact(e_shared - 127 - (mant_bits - 1))
    mag_f = mag.astype(jnp.float32)
    return jnp.where(sign == 1, -mag_f, mag_f) * scale


def bfp_roundtrip_ref(x, block_size=DEFAULT_BLOCK_SIZE,
                      mant_bits=DEFAULT_MANT_BITS):
    assert x.shape[-1] == block_size
    return bfp_decode_ref(*bfp_encode_ref(x, mant_bits), mant_bits)


def bfp_roundtrip_flat_ref(x, block_size=DEFAULT_BLOCK_SIZE,
                           mant_bits=DEFAULT_MANT_BITS):
    n = x.shape[0]
    padded = -(-n // block_size) * block_size
    xp = jnp.pad(x, (0, padded - n)).reshape(-1, block_size)
    return bfp_roundtrip_ref(xp, block_size, mant_bits).reshape(-1)[:n]


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def chunk_add_ref(a, b):
    return a + b
