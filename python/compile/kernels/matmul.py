# L1 Pallas kernel: MXU-tiled matmul — the worker-side tensor hot spot.
#
# The paper's workers spend their time in the MLP's GEMMs (T_F = 2M^2B/P,
# T_B = 4M^2B/P).  On TPU this is MXU work: we tile (bm, bk) x (bk, bn)
# blocks through VMEM and accumulate f32 in the output block, which stays
# resident across the k grid dimension (the canonical Pallas matmul).
# interpret=True so the lowered HLO runs on the CPU PJRT client.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles: the systolic array is 128x128, and f32 VMEM
# tiling is (8, 128).  We clamp to the actual dims for small problems.
DEFAULT_BM = 512
DEFAULT_BN = 512
DEFAULT_BK = 512


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick(dim, block):
    """Largest tile <= block that divides dim (dims here are powers of two
    times small factors; worst case degrades to 1 which is still correct)."""
    t = min(block, dim)
    while dim % t != 0:
        t -= 1
    return t


def matmul(x, w, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """f32 (M, K) @ (K, N) -> (M, N) via the Pallas tiled kernel."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = _pick(m, bm), _pick(n, bn), _pick(k, bk)
    nk = k // bk
    kern = functools.partial(_matmul_kernel, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_t_a(x, w, **kw):
    """x^T @ w for (K, M), (K, N) -> (M, N): the dW GEMM of the backward
    pass.  Transpose-then-matmul keeps one kernel; XLA fuses the transpose
    into the surrounding HLO."""
    return matmul(x.T, w, **kw)


def matmul_t_b(x, w, **kw):
    """x @ w^T for (M, K), (N, K) -> (M, N): the dX GEMM of the backward
    pass."""
    return matmul(x, w.T, **kw)
