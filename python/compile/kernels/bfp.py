# L1 Pallas kernel: Block Floating Point (BFP) compress / decompress.
#
# This is the TPU restatement of the paper's FPGA BFP datapath (Sec. IV-B):
# FP32 gradients are split into blocks of `block_size` elements; each block
# shares one 8-bit exponent (the max biased FP32 exponent in the block) and
# each element keeps a sign bit plus a `mant_bits`-bit magnitude.  With the
# paper's BFP16 parameters (block 16, 7-bit mantissa, 8-bit shared exponent)
# a block costs 16*(1+7)+8 = 136 bits vs 16*32 = 512 bits: 3.76x compression.
#
# The integer datapath below is specified exactly so that the Rust codec
# (rust/src/bfp/codec.rs) can reproduce it bit-for-bit; golden vectors are
# emitted by python/compile/golden.py and checked from `cargo test`.
#
#   bits  = bitcast_u32(x)
#   sign  = bits >> 31
#   e     = (bits >> 23) & 0xFF                    # biased FP32 exponent
#   sig   = e > 0 ? (bits & 0x7FFFFF) | 0x800000   # 24-bit significand
#                 : 0                              # flush subnormals
#   E     = max(e) over the block                  # shared (biased) exponent
#   shift = (E - e) + (24 - mant_bits)             # >= 24-mant_bits
#   m     = min((sig + (1 << (shift-1))) >> shift, 2^mant_bits - 1)
#           with shift clamped to 31 (sig + rounding bias stays < 2^32)
#   decode: x_hat = (-1)^sign * m * 2^(E - 127 - (mant_bits - 1))
#
# Kernels run with interpret=True: the CPU PJRT plugin cannot execute Mosaic
# custom-calls, and the interpret lowering emits plain HLO that the Rust
# runtime loads and runs.  See DESIGN.md "Hardware-Adaptation".

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_SIZE = 16  # elements sharing one exponent (paper: 16)
DEFAULT_MANT_BITS = 7    # magnitude bits per element  (paper: 7)

# Rows of blocks processed per Pallas grid step.  One grid step reads a
# (ROW_TILE, block_size) VMEM tile — the analogue of the FPGA's input FIFO
# burst; the grid loop is the analogue of the streaming datapath.
ROW_TILE = 256


def _encode_tile(x, mant_bits):
    """Integer BFP encode of a (rows, block) f32 tile -> (E, sign, mag)."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = (bits >> 31).astype(jnp.int32)
    e = ((bits >> 23) & 0xFF).astype(jnp.int32)
    frac = (bits & 0x7FFFFF).astype(jnp.uint32)
    sig = jnp.where(e > 0, frac | jnp.uint32(0x800000), jnp.uint32(0))
    e_shared = jnp.max(e, axis=-1, keepdims=True)
    shift = jnp.minimum((e_shared - e) + (24 - mant_bits), 31).astype(jnp.uint32)
    bias = (jnp.uint32(1) << (shift - 1)).astype(jnp.uint32)
    mag = (sig + bias) >> shift
    mag = jnp.minimum(mag, jnp.uint32((1 << mant_bits) - 1)).astype(jnp.int32)
    return e_shared, sign, mag


def _exp2_exact(k):
    """Exact 2^k as f32 for k in [-134, 127] via bit construction.

    jnp.exp2 is an approximation on some backends (off by 1 ulp at large
    |k|), which would break bit-compatibility with the Rust codec.  Split
    k = a + b with a in [-126, 127] (normal range, exact bitcast) and
    b in [-8, 0]; the f32 product 2^a * 2^b is an exact power of two even
    when the result is subnormal.
    """
    a = jnp.clip(k, -126, 127)
    b = k - a  # in [-8, 0]
    fa = jax.lax.bitcast_convert_type(((a + 127) << 23).astype(jnp.uint32),
                                      jnp.float32)
    fb = jax.lax.bitcast_convert_type(((b + 127) << 23).astype(jnp.uint32),
                                      jnp.float32)
    return fa * fb


def _decode_tile(e_shared, sign, mag, mant_bits):
    """Integer BFP decode -> f32 tile: (-1)^sign * mag * 2^(E-127-(mb-1))."""
    scale = _exp2_exact(e_shared - 127 - (mant_bits - 1))
    mag_f = mag.astype(jnp.float32)
    return jnp.where(sign == 1, -mag_f, mag_f) * scale


def _compress_kernel(x_ref, e_ref, s_ref, m_ref, *, mant_bits):
    e_shared, sign, mag = _encode_tile(x_ref[...], mant_bits)
    e_ref[...] = e_shared
    s_ref[...] = sign
    m_ref[...] = mag


def _decompress_kernel(e_ref, s_ref, m_ref, o_ref, *, mant_bits):
    o_ref[...] = _decode_tile(e_ref[...], s_ref[...], m_ref[...], mant_bits)


def _roundtrip_kernel(x_ref, o_ref, *, mant_bits):
    e_shared, sign, mag = _encode_tile(x_ref[...], mant_bits)
    o_ref[...] = _decode_tile(e_shared, sign, mag, mant_bits)


def _grid_rows(n_rows):
    tile = min(ROW_TILE, n_rows)
    if n_rows % tile != 0:  # fall back to one step for ragged row counts
        return n_rows, 1
    return tile, n_rows // tile


def bfp_compress(x, block_size=DEFAULT_BLOCK_SIZE, mant_bits=DEFAULT_MANT_BITS):
    """Compress a (rows, block_size) f32 array to (E, sign, mag) int32 arrays.

    E has shape (rows, 1); sign and mag have x's shape.
    """
    rows, bs = x.shape
    assert bs == block_size, f"last dim {bs} != block_size {block_size}"
    tile, steps = _grid_rows(rows)
    kern = functools.partial(_compress_kernel, mant_bits=mant_bits)
    return pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[pl.BlockSpec((tile, bs), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, bs), lambda i: (i, 0)),
            pl.BlockSpec((tile, bs), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
            jax.ShapeDtypeStruct((rows, bs), jnp.int32),
            jax.ShapeDtypeStruct((rows, bs), jnp.int32),
        ],
        interpret=True,
    )(x)


def bfp_decompress(e_shared, sign, mag, mant_bits=DEFAULT_MANT_BITS):
    """Inverse of bfp_compress: (E, sign, mag) int32 -> f32 (rows, block)."""
    rows, bs = mag.shape
    tile, steps = _grid_rows(rows)
    kern = functools.partial(_decompress_kernel, mant_bits=mant_bits)
    return pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, bs), lambda i: (i, 0)),
            pl.BlockSpec((tile, bs), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, bs), jnp.float32),
        interpret=True,
    )(e_shared, sign, mag)


def bfp_roundtrip(x, block_size=DEFAULT_BLOCK_SIZE, mant_bits=DEFAULT_MANT_BITS):
    """Quantize-dequantize in one kernel: what a gradient experiences on the
    wire (compress at Tx, decompress at Rx).  Shape-preserving over
    (rows, block_size) f32."""
    rows, bs = x.shape
    assert bs == block_size
    tile, steps = _grid_rows(rows)
    kern = functools.partial(_roundtrip_kernel, mant_bits=mant_bits)
    return pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[pl.BlockSpec((tile, bs), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, bs), jnp.float32),
        interpret=True,
    )(x)


def bfp_roundtrip_flat(x, block_size=DEFAULT_BLOCK_SIZE,
                       mant_bits=DEFAULT_MANT_BITS):
    """Roundtrip for an arbitrary-length 1-D vector: pad to a whole number of
    blocks (paper Sec. IV-C: gradients are padded), quantize, slice back."""
    n = x.shape[0]
    padded = -(-n // block_size) * block_size
    xp = jnp.pad(x, (0, padded - n))
    y = bfp_roundtrip(xp.reshape(-1, block_size), block_size, mant_bits)
    return y.reshape(-1)[:n]


def compression_ratio(block_size=DEFAULT_BLOCK_SIZE,
                      mant_bits=DEFAULT_MANT_BITS, exp_bits=8):
    """Wire-format compression ratio beta (paper: 512/136 = 3.76 ~ "3.8x")."""
    return (32.0 * block_size) / (block_size * (1 + mant_bits) + exp_bits)
