# pytest: L2 model — layerwise fwd/bwd entry points vs autodiff of the
# pure-jnp reference model, loss/update semantics, shape contracts.

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

RTOL, ATOL = 2e-4, 2e-6


def _setup(n_layers=3, hidden=32, batch=8, seed=0):
    params = model.init_params(jax.random.PRNGKey(seed), n_layers, hidden)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, hidden))
    t = jax.random.normal(jax.random.PRNGKey(seed + 2), (batch, hidden))
    return params, x, t


class TestLayerEntryPoints:
    def test_layer_fwd_shapes_and_values(self):
        params, x, _ = _setup()
        w, b = params[0][0], params[1][0]
        y, z = model.layer_fwd(x, w, b)
        zr = np.asarray(x) @ np.asarray(w) + np.asarray(b)
        np.testing.assert_allclose(np.asarray(z), zr, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(np.asarray(y), np.maximum(zr, 0),
                                   rtol=RTOL, atol=ATOL)

    def test_layer_fwd_linear_no_activation(self):
        params, x, _ = _setup()
        w, b = params[0][0], params[1][0]
        (y,) = model.layer_fwd_linear(x, w, b)
        yr = np.asarray(x) @ np.asarray(w) + np.asarray(b)
        np.testing.assert_allclose(np.asarray(y), yr, rtol=RTOL, atol=ATOL)
        assert (np.asarray(y) < 0).any(), "linear output should go negative"

    def test_manual_bwd_matches_autodiff(self):
        params, x, t = _setup(n_layers=4, hidden=48, batch=10)
        loss, dws, dbs = model.mlp_grads(params, x, t)
        gw, gb = jax.grad(model.mlp_loss_ref)(params, x, t)
        assert abs(float(loss) - float(model.mlp_loss_ref(params, x, t))) < 1e-5
        for a, b in zip(dws, gw):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=RTOL, atol=ATOL)
        for a, b in zip(dbs, gb):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=RTOL, atol=ATOL)

    def test_pallas_loss_matches_ref_loss(self):
        params, x, t = _setup(n_layers=2, hidden=16, batch=4)
        assert abs(float(model.mlp_loss(params, x, t)) -
                   float(model.mlp_loss_ref(params, x, t))) < 1e-5

    def test_mse_loss_grad(self):
        y = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
        t = jnp.asarray([[0.0, 0.0], [0.0, 0.0]])
        loss, dy = model.mse_loss_grad(y, t)
        assert float(loss.reshape(())) == pytest.approx((1 + 4 + 9 + 16) / 4)
        np.testing.assert_allclose(np.asarray(dy), np.asarray(y) * 2 / 4)

    def test_sgd_update(self):
        w = jnp.ones((4, 4))
        dw = jnp.full((4, 4), 2.0)
        lr = jnp.asarray([[0.25]])
        (w2,) = model.sgd_update(w, dw, lr)
        np.testing.assert_allclose(np.asarray(w2), np.full((4, 4), 0.5))

    def test_adam_update_matches_manual(self):
        rng = np.random.default_rng(9)
        w = np.asarray(rng.standard_normal((8, 8)), np.float32)
        dw = np.asarray(rng.standard_normal((8, 8)), np.float32)
        m = np.zeros((8, 8), np.float32)
        v = np.zeros((8, 8), np.float32)
        lr, b1, b2, eps = 0.001, 0.9, 0.999, 1e-8
        t = 1
        w2, m2, v2 = model.adam_update(
            jnp.asarray(w), jnp.asarray(dw), jnp.asarray(m), jnp.asarray(v),
            jnp.asarray([[lr]]), jnp.asarray([[b1 ** t]]),
            jnp.asarray([[b2 ** t]]))
        m_ref = b1 * m + (1 - b1) * dw
        v_ref = b2 * v + (1 - b2) * dw * dw
        mhat = m_ref / (1 - b1 ** t)
        vhat = v_ref / (1 - b2 ** t)
        w_ref = w - lr * mhat / (np.sqrt(vhat) + eps)
        np.testing.assert_allclose(np.asarray(w2), w_ref, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(np.asarray(m2), m_ref, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(v2), v_ref, rtol=1e-6)

    def test_adam_converges_on_quadratic(self):
        # minimize ||w||^2 with gradient 2w
        w = jnp.asarray(np.ones((4, 4), np.float32))
        m = jnp.zeros((4, 4))
        v = jnp.zeros((4, 4))
        lr = jnp.asarray([[0.1]])
        for t in range(1, 101):
            dw = 2.0 * w
            w, m, v = model.adam_update(
                w, dw, m, v, lr,
                jnp.asarray([[0.9 ** t]]), jnp.asarray([[0.999 ** t]]))
        assert float(jnp.abs(w).max()) < 0.05

    def test_bfp_roundtrip_grad_shape_preserving(self):
        g = jax.random.normal(jax.random.PRNGKey(3), (32, 32))
        (q,) = model.bfp_roundtrip_grad(g)
        assert q.shape == g.shape
        # quantization error must be small relative to tensor norm
        rel = float(jnp.linalg.norm(q - g) / jnp.linalg.norm(g))
        assert rel < 0.01

    def test_nic_chunk_add(self):
        a = jax.random.normal(jax.random.PRNGKey(4), (32, 128))
        b = jax.random.normal(jax.random.PRNGKey(5), (32, 128))
        (o,) = model.nic_chunk_add(a, b)
        np.testing.assert_array_equal(np.asarray(o),
                                      np.asarray(a) + np.asarray(b))


class TestTraining:
    def test_loss_decreases_under_sgd(self):
        params, x, t = _setup(n_layers=3, hidden=32, batch=16)
        ws, bs = [list(params[0]), list(params[1])]
        lr = jnp.asarray([[0.05]])
        losses = []
        for _ in range(10):
            loss, dws, dbs = model.mlp_grads((ws, bs), x, t)
            losses.append(float(loss))
            for i in range(len(ws)):
                (ws[i],) = model.sgd_update(ws[i], dws[i], lr)
                (nb,) = model.sgd_update(bs[i].reshape(1, -1),
                                         dbs[i].reshape(1, -1), lr)
                bs[i] = nb.reshape(-1)
        assert losses[-1] < losses[0] * 0.9, losses

    def test_bfp_quantized_grads_still_converge(self):
        # Paper Sec. IV-B claim: BFP16 compression has minimal accuracy
        # impact.  Quantize every gradient before the update.
        params, x, t = _setup(n_layers=3, hidden=32, batch=16)
        ws, bs = [list(params[0]), list(params[1])]
        lr = jnp.asarray([[0.05]])
        losses = []
        for _ in range(10):
            loss, dws, dbs = model.mlp_grads((ws, bs), x, t)
            losses.append(float(loss))
            for i in range(len(ws)):
                (qdw,) = model.bfp_roundtrip_grad(dws[i])
                (ws[i],) = model.sgd_update(ws[i], qdw, lr)
                (nb,) = model.sgd_update(bs[i].reshape(1, -1),
                                         dbs[i].reshape(1, -1), lr)
                bs[i] = nb.reshape(-1)
        assert losses[-1] < losses[0] * 0.9, losses


@settings(max_examples=8, deadline=None)
@given(
    n_layers=st.integers(2, 5),
    hidden=st.sampled_from([16, 32, 48]),
    batch=st.integers(2, 12),
    seed=st.integers(0, 10_000),
)
def test_bwd_vs_autodiff_hypothesis(n_layers, hidden, batch, seed):
    params = model.init_params(jax.random.PRNGKey(seed), n_layers, hidden)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, hidden))
    t = jax.random.normal(jax.random.PRNGKey(seed + 2), (batch, hidden))
    _, dws, dbs = model.mlp_grads(params, x, t)
    gw, gb = jax.grad(model.mlp_loss_ref)(params, x, t)
    for a, b in zip(dws, gw):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-5)
    for a, b in zip(dbs, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-5)
