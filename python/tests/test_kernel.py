# pytest: Pallas kernels vs pure-jnp oracles — the CORE correctness signal.
#
# hypothesis sweeps shapes and value distributions; every kernel output is
# compared against ref.py with assert_allclose (bit-equality for the integer
# BFP datapath).

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bfp, matmul, reduce as red, ref

RNG = np.random.default_rng(1234)


def f32(a):
    return jnp.asarray(np.asarray(a, np.float32))


# ---------------------------------------------------------------------------
# BFP compress / decompress / roundtrip
# ---------------------------------------------------------------------------

class TestBfpAgainstRef:
    @pytest.mark.parametrize("rows", [1, 2, 7, 64, 300])
    def test_compress_matches_ref(self, rows):
        x = f32(RNG.standard_normal((rows, 16)))
        e, s, m = bfp.bfp_compress(x)
        er, sr, mr = ref.bfp_encode_ref(x)
        np.testing.assert_array_equal(np.asarray(e), np.asarray(er))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
        np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))

    @pytest.mark.parametrize("rows", [1, 8, 257])
    def test_decompress_matches_ref(self, rows):
        x = f32(RNG.standard_normal((rows, 16)) * 100)
        e, s, m = ref.bfp_encode_ref(x)
        got = bfp.bfp_decompress(e, s, m)
        want = ref.bfp_decode_ref(e, s, m)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("rows", [1, 16, 128])
    def test_roundtrip_matches_ref(self, rows):
        x = f32(RNG.standard_normal((rows, 16)))
        got = bfp.bfp_roundtrip(x)
        want = ref.bfp_roundtrip_ref(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_roundtrip_equals_compress_then_decompress(self):
        x = f32(RNG.standard_normal((32, 16)))
        via_pair = bfp.bfp_decompress(*bfp.bfp_compress(x))
        via_rt = bfp.bfp_roundtrip(x)
        np.testing.assert_array_equal(np.asarray(via_pair), np.asarray(via_rt))

    def test_flat_handles_padding(self):
        x = f32(RNG.standard_normal(1000))  # not a multiple of 16
        got = bfp.bfp_roundtrip_flat(x)
        want = ref.bfp_roundtrip_flat_ref(x)
        assert got.shape == (1000,)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestBfpSemantics:
    """Properties of the format itself (paper Sec. IV-B)."""

    def test_zeros_roundtrip_to_zero(self):
        x = jnp.zeros((4, 16), jnp.float32)
        out = np.asarray(bfp.bfp_roundtrip(x))
        np.testing.assert_array_equal(out, np.zeros((4, 16), np.float32))

    def test_error_bound_half_ulp_of_block(self):
        # |x - dec(enc(x))| <= 2^(E-127-mant_bits) + tiny slack (saturation
        # of the max element adds at most one extra step).
        x = f32(RNG.standard_normal((64, 16)) * np.exp2(RNG.integers(-8, 8, (64, 16))))
        e, _, _ = ref.bfp_encode_ref(x)
        dec = np.asarray(bfp.bfp_roundtrip(x))
        bound = np.exp2(np.asarray(e) - 127.0 - 7.0) * 2.0
        assert (np.abs(dec - np.asarray(x)) <= bound + 1e-38).all()

    def test_max_element_relative_error(self):
        x = f32(RNG.standard_normal((128, 16)))
        dec = np.asarray(bfp.bfp_roundtrip(x))
        xa = np.asarray(x)
        idx = np.abs(xa).argmax(axis=1)
        rows = np.arange(xa.shape[0])
        rel = np.abs(dec[rows, idx] - xa[rows, idx]) / np.abs(xa[rows, idx])
        assert (rel <= 2.0 ** -7 + 1e-6).all()

    def test_signs_preserved(self):
        x = f32([[1.0, -1.0] * 8])
        dec = np.asarray(bfp.bfp_roundtrip(x))
        assert (np.sign(dec) == np.sign(np.asarray(x))).all()

    def test_denormals_flush_to_zero(self):
        x = f32(np.full((1, 16), 1e-41))  # subnormal in f32
        dec = np.asarray(bfp.bfp_roundtrip(x))
        np.testing.assert_array_equal(dec, np.zeros((1, 16), np.float32))

    def test_compression_ratio_is_papers_3p8(self):
        assert abs(bfp.compression_ratio() - 512.0 / 136.0) < 1e-12
        assert round(bfp.compression_ratio(), 1) == 3.8

    def test_quantization_is_idempotent(self):
        x = f32(RNG.standard_normal((32, 16)))
        once = bfp.bfp_roundtrip(x)
        twice = bfp.bfp_roundtrip(once)
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))

    @pytest.mark.parametrize("mant_bits", [3, 5, 7, 9])
    def test_error_shrinks_with_mantissa_bits(self, mant_bits):
        x = f32(RNG.standard_normal((64, 16)))
        dec = np.asarray(bfp.bfp_roundtrip(x, mant_bits=mant_bits))
        err = np.abs(dec - np.asarray(x)).mean()
        dec2 = np.asarray(bfp.bfp_roundtrip(x, mant_bits=mant_bits + 2))
        err2 = np.abs(dec2 - np.asarray(x)).mean()
        assert err2 <= err


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 200),
    scale_exp=st.integers(-30, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_bfp_hypothesis_sweep(rows, scale_exp, seed):
    rng = np.random.default_rng(seed)
    x = f32(rng.standard_normal((rows, 16)) * np.exp2(scale_exp))
    got = np.asarray(bfp.bfp_roundtrip(x))
    want = np.asarray(ref.bfp_roundtrip_ref(x))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 600), seed=st.integers(0, 2**31 - 1))
def test_bfp_flat_hypothesis(n, seed):
    rng = np.random.default_rng(seed)
    x = f32(rng.standard_normal(n))
    got = np.asarray(bfp.bfp_roundtrip_flat(x))
    want = np.asarray(ref.bfp_roundtrip_flat_ref(x))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Matmul kernel
# ---------------------------------------------------------------------------

class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (8, 8, 8), (16, 32, 16), (64, 64, 64), (128, 256, 128),
        (256, 128, 64), (448, 64, 64),
    ])
    def test_matches_ref(self, m, k, n):
        x = f32(RNG.standard_normal((m, k)))
        w = f32(RNG.standard_normal((k, n)))
        got = matmul.matmul(x, w)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.matmul_ref(x, w)),
            rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("m,k,n", [(7, 9, 5), (13, 128, 31), (100, 50, 3)])
    def test_ragged_shapes(self, m, k, n):
        # _pick degrades tile sizes to divisors; correctness must hold.
        x = f32(RNG.standard_normal((m, k)))
        w = f32(RNG.standard_normal((k, n)))
        got = matmul.matmul(x, w)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.matmul_ref(x, w)),
            rtol=1e-5, atol=1e-5)

    def test_transposed_helpers(self):
        x = f32(RNG.standard_normal((32, 16)))  # (K=32, M=16)
        w = f32(RNG.standard_normal((32, 24)))  # (K=32, N=24)
        np.testing.assert_allclose(
            np.asarray(matmul.matmul_t_a(x, w)),
            np.asarray(x).T @ np.asarray(w), rtol=1e-4, atol=1e-4)
        y = f32(RNG.standard_normal((16, 32)))  # (M=16, K=32)
        v = f32(RNG.standard_normal((24, 32)))  # (N=24, K=32)
        np.testing.assert_allclose(
            np.asarray(matmul.matmul_t_b(y, v)),
            np.asarray(y) @ np.asarray(v).T, rtol=1e-4, atol=1e-4)

    def test_identity(self):
        x = f32(RNG.standard_normal((16, 16)))
        eye = jnp.eye(16, dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(matmul.matmul(x, eye)), np.asarray(x),
            rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = f32(rng.standard_normal((m, k)))
    w = f32(rng.standard_normal((k, n)))
    got = np.asarray(matmul.matmul(x, w))
    want = np.asarray(ref.matmul_ref(x, w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# NIC chunk adder
# ---------------------------------------------------------------------------

class TestChunkAdd:
    @pytest.mark.parametrize("rows", [1, 8, 64, 321])
    def test_matches_ref(self, rows):
        a = f32(RNG.standard_normal((rows, 128)))
        b = f32(RNG.standard_normal((rows, 128)))
        got = red.chunk_add(a, b)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.chunk_add_ref(a, b)))

    def test_flat_with_padding(self):
        a = f32(RNG.standard_normal(1000))
        b = f32(RNG.standard_normal(1000))
        got = red.chunk_add_flat(a, b)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(a) + np.asarray(b))

    def test_additive_identity(self):
        a = f32(RNG.standard_normal((8, 128)))
        z = jnp.zeros_like(a)
        np.testing.assert_array_equal(np.asarray(red.chunk_add(a, z)),
                                      np.asarray(a))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 2000), seed=st.integers(0, 2**31 - 1))
def test_chunk_add_hypothesis(n, seed):
    rng = np.random.default_rng(seed)
    a = f32(rng.standard_normal(n))
    b = f32(rng.standard_normal(n))
    got = np.asarray(red.chunk_add_flat(a, b))
    np.testing.assert_array_equal(got, np.asarray(a) + np.asarray(b))
