# pytest: AOT pipeline — manifest integrity, HLO text validity, golden
# vector stability.

import json
import os

import numpy as np
import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(out), [(64, 16)], verbose=False)
    aot.write_golden(str(out))
    return str(out), manifest


class TestManifest:
    def test_artifact_count(self, built):
        _, manifest = built
        # 5 per (m, b) pair + 6 per hidden size
        assert len(manifest["artifacts"]) == 11

    def test_every_file_exists_and_is_hlo(self, built):
        out, manifest = built
        for art in manifest["artifacts"]:
            path = os.path.join(out, art["file"])
            assert os.path.exists(path), art["name"]
            head = open(path).read(200)
            assert "HloModule" in head, art["name"]

    def test_manifest_json_roundtrip(self, built):
        out, manifest = built
        on_disk = json.load(open(os.path.join(out, "manifest.json")))
        assert on_disk == manifest

    def test_shapes_recorded(self, built):
        _, manifest = built
        by_name = {a["name"]: a for a in manifest["artifacts"]}
        fwd = by_name["layer_fwd_m64_b16"]
        assert fwd["inputs"] == [[16, 64], [64, 64], [64]]
        assert fwd["outputs"] == [[16, 64], [16, 64]]
        bwd = by_name["layer_bwd_m64_b16"]
        assert len(bwd["inputs"]) == 4 and len(bwd["outputs"]) == 3

    def test_bfp_params_in_manifest(self, built):
        _, manifest = built
        assert manifest["bfp"] == {"block_size": 16, "mant_bits": 7,
                                   "exp_bits": 8}


class TestGolden:
    def test_golden_cases_deterministic(self):
        a = aot.golden_bfp_cases()
        b = aot.golden_bfp_cases()
        assert a == b

    def test_golden_case_structure(self):
        g = aot.golden_bfp_cases()
        assert len(g["cases"]) >= 8
        for case in g["cases"]:
            n = len(case["x_bits"])
            assert n % case["block_size"] == 0
            assert len(case["mag"]) == n
            assert len(case["sign"]) == n
            assert len(case["decoded_bits"]) == n
            assert len(case["e_shared"]) == n // case["block_size"]
            assert all(0 <= e <= 255 for e in case["e_shared"])
            assert all(0 <= m <= 127 for m in case["mag"])
            assert all(s in (0, 1) for s in case["sign"])

    def test_golden_decode_consistent(self):
        # decoded_bits must equal the reference decode of (E, sign, mag)
        import jax.numpy as jnp
        from compile.kernels import ref
        g = aot.golden_bfp_cases()
        for case in g["cases"]:
            bs = case["block_size"]
            e = jnp.asarray(case["e_shared"], jnp.int32).reshape(-1, 1)
            s = jnp.asarray(case["sign"], jnp.int32).reshape(-1, bs)
            m = jnp.asarray(case["mag"], jnp.int32).reshape(-1, bs)
            dec = np.asarray(ref.bfp_decode_ref(e, s, m))
            want = np.asarray(case["decoded_bits"], np.uint32).view(np.float32)
            np.testing.assert_array_equal(dec.reshape(-1), want)
