//! Bench E5 — regenerates Fig. 4b (scaling to 32 nodes, both paper batch
//! sizes) and times the sweep.

use ai_smartnic::benchkit::{quick_mode, Bencher};
use ai_smartnic::experiments::fig4b;

fn main() {
    println!("=== Fig. 4b — scaling to 32 nodes ===\n");
    let nodes: &[usize] = if quick_mode() {
        &[1, 3, 6, 32]
    } else {
        &[1, 2, 3, 4, 5, 6, 8, 12, 16, 24, 32]
    };
    for batch in [448usize, 1792] {
        let series = fig4b::run(nodes, batch);
        fig4b::print(&series, batch);
    }

    let mut b = Bencher::default();
    b.bench("fig4b::run(11 node counts x 3 systems, B=448)", || {
        fig4b::run(&[1, 2, 3, 4, 5, 6, 8, 12, 16, 24, 32], 448)
    });
}
