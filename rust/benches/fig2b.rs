//! Bench E2 — regenerates Fig. 2b (all-reduce scheme scaling) and times
//! the analytic sweep.

use ai_smartnic::benchkit::{quick_mode, Bencher};
use ai_smartnic::experiments::fig2b;

fn main() {
    println!("=== Fig. 2b — overlapped host all-reduce scheme scaling ===\n");
    let nodes: &[usize] = if quick_mode() {
        &[2, 6, 12]
    } else {
        &[2, 4, 6, 8, 12, 16, 24]
    };
    let series = fig2b::run(nodes, 1792);
    fig2b::print(&series);

    let mut b = Bencher::default();
    b.bench("fig2b::run(7 node counts x 5 schemes)", || {
        fig2b::run(&[2, 4, 6, 8, 12, 16, 24], 1792)
    });
}
