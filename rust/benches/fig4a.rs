//! Bench E4 — regenerates Fig. 4a (baseline vs smart NIC ± BFP) and times
//! the full DES iteration for each system.

use ai_smartnic::analytic::model::SystemKind;
use ai_smartnic::benchkit::Bencher;
use ai_smartnic::collective::Scheme;
use ai_smartnic::coordinator::simulate_iteration;
use ai_smartnic::experiments::fig4a;
use ai_smartnic::sysconfig::{SystemParams, Workload};

fn main() {
    println!("=== Fig. 4a — iteration breakdown at 6 nodes, B=448 ===\n");
    let rows = fig4a::run(6, 448);
    fig4a::print(&rows);

    let w = Workload::paper_mlp(448);
    let mut b = Bencher::default();
    b.bench("simulate_iteration(baseline)", || {
        simulate_iteration(
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            &SystemParams::baseline_100g(),
            &w,
            6,
        )
    });
    b.bench("simulate_iteration(smartnic)", || {
        simulate_iteration(
            SystemKind::SmartNic { bfp: false },
            &SystemParams::smartnic_40g(),
            &w,
            6,
        )
    });
    b.bench("simulate_iteration(smartnic+bfp)", || {
        simulate_iteration(
            SystemKind::SmartNic { bfp: true },
            &SystemParams::smartnic_40g(),
            &w,
            6,
        )
    });
}
