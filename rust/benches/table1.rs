//! Bench E3 — regenerates Table I (and the 100/400 Gbps variants) and
//! times the resource model + the BFP datapath it describes.

use ai_smartnic::benchkit::Bencher;
use ai_smartnic::bfp::{wire, BfpCodec};
use ai_smartnic::experiments::table1;
use ai_smartnic::nic::resources::Breakdown;
use ai_smartnic::util::rng::Rng;

fn main() {
    println!("=== Table I — FPGA resource breakdown ===\n");
    table1::run_all();

    let mut b = Bencher::default();
    b.bench("resource model (3 speeds)", || {
        (Breakdown::at(40.0), Breakdown::at(100.0), Breakdown::at(400.0))
    });

    // the datapath Table I describes: compression at line rate
    let codec = BfpCodec::bfp16();
    let mut rng = Rng::new(1);
    let grad: Vec<f32> = (0..1 << 20).map(|_| rng.normal() as f32).collect();
    let bytes = grad.len() as f64 * 4.0;
    b.bench_bytes("bfp wire compress (4 MiB gradient)", bytes, || {
        wire::compress(&codec, &grad)
    });
    let packed = wire::compress(&codec, &grad);
    b.bench_bytes("bfp wire decompress (4 MiB gradient)", bytes, || {
        wire::decompress(&codec, &packed, grad.len()).unwrap()
    });
    b.bench_bytes("bfp quantize in place (4 MiB gradient)", bytes, || {
        codec.quantize(&grad)
    });
}
