//! Bench E1 — regenerates Fig. 2a and times the simulation path.
//! Run: `cargo bench --bench fig2a` (add `--quick` to trim).

use ai_smartnic::benchkit::Bencher;
use ai_smartnic::experiments::fig2a;

fn main() {
    println!("=== Fig. 2a — naive vs overlapped host all-reduce ===\n");
    let rows = fig2a::run(6, 1792);
    fig2a::print(&rows);

    let mut b = Bencher::default();
    b.bench("fig2a::run(6 nodes, B=1792)", || fig2a::run(6, 1792));
    b.bench("fig2a::run(32 nodes, B=448)", || fig2a::run(32, 448));
}
