//! Hot-path microbenchmarks (the §Perf targets of DESIGN.md): BFP codec
//! throughput, the real ring-all-reduce data path, the NIC chunk DES, and
//! the calendar-queue engine.

use ai_smartnic::benchkit::Bencher;
use ai_smartnic::bfp::BfpCodec;
use ai_smartnic::collective::data::ring_allreduce;
use ai_smartnic::netsim::engine::{EngineKind, PartitionedWorld, Sim, World};
use ai_smartnic::netsim::Time;
use ai_smartnic::nic::{simulate_ring_allreduce, NicConfig};
use ai_smartnic::sysconfig::SystemParams;
use ai_smartnic::util::rng::Rng;

fn gradients(n_workers: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n_workers)
        .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn main() {
    let mut b = Bencher::default();

    // --- BFP codec (the NIC datapath) --------------------------------
    let codec = BfpCodec::bfp16();
    let mut rng = Rng::new(2);
    let grad: Vec<f32> = (0..1 << 20).map(|_| rng.normal() as f32).collect();
    let gbytes = grad.len() as f64 * 4.0;
    b.bench_bytes("bfp::quantize 4 MiB", gbytes, || codec.quantize(&grad));
    let blocks = codec.encode(&grad);
    b.bench_bytes("bfp::encode 4 MiB", gbytes, || codec.encode(&grad));
    b.bench_bytes("bfp::decode 4 MiB", gbytes, || {
        codec.decode(&blocks, grad.len())
    });

    // --- real ring all-reduce data path --------------------------------
    for (n, len) in [(6usize, 1 << 18), (6, 1 << 20)] {
        let bufs = gradients(n, len, 3);
        let total = (n * len * 4) as f64;
        b.bench_bytes(&format!("ring_allreduce fp32 n={n} len={len}"), total, || {
            let mut work = bufs.clone();
            ring_allreduce(&mut work, None)
        });
        b.bench_bytes(&format!("ring_allreduce bfp16 n={n} len={len}"), total, || {
            let mut work = bufs.clone();
            ring_allreduce(&mut work, Some(&codec))
        });
    }

    // --- NIC chunk-level DES -------------------------------------------
    let cfg = NicConfig::new(SystemParams::smartnic_40g(), Some(BfpCodec::bfp16()));
    b.bench("nic DES allreduce (6 nodes, 2048^2)", || {
        simulate_ring_allreduce(&cfg, 6, 2048 * 2048)
    });
    b.bench("nic DES allreduce (32 nodes, 2048^2)", || {
        simulate_ring_allreduce(&cfg, 32, 2048 * 2048)
    });

    // --- typed-event engine vs the boxed-closure baseline ---------------
    struct Count(u64);
    impl World for Count {
        type Event = ();
        fn handle(_sim: &mut Sim<Self>, state: &mut Self, _event: ()) {
            state.0 += 1;
        }
    }
    b.bench("DES engine: 100k typed events", || {
        let mut sim: Sim<Count> = Sim::new();
        let mut count = Count(0);
        for i in 0..100_000u64 {
            sim.schedule(i as f64 * 1e-6, ());
        }
        sim.run(&mut count);
        assert_eq!(count.0, 100_000);
        count.0
    });
    b.bench("DES engine: 100k boxed closures (baseline)", || {
        let mut sim: Sim<Count> = Sim::with_engine(EngineKind::BoxedBaseline);
        let mut count = Count(0);
        for i in 0..100_000u64 {
            sim.schedule_closure(i as f64 * 1e-6, |_, c: &mut Count| c.0 += 1);
        }
        sim.run(&mut count);
        assert_eq!(count.0, 100_000);
        count.0
    });

    // --- parallel executive: windowed multi-threaded drain ---------------
    // 64 partitions, 100k events packed ~1000 per lookahead window, no
    // cross-partition traffic: measures the window loop + scoped-worker
    // fan-out against the same drain on one thread.
    const SHARDS: u32 = 64;
    struct Shards {
        counts: Vec<u64>,
    }
    impl World for Shards {
        type Event = u32;
        fn handle(_sim: &mut Sim<Self>, state: &mut Self, event: u32) {
            state.counts[(event % SHARDS) as usize] += 1;
        }
    }
    // SAFETY: each event mutates only its own partition's counter slot,
    // and the bench schedules no cross-partition events at all.
    unsafe impl PartitionedWorld for Shards {
        type Map = u32;
        fn partition_map(&self) -> u32 {
            SHARDS
        }
        fn partition_count(map: &u32) -> usize {
            *map as usize
        }
        fn route(map: &u32, event: &u32) -> u32 {
            event % map
        }
        fn lookahead(&self) -> Time {
            1e-6
        }
        fn merge_key(_map: &u32, event: &u32) -> u128 {
            u128::from(*event)
        }
    }
    for threads in [1usize, 4] {
        b.bench(&format!("DES engine: 100k-event parallel drain, {threads} threads"), || {
            let mut sim: Sim<Shards> = Sim::new();
            let mut world = Shards {
                counts: vec![0; SHARDS as usize],
            };
            for i in 0..100_000u32 {
                sim.schedule(i as f64 * 1e-8, i);
            }
            sim.run_parallel(&mut world, threads);
            let total: u64 = world.counts.iter().sum();
            assert_eq!(total, 100_000);
            total
        });
    }
}
