//! Execution-trace recording (Fig. 3b style): named spans on named lanes
//! with virtual or wall-clock timestamps, exportable as chrome://tracing
//! JSON for inspection.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Sweep-line maximum of simultaneously-open `(start, end)` intervals.
/// Empty intervals are ignored, and intervals that merely touch (one ends
/// exactly where the next starts) do not count as overlapping.
pub fn max_overlap(intervals: impl IntoIterator<Item = (f64, f64)>) -> usize {
    // (+1 at start, -1 at end); sort ends before starts at equal time
    let mut events: Vec<(f64, i32)> = Vec::new();
    for (start, end) in intervals {
        if end > start {
            events.push((start, 1));
            events.push((end, -1));
        }
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut open = 0i32;
    let mut max = 0i32;
    for (_, delta) in events {
        open += delta;
        max = max.max(open);
    }
    max as usize
}

fn check_serial(lane: &str, mut spans: Vec<(f64, f64, &str)>) -> Result<(), String> {
    spans.sort_by(|a, b| a.0.total_cmp(&b.0));
    for w in spans.windows(2) {
        // allow exact touching (end == start)
        if w[1].0 < w[0].1 - 1e-12 {
            return Err(format!(
                "lane '{lane}': '{}' [{:.6},{:.6}] overlaps '{}' [{:.6},{:.6}]",
                w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
            ));
        }
    }
    Ok(())
}

/// One span on a lane.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub lane: String,
    pub name: String,
    pub start: f64,
    pub end: f64,
}

/// A trace: an ordered list of spans.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, lane: &str, name: &str, start: f64, end: f64) {
        debug_assert!(end >= start, "span '{name}' ends before it starts");
        self.spans.push(Span {
            lane: lane.to_string(),
            name: name.to_string(),
            start,
            end,
        });
    }

    /// Latest end time in the trace.
    pub fn makespan(&self) -> f64 {
        self.spans.iter().map(|s| s.end).fold(0.0, f64::max)
    }

    /// Total busy time on one lane.
    pub fn lane_busy(&self, lane: &str) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.lane == lane)
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Sum of durations of spans whose name starts with `prefix`.
    pub fn time_in(&self, prefix: &str) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.name.starts_with(prefix))
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Like [`Trace::time_in`] restricted to one lane.
    pub fn lane_time_in(&self, lane: &str, prefix: &str) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.lane == lane && s.name.starts_with(prefix))
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Lane names in first-appearance order.
    pub fn lanes(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for s in &self.spans {
            if !out.contains(&s.lane.as_str()) {
                out.push(&s.lane);
            }
        }
        out
    }

    /// Maximum number of simultaneously-open spans whose name starts with
    /// `prefix`, across all lanes.  Spans that merely touch (one ends
    /// exactly where another starts) do not count as concurrent.  This is
    /// how the unified engine's overlap claims are checked: e.g.
    /// `max_concurrent("ar") >= 2` means at least two all-reduces were in
    /// flight at once.
    pub fn max_concurrent(&self, prefix: &str) -> usize {
        max_overlap(
            self.spans
                .iter()
                .filter(|s| s.name.starts_with(prefix))
                .map(|s| (s.start, s.end)),
        )
    }

    /// Verify no two spans on the same lane overlap (schedule invariant).
    pub fn check_no_lane_overlap(&self) -> Result<(), String> {
        let mut by_lane: BTreeMap<&str, Vec<(f64, f64, &str)>> = BTreeMap::new();
        for s in &self.spans {
            by_lane
                .entry(&s.lane)
                .or_default()
                .push((s.start, s.end, &s.name));
        }
        for (lane, spans) in by_lane {
            check_serial(lane, spans)?;
        }
        Ok(())
    }

    /// Verify one specific lane is serial.  Unlike
    /// [`Trace::check_no_lane_overlap`] this is usable on unified-engine
    /// traces, whose collective lanes overlap *by design* while the worker
    /// lanes must not.
    pub fn check_lane_serial(&self, lane: &str) -> Result<(), String> {
        let spans: Vec<(f64, f64, &str)> = self
            .spans
            .iter()
            .filter(|s| s.lane == lane)
            .map(|s| (s.start, s.end, s.name.as_str()))
            .collect();
        check_serial(lane, spans)
    }

    /// Render an ASCII Gantt chart (the Fig. 3b visualization): one row
    /// per lane, `width` characters spanning [0, makespan].
    pub fn render_gantt(&self, width: usize) -> String {
        let span = self.makespan();
        if span <= 0.0 || self.spans.is_empty() {
            return String::from("(empty trace)\n");
        }
        let mut lanes: Vec<&str> = Vec::new();
        for s in &self.spans {
            if !lanes.contains(&s.lane.as_str()) {
                lanes.push(&s.lane);
            }
        }
        let lane_w = lanes.iter().map(|l| l.len()).max().unwrap_or(4).max(4);
        let mut out = String::new();
        for lane in &lanes {
            let mut row = vec![' '; width];
            for s in self.spans.iter().filter(|s| &s.lane == lane) {
                let a = ((s.start / span) * width as f64).floor() as usize;
                let b = (((s.end / span) * width as f64).ceil() as usize).min(width);
                let ch = match s.name.chars().next().unwrap_or('#') {
                    'f' => 'F', // fwd
                    'b' => 'B', // bwd
                    'u' => 'U', // upd
                    'a' => 'A', // ar
                    'w' => '.', // wait
                    c => c,
                };
                for cell in row.iter_mut().take(b).skip(a.min(width)) {
                    *cell = ch;
                }
            }
            out.push_str(&format!(
                "{:<lw$} |{}|\n",
                lane,
                row.iter().collect::<String>(),
                lw = lane_w
            ));
        }
        out.push_str(&format!(
            "{:<lw$}  0{:>w$}\n",
            "",
            format!("{:.2} ms", span * 1e3),
            lw = lane_w,
            w = width
        ));
        out
    }

    /// Export in chrome://tracing "trace event" format (µs timestamps).
    pub fn to_chrome_json(&self) -> String {
        let events: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::Str(s.name.clone())),
                    ("cat", Json::Str("span".into())),
                    ("ph", Json::Str("X".into())),
                    ("ts", Json::Num(s.start * 1e6)),
                    ("dur", Json::Num((s.end - s.start) * 1e6)),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Str(s.lane.clone())),
                ])
            })
            .collect();
        Json::Arr(events).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn makespan_and_busy() {
        let mut t = Trace::new();
        t.add("w0", "fwd", 0.0, 1.0);
        t.add("w0", "bwd", 1.0, 3.0);
        t.add("nic0", "ar", 2.0, 5.0);
        assert_eq!(t.makespan(), 5.0);
        assert_eq!(t.lane_busy("w0"), 3.0);
        assert_eq!(t.time_in("ar"), 3.0);
    }

    #[test]
    fn overlap_detection() {
        let mut ok = Trace::new();
        ok.add("w0", "a", 0.0, 1.0);
        ok.add("w0", "b", 1.0, 2.0); // touching is fine
        assert!(ok.check_no_lane_overlap().is_ok());

        let mut bad = Trace::new();
        bad.add("w0", "a", 0.0, 1.5);
        bad.add("w0", "b", 1.0, 2.0);
        assert!(bad.check_no_lane_overlap().is_err());
    }

    #[test]
    fn different_lanes_may_overlap() {
        let mut t = Trace::new();
        t.add("w0", "bwd", 0.0, 2.0);
        t.add("nic0", "ar", 0.5, 1.5); // the whole point of the paper
        assert!(t.check_no_lane_overlap().is_ok());
    }

    #[test]
    fn max_concurrent_counts_overlap() {
        let mut t = Trace::new();
        t.add("nic", "ar[0]", 0.0, 4.0);
        t.add("nic", "ar[1]", 1.0, 3.0);
        t.add("nic", "ar[2]", 2.0, 5.0);
        t.add("worker", "bwd[0]", 0.0, 10.0); // different prefix: ignored
        assert_eq!(t.max_concurrent("ar"), 3);
        assert_eq!(t.max_concurrent("bwd"), 1);
        assert_eq!(t.max_concurrent("upd"), 0);
    }

    #[test]
    fn touching_spans_are_not_concurrent() {
        let mut t = Trace::new();
        t.add("nic", "ar[0]", 0.0, 1.0);
        t.add("nic", "ar[1]", 1.0, 2.0);
        assert_eq!(t.max_concurrent("ar"), 1);
    }

    #[test]
    fn lane_scoped_helpers() {
        let mut t = Trace::new();
        t.add("j0/worker", "wait-ar[3]", 0.0, 1.0);
        t.add("j1/worker", "wait-ar[2]", 0.0, 5.0);
        assert_eq!(t.lane_time_in("j0/worker", "wait-ar"), 1.0);
        assert_eq!(t.lane_time_in("j1/worker", "wait-ar"), 5.0);
        assert_eq!(t.lanes(), vec!["j0/worker", "j1/worker"]);
    }

    #[test]
    fn chrome_export_is_valid_json() {
        let mut t = Trace::new();
        t.add("w0", "fwd", 0.0, 1e-3);
        let j = crate::util::json::Json::parse(&t.to_chrome_json()).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 1);
        assert_eq!(j.idx(0).unwrap().get("ph").unwrap().as_str(), Some("X"));
    }
}
