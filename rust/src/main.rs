//! `smartnic` — the leader binary: regenerate every paper artifact, run
//! the DES, train the real model through PJRT, validate the model.
//!
//! ```text
//! smartnic <command> [options]
//!
//! commands:
//!   fig2a     naive vs overlapped host all-reduce breakdown (paper Fig. 2a)
//!   fig2b     host all-reduce scheme scaling (paper Fig. 2b)
//!   fig4a     baseline vs smart NIC (+BFP) breakdown (paper Fig. 4a)
//!   fig4b     scaling to 32 nodes (paper Fig. 4b)
//!   table1    FPGA resource breakdown @ 40/100/400 Gbps (paper Table I)
//!   validate  analytical model vs DES (paper: "within 3%")
//!   train     real data-parallel training through PJRT artifacts
//!   sim       one simulated iteration with full trace output
//!   cluster   multi-job scenarios on the unified event engine
//!   cluster-trace  gang-scheduler policy study under churn, BENCH_cluster.json
//!   scale     hierarchical scaling sweep (6..512 nodes), BENCH_scaling.json
//!   plan      topology-aware planner study (NIC vs switch offload), BENCH_planner.json
//!   tenancy   multi-tenant in-switch contention + PFC study, BENCH_tenancy.json
//!   collectives  collective zoo (broadcast/allgather/reduce-scatter/all-to-all), BENCH_collectives.json
//!   engine-bench  typed engine vs boxed baseline + parallel scaling, BENCH_engine.json
//!   bfp       BFP design-space sweep (block size x mantissa bits)
//!   all       fig2a+fig2b+table1+fig4a+fig4b+validate, write results/
//! ```

use ai_smartnic::analytic::model::SystemKind;
use ai_smartnic::bfp::analysis;
use ai_smartnic::cluster::{
    run_scenario, run_scenario_on, ClusterSpec, EngineKind, JobSpec, Topology,
};
use ai_smartnic::collective::Scheme;
use ai_smartnic::coordinator::{
    simulate_iteration, simulate_iteration_unified, ArBackend, Trainer, TrainerConfig,
};
use ai_smartnic::sysconfig::ClusterFaults;
use ai_smartnic::experiments::{
    ablate, cluster_trace, collectives, engine_bench, fig2a, fig2b, fig4a, fig4b, planner,
    scaling, table1, tenancy, validate, write_result,
};
use ai_smartnic::log_info;
use ai_smartnic::sysconfig::{SystemParams, Workload};
use ai_smartnic::util::cli::Command;
use ai_smartnic::util::logger::{set_level, Level};
use ai_smartnic::util::rng::Rng;
use ai_smartnic::util::table::{fnum, Table};

const USAGE: &str = "usage: smartnic <fig2a|fig2b|fig4a|fig4b|table1|validate|train|sim|cluster|cluster-trace|scale|plan|tenancy|collectives|engine-bench|bfp|ablate|all> [--help]";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    let rest = argv[1..].to_vec();
    let code = match cmd.as_str() {
        "fig2a" => cmd_fig2a(&rest),
        "fig2b" => cmd_fig2b(&rest),
        "fig4a" => cmd_fig4a(&rest),
        "fig4b" => cmd_fig4b(&rest),
        "table1" => cmd_table1(&rest),
        "validate" => cmd_validate(&rest),
        "train" => cmd_train(&rest),
        "sim" => cmd_sim(&rest),
        "cluster" => cmd_cluster(&rest),
        "cluster-trace" => cmd_cluster_trace(&rest),
        "scale" => cmd_scale(&rest),
        "plan" => cmd_plan(&rest),
        "tenancy" => cmd_tenancy(&rest),
        "collectives" => cmd_collectives(&rest),
        "engine-bench" => cmd_engine_bench(&rest),
        "bfp" => cmd_bfp(&rest),
        "ablate" => cmd_ablate(&rest),
        "all" => cmd_all(&rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn parse(c: Command, rest: &[String]) -> Result<ai_smartnic::util::cli::Args, i32> {
    match c.parse(rest) {
        Ok(a) => Ok(a),
        Err(msg) => {
            eprintln!("{msg}");
            Err(2)
        }
    }
}

/// Shared `--system` parsing for the simulation subcommands.
fn parse_system(name: &str) -> Option<(SystemKind, SystemParams)> {
    match name {
        "baseline-naive" => Some((
            SystemKind::BaselineNaive { scheme: Scheme::Ring },
            SystemParams::baseline_100g(),
        )),
        "baseline" => Some((
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            SystemParams::baseline_100g(),
        )),
        "smartnic" => Some((SystemKind::SmartNic { bfp: false }, SystemParams::smartnic_40g())),
        "smartnic+bfp" => Some((SystemKind::SmartNic { bfp: true }, SystemParams::smartnic_40g())),
        _ => None,
    }
}

fn cmd_fig2a(rest: &[String]) -> i32 {
    let c = Command::new("fig2a", "naive vs overlapped host all-reduce breakdown")
        .opt("nodes", "6", "number of worker nodes")
        .opt("batch", "1792", "mini-batch per node")
        .flag("json", "also write results/fig2a.json");
    let Ok(a) = parse(c, rest) else { return 2 };
    let rows = fig2a::run(a.get_usize("nodes", 6), a.get_usize("batch", 1792));
    fig2a::print(&rows);
    if a.flag("json") {
        let p = write_result("fig2a", &fig2a::to_json(&rows)).unwrap();
        println!("wrote {}", p.display());
    }
    0
}

fn cmd_fig2b(rest: &[String]) -> i32 {
    let c = Command::new("fig2b", "host all-reduce scheme scaling")
        .opt("nodes", "2,4,6,8,12,16,24", "node counts (comma separated)")
        .opt("batch", "1792", "mini-batch per node")
        .flag("json", "also write results/fig2b.json");
    let Ok(a) = parse(c, rest) else { return 2 };
    let nodes: Vec<usize> = a.get_list("nodes").unwrap_or_default();
    let series = fig2b::run(&nodes, a.get_usize("batch", 1792));
    fig2b::print(&series);
    if a.flag("json") {
        let p = write_result("fig2b", &fig2b::to_json(&series)).unwrap();
        println!("wrote {}", p.display());
    }
    0
}

fn cmd_fig4a(rest: &[String]) -> i32 {
    let c = Command::new("fig4a", "baseline vs smart NIC (+BFP) breakdown")
        .opt("nodes", "6", "number of worker nodes")
        .opt("batch", "448", "mini-batch per node")
        .flag("json", "also write results/fig4a.json");
    let Ok(a) = parse(c, rest) else { return 2 };
    let rows = fig4a::run(a.get_usize("nodes", 6), a.get_usize("batch", 448));
    fig4a::print(&rows);
    if a.flag("json") {
        let p = write_result("fig4a", &fig4a::to_json(&rows)).unwrap();
        println!("wrote {}", p.display());
    }
    0
}

fn cmd_fig4b(rest: &[String]) -> i32 {
    let c = Command::new("fig4b", "scaling to 32 nodes")
        .opt("nodes", "1,2,3,4,5,6,8,12,16,24,32", "node counts")
        .opt("batch", "448", "mini-batch per node (448 or 1792 in the paper)")
        .flag("both", "run both paper batch sizes (448 and 1792)")
        .flag("json", "also write results/fig4b_<batch>.json");
    let Ok(a) = parse(c, rest) else { return 2 };
    let nodes: Vec<usize> = a.get_list("nodes").unwrap_or_default();
    let batches: Vec<usize> = if a.flag("both") {
        vec![448, 1792]
    } else {
        vec![a.get_usize("batch", 448)]
    };
    for b in batches {
        let series = fig4b::run(&nodes, b);
        fig4b::print(&series, b);
        if a.flag("json") {
            let p = write_result(&format!("fig4b_b{b}"), &fig4b::to_json(&series)).unwrap();
            println!("wrote {}", p.display());
        }
    }
    0
}

fn cmd_table1(rest: &[String]) -> i32 {
    let c = Command::new("table1", "FPGA resource breakdown")
        .flag("json", "also write results/table1.json");
    let Ok(a) = parse(c, rest) else { return 2 };
    table1::run_all();
    if a.flag("json") {
        let p = write_result("table1", &table1::to_json()).unwrap();
        println!("wrote {}", p.display());
    }
    0
}

fn cmd_validate(rest: &[String]) -> i32 {
    let c = Command::new("validate", "analytical model vs DES")
        .flag("ar-only", "only the all-reduce-level sweep")
        .flag("json", "also write results/validate.json");
    let Ok(a) = parse(c, rest) else { return 2 };
    let ar = validate::run_ar_grid();
    validate::print_ar(&ar);
    if !a.flag("ar-only") {
        let rows = validate::run_iteration_grid();
        validate::print_iteration(&rows);
        if a.flag("json") {
            let p = write_result("validate", &validate::to_json(&rows)).unwrap();
            println!("wrote {}", p.display());
        }
    }
    0
}

fn cmd_train(rest: &[String]) -> i32 {
    let c = Command::new("train", "real data-parallel training through PJRT")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("layers", "8", "MLP layers")
        .opt("hidden", "256", "hidden width (needs matching artifacts)")
        .opt("batch", "32", "mini-batch per worker (needs matching artifacts)")
        .opt("workers", "4", "data-parallel workers")
        .opt("steps", "100", "training steps")
        .opt("lr", "0.02", "learning rate")
        .opt("seed", "42", "rng seed")
        .opt("backend", "bfp16", "gradient wire format: fp32 | bfp16")
        .opt("optimizer", "sgd", "weight update rule: sgd | adam")
        .opt("log-every", "10", "log cadence")
        .flag("quiet", "suppress per-step logs");
    let Ok(a) = parse(c, rest) else { return 2 };
    if a.flag("quiet") {
        set_level(Level::Warn);
    }
    let backend = match a.get_str("backend", "bfp16").as_str() {
        "fp32" => ArBackend::Fp32,
        "bfp16" => ArBackend::Bfp16,
        other => {
            eprintln!("unknown backend '{other}' (fp32|bfp16)");
            return 2;
        }
    };
    let optimizer = match a.get_str("optimizer", "sgd").as_str() {
        "sgd" => ai_smartnic::coordinator::Optimizer::Sgd,
        "adam" => ai_smartnic::coordinator::Optimizer::Adam,
        other => {
            eprintln!("unknown optimizer '{other}' (sgd|adam)");
            return 2;
        }
    };
    let cfg = TrainerConfig {
        layers: a.get_usize("layers", 8),
        hidden: a.get_usize("hidden", 256),
        batch_per_worker: a.get_usize("batch", 32),
        workers: a.get_usize("workers", 4),
        lr: a.get_f64("lr", 0.02) as f32,
        seed: a.get_u64("seed", 42),
        backend,
        optimizer,
    };
    let steps = a.get_usize("steps", 100);
    log_info!(
        "training {}x{} MLP, {} workers, B={}/worker, backend {:?}",
        cfg.layers,
        cfg.hidden,
        cfg.workers,
        cfg.batch_per_worker,
        cfg.backend
    );
    let mut trainer = match Trainer::new(a.get_str("artifacts", "artifacts"), cfg) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trainer init failed: {e:#}");
            return 1;
        }
    };
    match trainer.train(steps, a.get_usize("log-every", 10)) {
        Ok(stats) => {
            let first = stats.first().unwrap();
            let last = stats.last().unwrap();
            println!(
                "loss {:.6} -> {:.6} over {} steps ({}x improvement)",
                first.loss,
                last.loss,
                stats.len(),
                fnum(first.loss / last.loss.max(1e-12), 1)
            );
            0
        }
        Err(e) => {
            eprintln!("training failed: {e:#}");
            1
        }
    }
}

fn cmd_sim(rest: &[String]) -> i32 {
    let c = Command::new("sim", "one simulated iteration with trace")
        .opt("system", "smartnic+bfp", "baseline-naive | baseline | smartnic | smartnic+bfp")
        .opt("nodes", "6", "worker nodes")
        .opt("batch", "448", "mini-batch per node")
        .opt("layers", "20", "MLP layers")
        .opt("hidden", "2048", "layer width")
        .opt("trace-out", "", "write chrome trace JSON to this path")
        .flag("unified", "run on the unified event engine (concurrent all-reduces)")
        .flag("gantt", "render an ASCII Gantt of the schedule (Fig. 3b)");
    let Ok(a) = parse(c, rest) else { return 2 };
    let sys_name = a.get_str("system", "smartnic+bfp");
    let Some((kind, sys)) = parse_system(&sys_name) else {
        eprintln!("unknown system '{sys_name}'");
        return 2;
    };
    let w = Workload {
        layers: a.get_usize("layers", 20),
        hidden: a.get_usize("hidden", 2048),
        batch_per_node: a.get_usize("batch", 448),
    };
    let nodes = a.get_usize("nodes", 6);
    let out = if a.flag("unified") {
        simulate_iteration_unified(kind, &sys, &w, nodes)
    } else {
        simulate_iteration(kind, &sys, &w, nodes)
    };
    let bd = &out.breakdown;
    let engine = if a.flag("unified") { "unified" } else { "serialized" };
    let mut t = Table::new(&["component", "time (ms)", "share"])
        .with_title(&format!("simulated iteration — {} ({engine} engine)", kind.name()));
    for (name, v) in [
        ("forward", bd.t_fwd),
        ("backward", bd.t_bwd),
        ("exposed all-reduce", bd.t_exposed_ar),
        ("weight update", bd.t_update),
        ("TOTAL", bd.t_total),
    ] {
        t.row(&[
            name.to_string(),
            fnum(v * 1e3, 2),
            format!("{:.1}%", 100.0 * v / bd.t_total),
        ]);
    }
    t.print();
    println!(
        "per-layer all-reduce: {} ({} spans in trace)",
        ai_smartnic::util::units::fmt_time(out.t_ar_layer),
        out.trace.spans.len()
    );
    if a.flag("gantt") {
        println!("\n{}", out.trace.render_gantt(100));
    }
    let path = a.get_str("trace-out", "");
    if !path.is_empty() {
        std::fs::write(&path, out.trace.to_chrome_json()).unwrap();
        println!("trace written to {path} (open in chrome://tracing)");
    }
    0
}

fn parse_fault(spec: &str) -> Option<(usize, f64)> {
    let (node, scale) = spec.split_once(':')?;
    Some((node.trim().parse().ok()?, scale.trim().parse().ok()?))
}

fn cmd_cluster(rest: &[String]) -> i32 {
    let c = Command::new("cluster", "multi-job scenarios on the unified event engine")
        .opt("nodes", "6", "physical nodes on the switch fabric")
        .opt("jobs", "2", "concurrent training jobs (all sharing every node)")
        .opt("batch", "448", "mini-batch per node")
        .opt("layers", "20", "MLP layers")
        .opt("hidden", "2048", "layer width")
        .opt("system", "smartnic+bfp", "baseline-naive | baseline | smartnic | smartnic+bfp")
        .opt("stagger", "0", "start-time offset between jobs (seconds)")
        .opt("leaves", "1", "leaf switches (1 = flat crossbar)")
        .opt("oversub", "1", "leaf uplink oversubscription factor")
        .opt("placement", "contiguous", "rank placement: contiguous | strided")
        .opt("threads", "0", "parallel-engine worker threads (0 = sequential typed engine)")
        .flag("audit", "run the checked executive: audit engine invariants + the conservation ledger")
        .opt("degrade-link", "", "node:scale — degrade one link (Tx + egress toward it)")
        .opt("straggler", "", "node:scale — slow one node's PCIe + adder + comm cores")
        .opt("trace-out", "", "write chrome trace JSON to this path")
        .flag("gantt", "render an ASCII Gantt of every lane");
    let Ok(a) = parse(c, rest) else { return 2 };
    let sys_name = a.get_str("system", "smartnic+bfp");
    let Some((kind, sys)) = parse_system(&sys_name) else {
        eprintln!("unknown system '{sys_name}'");
        return 2;
    };
    let nodes = a.get_usize("nodes", 6);
    let n_jobs = a.get_usize("jobs", 2).max(1);
    let stagger = a.get_f64("stagger", 0.0);
    if !(stagger >= 0.0 && stagger.is_finite()) {
        eprintln!("--stagger must be a finite non-negative number of seconds");
        return 2;
    }
    let w = Workload {
        layers: a.get_usize("layers", 20),
        hidden: a.get_usize("hidden", 2048),
        batch_per_node: a.get_usize("batch", 448),
    };
    let mut faults = ClusterFaults::none();
    for (opt, is_link) in [("degrade-link", true), ("straggler", false)] {
        let raw = a.get_str(opt, "");
        if raw.is_empty() {
            continue;
        }
        let Some((node, scale)) = parse_fault(&raw) else {
            eprintln!("--{opt} expects node:scale (e.g. 2:0.25), got '{raw}'");
            return 2;
        };
        if node >= nodes {
            eprintln!("--{opt}: node {node} is outside the {nodes}-node fabric");
            return 2;
        }
        if !(scale > 0.0 && scale <= 1.0) {
            eprintln!("--{opt}: scale must be in (0, 1], got {scale}");
            return 2;
        }
        faults = if is_link {
            faults.with_degraded_link(node, scale)
        } else {
            faults.with_straggler(node, scale)
        };
    }

    let leaves = a.get_usize("leaves", 1);
    let oversub = a.get_f64("oversub", 1.0);
    if !(oversub > 0.0 && oversub.is_finite()) {
        eprintln!("--oversub must be a positive finite factor");
        return 2;
    }
    let topology = if leaves <= 1 {
        Topology::flat(nodes)
    } else {
        if nodes % leaves != 0 {
            eprintln!("--leaves {leaves} must divide --nodes {nodes}");
            return 2;
        }
        Topology::leaf_spine(leaves, nodes / leaves, oversub)
    };
    let placement = a.get_str("placement", "contiguous");
    let ranks = match placement.as_str() {
        "contiguous" => topology.contiguous_ranks(nodes),
        "strided" => topology.strided_ranks(nodes),
        other => {
            eprintln!("unknown placement '{other}' (contiguous|strided)");
            return 2;
        }
    };

    let mut spec = ClusterSpec::new(sys, nodes)
        .with_topology(topology)
        .with_faults(faults.clone());
    for j in 0..n_jobs {
        spec = spec.with_job(
            JobSpec::new(&format!("j{j}"), kind, w, ranks.clone())
                .starting_at(stagger * j as f64),
        );
    }
    let threads = a.get_usize("threads", 0);
    let engine = if a.flag("audit") {
        EngineKind::Checked { threads }
    } else if threads == 0 {
        EngineKind::Typed
    } else {
        EngineKind::Parallel { threads }
    };
    let out = run_scenario_on(&spec, engine);

    let mut t = Table::new(&[
        "job", "duration (ms)", "mean AR (ms)", "max ARs in flight", "exposed wait (ms)",
    ])
    .with_title(&format!(
        "{n_jobs} x {} on {nodes} shared nodes ({placement}, {}) — unified engine",
        kind.name(),
        topology.describe()
    ));
    for j in &out.jobs {
        t.row(&[
            j.name.clone(),
            fnum(j.duration * 1e3, 2),
            fnum(j.mean_ar * 1e3, 2),
            j.max_inflight.to_string(),
            fnum(j.exposed_wait * 1e3, 2),
        ]);
    }
    t.print();
    println!(
        "fabric: eth util {:.2}, pcie util {:.2}, adder util {:.2}, {} events",
        out.eth_util, out.pcie_util, out.adder_util, out.events
    );
    if !out.partitions.is_empty() {
        // parallel runs: entry 0 is the coordinator, the rest the leaf
        // partitions — the events spread is the load-imbalance signal
        let mut t = Table::new(&["partition", "events", "peak queue depth"])
            .with_title(&format!("parallel engine load ({threads} threads)"));
        for (i, p) in out.partitions.iter().enumerate() {
            let name = if i == 0 {
                "coordinator".to_string()
            } else {
                format!("leaf {}", i - 1)
            };
            t.row(&[name, p.events.to_string(), p.peak_queue_depth.to_string()]);
        }
        t.print();
    }

    // isolated reference: the same job alone on the same (faulty) fabric
    let solo = run_scenario(
        &ClusterSpec::new(sys, nodes)
            .with_topology(topology)
            .with_faults(faults)
            .with_job(JobSpec::new("solo", kind, w, ranks.clone())),
    );
    let slow = out.jobs.iter().map(|j| j.duration).fold(0.0, f64::max)
        / solo.jobs[0].duration.max(1e-12);
    println!(
        "isolated job: {} ms -> multi-tenant slowdown x{}",
        fnum(solo.jobs[0].duration * 1e3, 2),
        fnum(slow, 2)
    );

    if a.flag("gantt") {
        println!("\n{}", out.trace.render_gantt(100));
    }
    let path = a.get_str("trace-out", "");
    if !path.is_empty() {
        std::fs::write(&path, out.trace.to_chrome_json()).unwrap();
        println!("trace written to {path} (open in chrome://tracing)");
    }
    if let Some(report) = &out.audit {
        println!("audit: {}", report.summary());
        if !report.is_clean() {
            for v in report.violations() {
                eprintln!("audit violation: {v}");
            }
            return 1;
        }
    }
    0
}

fn cmd_scale(rest: &[String]) -> i32 {
    let c = Command::new(
        "scale",
        "hierarchical scaling sweep: unified engine vs closed form, plus oversubscription",
    )
    .opt("nodes", "6,12,32,64,128,512", "node counts for the flat sweep")
    .opt("batch", "448", "mini-batch per node")
    .opt("leaves", "4", "leaf switches for the leaf-spine runs")
    .opt("oversub", "4", "leaf uplink oversubscription factor")
    .opt("out", "BENCH_scaling.json", "machine-readable output path")
    .flag("no-json", "skip writing the benchmark file");
    let Ok(a) = parse(c, rest) else { return 2 };
    let cfg = scaling::ScalingConfig {
        nodes: a.get_list("nodes").unwrap_or_default(),
        batch: a.get_usize("batch", 448),
        leaves: a.get_usize("leaves", 4),
        oversubscription: a.get_f64("oversub", 4.0),
    };
    // get_list silently drops unparsable entries; a typo must not shrink
    // the sweep while still reporting PASS
    let raw_nodes = a.get_str("nodes", "");
    let wanted = raw_nodes.split(',').filter(|s| !s.trim().is_empty()).count();
    if cfg.nodes.len() != wanted {
        eprintln!("--nodes contains invalid entries: '{raw_nodes}'");
        return 2;
    }
    if cfg.nodes.is_empty() {
        eprintln!("--nodes needs at least one node count");
        return 2;
    }
    if !(cfg.oversubscription > 0.0 && cfg.oversubscription.is_finite()) {
        eprintln!("--oversub must be a positive finite factor");
        return 2;
    }
    let sweep = scaling::run_sweep(&cfg);
    scaling::print_sweep(&sweep, &cfg);
    let oversub = scaling::run_oversub(&cfg);
    scaling::print_oversub(&oversub, &cfg);
    if !a.flag("no-json") {
        let path = a.get_str("out", "BENCH_scaling.json");
        match scaling::write_bench(&path, &cfg, &sweep, &oversub) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                return 1;
            }
        }
    }
    let worst = scaling::worst_err(&sweep);
    if worst >= scaling::VALIDATE_TOL {
        eprintln!(
            "cross-validation FAILED: worst unified-vs-model deviation {:.1}% >= {:.0}%",
            worst * 100.0,
            scaling::VALIDATE_TOL * 100.0
        );
        return 1;
    }
    0
}

fn cmd_plan(rest: &[String]) -> i32 {
    let c = Command::new(
        "plan",
        "topology-aware planner study: NIC ring vs hierarchical vs in-switch reduction",
    )
    .opt("nodes", "6,12,32,64,128,512", "node counts (even, >= 4)")
    .opt("oversub", "4", "leaf uplink oversubscription factor")
    .opt("hidden", "2048", "gradient width (hidden^2 elements per all-reduce)")
    .opt("out", "BENCH_planner.json", "machine-readable output path")
    .flag("no-json", "skip writing the benchmark file");
    let Ok(a) = parse(c, rest) else { return 2 };
    let cfg = planner::PlannerConfig {
        nodes: a.get_list("nodes").unwrap_or_default(),
        oversubscription: a.get_f64("oversub", 4.0),
        hidden: a.get_usize("hidden", 2048),
    };
    // get_list silently drops unparsable entries; a typo must not shrink
    // the sweep while still reporting PASS
    let raw_nodes = a.get_str("nodes", "");
    let wanted = raw_nodes.split(',').filter(|s| !s.trim().is_empty()).count();
    if cfg.nodes.len() != wanted || cfg.nodes.is_empty() {
        eprintln!("--nodes contains invalid entries: '{raw_nodes}'");
        return 2;
    }
    if cfg.nodes.iter().any(|&n| n < 4 || n % 2 != 0) {
        eprintln!("--nodes must all be even and >= 4, got '{raw_nodes}'");
        return 2;
    }
    if !(cfg.oversubscription > 0.0 && cfg.oversubscription.is_finite()) {
        eprintln!("--oversub must be a positive finite factor");
        return 2;
    }
    if cfg.hidden == 0 {
        eprintln!("--hidden must be positive");
        return 2;
    }
    let points = planner::run(&cfg);
    planner::print(&points, &cfg);
    if !a.flag("no-json") {
        let path = a.get_str("out", "BENCH_planner.json");
        match planner::write_bench(&path, &cfg, &points) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                return 1;
            }
        }
    }
    if let Some(worst) = planner::worst_inswitch_err(&points) {
        if worst >= planner::INSWITCH_TOL {
            eprintln!(
                "in-switch validation FAILED: worst closed-form deviation {:.1}% >= {:.0}%",
                worst * 100.0,
                planner::INSWITCH_TOL * 100.0
            );
            return 1;
        }
    }
    if !planner::hierarchical_beats_strided_ring(&points) {
        eprintln!("planner FAILED: hierarchical plan slower than the strided NIC ring");
        return 1;
    }
    0
}

fn cmd_tenancy(rest: &[String]) -> i32 {
    let c = Command::new(
        "tenancy",
        "multi-tenant in-switch contention study: tenants x table scales x PFC pause rates",
    )
    .opt("tenants", "1,2,3,4", "concurrent tenant counts (each <= nodes-per-leaf / 2)")
    .opt("table-scales", "0.015625,1,4", "aggregation-table capacities, x 8 MiB")
    .opt("pause-rates", "0,100,800", "PFC pause assertions per second (1 ms windows)")
    .opt("hidden", "1024", "gradient width (hidden^2 elements per all-reduce)")
    .opt("oversub", "4", "leaf uplink oversubscription factor")
    .opt("out", "BENCH_tenancy.json", "machine-readable output path")
    .flag("no-json", "skip writing the benchmark file");
    let Ok(a) = parse(c, rest) else { return 2 };
    let cfg = tenancy::TenancyConfig {
        tenant_counts: a.get_list("tenants").unwrap_or_default(),
        table_scales: a.get_list("table-scales").unwrap_or_default(),
        pause_rates: a.get_list("pause-rates").unwrap_or_default(),
        hidden: a.get_usize("hidden", 1024),
        oversubscription: a.get_f64("oversub", 4.0),
    };
    // get_list silently drops unparsable entries; a typo must not shrink
    // the sweep while still reporting PASS
    let wanted = |raw: &str| raw.split(',').filter(|s| !s.trim().is_empty()).count();
    for (flag, raw, got) in [
        ("tenants", a.get_str("tenants", ""), cfg.tenant_counts.len()),
        ("table-scales", a.get_str("table-scales", ""), cfg.table_scales.len()),
        ("pause-rates", a.get_str("pause-rates", ""), cfg.pause_rates.len()),
    ] {
        if got != wanted(&raw) || got == 0 {
            eprintln!("--{flag} contains invalid entries: '{raw}'");
            return 2;
        }
    }
    if cfg.tenant_counts.iter().any(|&t| t == 0 || 2 * t > tenancy::NODES_PER_LEAF) {
        eprintln!(
            "--tenants must be in 1..={} so tenant placements stay disjoint",
            tenancy::NODES_PER_LEAF / 2
        );
        return 2;
    }
    if cfg.table_scales.iter().any(|&s| !(s >= 0.0 && s.is_finite())) {
        eprintln!("--table-scales must be finite and non-negative");
        return 2;
    }
    if cfg.pause_rates.iter().any(|&r| !(r >= 0.0 && r.is_finite())) {
        eprintln!("--pause-rates must be finite and non-negative");
        return 2;
    }
    if cfg.hidden == 0 {
        eprintln!("--hidden must be positive");
        return 2;
    }
    if !(cfg.oversubscription > 0.0 && cfg.oversubscription.is_finite()) {
        eprintln!("--oversub must be a positive finite factor");
        return 2;
    }
    let points = tenancy::run(&cfg);
    let g = tenancy::gates(&cfg, &points);
    tenancy::print(&points, &cfg, &g);
    if !a.flag("no-json") {
        let path = a.get_str("out", "BENCH_tenancy.json");
        match tenancy::write_bench(&path, &cfg, &points, &g) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                return 1;
            }
        }
    }
    if !g.pass() {
        if !matches!(g.knee_default, Some(Some(k)) if k >= 2) {
            eprintln!("tenancy FAILED: no occupancy knee >= 2 tenants at the default point");
        }
        if g.solo_inswitch_wins != Some(true) {
            eprintln!("tenancy FAILED: solo in-switch tenant does not beat its host fallback");
        }
        if g.pause_collapses_knee != Some(true) {
            eprintln!("tenancy FAILED: heavy PFC pause does not pull the knee earlier");
        }
        if !g.audited_clean {
            eprintln!("tenancy FAILED: audited 4-thread re-run diverged or reported violations");
        }
        if !g.deterministic {
            eprintln!("tenancy FAILED: same-seed re-run did not reproduce the knee bit-for-bit");
        }
        return 1;
    }
    0
}

fn cmd_collectives(rest: &[String]) -> i32 {
    let c = Command::new(
        "collectives",
        "collective zoo: broadcast/allgather/reduce-scatter/all-to-all vs closed forms",
    )
    .opt("nodes", "6,32,128", "node counts (even, >= 4)")
    .opt("oversub", "2", "leaf uplink oversubscription factor")
    .opt("hidden", "1024", "payload width (hidden^2 elements per collective)")
    .opt("threads", "0", "parallel-engine worker threads (0 = sequential typed engine)")
    .flag("audit", "run the checked executive: engine invariants + conservation ledgers")
    .opt("out", "BENCH_collectives.json", "machine-readable output path")
    .flag("no-json", "skip writing the benchmark file");
    let Ok(a) = parse(c, rest) else { return 2 };
    let threads = a.get_usize("threads", 0);
    let engine = if a.flag("audit") {
        EngineKind::Checked { threads }
    } else if threads == 0 {
        EngineKind::Typed
    } else {
        EngineKind::Parallel { threads }
    };
    let cfg = collectives::CollectivesConfig {
        nodes: a.get_list("nodes").unwrap_or_default(),
        oversubscription: a.get_f64("oversub", 2.0),
        hidden: a.get_usize("hidden", 1024),
        engine,
    };
    // get_list silently drops unparsable entries; a typo must not shrink
    // the sweep while still reporting PASS
    let raw_nodes = a.get_str("nodes", "");
    let wanted = raw_nodes.split(',').filter(|s| !s.trim().is_empty()).count();
    if cfg.nodes.len() != wanted || cfg.nodes.is_empty() {
        eprintln!("--nodes contains invalid entries: '{raw_nodes}'");
        return 2;
    }
    if cfg.nodes.iter().any(|&n| n < 4 || n % 2 != 0) {
        eprintln!("--nodes must all be even and >= 4, got '{raw_nodes}'");
        return 2;
    }
    if !(cfg.oversubscription > 0.0 && cfg.oversubscription.is_finite()) {
        eprintln!("--oversub must be a positive finite factor");
        return 2;
    }
    if cfg.hidden == 0 {
        eprintln!("--hidden must be positive");
        return 2;
    }
    let study = collectives::run(&cfg);
    collectives::print(&study, &cfg);
    if !a.flag("no-json") {
        let path = a.get_str("out", "BENCH_collectives.json");
        match collectives::write_bench(&path, &cfg, &study) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                return 1;
            }
        }
    }
    if let Some(worst) = collectives::worst_gated_parity(&study.points) {
        if worst >= collectives::PARITY_TOL {
            eprintln!(
                "collective parity FAILED: worst gated closed-form deviation {:.1}% >= {:.0}%",
                worst * 100.0,
                collectives::PARITY_TOL * 100.0
            );
            return 1;
        }
    }
    if collectives::mcast_beats_binomial(&study.points) == Some(false) {
        eprintln!(
            "multicast FAILED: switch multicast lost to the binomial tree at N >= 32 on the spine"
        );
        return 1;
    }
    if study.audit_clean == Some(false) {
        for f in &study.audit_failures {
            eprintln!("audit violation: {f}");
        }
        return 1;
    }
    0
}

fn cmd_engine_bench(rest: &[String]) -> i32 {
    let c = Command::new(
        "engine-bench",
        "typed-event calendar engine vs the boxed-closure baseline (BENCH_engine.json)",
    )
    .opt("nodes", "128,512,2048", "node counts for the typed sweep (even, >= 4)")
    .opt("baseline-nodes", "128,512", "node counts also run on the boxed-closure baseline")
    .opt("threads", "1,2,4", "worker-thread counts for the parallel executive rows")
    .opt("scaling-nodes", "4096,16384,65536", "ring-only node counts for the capped scaling sweep")
    .opt("max-events", "2000000", "event budget each capped scaling run burns")
    .opt("oversub", "4", "leaf uplink oversubscription factor")
    .opt("hidden", "2048", "gradient width (hidden^2 elements per all-reduce)")
    .opt("out", "BENCH_engine.json", "machine-readable output path")
    .flag("no-json", "skip writing the benchmark file");
    let Ok(a) = parse(c, rest) else { return 2 };
    let cfg = engine_bench::EngineBenchConfig {
        nodes: a.get_list("nodes").unwrap_or_default(),
        baseline_nodes: a.get_list("baseline-nodes").unwrap_or_default(),
        threads: a.get_list("threads").unwrap_or_default(),
        scaling_nodes: a.get_list("scaling-nodes").unwrap_or_default(),
        max_events: a.get_u64("max-events", 2_000_000),
        oversubscription: a.get_f64("oversub", 4.0),
        hidden: a.get_usize("hidden", 2048),
    };
    // get_list silently drops unparsable entries; a typo must not shrink
    // the sweep (or silently disable the baseline gates) while still
    // reporting PASS
    let raw_nodes = a.get_str("nodes", "");
    let wanted = raw_nodes.split(',').filter(|s| !s.trim().is_empty()).count();
    if cfg.nodes.len() != wanted || cfg.nodes.is_empty() {
        eprintln!("--nodes contains invalid entries: '{raw_nodes}'");
        return 2;
    }
    let raw_base = a.get_str("baseline-nodes", "");
    let base_wanted = raw_base.split(',').filter(|s| !s.trim().is_empty()).count();
    if cfg.baseline_nodes.len() != base_wanted {
        eprintln!("--baseline-nodes contains invalid entries: '{raw_base}'");
        return 2;
    }
    if let Some(orphan) = cfg.baseline_nodes.iter().find(|&&n| !cfg.nodes.contains(&n)) {
        eprintln!("--baseline-nodes {orphan} is not in --nodes, so it would never be baselined");
        return 2;
    }
    let raw_threads = a.get_str("threads", "");
    let threads_wanted = raw_threads.split(',').filter(|s| !s.trim().is_empty()).count();
    if cfg.threads.len() != threads_wanted || cfg.threads.is_empty() {
        eprintln!("--threads contains invalid entries: '{raw_threads}'");
        return 2;
    }
    if cfg.threads.iter().any(|&t| t == 0) {
        eprintln!("--threads entries must be >= 1");
        return 2;
    }
    let raw_scaling = a.get_str("scaling-nodes", "");
    let scaling_wanted = raw_scaling.split(',').filter(|s| !s.trim().is_empty()).count();
    if cfg.scaling_nodes.len() != scaling_wanted {
        eprintln!("--scaling-nodes contains invalid entries: '{raw_scaling}'");
        return 2;
    }
    if cfg
        .nodes
        .iter()
        .chain(&cfg.baseline_nodes)
        .chain(&cfg.scaling_nodes)
        .any(|&n| n < 4 || n % 2 != 0)
    {
        eprintln!("node counts must all be even and >= 4");
        return 2;
    }
    if cfg.max_events == 0 {
        eprintln!("--max-events must be positive");
        return 2;
    }
    if !(cfg.oversubscription > 0.0 && cfg.oversubscription.is_finite()) {
        eprintln!("--oversub must be a positive finite factor");
        return 2;
    }
    if cfg.hidden == 0 {
        eprintln!("--hidden must be positive");
        return 2;
    }
    let points = engine_bench::run(&cfg);
    let scaling = engine_bench::run_scaling(&cfg);
    engine_bench::print(&points, &scaling, &cfg);
    if !a.flag("no-json") {
        let path = a.get_str("out", "BENCH_engine.json");
        match engine_bench::write_bench(&path, &cfg, &points, &scaling) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                return 1;
            }
        }
    }
    if let Some(worst) = engine_bench::worst_virtual_err(&points) {
        if worst > engine_bench::VIRTUAL_TIME_TOL {
            eprintln!(
                "engine parity FAILED: typed vs boxed virtual time deviates by {worst:.2e} \
                 (tol {:.0e})",
                engine_bench::VIRTUAL_TIME_TOL
            );
            return 1;
        }
    }
    if let Some(worst) = engine_bench::worst_parallel_virtual_err(&points) {
        if worst > engine_bench::VIRTUAL_TIME_TOL {
            eprintln!(
                "engine parity FAILED: parallel vs typed virtual time deviates by {worst:.2e} \
                 (tol {:.0e})",
                engine_bench::VIRTUAL_TIME_TOL
            );
            return 1;
        }
    }
    if let Some(violations) = engine_bench::checked_violation_total(&points) {
        if violations > 0 {
            eprintln!("checked executive FAILED: {violations} audit violation(s) — see the table");
            return 1;
        }
    }
    if let Some(worst) = engine_bench::worst_checked_virtual_err(&points) {
        if worst > engine_bench::VIRTUAL_TIME_TOL {
            eprintln!(
                "engine parity FAILED: checked vs typed virtual time deviates by {worst:.2e} \
                 (tol {:.0e})",
                engine_bench::VIRTUAL_TIME_TOL
            );
            return 1;
        }
    }
    if let Some(overhead) = engine_bench::worst_checked_overhead(&points) {
        if overhead > engine_bench::CHECKED_OVERHEAD_TOL {
            // wall-clock ratios are noisy on shared runners; the budget is
            // tracked in BENCH_engine.json, a breach warns rather than fails.
            let msg = format!(
                "checked executive over its overhead budget: {:+.1}% (budget {:.0}%)",
                overhead * 100.0,
                engine_bench::CHECKED_OVERHEAD_TOL * 100.0
            );
            eprintln!("warning: {msg}");
            println!("::warning title=engine-bench::{msg}");
        }
    }
    if let Some(speedup) = engine_bench::gate_speedup(&points) {
        if speedup < engine_bench::SPEEDUP_GATE {
            eprintln!(
                "engine speedup FAILED: x{speedup:.2} on the {}-node NIC ring (gate x{})",
                engine_bench::GATE_NODES,
                engine_bench::SPEEDUP_GATE
            );
            return 1;
        }
    }
    if let Some(speedup) = engine_bench::parallel_gate_speedup(&scaling) {
        if speedup < engine_bench::PARALLEL_SPEEDUP_FLOOR {
            eprintln!(
                "parallel scaling FAILED: x{speedup:.2} at {} threads on the {}-node ring \
                 (hard floor x{})",
                engine_bench::PARALLEL_GATE_THREADS,
                engine_bench::PARALLEL_GATE_NODES,
                engine_bench::PARALLEL_SPEEDUP_FLOOR
            );
            return 1;
        }
        if speedup < engine_bench::PARALLEL_SPEEDUP_GATE {
            // below target but above the floor: shared-runner noise, not a
            // regression — warn (surfaced as a GitHub annotation in CI) and
            // leave the measurement in BENCH_engine.json.
            let msg = format!(
                "parallel scaling below target: x{speedup:.2} at {} threads on the {}-node \
                 ring (target x{}, floor x{})",
                engine_bench::PARALLEL_GATE_THREADS,
                engine_bench::PARALLEL_GATE_NODES,
                engine_bench::PARALLEL_SPEEDUP_GATE,
                engine_bench::PARALLEL_SPEEDUP_FLOOR
            );
            eprintln!("warning: {msg}");
            println!("::warning title=engine-bench::{msg}");
        }
    }
    0
}

fn cmd_cluster_trace(rest: &[String]) -> i32 {
    let c = Command::new(
        "cluster-trace",
        "trace-driven gang-scheduler policy study under churn (BENCH_cluster.json)",
    )
    .opt("nodes", "64", "fabric nodes")
    .opt("leaves", "8", "leaf switches (1 = flat crossbar)")
    .opt("oversub", "4", "leaf uplink oversubscription factor")
    .opt("jobs", "80", "jobs in the arrival trace")
    .opt("seed", "7", "trace seed")
    .opt("interarrival", "0.02", "mean job inter-arrival gap (s)")
    .opt("min-gang", "2", "smallest gang size")
    .opt("max-gang", "16", "largest gang size (heavy-tailed in between)")
    .opt("max-iters", "6", "largest per-job iteration count")
    .opt("layers", "2", "model layers per job")
    .opt("hidden", "256", "gradient width (hidden^2 elements per all-reduce)")
    .opt("batch", "32", "mini-batch per node")
    .opt("elastic", "0.25", "fraction of jobs filing one elastic resize")
    .opt("failures", "3", "node failures injected over the trace")
    .opt("restart-delay", "0.05", "checkpoint-reload delay after a preempt (s)")
    .opt("repair-delay", "0.2", "node repair delay after a failure (s)")
    .opt("threads", "0", "parallel worker threads (0 = sequential typed engine)")
    .opt("out", "BENCH_cluster.json", "machine-readable output path")
    .flag("no-audit", "skip the audited (checked-engine) churn gate run")
    .flag("no-json", "skip writing the benchmark file");
    let Ok(a) = parse(c, rest) else { return 2 };
    let cfg = cluster_trace::ClusterTraceConfig {
        nodes: a.get_usize("nodes", 64),
        leaves: a.get_usize("leaves", 8),
        oversubscription: a.get_f64("oversub", 4.0),
        jobs: a.get_usize("jobs", 80),
        seed: a.get_u64("seed", 7),
        mean_interarrival: a.get_f64("interarrival", 0.02),
        min_gang: a.get_usize("min-gang", 2),
        max_gang: a.get_usize("max-gang", 16),
        max_iters: a.get_usize("max-iters", 6),
        layers: a.get_usize("layers", 2),
        hidden: a.get_usize("hidden", 256),
        batch_per_node: a.get_usize("batch", 32),
        elastic_fraction: a.get_f64("elastic", 0.25),
        failures: a.get_usize("failures", 3),
        restart_delay: a.get_f64("restart-delay", 0.05),
        repair_delay: a.get_f64("repair-delay", 0.2),
        threads: a.get_usize("threads", 0),
    };
    if cfg.leaves == 0 || cfg.nodes == 0 || cfg.nodes % cfg.leaves != 0 {
        eprintln!("--nodes must be a positive multiple of --leaves");
        return 2;
    }
    if cfg.jobs == 0 {
        eprintln!("--jobs must be positive");
        return 2;
    }
    if cfg.min_gang == 0 || cfg.min_gang > cfg.max_gang || cfg.max_gang > cfg.nodes {
        eprintln!(
            "gang range [{}, {}] must satisfy 1 <= min <= max <= nodes ({})",
            cfg.min_gang, cfg.max_gang, cfg.nodes
        );
        return 2;
    }
    if cfg.max_iters == 0 || cfg.layers == 0 || cfg.hidden == 0 || cfg.batch_per_node == 0 {
        eprintln!("--max-iters, --layers, --hidden and --batch must all be positive");
        return 2;
    }
    if !(cfg.mean_interarrival > 0.0 && cfg.mean_interarrival.is_finite()) {
        eprintln!("--interarrival must be a positive finite gap");
        return 2;
    }
    if !(0.0..=1.0).contains(&cfg.elastic_fraction) {
        eprintln!("--elastic must be a fraction in [0, 1]");
        return 2;
    }
    if !(cfg.restart_delay >= 0.0 && cfg.restart_delay.is_finite())
        || !(cfg.repair_delay >= 0.0 && cfg.repair_delay.is_finite())
    {
        eprintln!("--restart-delay and --repair-delay must be non-negative and finite");
        return 2;
    }
    if !(cfg.oversubscription > 0.0 && cfg.oversubscription.is_finite()) {
        eprintln!("--oversub must be a positive finite factor");
        return 2;
    }
    let points = cluster_trace::run(&cfg);
    let audit = if a.flag("no-audit") { None } else { Some(cluster_trace::run_audited(&cfg)) };
    let determinism = cluster_trace::check_determinism(&cfg, &points);
    cluster_trace::print(&cfg, &points, audit.as_ref(), determinism);
    if !a.flag("no-json") {
        let path = a.get_str("out", "BENCH_cluster.json");
        match cluster_trace::write_bench(&path, &cfg, &points, audit.as_ref(), determinism) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("failed to write {path}: {e}");
                return 1;
            }
        }
    }
    if let Some(ref audit) = audit {
        if audit.violations > 0 {
            eprintln!(
                "audited churn run FAILED: {} violation(s) on the {} policy — see the report",
                audit.violations, audit.policy
            );
            return 1;
        }
    }
    if determinism == Some(false) {
        eprintln!("determinism FAILED: same-seed re-run diverged in p50/p99 JCT or event count");
        return 1;
    }
    if let Some(gap) = cluster_trace::frag_jct_gap(&points) {
        if gap <= cluster_trace::FRAG_GAP_MIN {
            eprintln!(
                "fragmentation penalty FAILED: scatter/first-fit mean JCT x{gap:.4} \
                 (hard floor x{})",
                cluster_trace::FRAG_GAP_MIN
            );
            return 1;
        }
        if gap < cluster_trace::FRAG_GAP_TARGET {
            // the gap's magnitude depends on the trace mix; only its sign
            // is load-independent, so the trend level warns rather than
            // fails.
            let msg = format!(
                "fragmentation penalty below target: x{gap:.3} (target x{}, floor x{})",
                cluster_trace::FRAG_GAP_TARGET,
                cluster_trace::FRAG_GAP_MIN
            );
            eprintln!("warning: {msg}");
            println!("::warning title=cluster-trace::{msg}");
        }
    }
    0
}

fn cmd_bfp(rest: &[String]) -> i32 {
    let c = Command::new("bfp", "BFP design-space sweep on synthetic gradients")
        .opt("n", "65536", "gradient elements")
        .opt("seed", "7", "rng seed")
        .opt("blocks", "4,8,16,32,64", "block sizes")
        .opt("mants", "3,5,7,9", "mantissa bit widths");
    let Ok(a) = parse(c, rest) else { return 2 };
    let mut rng = Rng::new(a.get_u64("seed", 7));
    let x: Vec<f32> = (0..a.get_usize("n", 65536))
        .map(|_| rng.normal() as f32)
        .collect();
    let blocks: Vec<usize> = a.get_list("blocks").unwrap_or_default();
    let mants: Vec<u32> = a.get_list("mants").unwrap_or_default();
    let pts = analysis::sweep(&x, &blocks, &mants);
    let mut t = Table::new(&["block", "mant bits", "ratio", "SNR (dB)", "rel L2"])
        .with_title("BFP design space (paper Sec. IV-B: tunable via FPGA reconfigurability)");
    for p in pts {
        t.row(&[
            p.block_size.to_string(),
            p.mant_bits.to_string(),
            fnum(p.ratio, 2),
            fnum(p.snr_db, 1),
            format!("{:.4}", p.rel_l2),
        ]);
    }
    t.print();
    println!("paper's BFP16 = block 16, 7-bit mantissa: 3.76x ratio\n");
    0
}

fn cmd_ablate(rest: &[String]) -> i32 {
    let c = Command::new("ablate", "design-choice ablations (segment size, comm cores, alpha)");
    let Ok(_a) = parse(c, rest) else { return 2 };
    ablate::print_all();
    0
}

fn cmd_all(rest: &[String]) -> i32 {
    let c = Command::new("all", "run every paper experiment, write results/");
    let Ok(_a) = parse(c, rest) else { return 2 };
    println!("=== E1 Fig. 2a ===");
    let r = fig2a::run(6, 1792);
    fig2a::print(&r);
    write_result("fig2a", &fig2a::to_json(&r)).unwrap();
    println!("=== E2 Fig. 2b ===");
    let s = fig2b::run(&[2, 4, 6, 8, 12, 16, 24], 1792);
    fig2b::print(&s);
    write_result("fig2b", &fig2b::to_json(&s)).unwrap();
    println!("=== E3 Table I ===");
    table1::run_all();
    write_result("table1", &table1::to_json()).unwrap();
    println!("=== E4 Fig. 4a ===");
    let r = fig4a::run(6, 448);
    fig4a::print(&r);
    write_result("fig4a", &fig4a::to_json(&r)).unwrap();
    println!("=== E5 Fig. 4b ===");
    for b in [448usize, 1792] {
        let s = fig4b::run(&[1, 2, 3, 4, 5, 6, 8, 12, 16, 24, 32], b);
        fig4b::print(&s, b);
        write_result(&format!("fig4b_b{b}"), &fig4b::to_json(&s)).unwrap();
    }
    println!("=== E6 validation ===");
    let rows = validate::run_iteration_grid();
    validate::print_iteration(&rows);
    write_result("validate", &validate::to_json(&rows)).unwrap();
    println!("all results written to results/");
    0
}
