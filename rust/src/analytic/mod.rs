//! The paper's analytical performance model (Sec. IV-C) and its validation
//! against the discrete-event simulator.

pub mod model;
pub mod validate;

pub use model::{iteration, IterationBreakdown, SystemKind};
