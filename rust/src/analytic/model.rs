//! Closed-form iteration-time model — a faithful transcription of the
//! equations in Sec. IV-C:
//!
//!   T_F_l  = 2 M_l² B / P_worker          T_B_l = 4 M_l² B / P_worker
//!   R_l    = b · N · ⌈M_l² / N⌉                       (bits, b = 32)
//!   T_ring = R_l · 2(N−1) / (N · α·BW_eth·β · c)
//!            (α·BW_eth·β = `NetParams::effective_bw`, the same
//!            wire-protocol-derated rate the serialized NIC DES and the
//!            unified fabric give their Tx links; c = BFP compression)
//!   T_add  = R_l · 2(N−1) / (N · P_FPGA · b)
//!   T_mem  = 2 R_l / BW_pcie
//!   T_AR_l = max(T_ring, T_add, T_mem)
//!
//!   T_total = Σ T_F + T_B_L + max(T_B_{L−1}, T_AR_L)
//!           + Σ_{l=2}^{L−1} max(T_U_{l+1} + T_B_{l−1}, T_AR_l)
//!           + max(T_U_2, T_AR_1) + T_U_1
//!
//! The same trace composition covers the baseline systems: for the
//! overlapped host baseline, T_AR comes from the software collective cost
//! model and T_B carries the core-stealing slowdown; for the naive
//! baseline all terms serialize.
//!
//! Beyond the paper's flat ring, the planner's plan families each have a
//! closed form here, paired with the unified-engine path that executes
//! them (see `docs/ARCHITECTURE.md` for the full table):
//!
//! * [`nic_ring_ar_time_elems`] — the ring T_AR generalized with a wire
//!   compression ratio and the placement's leaf-uplink contention factor;
//! * [`hierarchical_ar_time_elems`] — reduce-scatter in leaf → shard
//!   ring across the spine → allgather in leaf, priced round by round;
//! * [`inswitch_ar_time_elems`] — the **in-switch pipeline closed
//!   form**: the gradient streams through the switch tier's aggregation
//!   engines as `segs` segments, so the total is one segment's *fill*
//!   (PCIe fetch → Tx → folds → multicast → writeback) plus `(segs − 1)`
//!   times the *bottleneck* stage, throttled to `fill / window` when the
//!   aggregation table holds only `window` segments; infinite (planner
//!   falls back to the ring) when the switch cannot reduce or the table
//!   cannot hold one segment.
//!
//! The pairing is measured, not assumed — for example, switch-side
//! reduction beating the uplink-derated ring on a provisioned fabric is
//! exactly what `smartnic plan` gates on:
//!
//! ```
//! use ai_smartnic::analytic::model::{inswitch_ar_time_elems, nic_ring_ar_time_elems};
//! use ai_smartnic::experiments::planner::planner_system;
//!
//! // 4 leaves x 8 ranks, 4:1-tapered spine, NetReduce-provisioned
//! let sys = planner_system(4, 8);
//! let elems = 1 << 20;
//! // strided ring pays the ~4x uplink factor; the switch pipeline does not
//! let ring = nic_ring_ar_time_elems(&sys, elems, 32, 1.0, 4.0);
//! let inswitch = inswitch_ar_time_elems(&sys, elems, 8, 4, 4.0, 1.0);
//! assert!(inswitch.is_finite() && inswitch < ring);
//! ```

use crate::bfp::BfpCodec;
use crate::collective::host::HostStrategy;
use crate::collective::timing::{allreduce_time, HostNet};
use crate::collective::Scheme;
use crate::sysconfig::{SystemParams, Workload};

/// Which system variant the model evaluates (paper Figs. 2a / 4a / 4b).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SystemKind {
    /// conventional NICs, blocking host all-reduce
    BaselineNaive { scheme: Scheme },
    /// conventional NICs, dedicated comm cores overlap AR with backward
    BaselineOverlapped { scheme: Scheme, comm_cores: usize },
    /// FPGA AI smart NIC (optionally with BFP wire compression)
    SmartNic { bfp: bool },
}

impl SystemKind {
    pub fn name(&self) -> String {
        match self {
            SystemKind::BaselineNaive { scheme } => format!("baseline-naive({})", scheme.name()),
            SystemKind::BaselineOverlapped { scheme, comm_cores } => {
                format!("baseline-overlapped({}, k={comm_cores})", scheme.name())
            }
            SystemKind::SmartNic { bfp: false } => "smartnic".to_string(),
            SystemKind::SmartNic { bfp: true } => "smartnic+bfp".to_string(),
        }
    }
}

/// Fig. 2a / 4a style iteration breakdown (all seconds).
#[derive(Clone, Copy, Debug)]
pub struct IterationBreakdown {
    pub t_fwd: f64,
    /// backward-pass compute on the critical path (slowdown included)
    pub t_bwd: f64,
    /// all-reduce time NOT hidden behind compute
    pub t_exposed_ar: f64,
    /// weight-update time on the critical path
    pub t_update: f64,
    pub t_total: f64,
    /// raw all-reduce time per layer (before overlap), for reporting
    pub t_ar_raw: f64,
}

impl IterationBreakdown {
    /// Throughput in training samples/second for a given global batch.
    pub fn throughput(&self, global_batch: usize) -> f64 {
        global_batch as f64 / self.t_total
    }
}

/// Per-layer primitive times for a (system, workload, N) configuration.
#[derive(Clone, Debug)]
pub struct LayerTimes {
    pub t_f: f64,
    pub t_b: f64,
    pub t_ar: f64,
    pub t_u: f64,
    pub layers: usize,
}

/// Weight-update time: touches grad + read/write weights ≈ 3 streams of
/// 4·M² bytes at the worker's update memory bandwidth (the paper measures
/// T_U and scales it linearly in layer size).
fn t_update_layer(sys: &SystemParams, w: &Workload) -> f64 {
    3.0 * w.grad_bytes_per_layer() / sys.worker.update_membw
}

/// Sec. IV-C T_AR for a raw element count (not tied to a square layer) —
/// the single copy of the formula, shared with `analytic::validate`.
pub fn smartnic_ar_time_elems(sys: &SystemParams, elems: usize, n: usize, bfp: bool) -> f64 {
    let compression = if bfp {
        BfpCodec::bfp16().compression_ratio()
    } else {
        1.0
    };
    nic_ring_ar_time_elems(sys, elems, n, compression, 1.0)
}

/// The ring T_AR generalized for the planner: `wire_ratio` is the wire
/// compression factor (1.0 = raw FP32) and `uplink_factor` (≥ 1) is the
/// placement's leaf-uplink contention multiplier — the worst per-step
/// bundle load relative to one port's serialization, 1.0 on a flat
/// crossbar or for a placement whose ring edges stay inside leaves
/// ([`crate::cluster::planner::ring_uplink_factor`] computes it).
pub fn nic_ring_ar_time_elems(
    sys: &SystemParams,
    elems: usize,
    n: usize,
    wire_ratio: f64,
    uplink_factor: f64,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    assert!(uplink_factor >= 1.0, "uplink factor {uplink_factor} < 1");
    let nf = n as f64;
    let b_bits = 32.0;
    let r_bits = b_bits * nf * (elems as f64 / nf).ceil();
    // α·BW_eth·β via NetParams::effective_bw — the same wire-protocol
    // efficiency the event fabrics apply to their Tx links, so the closed
    // form and both simulators price the wire identically
    let eff_wire = nf * sys.net.effective_bw() * 8.0 * wire_ratio;
    let t_ring = r_bits * 2.0 * (nf - 1.0) * uplink_factor / eff_wire;
    let t_add = r_bits * 2.0 * (nf - 1.0) / (nf * sys.nic.add_flops * b_bits);
    // Sec. IV-C's T_mem = 2R/BW_pcie.  The DES shows the dependency
    // structure precisely: the full R must come down before the last
    // reduce completes, and only the first R/N of the writeback overlaps
    // that fetch tail — so T_mem = R(2N−1)/(N·BW_pcie), which converges
    // to the paper's 2R/BW_pcie as N grows.
    let t_mem = r_bits * (2.0 * nf - 1.0) / (nf * sys.nic.pcie_bw * 8.0);
    t_ring.max(t_add).max(t_mem) + sys.nic_request_overhead
}

/// Closed form for the hierarchical plan on an `l`-leaf fabric with `m`
/// ranks per leaf at `oversub`:1 uplink tapering: ring reduce-scatter
/// inside each leaf, ring all-reduce of the per-rank shards across leaf
/// representatives (m concurrent rings of l over the spine), ring
/// allgather inside the leaf — mirroring the barrier-synchronized round
/// execution of [`crate::cluster::collective::Phase::Rounds`]:
///
///   T = T_fetch + (m−1)(c₁/bw + λ + e₁/ρ)                 reduce-scatter
///     + (l−1)(c₂/bw + q + 3λ + e₂/ρ) + (l−1)(c₂/bw + q + 3λ)   cross AR
///     + (m−1)(c₁/bw + λ) + T_wb + T_req                    allgather
///
/// with c₁ = S/m, c₂ = S/(m·l) on the wire, e₁ = E/m, e₂ = E/(m·l), and
/// q = (m−1)·c₂·oversub/(m·bw) the uplink-bundle queueing of the m
/// concurrent spine crossings per leaf per round.
///
/// `oversub` is the *effective* per-group tapering, m·bw/uplink_bw: equal
/// to the fabric's oversubscription factor when the m ranks fill their
/// leaf, proportionally milder when a job only partially occupies it
/// (the bundle stays provisioned by the topology's nodes-per-leaf).
pub fn hierarchical_ar_time_elems(
    sys: &SystemParams,
    elems: usize,
    m: usize,
    l: usize,
    oversub: f64,
    wire_ratio: f64,
) -> f64 {
    let n = m * l;
    if n <= 1 {
        return 0.0;
    }
    let s = elems as f64 * 4.0;
    let e = elems as f64;
    let bw = sys.net.effective_bw();
    let lat = sys.net.hop_latency;
    let rho = sys.nic.add_flops;
    let (mf, lf) = (m as f64, l as f64);
    let t_pcie = s / sys.nic.pcie_bw + sys.nic.pcie_latency;
    let mut t = sys.nic_request_overhead + 2.0 * t_pcie;
    if m >= 2 {
        let c1 = s / mf / wire_ratio;
        let e1 = e / mf;
        t += (mf - 1.0) * (c1 / bw + lat + e1 / rho); // reduce-scatter
        t += (mf - 1.0) * (c1 / bw + lat); // allgather
    }
    if l >= 2 {
        let c2 = s / (mf * lf) / wire_ratio;
        let e2 = e / (mf * lf);
        let q = (mf - 1.0) * c2 * oversub / (mf * bw);
        t += (lf - 1.0) * (c2 / bw + q + 3.0 * lat + e2 / rho); // cross reduce
        t += (lf - 1.0) * (c2 / bw + q + 3.0 * lat); // cross gather
    }
    t
}

/// Closed form for the NetReduce-style in-switch reduction: every rank
/// streams its gradient up in segments, the leaf engines fold the m local
/// contributions, the spine engine folds the l leaf aggregates, and the
/// reduced stream multicasts back down — a segment pipeline whose total is
/// the fill of one segment plus (segs−1) times the bottleneck stage,
/// throttled to fill/window when the aggregation table holds fewer than
/// `window` segments.  `l = 1` is the single-switch (crossbar or
/// one-leaf) case; `oversub` is the *effective* per-group tapering
/// m·bw/uplink_bw (see [`hierarchical_ar_time_elems`]).  Returns infinity
/// when the switch cannot reduce — the planner then falls back to the
/// NIC ring.
pub fn inswitch_ar_time_elems(
    sys: &SystemParams,
    elems: usize,
    m: usize,
    l: usize,
    oversub: f64,
    wire_ratio: f64,
) -> f64 {
    inswitch_ar_time_contended(
        sys,
        elems,
        m,
        l,
        oversub,
        wire_ratio,
        1,
        sys.switch.reduce_table_bytes,
        1.0,
    )
}

/// [`inswitch_ar_time_elems`] under multi-tenant load: `tenants` identical
/// jobs share the root engine (their `tenants·segs` segments drain the
/// engine-occupancy server back to back, so the pipeline term scales to
/// `(tenants·segs − 1)·b` — the *last* tenant's completion), the
/// aggregation table holds `table_bytes` (the tenant's granted share, not
/// the switch's full capacity), and PFC throttles the spine stages to
/// `pause_duty` of their bandwidth.  With `(1, full table, duty 1.0)`
/// this is exactly the solo closed form.  Returns infinity when the
/// switch cannot reduce, the granted table cannot hold one segment, or a
/// pause storm (`duty ≤ 0`) stalls the tree — the planner then prices the
/// host/NIC plans instead.
#[allow(clippy::too_many_arguments)]
pub fn inswitch_ar_time_contended(
    sys: &SystemParams,
    elems: usize,
    m: usize,
    l: usize,
    oversub: f64,
    wire_ratio: f64,
    tenants: usize,
    table_bytes: f64,
    pause_duty: f64,
) -> f64 {
    let n = m * l;
    if n <= 1 {
        return 0.0;
    }
    if !sys.switch.enabled() {
        return f64::INFINITY;
    }
    if pause_duty <= 0.0 {
        return f64::INFINITY; // pause storm: the reduction tree stalls
    }
    assert!(tenants >= 1, "contended form needs at least one tenant");
    let s = elems as f64 * 4.0;
    let segs = (s / sys.nic.segment_bytes).ceil().max(1.0);
    let seg = s / segs;
    let seg_e = elems as f64 / segs;
    let wire = seg / wire_ratio;
    let bw = sys.net.effective_bw();
    let lat = sys.net.hop_latency;
    let rho = sys.switch.reduce_flops;
    let window = (table_bytes / seg).floor();
    if window < 1.0 {
        return f64::INFINITY; // table cannot hold one segment: fall back
    }
    let d_f = seg / sys.nic.pcie_bw;
    let d_t = wire / bw;
    // engine occupancy: the reduced segment drains the engine at port
    // line rate before multicast — a serial pipeline stage of its own
    let d_e = wire / bw;
    let d_wb = seg / sys.nic.pcie_bw;
    let (fill, bottleneck) = if l <= 1 {
        let d_fold = n as f64 * seg_e / rho;
        (
            d_f + d_t + d_fold + lat + d_e + d_wb + 2.0 * sys.nic.pcie_latency,
            d_f.max(d_t).max(d_fold).max(d_e).max(d_wb),
        )
    } else {
        let up_bw = m as f64 * bw / oversub * pause_duty;
        let d_lf = m as f64 * seg_e / rho;
        let d_u = wire / up_bw;
        let d_sf = l as f64 * seg_e / rho;
        let d_d = wire / up_bw;
        (
            d_f + d_t + d_lf + lat + d_sf + d_e + 2.0 * lat + d_wb + 2.0 * sys.nic.pcie_latency,
            d_f.max(d_t).max(d_lf).max(d_u).max(d_sf).max(d_d).max(d_e).max(d_wb),
        )
    };
    let b = bottleneck.max(fill / window);
    sys.nic_request_overhead + fill + (tenants as f64 * segs - 1.0) * b
}

/// Closed form for switch-resident *multicast* — the replication dual of
/// [`inswitch_ar_time_elems`] with every fold stage removed: the root
/// streams the payload up in segments and the switch tier's egress
/// engines replicate each segment to every other member.  Same segment
/// pipeline (total = fill + (segs−1)·bottleneck, throttled to
/// fill/window by the finite replication table), same fallback signal
/// (infinity when the switch has no engines or the table cannot hold a
/// segment — the planner then uses the host binomial tree), but the
/// pipeline stages are pure wire: PCIe fetch at the root → Tx → (spine
/// crossing when the members span leaves) → per-leaf downlink → final
/// egress → PCIe writeback at each non-root.
pub fn switch_multicast_time_elems(
    sys: &SystemParams,
    elems: usize,
    m: usize,
    l: usize,
    oversub: f64,
    wire_ratio: f64,
) -> f64 {
    let n = m * l;
    if n <= 1 {
        return 0.0;
    }
    if !sys.switch.enabled() {
        return f64::INFINITY;
    }
    let s = elems as f64 * 4.0;
    let segs = (s / sys.nic.segment_bytes).ceil().max(1.0);
    let seg = s / segs;
    let wire = seg / wire_ratio;
    let bw = sys.net.effective_bw();
    let lat = sys.net.hop_latency;
    let window = (sys.switch.reduce_table_bytes / seg).floor();
    if window < 1.0 {
        return f64::INFINITY; // table cannot hold one segment: fall back
    }
    let d_f = seg / sys.nic.pcie_bw;
    let d_t = wire / bw;
    let d_e = wire / bw;
    let d_wb = seg / sys.nic.pcie_bw;
    let (fill, bottleneck) = if l <= 1 {
        (
            d_f + d_t + lat + d_wb + 2.0 * sys.nic.pcie_latency,
            d_f.max(d_t).max(d_e).max(d_wb),
        )
    } else {
        let up_bw = m as f64 * bw / oversub;
        let d_u = wire / up_bw;
        let d_d = wire / up_bw;
        (
            d_f + d_t + 3.0 * lat + d_wb + 2.0 * sys.nic.pcie_latency,
            d_f.max(d_t).max(d_u).max(d_d).max(d_e).max(d_wb),
        )
    };
    let b = bottleneck.max(fill / window);
    sys.nic_request_overhead + fill + (segs - 1.0) * b
}

/// Closed form for the binomial-tree broadcast on an uncontended flat
/// crossbar: the root DMA-fetches the payload, ⌈log₂ n⌉ rounds each
/// forward one full payload per holder, every non-root writes it back.
/// Equal to `planner::rounds_cost` over `broadcast_binomial_rounds` on a
/// flat topology (the planner form adds the leaf/spine terms).
pub fn broadcast_tree_time_elems(sys: &SystemParams, elems: usize, n: usize, wire_ratio: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let s = elems as f64 * 4.0;
    let rounds = (n as f64).log2().ceil();
    let per_round = s / wire_ratio / sys.net.effective_bw() + sys.net.hop_latency;
    sys.nic_request_overhead
        + 2.0 * (s / sys.nic.pcie_bw + sys.nic.pcie_latency)
        + rounds * per_round
}

/// Closed form for the ring allgather on an uncontended flat crossbar:
/// each rank DMA-fetches its shard (S/n), n−1 rounds walk every shard
/// around the ring, the full vector writes back.  S is padded to n·⌈E/n⌉
/// elements like the ring all-reduce.
pub fn allgather_ring_time_elems(
    sys: &SystemParams,
    elems: usize,
    n: usize,
    wire_ratio: f64,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let s = elems.div_ceil(n).max(1) as f64 * 4.0 * n as f64;
    let shard = s / n as f64;
    let per_round = shard / wire_ratio / sys.net.effective_bw() + sys.net.hop_latency;
    sys.nic_request_overhead
        + (shard / sys.nic.pcie_bw + sys.nic.pcie_latency)
        + (s / sys.nic.pcie_bw + sys.nic.pcie_latency)
        + (n as f64 - 1.0) * per_round
}

/// Closed form for the ring reduce-scatter on an uncontended flat
/// crossbar: the full (padded) vector comes down over PCIe, n−1 rounds
/// each forward a shard and fold E/n elements at the receiver's adder,
/// and only the owned shard writes back.
pub fn reduce_scatter_ring_time_elems(
    sys: &SystemParams,
    elems: usize,
    n: usize,
    wire_ratio: f64,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let s = elems.div_ceil(n).max(1) as f64 * 4.0 * n as f64;
    let shard = s / n as f64;
    let per_round = shard / wire_ratio / sys.net.effective_bw()
        + sys.net.hop_latency
        + elems as f64 / n as f64 / sys.nic.add_flops;
    sys.nic_request_overhead
        + (s / sys.nic.pcie_bw + sys.nic.pcie_latency)
        + (shard / sys.nic.pcie_bw + sys.nic.pcie_latency)
        + (n as f64 - 1.0) * per_round
}

/// Closed form for the pairwise-exchange all-to-all on an uncontended
/// flat crossbar: full vector down, n−1 rounds each exchanging one S/n
/// block per ordered pair, full (permuted) vector back up.
pub fn alltoall_pairwise_time_elems(
    sys: &SystemParams,
    elems: usize,
    n: usize,
    wire_ratio: f64,
) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let s = elems.div_ceil(n).max(1) as f64 * 4.0 * n as f64;
    let per_round = s / n as f64 / wire_ratio / sys.net.effective_bw() + sys.net.hop_latency;
    sys.nic_request_overhead
        + 2.0 * (s / sys.nic.pcie_bw + sys.nic.pcie_latency)
        + (n as f64 - 1.0) * per_round
}

/// Smart-NIC all-reduce time for one layer (the Sec. IV-C max of three).
pub fn smartnic_ar_time(sys: &SystemParams, w: &Workload, n: usize, bfp: bool) -> f64 {
    smartnic_ar_time_elems(sys, w.grad_elems_per_layer(), n, bfp)
}

/// Compute the per-layer primitive times for a system variant.
pub fn layer_times(kind: SystemKind, sys: &SystemParams, w: &Workload, n: usize) -> LayerTimes {
    let strategy = match kind {
        SystemKind::BaselineNaive { .. } => HostStrategy::Naive,
        SystemKind::BaselineOverlapped { comm_cores, .. } => {
            HostStrategy::Overlapped { comm_cores }
        }
        // smart NIC: the FPGA does the work; all cores compute
        SystemKind::SmartNic { .. } => HostStrategy::Naive,
    };
    let p = sys.worker.flops(strategy.compute_cores(&sys.worker));
    let t_f = w.fwd_flops_per_layer() / p;
    let t_b = w.bwd_flops_per_layer() / p * strategy.bwd_slowdown(&sys.worker);
    let t_ar = match kind {
        SystemKind::SmartNic { bfp } => smartnic_ar_time(sys, w, n, bfp),
        SystemKind::BaselineNaive { scheme } | SystemKind::BaselineOverlapped { scheme, .. } => {
            // the host software stack, not the 100G link, is the real
            // bottleneck: one volunteer thread for naive, k dedicated
            // progress cores for overlapped, with per-node efficiency
            // decay at scale (calibration: DESIGN.md §6)
            let cap = match kind {
                SystemKind::BaselineOverlapped { comm_cores, .. } => {
                    sys.worker.host_comm_bw(Some(comm_cores), n)
                }
                _ => sys.worker.host_comm_bw(None, n),
            };
            let env = HostNet {
                net: sys.net,
                step_overhead: sys.host_step_overhead,
                comm_bw_cap: cap,
            };
            allreduce_time(scheme, n, w.grad_bytes_per_layer(), &env)
        }
    };
    LayerTimes {
        t_f,
        t_b,
        t_ar,
        t_u: t_update_layer(sys, w),
        layers: w.layers,
    }
}

/// Compose per-layer times along the Fig. 3b execution trace.
/// `overlap=false` serializes everything (the naive baseline).
pub fn compose_trace(lt: &LayerTimes, overlap: bool) -> IterationBreakdown {
    let l = lt.layers;
    let (t_f, t_b, t_ar, t_u) = (lt.t_f, lt.t_b, lt.t_ar, lt.t_u);
    let fwd = t_f * l as f64;
    let bwd = t_b * l as f64;
    let upd = t_u * l as f64;
    let ar_raw = t_ar * l as f64;
    let t_total = if !overlap {
        fwd + bwd + ar_raw + upd
    } else if l == 1 {
        fwd + t_b + t_ar + t_u
    } else {
        // Sec. IV-C composition (1-based layer indices; symmetric layers
        // make every T_X_l identical, but keep the structure explicit)
        let mut t = fwd + t_b; // Σ T_F + T_B_L
        t += t_b.max(t_ar); // max(T_B_{L-1}, T_AR_L)
        for _l in 2..l {
            // Σ_{l=2}^{L-1} max(T_U_{l+1} + T_B_{l-1}, T_AR_l)
            t += (t_u + t_b).max(t_ar);
        }
        t += t_u.max(t_ar); // max(T_U_2, T_AR_1)
        t += t_u; // T_U_1
        t
    };
    IterationBreakdown {
        t_fwd: fwd,
        t_bwd: bwd,
        t_exposed_ar: (t_total - fwd - bwd - upd).max(0.0),
        t_update: upd,
        t_total,
        t_ar_raw: ar_raw,
    }
}

/// Full analytical iteration model for a system variant.
pub fn iteration(
    kind: SystemKind,
    sys: &SystemParams,
    w: &Workload,
    n: usize,
) -> IterationBreakdown {
    let lt = layer_times(kind, sys, w, n);
    let overlap = !matches!(kind, SystemKind::BaselineNaive { .. });
    compose_trace(&lt, overlap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysconfig::SystemParams;

    fn paper_workload(b: usize) -> Workload {
        Workload::paper_mlp(b)
    }

    #[test]
    fn naive_serializes_everything() {
        let sys = SystemParams::baseline_100g();
        let w = paper_workload(1792);
        let lt = layer_times(SystemKind::BaselineNaive { scheme: Scheme::Ring }, &sys, &w, 6);
        let bd = compose_trace(&lt, false);
        let sum = bd.t_fwd + bd.t_bwd + bd.t_exposed_ar + bd.t_update;
        assert!((bd.t_total - sum).abs() < 1e-12);
        assert!((bd.t_exposed_ar - lt.t_ar * 20.0).abs() < 1e-9);
    }

    #[test]
    fn fig2a_naive_ar_fraction_near_half() {
        // paper: exposed AR is 51% of naive iteration time at 6 nodes,
        // B=1792.  Accept 40-60% — the shape, not the exact constant.
        let sys = SystemParams::baseline_100g();
        let w = paper_workload(1792);
        let bd = iteration(SystemKind::BaselineNaive { scheme: Scheme::Ring }, &sys, &w, 6);
        let frac = bd.t_exposed_ar / bd.t_total;
        assert!((0.40..=0.60).contains(&frac), "AR fraction {frac:.2}");
    }

    #[test]
    fn fig2a_overlap_hides_most_ar() {
        // paper: overlapped exposed AR is ~50x less; total ~1.85x better
        let sys = SystemParams::baseline_100g();
        let w = paper_workload(1792);
        let naive = iteration(SystemKind::BaselineNaive { scheme: Scheme::Ring }, &sys, &w, 6);
        let over = iteration(
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            &sys,
            &w,
            6,
        );
        // the paper reports ~50x on their testbed; our calibration gives
        // the same qualitative collapse (naive's half-the-bar sliver vs a
        // thin residue), quantitatively >5x
        assert!(
            naive.t_exposed_ar / over.t_exposed_ar.max(1e-9) > 5.0,
            "naive {} over {}",
            naive.t_exposed_ar,
            over.t_exposed_ar
        );
        let speedup = naive.t_total / over.t_total;
        assert!((1.5..=2.2).contains(&speedup), "speedup {speedup:.2}");
    }

    #[test]
    fn smartnic_beats_overlapped_baseline_at_b448() {
        let w = paper_workload(448);
        let base = iteration(
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            &SystemParams::baseline_100g(),
            &w,
            6,
        );
        let nic = iteration(
            SystemKind::SmartNic { bfp: false },
            &SystemParams::smartnic_40g(),
            &w,
            6,
        );
        let bfp = iteration(
            SystemKind::SmartNic { bfp: true },
            &SystemParams::smartnic_40g(),
            &w,
            6,
        );
        assert!(nic.t_total < base.t_total);
        assert!(bfp.t_total < nic.t_total);
        // paper Fig. 4a: ~18% and ~40% total reduction
        let red_nic = 1.0 - nic.t_total / base.t_total;
        let red_bfp = 1.0 - bfp.t_total / base.t_total;
        assert!((0.10..=0.30).contains(&red_nic), "nic reduction {red_nic:.2}");
        assert!((0.30..=0.50).contains(&red_bfp), "bfp reduction {red_bfp:.2}");
    }

    #[test]
    fn large_batch_hides_ar_entirely() {
        // B=1792: smart NIC reaches compute-bound; BFP adds nothing
        let w = paper_workload(1792);
        let sys = SystemParams::smartnic_40g();
        let nic = iteration(SystemKind::SmartNic { bfp: false }, &sys, &w, 6);
        let bfp = iteration(SystemKind::SmartNic { bfp: true }, &sys, &w, 6);
        assert!(nic.t_exposed_ar / nic.t_total < 0.05);
        assert!((nic.t_total - bfp.t_total).abs() / nic.t_total < 0.02);
    }

    #[test]
    fn single_node_has_no_ar() {
        let w = paper_workload(448);
        let bd = iteration(
            SystemKind::SmartNic { bfp: false },
            &SystemParams::smartnic_40g(),
            &w,
            1,
        );
        assert!(bd.t_exposed_ar < 1e-6);
    }

    #[test]
    fn derated_ring_reduces_to_the_paper_form() {
        let sys = SystemParams::smartnic_40g();
        let elems = 2048 * 2048;
        for n in [2usize, 6, 32] {
            let paper = smartnic_ar_time_elems(&sys, elems, n, false);
            let general = nic_ring_ar_time_elems(&sys, elems, n, 1.0, 1.0);
            assert!((paper - general).abs() < 1e-15, "n={n}");
        }
        // the uplink factor scales only the wire term, so a factor-4
        // derate on a wire-bound point costs at most 4x
        let derated = nic_ring_ar_time_elems(&sys, elems, 32, 1.0, 4.0);
        let flat = nic_ring_ar_time_elems(&sys, elems, 32, 1.0, 1.0);
        assert!(derated > flat * 1.5 && derated <= flat * 4.0 + 1e-12);
    }

    #[test]
    fn hierarchical_beats_the_derated_ring_under_tapering() {
        // 4 leaves x 8 ranks at 4:1: the strided ring pays the full 4x
        // wire derate, the hierarchical plan crosses the spine with only
        // the shard traffic
        let sys = SystemParams::smartnic_40g();
        let elems = 2048 * 2048;
        let strided_ring = nic_ring_ar_time_elems(&sys, elems, 32, 1.0, 4.0);
        let hier = hierarchical_ar_time_elems(&sys, elems, 8, 4, 4.0, 1.0);
        assert!(
            hier < strided_ring * 0.8,
            "hierarchical {hier} vs strided ring {strided_ring}"
        );
        // degenerate shapes are free or near-free
        assert_eq!(hierarchical_ar_time_elems(&sys, elems, 1, 1, 1.0, 1.0), 0.0);
        assert!(hierarchical_ar_time_elems(&sys, elems, 2, 1, 1.0, 1.0) > 0.0);
    }

    #[test]
    fn inswitch_closed_form_limits() {
        use crate::sysconfig::SwitchParams;
        let plain = SystemParams::smartnic_40g();
        let elems = 2048 * 2048;
        // no capability: infinite cost (planner falls back to the ring)
        assert!(inswitch_ar_time_elems(&plain, elems, 8, 4, 4.0, 1.0).is_infinite());
        // table too small for one segment: same fallback signal
        let tiny = plain.with_switch_reduction(SwitchParams {
            reduce_flops: 1e12,
            reduce_table_bytes: 1024.0,
        });
        assert!(inswitch_ar_time_elems(&tiny, elems, 8, 4, 4.0, 1.0).is_infinite());
        // infinite-rate engines and an ample table converge to the wire
        // lower bound: one full gradient per Tx link, pipelined
        let ideal = plain.with_switch_reduction(SwitchParams {
            reduce_flops: f64::INFINITY,
            reduce_table_bytes: 1e18,
        });
        let t = inswitch_ar_time_elems(&ideal, elems, 8, 4, 4.0, 1.0);
        let s = elems as f64 * 4.0;
        let wire_bound = s / plain.net.effective_bw();
        assert!(t > wire_bound, "{t} vs {wire_bound}");
        assert!(t < wire_bound * 1.25, "{t} vs {wire_bound}");
        // and it undercuts the 4:1-strided NIC ring by a wide margin
        let ring = nic_ring_ar_time_elems(&plain, elems, 32, 1.0, 4.0);
        assert!(t < ring * 0.5, "in-switch {t} vs strided ring {ring}");
    }

    #[test]
    fn contended_inswitch_form_prices_tenancy_pressure() {
        use crate::sysconfig::SwitchParams;
        let sys = SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: 1e12,
            reduce_table_bytes: 4.0 * 1024.0 * 1024.0,
        });
        let elems = 2048 * 2048;
        let table = sys.switch.reduce_table_bytes;
        // one tenant on the full table at full duty IS the solo form,
        // bit for bit
        let solo = inswitch_ar_time_elems(&sys, elems, 8, 4, 4.0, 1.0);
        let one = inswitch_ar_time_contended(&sys, elems, 8, 4, 4.0, 1.0, 1, table, 1.0);
        assert_eq!(solo.to_bits(), one.to_bits());
        // strictly monotone in tenant count: each extra tenant adds
        // `segs` bottleneck drains
        let two = inswitch_ar_time_contended(&sys, elems, 8, 4, 4.0, 1.0, 2, table, 1.0);
        let four = inswitch_ar_time_contended(&sys, elems, 8, 4, 4.0, 1.0, 4, table, 1.0);
        assert!(solo < two && two < four, "{solo} {two} {four}");
        // PFC derating slows the spanning pipeline; a pause storm stalls it
        let paused = inswitch_ar_time_contended(&sys, elems, 8, 4, 4.0, 1.0, 1, table, 0.5);
        assert!(paused > solo, "{paused} vs {solo}");
        assert!(
            inswitch_ar_time_contended(&sys, elems, 8, 4, 4.0, 1.0, 1, table, 0.0).is_infinite()
        );
        // a granted share below one segment is the per-flow fallback signal
        assert!(
            inswitch_ar_time_contended(&sys, elems, 8, 4, 4.0, 1.0, 1, 1024.0, 1.0).is_infinite()
        );
        // a squeezed (but >= 1 segment) share throttles via fill/window
        let seg = sys.nic.segment_bytes;
        let squeezed = inswitch_ar_time_contended(&sys, elems, 8, 4, 4.0, 1.0, 1, seg, 1.0);
        assert!(squeezed > solo, "{squeezed} vs {solo}");
    }

    #[test]
    fn switch_multicast_closed_form_limits() {
        use crate::sysconfig::SwitchParams;
        let plain = SystemParams::smartnic_40g();
        let elems = 2048 * 2048;
        // no capability / undersized table: infinite (host-tree fallback)
        assert!(switch_multicast_time_elems(&plain, elems, 8, 4, 4.0, 1.0).is_infinite());
        let tiny = plain.with_switch_reduction(SwitchParams {
            reduce_flops: 1e12,
            reduce_table_bytes: 1024.0,
        });
        assert!(switch_multicast_time_elems(&tiny, elems, 8, 4, 4.0, 1.0).is_infinite());
        // with an ample table the pipeline converges to the wire lower
        // bound: one full payload through the root's Tx link
        let ideal = plain.with_switch_reduction(SwitchParams {
            reduce_flops: 1e12,
            reduce_table_bytes: 1e18,
        });
        let t = switch_multicast_time_elems(&ideal, elems, 8, 4, 4.0, 1.0);
        let s = elems as f64 * 4.0;
        let wire_bound = s / plain.net.effective_bw();
        assert!(t > wire_bound, "{t} vs {wire_bound}");
        assert!(t < wire_bound * 1.25, "{t} vs {wire_bound}");
        // replication never folds, so the engine rate cannot matter
        let slow = plain.with_switch_reduction(SwitchParams {
            reduce_flops: 1.0,
            reduce_table_bytes: 1e18,
        });
        assert_eq!(switch_multicast_time_elems(&slow, elems, 8, 4, 4.0, 1.0), t);
        // and it beats the host binomial tree well before N = 32: the
        // tree pays log2(n) serial full-payload hops, the switch one
        assert!(
            t < broadcast_tree_time_elems(&plain, elems, 32, 1.0) / 2.0,
            "multicast {t} vs tree {}",
            broadcast_tree_time_elems(&plain, elems, 32, 1.0)
        );
        // degenerate group is free
        assert_eq!(switch_multicast_time_elems(&ideal, elems, 1, 1, 1.0, 1.0), 0.0);
    }

    #[test]
    fn collective_closed_forms_scale_sanely() {
        let sys = SystemParams::smartnic_40g();
        let elems = 2048 * 2048;
        for n in [2usize, 6, 32, 128] {
            let bc = broadcast_tree_time_elems(&sys, elems, n, 1.0);
            let ag = allgather_ring_time_elems(&sys, elems, n, 1.0);
            let rs = reduce_scatter_ring_time_elems(&sys, elems, n, 1.0);
            let a2a = alltoall_pairwise_time_elems(&sys, elems, n, 1.0);
            for t in [bc, ag, rs, a2a] {
                assert!(t.is_finite() && t > 0.0, "n={n}");
            }
            // ring reduce-scatter = ring allgather + the fold time (the
            // DMA legs mirror each other exactly)
            assert!(rs > ag, "n={n}: rs {rs} vs ag {ag}");
            // allgather/reduce-scatter move (n-1)/n of the payload per
            // rank; the tree broadcast pays log2(n) full payloads
            if n >= 8 {
                assert!(bc > ag, "n={n}: tree {bc} vs ring allgather {ag}");
            }
        }
        // single rank: every collective is a no-op
        for t in [
            broadcast_tree_time_elems(&sys, elems, 1, 1.0),
            allgather_ring_time_elems(&sys, elems, 1, 1.0),
            reduce_scatter_ring_time_elems(&sys, elems, 1, 1.0),
            alltoall_pairwise_time_elems(&sys, elems, 1, 1.0),
        ] {
            assert_eq!(t, 0.0);
        }
    }

    #[test]
    fn throughput_definition() {
        let bd = IterationBreakdown {
            t_fwd: 0.0,
            t_bwd: 0.0,
            t_exposed_ar: 0.0,
            t_update: 0.0,
            t_total: 2.0,
            t_ar_raw: 0.0,
        };
        assert_eq!(bd.throughput(100), 50.0);
    }
}
