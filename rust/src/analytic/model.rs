//! Closed-form iteration-time model — a faithful transcription of the
//! equations in Sec. IV-C:
//!
//!   T_F_l  = 2 M_l² B / P_worker          T_B_l = 4 M_l² B / P_worker
//!   R_l    = b · N · ⌈M_l² / N⌉                       (bits, b = 32)
//!   T_ring = R_l · 2(N−1) / (N · α·BW_eth·β · c)
//!            (α·BW_eth·β = `NetParams::effective_bw`, the same
//!            wire-protocol-derated rate the serialized NIC DES and the
//!            unified fabric give their Tx links; c = BFP compression)
//!   T_add  = R_l · 2(N−1) / (N · P_FPGA · b)
//!   T_mem  = 2 R_l / BW_pcie
//!   T_AR_l = max(T_ring, T_add, T_mem)
//!
//!   T_total = Σ T_F + T_B_L + max(T_B_{L−1}, T_AR_L)
//!           + Σ_{l=2}^{L−1} max(T_U_{l+1} + T_B_{l−1}, T_AR_l)
//!           + max(T_U_2, T_AR_1) + T_U_1
//!
//! The same trace composition covers the baseline systems: for the
//! overlapped host baseline, T_AR comes from the software collective cost
//! model and T_B carries the core-stealing slowdown; for the naive
//! baseline all terms serialize.

use crate::bfp::BfpCodec;
use crate::collective::host::HostStrategy;
use crate::collective::timing::{allreduce_time, HostNet};
use crate::collective::Scheme;
use crate::sysconfig::{SystemParams, Workload};

/// Which system variant the model evaluates (paper Figs. 2a / 4a / 4b).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SystemKind {
    /// conventional NICs, blocking host all-reduce
    BaselineNaive { scheme: Scheme },
    /// conventional NICs, dedicated comm cores overlap AR with backward
    BaselineOverlapped { scheme: Scheme, comm_cores: usize },
    /// FPGA AI smart NIC (optionally with BFP wire compression)
    SmartNic { bfp: bool },
}

impl SystemKind {
    pub fn name(&self) -> String {
        match self {
            SystemKind::BaselineNaive { scheme } => format!("baseline-naive({})", scheme.name()),
            SystemKind::BaselineOverlapped { scheme, comm_cores } => {
                format!("baseline-overlapped({}, k={comm_cores})", scheme.name())
            }
            SystemKind::SmartNic { bfp: false } => "smartnic".to_string(),
            SystemKind::SmartNic { bfp: true } => "smartnic+bfp".to_string(),
        }
    }
}

/// Fig. 2a / 4a style iteration breakdown (all seconds).
#[derive(Clone, Copy, Debug)]
pub struct IterationBreakdown {
    pub t_fwd: f64,
    /// backward-pass compute on the critical path (slowdown included)
    pub t_bwd: f64,
    /// all-reduce time NOT hidden behind compute
    pub t_exposed_ar: f64,
    /// weight-update time on the critical path
    pub t_update: f64,
    pub t_total: f64,
    /// raw all-reduce time per layer (before overlap), for reporting
    pub t_ar_raw: f64,
}

impl IterationBreakdown {
    /// Throughput in training samples/second for a given global batch.
    pub fn throughput(&self, global_batch: usize) -> f64 {
        global_batch as f64 / self.t_total
    }
}

/// Per-layer primitive times for a (system, workload, N) configuration.
#[derive(Clone, Debug)]
pub struct LayerTimes {
    pub t_f: f64,
    pub t_b: f64,
    pub t_ar: f64,
    pub t_u: f64,
    pub layers: usize,
}

/// Weight-update time: touches grad + read/write weights ≈ 3 streams of
/// 4·M² bytes at the worker's update memory bandwidth (the paper measures
/// T_U and scales it linearly in layer size).
fn t_update_layer(sys: &SystemParams, w: &Workload) -> f64 {
    3.0 * w.grad_bytes_per_layer() / sys.worker.update_membw
}

/// Sec. IV-C T_AR for a raw element count (not tied to a square layer) —
/// the single copy of the formula, shared with `analytic::validate`.
pub fn smartnic_ar_time_elems(sys: &SystemParams, elems: usize, n: usize, bfp: bool) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    let b_bits = 32.0;
    let r_bits = b_bits * nf * (elems as f64 / nf).ceil();
    let compression = if bfp {
        BfpCodec::bfp16().compression_ratio()
    } else {
        1.0
    };
    // α·BW_eth·β via NetParams::effective_bw — the same wire-protocol
    // efficiency the event fabrics apply to their Tx links, so the closed
    // form and both simulators price the wire identically
    let t_ring = r_bits * 2.0 * (nf - 1.0) / (nf * sys.net.effective_bw() * 8.0 * compression);
    let t_add = r_bits * 2.0 * (nf - 1.0) / (nf * sys.nic.add_flops * b_bits);
    // Sec. IV-C's T_mem = 2R/BW_pcie.  The DES shows the dependency
    // structure precisely: the full R must come down before the last
    // reduce completes, and only the first R/N of the writeback overlaps
    // that fetch tail — so T_mem = R(2N−1)/(N·BW_pcie), which converges
    // to the paper's 2R/BW_pcie as N grows.
    let t_mem = r_bits * (2.0 * nf - 1.0) / (nf * sys.nic.pcie_bw * 8.0);
    t_ring.max(t_add).max(t_mem) + sys.nic_request_overhead
}

/// Smart-NIC all-reduce time for one layer (the Sec. IV-C max of three).
pub fn smartnic_ar_time(sys: &SystemParams, w: &Workload, n: usize, bfp: bool) -> f64 {
    smartnic_ar_time_elems(sys, w.grad_elems_per_layer(), n, bfp)
}

/// Compute the per-layer primitive times for a system variant.
pub fn layer_times(kind: SystemKind, sys: &SystemParams, w: &Workload, n: usize) -> LayerTimes {
    let strategy = match kind {
        SystemKind::BaselineNaive { .. } => HostStrategy::Naive,
        SystemKind::BaselineOverlapped { comm_cores, .. } => {
            HostStrategy::Overlapped { comm_cores }
        }
        // smart NIC: the FPGA does the work; all cores compute
        SystemKind::SmartNic { .. } => HostStrategy::Naive,
    };
    let p = sys.worker.flops(strategy.compute_cores(&sys.worker));
    let t_f = w.fwd_flops_per_layer() / p;
    let t_b = w.bwd_flops_per_layer() / p * strategy.bwd_slowdown(&sys.worker);
    let t_ar = match kind {
        SystemKind::SmartNic { bfp } => smartnic_ar_time(sys, w, n, bfp),
        SystemKind::BaselineNaive { scheme } | SystemKind::BaselineOverlapped { scheme, .. } => {
            // the host software stack, not the 100G link, is the real
            // bottleneck: one volunteer thread for naive, k dedicated
            // progress cores for overlapped, with per-node efficiency
            // decay at scale (calibration: DESIGN.md §6)
            let cap = match kind {
                SystemKind::BaselineOverlapped { comm_cores, .. } => {
                    sys.worker.host_comm_bw(Some(comm_cores), n)
                }
                _ => sys.worker.host_comm_bw(None, n),
            };
            let env = HostNet {
                net: sys.net,
                step_overhead: sys.host_step_overhead,
                comm_bw_cap: cap,
            };
            allreduce_time(scheme, n, w.grad_bytes_per_layer(), &env)
        }
    };
    LayerTimes {
        t_f,
        t_b,
        t_ar,
        t_u: t_update_layer(sys, w),
        layers: w.layers,
    }
}

/// Compose per-layer times along the Fig. 3b execution trace.
/// `overlap=false` serializes everything (the naive baseline).
pub fn compose_trace(lt: &LayerTimes, overlap: bool) -> IterationBreakdown {
    let l = lt.layers;
    let (t_f, t_b, t_ar, t_u) = (lt.t_f, lt.t_b, lt.t_ar, lt.t_u);
    let fwd = t_f * l as f64;
    let bwd = t_b * l as f64;
    let upd = t_u * l as f64;
    let ar_raw = t_ar * l as f64;
    let t_total = if !overlap {
        fwd + bwd + ar_raw + upd
    } else if l == 1 {
        fwd + t_b + t_ar + t_u
    } else {
        // Sec. IV-C composition (1-based layer indices; symmetric layers
        // make every T_X_l identical, but keep the structure explicit)
        let mut t = fwd + t_b; // Σ T_F + T_B_L
        t += t_b.max(t_ar); // max(T_B_{L-1}, T_AR_L)
        for _l in 2..l {
            // Σ_{l=2}^{L-1} max(T_U_{l+1} + T_B_{l-1}, T_AR_l)
            t += (t_u + t_b).max(t_ar);
        }
        t += t_u.max(t_ar); // max(T_U_2, T_AR_1)
        t += t_u; // T_U_1
        t
    };
    IterationBreakdown {
        t_fwd: fwd,
        t_bwd: bwd,
        t_exposed_ar: (t_total - fwd - bwd - upd).max(0.0),
        t_update: upd,
        t_total,
        t_ar_raw: ar_raw,
    }
}

/// Full analytical iteration model for a system variant.
pub fn iteration(
    kind: SystemKind,
    sys: &SystemParams,
    w: &Workload,
    n: usize,
) -> IterationBreakdown {
    let lt = layer_times(kind, sys, w, n);
    let overlap = !matches!(kind, SystemKind::BaselineNaive { .. });
    compose_trace(&lt, overlap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysconfig::SystemParams;

    fn paper_workload(b: usize) -> Workload {
        Workload::paper_mlp(b)
    }

    #[test]
    fn naive_serializes_everything() {
        let sys = SystemParams::baseline_100g();
        let w = paper_workload(1792);
        let lt = layer_times(SystemKind::BaselineNaive { scheme: Scheme::Ring }, &sys, &w, 6);
        let bd = compose_trace(&lt, false);
        let sum = bd.t_fwd + bd.t_bwd + bd.t_exposed_ar + bd.t_update;
        assert!((bd.t_total - sum).abs() < 1e-12);
        assert!((bd.t_exposed_ar - lt.t_ar * 20.0).abs() < 1e-9);
    }

    #[test]
    fn fig2a_naive_ar_fraction_near_half() {
        // paper: exposed AR is 51% of naive iteration time at 6 nodes,
        // B=1792.  Accept 40-60% — the shape, not the exact constant.
        let sys = SystemParams::baseline_100g();
        let w = paper_workload(1792);
        let bd = iteration(SystemKind::BaselineNaive { scheme: Scheme::Ring }, &sys, &w, 6);
        let frac = bd.t_exposed_ar / bd.t_total;
        assert!((0.40..=0.60).contains(&frac), "AR fraction {frac:.2}");
    }

    #[test]
    fn fig2a_overlap_hides_most_ar() {
        // paper: overlapped exposed AR is ~50x less; total ~1.85x better
        let sys = SystemParams::baseline_100g();
        let w = paper_workload(1792);
        let naive = iteration(SystemKind::BaselineNaive { scheme: Scheme::Ring }, &sys, &w, 6);
        let over = iteration(
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            &sys,
            &w,
            6,
        );
        // the paper reports ~50x on their testbed; our calibration gives
        // the same qualitative collapse (naive's half-the-bar sliver vs a
        // thin residue), quantitatively >5x
        assert!(
            naive.t_exposed_ar / over.t_exposed_ar.max(1e-9) > 5.0,
            "naive {} over {}",
            naive.t_exposed_ar,
            over.t_exposed_ar
        );
        let speedup = naive.t_total / over.t_total;
        assert!((1.5..=2.2).contains(&speedup), "speedup {speedup:.2}");
    }

    #[test]
    fn smartnic_beats_overlapped_baseline_at_b448() {
        let w = paper_workload(448);
        let base = iteration(
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            &SystemParams::baseline_100g(),
            &w,
            6,
        );
        let nic = iteration(
            SystemKind::SmartNic { bfp: false },
            &SystemParams::smartnic_40g(),
            &w,
            6,
        );
        let bfp = iteration(
            SystemKind::SmartNic { bfp: true },
            &SystemParams::smartnic_40g(),
            &w,
            6,
        );
        assert!(nic.t_total < base.t_total);
        assert!(bfp.t_total < nic.t_total);
        // paper Fig. 4a: ~18% and ~40% total reduction
        let red_nic = 1.0 - nic.t_total / base.t_total;
        let red_bfp = 1.0 - bfp.t_total / base.t_total;
        assert!((0.10..=0.30).contains(&red_nic), "nic reduction {red_nic:.2}");
        assert!((0.30..=0.50).contains(&red_bfp), "bfp reduction {red_bfp:.2}");
    }

    #[test]
    fn large_batch_hides_ar_entirely() {
        // B=1792: smart NIC reaches compute-bound; BFP adds nothing
        let w = paper_workload(1792);
        let sys = SystemParams::smartnic_40g();
        let nic = iteration(SystemKind::SmartNic { bfp: false }, &sys, &w, 6);
        let bfp = iteration(SystemKind::SmartNic { bfp: true }, &sys, &w, 6);
        assert!(nic.t_exposed_ar / nic.t_total < 0.05);
        assert!((nic.t_total - bfp.t_total).abs() / nic.t_total < 0.02);
    }

    #[test]
    fn single_node_has_no_ar() {
        let w = paper_workload(448);
        let bd = iteration(
            SystemKind::SmartNic { bfp: false },
            &SystemParams::smartnic_40g(),
            &w,
            1,
        );
        assert!(bd.t_exposed_ar < 1e-6);
    }

    #[test]
    fn throughput_definition() {
        let bd = IterationBreakdown {
            t_fwd: 0.0,
            t_bwd: 0.0,
            t_exposed_ar: 0.0,
            t_update: 0.0,
            t_total: 2.0,
            t_ar_raw: 0.0,
        };
        assert_eq!(bd.throughput(100), 50.0);
    }
}
