//! Validation of the closed-form model against the discrete-event
//! simulator (paper: "our analytical model can estimate system performance
//! within 3% of the real measurements").  Here the DES plays the role of
//! the prototype measurements; experiment E6 sweeps configurations and
//! reports the error distribution.

use crate::bfp::BfpCodec;
use crate::nic::{simulate_ring_allreduce, NicConfig};
use crate::sysconfig::SystemParams;
use crate::util::stats::rel_err;

pub use crate::analytic::model::smartnic_ar_time_elems;

/// One validation point: analytic vs simulated all-reduce time.
#[derive(Clone, Copy, Debug)]
pub struct ArValidation {
    pub nodes: usize,
    pub elems: usize,
    pub bfp: bool,
    pub t_analytic: f64,
    pub t_sim: f64,
    pub rel_err: f64,
}

/// Compare Sec. IV-C's T_AR against the chunk-level DES for one point.
pub fn validate_ar(sys: &SystemParams, nodes: usize, elems: usize, bfp: bool) -> ArValidation {
    let t_analytic = smartnic_ar_time_elems(sys, elems, nodes, bfp);
    let cfg = NicConfig::new(*sys, if bfp { Some(BfpCodec::bfp16()) } else { None });
    let t_sim = simulate_ring_allreduce(&cfg, nodes, elems).t_total;
    ArValidation {
        nodes,
        elems,
        bfp,
        t_analytic,
        t_sim,
        rel_err: rel_err(t_analytic, t_sim),
    }
}

/// Sweep a grid and return all validation points.
pub fn sweep(sys: &SystemParams, nodes: &[usize], elems: &[usize]) -> Vec<ArValidation> {
    let mut out = Vec::new();
    for &n in nodes {
        for &e in elems {
            for bfp in [false, true] {
                out.push(validate_ar(sys, n, e, bfp));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_ar_within_3pct() {
        // the paper's layer: 2048x2048 f32 = 16 MiB, 3..6 nodes
        let sys = SystemParams::smartnic_40g();
        for n in [3usize, 4, 5, 6] {
            for bfp in [false, true] {
                let v = validate_ar(&sys, n, 2048 * 2048, bfp);
                assert!(
                    v.rel_err < 0.03,
                    "n={n} bfp={bfp}: analytic {} sim {} err {:.1}%",
                    v.t_analytic,
                    v.t_sim,
                    v.rel_err * 100.0
                );
            }
        }
    }

    #[test]
    fn larger_systems_stay_close() {
        let sys = SystemParams::smartnic_40g();
        for n in [8usize, 16, 32] {
            let v = validate_ar(&sys, n, 2048 * 2048, true);
            assert!(v.rel_err < 0.05, "n={n}: err {:.1}%", v.rel_err * 100.0);
        }
    }

    #[test]
    fn small_tensors_diverge_gracefully() {
        // latency-dominated regime: the bandwidth-only closed form
        // underestimates; we only require the sim to be the larger one
        let sys = SystemParams::smartnic_40g();
        let v = validate_ar(&sys, 6, 1024, false);
        assert!(v.t_sim >= v.t_analytic * 0.5);
    }
}
