//! Tiny declarative CLI parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list -> Vec<T>.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str) -> Option<Vec<T>> {
        self.get(key).map(|v| {
            v.split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect()
        })
    }
}

pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            args: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for a in &self.args {
            let lhs = if a.is_flag {
                format!("  --{}", a.name)
            } else {
                format!("  --{} <v>", a.name)
            };
            let def = a
                .default
                .filter(|d| !d.is_empty())
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{lhs:26} {}{def}\n", a.help));
        }
        s
    }

    /// Parse raw argv (already stripped of binary + subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        // seed defaults
        for a in &self.args {
            if let Some(d) = a.default {
                if !d.is_empty() {
                    out.values.insert(a.name.to_string(), d.to_string());
                }
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    out.flags.push(key.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    out.values.insert(key.to_string(), val);
                }
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .opt("nodes", "6", "number of nodes")
            .opt("batch", "448", "mini-batch size")
            .flag("verbose", "chatty output")
    }

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("nodes", 0), 6);
        assert_eq!(a.get_usize("batch", 0), 448);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let a = cmd()
            .parse(&argv(&["--nodes", "32", "--verbose", "--batch=1792"]))
            .unwrap();
        assert_eq!(a.get_usize("nodes", 0), 32);
        assert_eq!(a.get_usize("batch", 0), 1792);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&argv(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&argv(&["--nodes"])).is_err());
    }

    #[test]
    fn positional_passthrough() {
        let a = cmd().parse(&argv(&["foo", "--nodes", "2", "bar"])).unwrap();
        assert_eq!(a.positional, vec!["foo", "bar"]);
    }

    #[test]
    fn list_parsing() {
        let c = Command::new("t", "t").opt("sizes", "", "sizes");
        let a = c.parse(&argv(&["--sizes", "1,2,3"])).unwrap();
        assert_eq!(a.get_list::<usize>("sizes").unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--nodes"));
    }
}
