//! Leveled stderr logger with a global verbosity switch (the `log` crate
//! facade without the crate).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Program start, for relative timestamps.
fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn set_level(level: Level) {
    start();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{:>9.3}s {}] {}", start().elapsed().as_secs_f64(), tag, args);
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn set_and_query() {
        let old = level();
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Error);
        assert!(!enabled(Level::Info));
        set_level(old);
    }
}
