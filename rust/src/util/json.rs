//! Minimal JSON codec (parser + writer) — serde is unavailable offline.
//!
//! Supports the full JSON grammar we produce/consume: the AOT
//! `artifacts/manifest.json`, the golden BFP vectors, and experiment result
//! files.  Numbers are kept as f64 (with i64 fast-path accessors), which is
//! lossless for every value we exchange (u32 bit patterns fit in f64's 53-bit
//! mantissa).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as u64) } else { None })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<T> via a conversion closure.
    pub fn num_vec<T>(&self, f: impl Fn(f64) -> T) -> Option<Vec<T>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(&f).collect())
    }

    // -- construction helpers ---------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // -- writer -------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no inf/nan
    } else if n == n.trunc() && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our files)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 run
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_i64(), Some(2));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"x":true,"y":"z \" esc"},"s":"hi"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn u32_bit_patterns_are_lossless() {
        for bits in [0u32, 1, 0x7F80_0000, 0xFFFF_FFFF, 0x3F80_0001] {
            let j = Json::Num(bits as f64);
            let back = Json::parse(&j.to_string()).unwrap().as_u64().unwrap();
            assert_eq!(back as u32, bits);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }

    #[test]
    fn num_vec_helper() {
        let j = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(j.num_vec(|x| x as u32).unwrap(), vec![1, 2, 3]);
    }
}
