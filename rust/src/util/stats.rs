//! Small statistics toolkit for benchmarks and experiment analysis:
//! summary statistics, percentiles, linear regression, and relative-error
//! helpers used by the analytic-model validation.

/// Summary of a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize: empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median: percentile_sorted(&sorted, 50.0),
        p95: percentile_sorted(&sorted, 95.0),
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    percentile_sorted(&sorted, p)
}

/// |a - b| / |b|, guarding b == 0.
pub fn rel_err(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        if a == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (a - b).abs() / b.abs()
    }
}

/// Maximum relative error across paired samples.
pub fn max_rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| rel_err(x, y))
        .fold(0.0, f64::max)
}

/// Ordinary least squares y = slope*x + intercept; returns (slope,
/// intercept, r2).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (slope, intercept, r2)
}

/// Geometric mean (used for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
// exact float equalities are deliberate: the tests pin exact results of
// pure arithmetic
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn rel_err_cases() {
        assert!((rel_err(1.03, 1.0) - 0.03).abs() < 1e-12);
        assert_eq!(rel_err(0.0, 0.0), 0.0);
        assert!(rel_err(1.0, 0.0).is_infinite());
    }

    #[test]
    fn linreg_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (m, b, r2) = linreg(&xs, &ys);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn max_rel_err_picks_worst() {
        let a = [1.0, 2.2];
        let b = [1.0, 2.0];
        assert!((max_rel_err(&a, &b) - 0.1).abs() < 1e-9);
    }
}
