//! Deterministic pseudo-random number generation (SplitMix64 seeding +
//! xoshiro256** core), plus normal/uniform helpers.  All experiment and
//! training randomness flows through this module so every run is exactly
//! reproducible from a `u64` seed.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the Box-Muller pair
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (e.g. one per worker) from this RNG.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough for
    /// simulation workloads; exact rejection for small n).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.next_f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.next_f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Standard-normal f32 vector (He-style init, synthetic data, ...).
    pub fn normal_vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
