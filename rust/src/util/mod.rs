//! Foundation substrates built in-tree because the offline environment
//! provides no clap/serde/rand/criterion/proptest: a CLI parser, a JSON
//! codec, deterministic RNGs, statistics, ASCII tables, a logger and
//! unit-formatting helpers.

pub mod cli;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
