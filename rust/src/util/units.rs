//! Unit helpers: bandwidth (Gbps <-> bytes/s), byte and time formatting.
//! The paper speaks in Gbps (network), GB/s (PCIe) and GFLOPS (compute);
//! all simulator-internal quantities are SI (bytes, seconds, FLOP/s).

/// Gigabits-per-second to bytes-per-second.
pub const fn gbps(g: f64) -> f64 {
    g * 1e9 / 8.0
}

/// Gigabytes-per-second to bytes-per-second.
pub const fn gbytes_per_s(g: f64) -> f64 {
    g * 1e9
}

/// GFLOPS to FLOP/s.
pub const fn gflops(g: f64) -> f64 {
    g * 1e9
}

/// Microseconds to seconds.
pub const fn us(x: f64) -> f64 {
    x * 1e-6
}

/// Human-readable seconds (ns/us/ms/s).
pub fn fmt_time(secs: f64) -> String {
    let a = secs.abs();
    if a >= 1.0 {
        format!("{secs:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

/// Human-readable rate.
pub fn fmt_rate(bytes_per_s: f64) -> String {
    format!("{}/s", fmt_bytes(bytes_per_s))
}

#[cfg(test)]
// exact float equalities are deliberate: unit conversions are exact
// power-of-ten scalings
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(gbps(40.0), 5e9);
        assert_eq!(gbps(100.0), 12.5e9);
        assert_eq!(gbytes_per_s(7.88), 7.88e9);
        assert_eq!(gflops(2.0), 2e9);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_time(1.5), "1.500 s");
        assert_eq!(fmt_time(0.0023), "2.300 ms");
        assert_eq!(fmt_time(4.5e-6), "4.500 us");
        assert_eq!(fmt_bytes(2.5e6), "2.50 MB");
        assert_eq!(fmt_bytes(12.0), "12 B");
    }
}
