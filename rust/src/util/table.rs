//! ASCII table rendering for experiment output — every `smartnic fig*` and
//! `table1` subcommand prints its paper-figure rows through this.

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width must match header"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<w$} ", cells[i], w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `d` decimals.
pub fn fnum(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["short", "1"]);
        t.row_strs(&["a-much-longer-name", "23456"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(lines[3].starts_with(" a-much-longer-name"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn title_included() {
        let mut t = Table::new(&["x"]).with_title("Table I");
        t.row_strs(&["1"]);
        assert!(t.render().starts_with("Table I\n"));
    }
}
