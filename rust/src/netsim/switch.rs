//! Switched-fabric model (paper Sec. V-A: a Dell EMC S6100-ON connects all
//! NICs; the ring is a logical overlay).  Models per-egress-port
//! contention: flows to the same destination serialize on that
//! destination's egress port, flows to distinct destinations don't
//! interact — exactly the property that makes the ring all-reduce
//! "contention-free" (Sec. II-B), which the tests verify.

use super::link::Server;
use super::Time;

/// A non-blocking crossbar switch with per-egress-port serialization and
/// an optional per-egress-port reduction capability (NetReduce,
/// arXiv:2009.09736): each port can own an aggregation engine that folds
/// arriving f32 streams into an on-chip table before forwarding the
/// reduced stream out of the port.
#[derive(Clone, Debug)]
pub struct Switch {
    egress: Vec<Server>,
    /// per-egress-port aggregation engines; empty on a plain forwarding
    /// switch (the seed behavior)
    reducers: Vec<Server>,
    /// per-engine occupancy servers (one per port, port line rate): after
    /// a fold completes, the engine streams the reduced segment out of
    /// its egress and is *occupied* for that drain — two tenants folding
    /// through one root egress genuinely serialize here, not just on the
    /// fold arithmetic.  Empty without reduction capability.
    occupancy: Vec<Server>,
    /// per-port aggregation table capacity (bytes of f32 accumulators)
    table_bytes: f64,
    /// port-to-port forwarding latency
    pub latency: Time,
}

impl Switch {
    pub fn new(ports: usize, port_bw_bytes_per_s: f64, latency: Time) -> Self {
        Self::new_scaled(ports, port_bw_bytes_per_s, latency, |_| 1.0)
    }

    /// A switch whose egress port `p` runs at `port_bw * scale_of(p)` —
    /// the fault-injection hook that makes a degraded physical link slow
    /// traffic *toward* its node, not just away from it.
    pub fn new_scaled(
        ports: usize,
        port_bw_bytes_per_s: f64,
        latency: Time,
        scale_of: impl Fn(usize) -> f64,
    ) -> Self {
        Self {
            egress: (0..ports)
                .map(|p| Server::new(port_bw_bytes_per_s * scale_of(p)))
                .collect(),
            reducers: Vec::new(),
            occupancy: Vec::new(),
            table_bytes: 0.0,
            latency,
        }
    }

    /// Attach an aggregation engine of `reduce_flops` f32 adds/s and a
    /// `table_bytes` accumulation table to every egress port.  Zero for
    /// either leaves the switch a plain forwarding fabric.
    #[must_use]
    pub fn with_reduction(mut self, reduce_flops: f64, table_bytes: f64) -> Self {
        if reduce_flops > 0.0 && table_bytes > 0.0 {
            self.reducers = (0..self.egress.len()).map(|_| Server::new(reduce_flops)).collect();
            // one occupancy server per engine at its port's line rate
            self.occupancy = self.egress.iter().map(|e| Server::new(e.rate)).collect();
            self.table_bytes = table_bytes;
        }
        self
    }

    /// Can this switch reduce in-network?
    #[must_use]
    pub fn reduce_capable(&self) -> bool {
        !self.reducers.is_empty()
    }

    /// Aggregation table capacity per port (bytes; 0 when not capable).
    #[must_use]
    pub fn table_bytes(&self) -> f64 {
        self.table_bytes
    }

    /// Fold one contribution of `elems` f32 values into `port`'s
    /// aggregation engine; returns the time the contribution is folded
    /// into the table.  Every contribution — the table write-in included —
    /// costs `elems` adds of engine bandwidth, FIFO with everything else
    /// the engine is folding.
    #[must_use]
    pub fn reduce_contribution(&mut self, port: usize, arrival: Time, elems: f64) -> Time {
        assert!(self.reduce_capable(), "switch has no reduction capability");
        self.reducers[port].serve(arrival, elems)
    }

    /// Occupy `port`'s aggregation engine for the drain of a reduced
    /// segment of `wire_bytes` starting no earlier than `ready`; returns
    /// the time the engine is free again (= the earliest the segment's
    /// multicast/downlink can start).  FIFO across tenants: two jobs
    /// folding through one root egress serialize here.
    #[must_use]
    pub fn engine_occupancy(&mut self, port: usize, ready: Time, wire_bytes: f64) -> Time {
        assert!(self.reduce_capable(), "switch has no reduction capability");
        self.occupancy[port].serve(ready, wire_bytes)
    }

    pub fn ports(&self) -> usize {
        self.egress.len()
    }

    /// Configured bandwidth of one egress port (bytes/s, fault scaling
    /// included).
    #[must_use]
    pub fn port_rate(&self, port: usize) -> f64 {
        self.egress[port].rate
    }

    /// Forward `bytes` arriving at the switch at `arrival` toward
    /// `dst_port`; returns delivery time at the destination NIC
    /// (store-and-forward: full egress serialization + latency).
    #[must_use]
    pub fn forward(&mut self, dst_port: usize, arrival: Time, bytes: f64) -> Time {
        self.egress[dst_port].serve(arrival, bytes) + self.latency
    }

    /// Cut-through forwarding: the egress port's capacity is reserved FIFO
    /// (so concurrent flows to the same destination queue-delay each
    /// other), but an uncontended transfer — whose egress streaming
    /// overlapped its ingress arrival — is delivered after just the
    /// port-to-port latency.  This is the fabric model of the unified
    /// cluster engine: the sender's Tx link pays serialization once, and
    /// the switch adds only latency plus contention.
    #[must_use]
    pub fn forward_cut_through(&mut self, dst_port: usize, arrival: Time, bytes: f64) -> Time {
        self.egress[dst_port].reserve(arrival, bytes) + self.latency
    }

    /// Utilization of one egress port over [0, horizon] (guarded against a
    /// zero horizon by [`Server::utilization`]).
    #[must_use]
    pub fn port_utilization(&self, port: usize, horizon: Time) -> f64 {
        self.egress[port].utilization(horizon)
    }

    /// Total f32 elements folded by this switch's aggregation engines
    /// (0 on a plain forwarding switch) — the observed side of the
    /// conservation auditor's exactly-once ledger.
    #[must_use]
    pub fn engines_served(&self) -> f64 {
        self.reducers.iter().map(Server::served).sum()
    }

    /// Every FIFO server in the switch (egress ports, then aggregation
    /// engines, then engine-occupancy servers) — enumerated by the
    /// quiescence audit's leaked-reservation scan.
    pub fn servers(&self) -> impl Iterator<Item = &Server> + '_ {
        self.egress.iter().chain(self.reducers.iter()).chain(self.occupancy.iter())
    }

    pub fn reset(&mut self) {
        for p in &mut self.egress {
            p.reset();
        }
        for r in &mut self.reducers {
            r.reset();
        }
        for o in &mut self.occupancy {
            o.reset();
        }
    }
}

/// One job's reservation in a finite aggregation table.
///
/// Reservations are per *job*, not per flow: concurrent layer collectives
/// of one job share the job's slot (the realistic model — they share the
/// switch's aggregation context — and the one that keeps a solo multi-layer
/// job's timing identical to the unlimited-table seed behavior).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TableReservation {
    pub job: u32,
    /// byte offset of the slot inside the table
    pub offset: f64,
    /// granted bytes
    pub len: f64,
    /// flows of this job currently folding through the slot; 0 ⇒ idle
    /// (sticky: the slot stays warm until evicted by a competing tenant)
    pub active_flows: u32,
    /// LRU stamp — bumped when the slot goes idle; the lowest idle stamp
    /// is evicted first
    pub idle_seq: u64,
}

/// Finite aggregation-table allocator (NetReduce-style table *pressure*,
/// arXiv:2009.09736 Sec. 4): tenants request table bytes per flow,
/// admission grants what fits (after evicting LRU idle slots of other
/// jobs), and a tenant whose request can't fit even one segment is denied
/// — that flow falls back to its host/NIC plan, per-flow, not per-switch.
///
/// Deterministic by construction: slots live in a `Vec` in insertion
/// order, placement is first-fit with compaction fallback, eviction is
/// strictly by `idle_seq`.  No wall-clock, no hashing.
#[derive(Clone, Debug, Default)]
pub struct TableAllocator {
    capacity: f64,
    slots: Vec<TableReservation>,
    next_seq: u64,
    evictions: u64,
    /// jobs owing an eviction: their *next* denied request reports
    /// `Evicted` (they lost a warm slot) rather than plain `Fallback`
    evicted_jobs: Vec<u32>,
}

impl TableAllocator {
    #[must_use]
    pub fn new(capacity: f64) -> Self {
        Self { capacity, ..Self::default() }
    }

    #[must_use]
    pub fn capacity(&self) -> f64 {
        self.capacity
    }

    /// Unreserved bytes.
    #[must_use]
    pub fn free_bytes(&self) -> f64 {
        self.capacity - self.slots.iter().map(|s| s.len).sum::<f64>()
    }

    /// Bytes `job` could obtain right now: its own slot if it holds one,
    /// else free bytes plus every *other* job's idle (evictable) bytes.
    #[must_use]
    pub fn available_to(&self, job: u32) -> f64 {
        if let Some(s) = self.slots.iter().find(|s| s.job == job) {
            return s.len;
        }
        self.free_bytes()
            + self
                .slots
                .iter()
                .filter(|s| s.job != job && s.active_flows == 0)
                .map(|s| s.len)
                .sum::<f64>()
    }

    /// Total evictions performed since construction.
    #[must_use]
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Active tenants: jobs currently holding a slot.
    #[must_use]
    pub fn tenants(&self) -> usize {
        self.slots.len()
    }

    /// Current reservations (audit / test visibility).
    #[must_use]
    pub fn slots(&self) -> &[TableReservation] {
        &self.slots
    }

    /// Request up to `want` bytes for a flow of `job`, in multiples of
    /// `unit` (one segment).  Returns granted bytes, 0.0 = denied.
    ///
    /// - A job already holding a slot shares it (refcount++) — same-job
    ///   flows never contend with each other for the table.
    /// - Otherwise LRU *idle* slots of other jobs are evicted until the
    ///   request fits or nothing evictable remains; the grant is
    ///   `min(want, free)` floored to a `unit` multiple, denied if < unit.
    pub fn request(&mut self, job: u32, want: f64, unit: f64) -> f64 {
        assert!(want > 0.0 && unit > 0.0 && want >= unit, "malformed table request");
        if let Some(s) = self.slots.iter_mut().find(|s| s.job == job) {
            s.active_flows += 1;
            return s.len;
        }
        while self.free_bytes() < want {
            let victim = self
                .slots
                .iter()
                .enumerate()
                .filter(|(_, s)| s.active_flows == 0)
                .min_by_key(|(_, s)| s.idle_seq)
                .map(|(i, _)| i);
            let Some(i) = victim else { break };
            let evicted = self.slots.remove(i);
            self.evictions += 1;
            if !self.evicted_jobs.contains(&evicted.job) {
                self.evicted_jobs.push(evicted.job);
            }
        }
        let grant = (want.min(self.free_bytes()) / unit).floor() * unit;
        if grant < unit {
            return 0.0;
        }
        let offset = self.place(grant);
        self.slots.push(TableReservation {
            job,
            offset,
            len: grant,
            active_flows: 1,
            idle_seq: 0,
        });
        grant
    }

    /// First-fit offset for `len` bytes among current slots; falls back to
    /// deterministic compaction (slots keep their order, packed from 0).
    fn place(&mut self, len: f64) -> f64 {
        let mut by_offset: Vec<&TableReservation> = self.slots.iter().collect();
        by_offset.sort_by(|a, b| a.offset.total_cmp(&b.offset));
        let mut cursor = 0.0;
        for s in &by_offset {
            if s.offset - cursor >= len {
                return cursor;
            }
            cursor = s.offset + s.len;
        }
        if self.capacity - cursor >= len {
            return cursor;
        }
        // fragmented: compact in place (pure bookkeeping — offsets only
        // matter to the overcommit audit, not to timing)
        let mut packed = 0.0;
        let order: Vec<u32> = by_offset.iter().map(|s| s.job).collect();
        for job in order {
            let s = self.slots.iter_mut().find(|s| s.job == job).unwrap();
            s.offset = packed;
            packed += s.len;
        }
        packed
    }

    /// A flow of `job` finished with the table.  The slot refcount drops;
    /// at zero it goes idle (sticky — evictable but warm for the job's
    /// next flow).
    pub fn release(&mut self, job: u32) {
        if let Some(s) = self.slots.iter_mut().find(|s| s.job == job) {
            assert!(s.active_flows > 0, "table release without a matching request");
            s.active_flows -= 1;
            if s.active_flows == 0 {
                self.next_seq += 1;
                s.idle_seq = self.next_seq;
            }
        }
    }

    /// Consume `job`'s eviction debt: true exactly once after the job's
    /// warm slot was evicted by a competing tenant — the denial it next
    /// suffers is classified `Evicted`, not plain `Fallback`.
    pub fn take_eviction_debt(&mut self, job: u32) -> bool {
        if let Some(i) = self.evicted_jobs.iter().position(|&j| j == job) {
            self.evicted_jobs.remove(i);
            true
        } else {
            false
        }
    }

    /// Forge a raw reservation (test hook for the overcommit audit —
    /// bypasses placement and capacity checks entirely).
    pub fn force_reservation(&mut self, r: TableReservation) {
        self.slots.push(r);
    }

    pub fn reset(&mut self) {
        self.slots.clear();
        self.next_seq = 0;
        self.evictions = 0;
        self.evicted_jobs.clear();
    }
}

#[cfg(test)]
// exact float equalities are deliberate here: the switch model is pure
// arithmetic and the tests pin bit-exact results
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;
    use crate::netsim::topology::Ring;

    const BW: f64 = 5e9; // 40 GbE
    const MB: f64 = 1e6;

    #[test]
    fn distinct_destinations_do_not_contend() {
        let mut sw = Switch::new(6, BW, 1e-6);
        // 6 flows, all to different ports, all at t=0
        let done: Vec<f64> = (0..6).map(|p| sw.forward(p, 0.0, MB)).collect();
        let expect = MB / BW + 1e-6;
        for d in done {
            assert!((d - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn incast_serializes_on_the_egress_port() {
        let mut sw = Switch::new(6, BW, 0.0);
        // 5 flows all to port 0 (all-to-one): last finishes 5x later
        let done: Vec<f64> = (0..5).map(|_| sw.forward(0, 0.0, MB)).collect();
        assert!((done[4] - 5.0 * MB / BW).abs() < 1e-12);
    }

    #[test]
    fn ring_allreduce_schedule_is_contention_free() {
        // the paper's Sec. II-B claim, end to end: replay every step of
        // the pipelined ring schedule through the switch; each transfer
        // must complete in exactly serialization + latency (no queueing)
        for n in [3usize, 4, 6, 8] {
            let ring = Ring::new(n);
            let mut sw = Switch::new(n, BW, 1e-6);
            let chunk = MB;
            let mut t_step = 0.0;
            for _step in 0..ring.allreduce_steps() {
                let mut max_done = t_step;
                for node in 0..n {
                    let dst = ring.next(node);
                    let done = sw.forward(dst, t_step, chunk);
                    let ideal = t_step + chunk / BW + 1e-6;
                    assert!(
                        (done - ideal).abs() < 1e-12,
                        "n={n}: queueing detected on port {dst}"
                    );
                    max_done = max_done.max(done);
                }
                t_step = max_done;
            }
            // total = 2(n-1) ideal steps exactly
            let ideal_total = ring.allreduce_steps() as f64 * (chunk / BW + 1e-6);
            assert!((t_step - ideal_total).abs() < 1e-9);
        }
    }

    #[test]
    fn cut_through_is_latency_only_when_uncontended() {
        let mut sw = Switch::new(4, BW, 1e-6);
        // single flow: delivered after just the port latency
        let d = sw.forward_cut_through(1, 5.0, MB);
        assert!((d - (5.0 + 1e-6)).abs() < 1e-12);
        // a second flow to the same port queues behind the first's
        // reservation (MB/BW seconds of egress capacity)
        let d2 = sw.forward_cut_through(1, 5.0, MB);
        assert!((d2 - (5.0 + MB / BW + 1e-6)).abs() < 1e-12);
        // a flow to a different port is unaffected
        let d3 = sw.forward_cut_through(2, 5.0, MB);
        assert!((d3 - (5.0 + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn scaled_port_slows_traffic_toward_it_only() {
        let mut sw = Switch::new_scaled(4, BW, 0.0, |p| if p == 1 { 0.25 } else { 1.0 });
        assert_eq!(sw.port_rate(1), BW * 0.25);
        assert_eq!(sw.port_rate(0), BW);
        // incast of two flows toward the degraded port: the second queues
        // behind a 4x-longer reservation than it would on a healthy port
        let _ = sw.forward_cut_through(1, 0.0, MB);
        let d_degraded = sw.forward_cut_through(1, 0.0, MB);
        let _ = sw.forward_cut_through(2, 0.0, MB);
        let d_healthy = sw.forward_cut_through(2, 0.0, MB);
        assert!((d_degraded - 4.0 * MB / BW).abs() < 1e-12, "{d_degraded}");
        assert!((d_healthy - MB / BW).abs() < 1e-12, "{d_healthy}");
    }

    #[test]
    fn port_utilization_zero_horizon_is_zero() {
        let mut sw = Switch::new(2, BW, 0.0);
        let _ = sw.forward(0, 0.0, MB);
        assert_eq!(sw.port_utilization(0, 0.0), 0.0);
        assert!(sw.port_utilization(0, 1.0) > 0.0);
    }

    #[test]
    fn plain_switch_has_no_reduction() {
        let sw = Switch::new(4, BW, 0.0);
        assert!(!sw.reduce_capable());
        assert_eq!(sw.table_bytes(), 0.0);
        // zero rate or zero table keeps it plain
        assert!(!Switch::new(4, BW, 0.0).with_reduction(0.0, 1e6).reduce_capable());
        assert!(!Switch::new(4, BW, 0.0).with_reduction(1e9, 0.0).reduce_capable());
    }

    #[test]
    fn reduce_contributions_serialize_on_the_port_engine() {
        // engine at 1 G adds/s: four simultaneous 1 M-element contributions
        // fold FIFO, 1 ms each
        let mut sw = Switch::new(4, BW, 0.0).with_reduction(1e9, 1e6);
        assert!(sw.reduce_capable());
        let e = 1e6;
        let folds: Vec<f64> = (0..4).map(|_| sw.reduce_contribution(0, 0.0, e)).collect();
        for (k, t) in folds.iter().enumerate() {
            assert!((t - (k as f64 + 1.0) * 1e-3).abs() < 1e-12, "{k}: {t}");
        }
        // a different port's engine is independent
        let other = sw.reduce_contribution(1, 0.0, e);
        assert!((other - 1e-3).abs() < 1e-12);
        // engines reset with the switch
        sw.reset();
        assert!((sw.reduce_contribution(0, 0.0, e) - 1e-3).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "no reduction capability")]
    fn reducing_on_a_plain_switch_panics() {
        let mut sw = Switch::new(2, BW, 0.0);
        let _ = sw.reduce_contribution(0, 0.0, 1.0);
    }

    #[test]
    fn engine_occupancy_serializes_tenants_on_one_root_egress() {
        // two tenants' reduced segments draining the same engine at the
        // same instant: the second waits out the first's full drain
        let mut sw = Switch::new(4, BW, 0.0).with_reduction(1e9, 4e6);
        let a = sw.engine_occupancy(0, 0.0, MB);
        let b = sw.engine_occupancy(0, 0.0, MB);
        assert_eq!(a, MB / BW);
        assert_eq!(b, 2.0 * MB / BW);
        // a different engine is independent
        assert_eq!(sw.engine_occupancy(1, 0.0, MB), MB / BW);
        // occupancy servers reset and are enumerated by the audit scan
        sw.reset();
        assert_eq!(sw.engine_occupancy(0, 0.0, MB), MB / BW);
        assert_eq!(sw.servers().count(), 4 + 4 + 4);
        assert_eq!(Switch::new(4, BW, 0.0).servers().count(), 4);
    }

    #[test]
    fn table_allocator_grants_shares_and_floors_to_units() {
        let mut t = TableAllocator::new(10.0);
        // job 0 wants 8 units of 1 byte: full grant
        assert_eq!(t.request(0, 8.0, 1.0), 8.0);
        assert_eq!(t.free_bytes(), 2.0);
        // job 1 wants 4: job 0 is busy (not evictable), grant floors to 2
        assert_eq!(t.request(1, 4.0, 1.0), 2.0);
        // job 2 wants even one 1-byte unit... but unit is 2: denied
        assert_eq!(t.request(2, 2.0, 2.0), 0.0);
        assert!(!t.take_eviction_debt(2), "a plain denial is not an eviction");
        // a second flow of job 0 shares the existing slot (refcount, same grant)
        assert_eq!(t.request(0, 8.0, 1.0), 8.0);
        assert_eq!(t.tenants(), 2);
        assert_eq!(t.evictions(), 0);
    }

    #[test]
    fn lru_idle_slots_are_evicted_and_leave_a_debt() {
        let mut t = TableAllocator::new(8.0);
        assert_eq!(t.request(0, 4.0, 1.0), 4.0);
        assert_eq!(t.request(1, 4.0, 1.0), 4.0);
        t.release(0); // job 0 idle first → LRU victim
        t.release(1);
        // job 2 needs 6: evicts job 0 (LRU), then job 1
        assert_eq!(t.request(2, 6.0, 1.0), 6.0);
        assert_eq!(t.evictions(), 2);
        // both evicted jobs carry a one-shot debt
        assert!(t.take_eviction_debt(0));
        assert!(!t.take_eviction_debt(0));
        assert!(t.take_eviction_debt(1));
        // an active slot is never evicted
        let mut t2 = TableAllocator::new(4.0);
        assert_eq!(t2.request(7, 4.0, 1.0), 4.0);
        assert_eq!(t2.request(8, 4.0, 1.0), 0.0, "active tenant must not be evicted");
        assert_eq!(t2.evictions(), 0);
    }

    #[test]
    fn available_to_counts_own_slot_free_and_idle_bytes() {
        let mut t = TableAllocator::new(10.0);
        assert_eq!(t.request(0, 4.0, 1.0), 4.0);
        assert_eq!(t.request(1, 3.0, 1.0), 3.0);
        // holder sees its own slot
        assert_eq!(t.available_to(0), 4.0);
        // outsider sees free bytes only while both tenants are active
        assert_eq!(t.available_to(9), 3.0);
        t.release(1);
        // ... plus job 1's now-idle slot
        assert_eq!(t.available_to(9), 6.0);
        assert_eq!(t.available_to(0), 4.0, "own slot still wins");
    }

    #[test]
    fn placement_is_first_fit_with_deterministic_compaction() {
        let mut t = TableAllocator::new(10.0);
        assert_eq!(t.request(0, 4.0, 1.0), 4.0);
        assert_eq!(t.request(1, 3.0, 1.0), 3.0);
        assert_eq!(t.slots()[0].offset, 0.0);
        assert_eq!(t.slots()[1].offset, 4.0);
        // free the middle, leaving a 4-byte hole at 0 after job 0 leaves
        t.release(0);
        assert_eq!(t.request(2, 3.0, 1.0), 3.0);
        assert_eq!(t.slots().last().unwrap().offset, 7.0, "first fit uses the tail gap");
        // now a request that only fits after eviction + compaction
        t.release(2);
        let mut t = TableAllocator::new(10.0);
        let _ = t.request(0, 3.0, 1.0);
        let _ = t.request(1, 4.0, 1.0);
        t.release(0);
        // evicting job 0 leaves holes [0,3) and [7,10): 5 bytes only fit compacted
        assert_eq!(t.request(2, 5.0, 1.0), 5.0);
        let s1 = t.slots().iter().find(|s| s.job == 1).unwrap();
        assert_eq!(s1.offset, 0.0, "compaction packs the survivor to 0");
        assert_eq!(t.slots().iter().find(|s| s.job == 2).unwrap().offset, 4.0);
        // no overlap, within capacity
        let total: f64 = t.slots().iter().map(|s| s.len).sum();
        assert!(total <= t.capacity());
    }

    #[test]
    fn all_to_one_is_n_times_slower_than_ring_step() {
        let n = 6;
        let mut sw = Switch::new(n, BW, 0.0);
        let mut worst = 0.0f64;
        for _ in 0..n - 1 {
            worst = worst.max(sw.forward(0, 0.0, MB));
        }
        sw.reset();
        let ring = Ring::new(n);
        let mut ring_worst = 0.0f64;
        for node in 0..n {
            ring_worst = ring_worst.max(sw.forward(ring.next(node), 0.0, MB));
        }
        assert!((worst / ring_worst - (n as f64 - 1.0)).abs() < 1e-9);
    }
}
