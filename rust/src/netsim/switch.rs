//! Switched-fabric model (paper Sec. V-A: a Dell EMC S6100-ON connects all
//! NICs; the ring is a logical overlay).  Models per-egress-port
//! contention: flows to the same destination serialize on that
//! destination's egress port, flows to distinct destinations don't
//! interact — exactly the property that makes the ring all-reduce
//! "contention-free" (Sec. II-B), which the tests verify.

use super::link::Server;
use super::Time;

/// A non-blocking crossbar switch with per-egress-port serialization and
/// an optional per-egress-port reduction capability (NetReduce,
/// arXiv:2009.09736): each port can own an aggregation engine that folds
/// arriving f32 streams into an on-chip table before forwarding the
/// reduced stream out of the port.
#[derive(Clone, Debug)]
pub struct Switch {
    egress: Vec<Server>,
    /// per-egress-port aggregation engines; empty on a plain forwarding
    /// switch (the seed behavior)
    reducers: Vec<Server>,
    /// per-port aggregation table capacity (bytes of f32 accumulators)
    table_bytes: f64,
    /// port-to-port forwarding latency
    pub latency: Time,
}

impl Switch {
    pub fn new(ports: usize, port_bw_bytes_per_s: f64, latency: Time) -> Self {
        Self::new_scaled(ports, port_bw_bytes_per_s, latency, |_| 1.0)
    }

    /// A switch whose egress port `p` runs at `port_bw * scale_of(p)` —
    /// the fault-injection hook that makes a degraded physical link slow
    /// traffic *toward* its node, not just away from it.
    pub fn new_scaled(
        ports: usize,
        port_bw_bytes_per_s: f64,
        latency: Time,
        scale_of: impl Fn(usize) -> f64,
    ) -> Self {
        Self {
            egress: (0..ports)
                .map(|p| Server::new(port_bw_bytes_per_s * scale_of(p)))
                .collect(),
            reducers: Vec::new(),
            table_bytes: 0.0,
            latency,
        }
    }

    /// Attach an aggregation engine of `reduce_flops` f32 adds/s and a
    /// `table_bytes` accumulation table to every egress port.  Zero for
    /// either leaves the switch a plain forwarding fabric.
    #[must_use]
    pub fn with_reduction(mut self, reduce_flops: f64, table_bytes: f64) -> Self {
        if reduce_flops > 0.0 && table_bytes > 0.0 {
            self.reducers = (0..self.egress.len()).map(|_| Server::new(reduce_flops)).collect();
            self.table_bytes = table_bytes;
        }
        self
    }

    /// Can this switch reduce in-network?
    #[must_use]
    pub fn reduce_capable(&self) -> bool {
        !self.reducers.is_empty()
    }

    /// Aggregation table capacity per port (bytes; 0 when not capable).
    #[must_use]
    pub fn table_bytes(&self) -> f64 {
        self.table_bytes
    }

    /// Fold one contribution of `elems` f32 values into `port`'s
    /// aggregation engine; returns the time the contribution is folded
    /// into the table.  Every contribution — the table write-in included —
    /// costs `elems` adds of engine bandwidth, FIFO with everything else
    /// the engine is folding.
    #[must_use]
    pub fn reduce_contribution(&mut self, port: usize, arrival: Time, elems: f64) -> Time {
        assert!(self.reduce_capable(), "switch has no reduction capability");
        self.reducers[port].serve(arrival, elems)
    }

    pub fn ports(&self) -> usize {
        self.egress.len()
    }

    /// Configured bandwidth of one egress port (bytes/s, fault scaling
    /// included).
    #[must_use]
    pub fn port_rate(&self, port: usize) -> f64 {
        self.egress[port].rate
    }

    /// Forward `bytes` arriving at the switch at `arrival` toward
    /// `dst_port`; returns delivery time at the destination NIC
    /// (store-and-forward: full egress serialization + latency).
    #[must_use]
    pub fn forward(&mut self, dst_port: usize, arrival: Time, bytes: f64) -> Time {
        self.egress[dst_port].serve(arrival, bytes) + self.latency
    }

    /// Cut-through forwarding: the egress port's capacity is reserved FIFO
    /// (so concurrent flows to the same destination queue-delay each
    /// other), but an uncontended transfer — whose egress streaming
    /// overlapped its ingress arrival — is delivered after just the
    /// port-to-port latency.  This is the fabric model of the unified
    /// cluster engine: the sender's Tx link pays serialization once, and
    /// the switch adds only latency plus contention.
    #[must_use]
    pub fn forward_cut_through(&mut self, dst_port: usize, arrival: Time, bytes: f64) -> Time {
        self.egress[dst_port].reserve(arrival, bytes) + self.latency
    }

    /// Utilization of one egress port over [0, horizon] (guarded against a
    /// zero horizon by [`Server::utilization`]).
    #[must_use]
    pub fn port_utilization(&self, port: usize, horizon: Time) -> f64 {
        self.egress[port].utilization(horizon)
    }

    /// Total f32 elements folded by this switch's aggregation engines
    /// (0 on a plain forwarding switch) — the observed side of the
    /// conservation auditor's exactly-once ledger.
    #[must_use]
    pub fn engines_served(&self) -> f64 {
        self.reducers.iter().map(Server::served).sum()
    }

    /// Every FIFO server in the switch (egress ports, then aggregation
    /// engines) — enumerated by the quiescence audit's leaked-reservation
    /// scan.
    pub fn servers(&self) -> impl Iterator<Item = &Server> + '_ {
        self.egress.iter().chain(self.reducers.iter())
    }

    pub fn reset(&mut self) {
        for p in &mut self.egress {
            p.reset();
        }
        for r in &mut self.reducers {
            r.reset();
        }
    }
}

#[cfg(test)]
// exact float equalities are deliberate here: the switch model is pure
// arithmetic and the tests pin bit-exact results
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;
    use crate::netsim::topology::Ring;

    const BW: f64 = 5e9; // 40 GbE
    const MB: f64 = 1e6;

    #[test]
    fn distinct_destinations_do_not_contend() {
        let mut sw = Switch::new(6, BW, 1e-6);
        // 6 flows, all to different ports, all at t=0
        let done: Vec<f64> = (0..6).map(|p| sw.forward(p, 0.0, MB)).collect();
        let expect = MB / BW + 1e-6;
        for d in done {
            assert!((d - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn incast_serializes_on_the_egress_port() {
        let mut sw = Switch::new(6, BW, 0.0);
        // 5 flows all to port 0 (all-to-one): last finishes 5x later
        let done: Vec<f64> = (0..5).map(|_| sw.forward(0, 0.0, MB)).collect();
        assert!((done[4] - 5.0 * MB / BW).abs() < 1e-12);
    }

    #[test]
    fn ring_allreduce_schedule_is_contention_free() {
        // the paper's Sec. II-B claim, end to end: replay every step of
        // the pipelined ring schedule through the switch; each transfer
        // must complete in exactly serialization + latency (no queueing)
        for n in [3usize, 4, 6, 8] {
            let ring = Ring::new(n);
            let mut sw = Switch::new(n, BW, 1e-6);
            let chunk = MB;
            let mut t_step = 0.0;
            for _step in 0..ring.allreduce_steps() {
                let mut max_done = t_step;
                for node in 0..n {
                    let dst = ring.next(node);
                    let done = sw.forward(dst, t_step, chunk);
                    let ideal = t_step + chunk / BW + 1e-6;
                    assert!(
                        (done - ideal).abs() < 1e-12,
                        "n={n}: queueing detected on port {dst}"
                    );
                    max_done = max_done.max(done);
                }
                t_step = max_done;
            }
            // total = 2(n-1) ideal steps exactly
            let ideal_total = ring.allreduce_steps() as f64 * (chunk / BW + 1e-6);
            assert!((t_step - ideal_total).abs() < 1e-9);
        }
    }

    #[test]
    fn cut_through_is_latency_only_when_uncontended() {
        let mut sw = Switch::new(4, BW, 1e-6);
        // single flow: delivered after just the port latency
        let d = sw.forward_cut_through(1, 5.0, MB);
        assert!((d - (5.0 + 1e-6)).abs() < 1e-12);
        // a second flow to the same port queues behind the first's
        // reservation (MB/BW seconds of egress capacity)
        let d2 = sw.forward_cut_through(1, 5.0, MB);
        assert!((d2 - (5.0 + MB / BW + 1e-6)).abs() < 1e-12);
        // a flow to a different port is unaffected
        let d3 = sw.forward_cut_through(2, 5.0, MB);
        assert!((d3 - (5.0 + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn scaled_port_slows_traffic_toward_it_only() {
        let mut sw = Switch::new_scaled(4, BW, 0.0, |p| if p == 1 { 0.25 } else { 1.0 });
        assert_eq!(sw.port_rate(1), BW * 0.25);
        assert_eq!(sw.port_rate(0), BW);
        // incast of two flows toward the degraded port: the second queues
        // behind a 4x-longer reservation than it would on a healthy port
        let _ = sw.forward_cut_through(1, 0.0, MB);
        let d_degraded = sw.forward_cut_through(1, 0.0, MB);
        let _ = sw.forward_cut_through(2, 0.0, MB);
        let d_healthy = sw.forward_cut_through(2, 0.0, MB);
        assert!((d_degraded - 4.0 * MB / BW).abs() < 1e-12, "{d_degraded}");
        assert!((d_healthy - MB / BW).abs() < 1e-12, "{d_healthy}");
    }

    #[test]
    fn port_utilization_zero_horizon_is_zero() {
        let mut sw = Switch::new(2, BW, 0.0);
        let _ = sw.forward(0, 0.0, MB);
        assert_eq!(sw.port_utilization(0, 0.0), 0.0);
        assert!(sw.port_utilization(0, 1.0) > 0.0);
    }

    #[test]
    fn plain_switch_has_no_reduction() {
        let sw = Switch::new(4, BW, 0.0);
        assert!(!sw.reduce_capable());
        assert_eq!(sw.table_bytes(), 0.0);
        // zero rate or zero table keeps it plain
        assert!(!Switch::new(4, BW, 0.0).with_reduction(0.0, 1e6).reduce_capable());
        assert!(!Switch::new(4, BW, 0.0).with_reduction(1e9, 0.0).reduce_capable());
    }

    #[test]
    fn reduce_contributions_serialize_on_the_port_engine() {
        // engine at 1 G adds/s: four simultaneous 1 M-element contributions
        // fold FIFO, 1 ms each
        let mut sw = Switch::new(4, BW, 0.0).with_reduction(1e9, 1e6);
        assert!(sw.reduce_capable());
        let e = 1e6;
        let folds: Vec<f64> = (0..4).map(|_| sw.reduce_contribution(0, 0.0, e)).collect();
        for (k, t) in folds.iter().enumerate() {
            assert!((t - (k as f64 + 1.0) * 1e-3).abs() < 1e-12, "{k}: {t}");
        }
        // a different port's engine is independent
        let other = sw.reduce_contribution(1, 0.0, e);
        assert!((other - 1e-3).abs() < 1e-12);
        // engines reset with the switch
        sw.reset();
        assert!((sw.reduce_contribution(0, 0.0, e) - 1e-3).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "no reduction capability")]
    fn reducing_on_a_plain_switch_panics() {
        let mut sw = Switch::new(2, BW, 0.0);
        let _ = sw.reduce_contribution(0, 0.0, 1.0);
    }

    #[test]
    fn all_to_one_is_n_times_slower_than_ring_step() {
        let n = 6;
        let mut sw = Switch::new(n, BW, 0.0);
        let mut worst = 0.0f64;
        for _ in 0..n - 1 {
            worst = worst.max(sw.forward(0, 0.0, MB));
        }
        sw.reset();
        let ring = Ring::new(n);
        let mut ring_worst = 0.0f64;
        for node in 0..n {
            ring_worst = ring_worst.max(sw.forward(ring.next(node), 0.0, MB));
        }
        assert!((worst / ring_worst - (n as f64 - 1.0)).abs() < 1e-9);
    }
}
