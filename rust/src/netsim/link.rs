//! FIFO servers: the composable timing primitives of the simulator.
//!
//! A [`Server`] owns a rate (bytes/s or FLOP/s) and a `busy_until` horizon;
//! `serve(arrival, amount)` returns the completion time under FIFO order.
//! A [`Link`] is a server plus propagation latency — the standard
//! store-and-forward transmission model:
//!
//!   depart = max(arrival, busy_until) + amount / rate
//!   arrive = depart + latency
//!
//! Paper constants (Sec. V-A): 40 GbE inter-FPGA links (α≈1), 100 GbE
//! baseline NICs (α<1 for host MPI), PCIe Gen3 x8 ≈ 7.88 GB/s per
//! direction, Dell S6100 switch port-to-port latency ≈ 1 µs.

use super::Time;

/// A FIFO rate server with utilization accounting.
#[derive(Clone, Debug)]
pub struct Server {
    /// service rate in units/second (bytes/s, FLOP/s, ...)
    pub rate: f64,
    busy_until: Time,
    busy_time: f64,
    served: f64,
    /// longest single service/reservation drain time — the slack the
    /// quiescence audit grants `busy_until` past the final event time
    /// (a cut-through reservation legitimately outlives its delivery
    /// event by at most one drain time)
    max_service: Time,
}

impl Server {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0);
        Self {
            rate,
            busy_until: 0.0,
            busy_time: 0.0,
            served: 0.0,
            max_service: 0.0,
        }
    }

    /// Serve `amount` units arriving at `arrival`; returns completion time.
    #[must_use]
    pub fn serve(&mut self, arrival: Time, amount: f64) -> Time {
        let start = arrival.max(self.busy_until);
        let dur = amount / self.rate;
        self.busy_until = start + dur;
        self.busy_time += dur;
        self.served += amount;
        self.max_service = self.max_service.max(dur);
        self.busy_until
    }

    /// Cut-through reservation: queue `amount` of capacity FIFO and return
    /// the time service *begins* (= queue exit).  An uncontended item
    /// passes through with zero added delay — its serialization overlapped
    /// the upstream stage — while a contended one waits for the earlier
    /// reservations to drain.  Used for switch egress ports, where
    /// store-and-forward accounting would double-count the serialization
    /// already paid on the sender's Tx link.
    #[must_use]
    pub fn reserve(&mut self, arrival: Time, amount: f64) -> Time {
        let start = arrival.max(self.busy_until);
        let dur = amount / self.rate;
        self.busy_until = start + dur;
        self.busy_time += dur;
        self.served += amount;
        self.max_service = self.max_service.max(dur);
        start
    }

    #[must_use]
    pub fn busy_until(&self) -> Time {
        self.busy_until
    }

    /// Longest single service/reservation drain time seen so far.
    #[must_use]
    pub fn max_service(&self) -> Time {
        self.max_service
    }

    /// Total units served.
    #[must_use]
    pub fn served(&self) -> f64 {
        self.served
    }

    /// Fraction of [0, horizon] this server was busy.  A non-positive
    /// horizon (nothing has run yet) reports zero utilization rather than
    /// dividing by it.
    #[must_use]
    pub fn utilization(&self, horizon: Time) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            (self.busy_time / horizon).min(1.0)
        }
    }

    pub fn reset(&mut self) {
        self.busy_until = 0.0;
        self.busy_time = 0.0;
        self.served = 0.0;
        self.max_service = 0.0;
    }
}

/// A network link: serialization server + fixed propagation latency.
#[derive(Clone, Debug)]
pub struct Link {
    pub server: Server,
    pub latency: Time,
}

impl Link {
    pub fn new(bandwidth_bytes_per_s: f64, latency: Time) -> Self {
        Self {
            server: Server::new(bandwidth_bytes_per_s),
            latency,
        }
    }

    /// Transmit `bytes` arriving at the NIC at `arrival`; returns the time
    /// the last byte arrives at the far end.
    #[must_use]
    pub fn transmit(&mut self, arrival: Time, bytes: f64) -> Time {
        self.server.serve(arrival, bytes) + self.latency
    }

    #[must_use]
    pub fn bytes_sent(&self) -> f64 {
        self.server.served()
    }

    /// Fraction of [0, horizon] the serialization stage was busy (guarded
    /// against a zero horizon).
    #[must_use]
    pub fn utilization(&self, horizon: Time) -> f64 {
        self.server.utilization(horizon)
    }

    pub fn reset(&mut self) {
        self.server.reset();
    }
}

/// Bidirectional PCIe endpoint (independent up/down servers, full duplex —
/// PCIe Gen3 x8 gives ~7.88 GB/s each direction).
#[derive(Clone, Debug)]
pub struct Pcie {
    pub to_device: Link,
    pub to_host: Link,
}

impl Pcie {
    pub fn new(bandwidth_bytes_per_s: f64, latency: Time) -> Self {
        Self {
            to_device: Link::new(bandwidth_bytes_per_s, latency),
            to_host: Link::new(bandwidth_bytes_per_s, latency),
        }
    }

    pub fn reset(&mut self) {
        self.to_device.reset();
        self.to_host.reset();
    }
}

#[cfg(test)]
// exact float equalities are deliberate here: servers are pure arithmetic
// and the tests pin bit-exact results
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;
    use crate::util::units::gbps;

    #[test]
    fn serve_accumulates_backlog() {
        let mut s = Server::new(100.0); // 100 units/s
        assert_eq!(s.serve(0.0, 100.0), 1.0);
        // arrives while busy: queues behind
        assert_eq!(s.serve(0.5, 100.0), 2.0);
        // arrives after idle gap
        assert_eq!(s.serve(10.0, 50.0), 10.5);
        assert_eq!(s.served(), 250.0);
    }

    #[test]
    fn utilization_accounts_busy_time_only() {
        let mut s = Server::new(100.0);
        let _ = s.serve(0.0, 100.0); // busy [0,1]
        let _ = s.serve(3.0, 100.0); // busy [3,4]
        assert!((s.utilization(4.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn link_adds_latency() {
        let mut l = Link::new(gbps(40.0), 1e-6);
        // 5 GB/s: 5 MB takes 1 ms + 1 us latency
        let t = l.transmit(0.0, 5e6);
        assert!((t - (1e-3 + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn link_pipelines_chunks() {
        // two chunks back-to-back: serialization serializes, latency overlaps
        let mut l = Link::new(1e6, 10e-3);
        let t1 = l.transmit(0.0, 1000.0); // ser 1ms -> arrives 11ms
        let t2 = l.transmit(0.0, 1000.0); // queued: ser ends 2ms -> 12ms
        assert!((t1 - 0.011).abs() < 1e-12);
        assert!((t2 - 0.012).abs() < 1e-12);
    }

    #[test]
    fn pcie_directions_independent() {
        let mut p = Pcie::new(1e9, 0.0);
        let up = p.to_device.transmit(0.0, 1e9);
        let down = p.to_host.transmit(0.0, 1e9);
        assert_eq!(up, 1.0);
        assert_eq!(down, 1.0); // not queued behind the other direction
    }

    #[test]
    fn reserve_is_cut_through() {
        let mut s = Server::new(100.0); // 100 units/s
        // uncontended: passes through at its arrival time
        assert_eq!(s.reserve(0.0, 100.0), 0.0);
        // contended: waits for the first reservation to drain (t=1.0)
        assert_eq!(s.reserve(0.5, 100.0), 1.0);
        // capacity accounting still accrues
        assert_eq!(s.served(), 200.0);
        assert!((s.utilization(2.0) - 1.0).abs() < 1e-12);
        // the audit slack tracks the longest single drain
        assert_eq!(s.max_service(), 1.0);
    }

    #[test]
    fn utilization_guards_zero_horizon() {
        let mut s = Server::new(10.0);
        let _ = s.serve(0.0, 100.0);
        assert_eq!(s.utilization(0.0), 0.0);
        assert_eq!(s.utilization(-1.0), 0.0);
        let mut l = Link::new(10.0, 0.0);
        let _ = l.transmit(0.0, 100.0);
        assert_eq!(l.utilization(0.0), 0.0);
        assert!(l.utilization(20.0) > 0.0);
    }

    #[test]
    fn reset_clears_state() {
        let mut s = Server::new(10.0);
        let _ = s.serve(0.0, 100.0);
        s.reset();
        assert_eq!(s.busy_until(), 0.0);
        assert_eq!(s.served(), 0.0);
        assert_eq!(s.max_service(), 0.0);
    }
}
