//! Ring topology over a switched Ethernet fabric (paper Fig. 3a: FPGAs
//! connect to a Dell S6100 switch; a logical ring is overlaid on top).

/// A unidirectional ring of `n` nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ring {
    pub n: usize,
}

impl Ring {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "ring needs at least one node");
        Self { n }
    }

    /// Downstream neighbor (the node we send to).
    pub fn next(&self, node: usize) -> usize {
        debug_assert!(node < self.n);
        (node + 1) % self.n
    }

    /// Upstream neighbor (the node we receive from).
    pub fn prev(&self, node: usize) -> usize {
        debug_assert!(node < self.n);
        (node + self.n - 1) % self.n
    }

    /// The chunk index node `node` *sends* during reduce-scatter step `s`
    /// (0-based), for the standard pipelined ring all-reduce: node i sends
    /// chunk (i - s) mod n at step s.
    pub fn send_chunk(&self, node: usize, step: usize) -> usize {
        (node + self.n - (step % self.n)) % self.n
    }

    /// The chunk index node `node` *receives* (and reduces or stores)
    /// during step `s`: what its upstream neighbor sends.
    pub fn recv_chunk(&self, node: usize, step: usize) -> usize {
        self.send_chunk(self.prev(node), step)
    }

    /// Number of steps in a full ring all-reduce: 2(n-1).
    pub fn allreduce_steps(&self) -> usize {
        2 * (self.n - 1)
    }

    /// Steps in the reduce-scatter phase: n-1.
    pub fn reduce_scatter_steps(&self) -> usize {
        self.n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_wrap() {
        let r = Ring::new(4);
        assert_eq!(r.next(3), 0);
        assert_eq!(r.prev(0), 3);
        assert_eq!(r.next(1), 2);
    }

    #[test]
    fn chunk_schedule_is_contention_free() {
        // at every step, the n sent chunks are distinct (each node sends a
        // different chunk) — the property that makes ring bandwidth-optimal
        for n in [2usize, 3, 4, 6, 8] {
            let r = Ring::new(n);
            for s in 0..r.allreduce_steps() {
                let mut seen = vec![false; n];
                for node in 0..n {
                    let c = r.send_chunk(node, s);
                    assert!(!seen[c], "n={n} step={s}");
                    seen[c] = true;
                }
            }
        }
    }

    #[test]
    fn recv_is_upstream_send() {
        let r = Ring::new(6);
        for s in 0..r.allreduce_steps() {
            for node in 0..6 {
                assert_eq!(r.recv_chunk(node, s), r.send_chunk(r.prev(node), s));
            }
        }
    }

    #[test]
    fn reduce_scatter_covers_all_chunks() {
        // after n-1 reduce steps, node i has fully reduced chunk (i+1) mod n
        // (it received every other node's contribution exactly once)
        let n = 5;
        let r = Ring::new(n);
        for node in 0..n {
            let mut received: Vec<usize> = (0..r.reduce_scatter_steps())
                .map(|s| r.recv_chunk(node, s))
                .collect();
            received.sort_unstable();
            received.dedup();
            assert_eq!(received.len(), n - 1, "node {node} got {received:?}");
        }
    }

    #[test]
    fn step_count() {
        assert_eq!(Ring::new(6).allreduce_steps(), 10);
        assert_eq!(Ring::new(2).allreduce_steps(), 2);
        assert_eq!(Ring::new(1).allreduce_steps(), 0);
    }
}
