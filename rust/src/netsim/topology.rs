//! Physical and logical topology of the cluster fabric.
//!
//! Two layers:
//!
//! * [`Topology`] — the *physical* interconnect shape: either the paper's
//!   single non-blocking crossbar (Fig. 3a: every FPGA on one Dell S6100)
//!   or a two-tier leaf–spine fabric with a configurable uplink
//!   oversubscription factor, the regime NetReduce/ACCL+ show changes
//!   in-network reduction behavior qualitatively.  The topology also owns
//!   the *placement* helpers ([`Topology::contiguous_ranks`] /
//!   [`Topology::strided_ranks`]) that decide whether a logical ring's
//!   neighbor edges stay inside one leaf (contention-free) or cross the
//!   oversubscribed spine on every hop.
//! * [`Ring`] — the *logical* ring overlay and its pipelined all-reduce
//!   chunk schedule, unchanged from the paper's Sec. II-B.

/// Physical interconnect shape of the cluster.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Topology {
    /// One non-blocking crossbar switch: every pair of nodes is a single
    /// hop apart and only egress ports can contend (the seed model).
    Flat {
        /// total nodes (= switch ports)
        nodes: usize,
    },
    /// Two-tier leaf–spine: `leaves` edge switches with `nodes_per_leaf`
    /// down-ports each; every leaf connects to a non-blocking spine tier
    /// through an uplink bundle carrying `nodes_per_leaf / oversubscription`
    /// ports worth of bandwidth.  `oversubscription` = 1 is rearrangeably
    /// non-blocking; > 1 means inter-leaf traffic can queue on the
    /// uplinks even when every egress port is idle.
    LeafSpine {
        leaves: usize,
        nodes_per_leaf: usize,
        /// uplink oversubscription factor (any positive value; 1.0 =
        /// full bisection bandwidth, 4.0 = classic 4:1 tapering)
        oversubscription: f64,
    },
}

impl Topology {
    /// A flat single-switch fabric of `nodes` ports.
    pub fn flat(nodes: usize) -> Self {
        assert!(nodes >= 1, "topology needs at least one node");
        Topology::Flat { nodes }
    }

    /// A leaf–spine fabric. `oversubscription` is the ratio of downlink to
    /// uplink capacity per leaf (1.0 = non-blocking).
    pub fn leaf_spine(leaves: usize, nodes_per_leaf: usize, oversubscription: f64) -> Self {
        assert!(leaves >= 1, "need at least one leaf switch");
        assert!(nodes_per_leaf >= 1, "need at least one node per leaf");
        assert!(
            oversubscription > 0.0 && oversubscription.is_finite(),
            "oversubscription {oversubscription} must be positive and finite"
        );
        Topology::LeafSpine {
            leaves,
            nodes_per_leaf,
            oversubscription,
        }
    }

    /// Total number of nodes.
    pub fn nodes(&self) -> usize {
        match *self {
            Topology::Flat { nodes } => nodes,
            Topology::LeafSpine { leaves, nodes_per_leaf, .. } => leaves * nodes_per_leaf,
        }
    }

    /// Number of leaf switches (1 for the flat crossbar).
    pub fn leaves(&self) -> usize {
        match *self {
            Topology::Flat { .. } => 1,
            Topology::LeafSpine { leaves, .. } => leaves,
        }
    }

    /// Uplink oversubscription factor (1.0 for the flat crossbar).
    pub fn oversubscription(&self) -> f64 {
        match *self {
            Topology::Flat { .. } => 1.0,
            Topology::LeafSpine { oversubscription, .. } => oversubscription,
        }
    }

    /// Which leaf switch `node` hangs off (0 for the flat crossbar).
    pub fn leaf_of(&self, node: usize) -> usize {
        debug_assert!(node < self.nodes());
        match *self {
            Topology::Flat { .. } => 0,
            Topology::LeafSpine { nodes_per_leaf, .. } => node / nodes_per_leaf,
        }
    }

    /// `node`'s local down-port index on its leaf switch.
    pub fn leaf_port(&self, node: usize) -> usize {
        debug_assert!(node < self.nodes());
        match *self {
            Topology::Flat { .. } => node,
            Topology::LeafSpine { nodes_per_leaf, .. } => node % nodes_per_leaf,
        }
    }

    /// Do `a` and `b` share a leaf switch (always true on the crossbar)?
    pub fn same_leaf(&self, a: usize, b: usize) -> bool {
        self.leaf_of(a) == self.leaf_of(b)
    }

    /// Switch hops a packet from `src` to `dst` traverses: 1 inside a leaf
    /// (or anywhere on the crossbar), 3 across the spine (leaf → spine →
    /// leaf).
    pub fn hops(&self, src: usize, dst: usize) -> usize {
        if self.same_leaf(src, dst) {
            1
        } else {
            3
        }
    }

    /// Aggregated leaf→spine (or spine→leaf) bundle bandwidth, given one
    /// down-port's bandwidth.
    pub fn uplink_bw(&self, port_bw: f64) -> f64 {
        match *self {
            Topology::Flat { nodes } => nodes as f64 * port_bw,
            Topology::LeafSpine { nodes_per_leaf, oversubscription, .. } => {
                nodes_per_leaf as f64 * port_bw / oversubscription
            }
        }
    }

    /// Leaf-packing placement: ranks fill one leaf completely before
    /// spilling into the next, so a `k`-rank ring has at most one spine
    /// crossing per leaf boundary.
    pub fn contiguous_ranks(&self, k: usize) -> Vec<usize> {
        assert!(k <= self.nodes(), "placement of {k} ranks needs {k} nodes");
        (0..k).collect()
    }

    /// Leaf-striding (round-robin) placement: consecutive ranks land on
    /// consecutive leaves, so with >= 2 leaves every ring-neighbor edge
    /// crosses the spine — the placement that breaks ring
    /// contention-freedom under oversubscription.
    pub fn strided_ranks(&self, k: usize) -> Vec<usize> {
        assert!(k <= self.nodes(), "placement of {k} ranks needs {k} nodes");
        match *self {
            Topology::Flat { .. } => (0..k).collect(),
            Topology::LeafSpine { leaves, nodes_per_leaf, .. } => (0..k)
                .map(|i| (i % leaves) * nodes_per_leaf + i / leaves)
                .collect(),
        }
    }

    /// Human-readable shape, for tables and logs.
    pub fn describe(&self) -> String {
        match *self {
            Topology::Flat { nodes } => format!("flat crossbar, {nodes} ports"),
            Topology::LeafSpine { leaves, nodes_per_leaf, oversubscription } => format!(
                "leaf-spine, {leaves} leaves x {nodes_per_leaf} nodes, {oversubscription}:1 oversubscribed"
            ),
        }
    }
}

/// A unidirectional ring of `n` nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ring {
    pub n: usize,
}

impl Ring {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "ring needs at least one node");
        Self { n }
    }

    /// Downstream neighbor (the node we send to).
    pub fn next(&self, node: usize) -> usize {
        debug_assert!(node < self.n);
        (node + 1) % self.n
    }

    /// Upstream neighbor (the node we receive from).
    pub fn prev(&self, node: usize) -> usize {
        debug_assert!(node < self.n);
        (node + self.n - 1) % self.n
    }

    /// The chunk index node `node` *sends* during reduce-scatter step `s`
    /// (0-based), for the standard pipelined ring all-reduce: node i sends
    /// chunk (i - s) mod n at step s.
    pub fn send_chunk(&self, node: usize, step: usize) -> usize {
        (node + self.n - (step % self.n)) % self.n
    }

    /// The chunk index node `node` *receives* (and reduces or stores)
    /// during step `s`: what its upstream neighbor sends.
    pub fn recv_chunk(&self, node: usize, step: usize) -> usize {
        self.send_chunk(self.prev(node), step)
    }

    /// Number of steps in a full ring all-reduce: 2(n-1).
    pub fn allreduce_steps(&self) -> usize {
        2 * (self.n - 1)
    }

    /// Steps in the reduce-scatter phase: n-1.
    pub fn reduce_scatter_steps(&self) -> usize {
        self.n - 1
    }
}

#[cfg(test)]
// exact float equalities are deliberate: the tests pin exact results of
// pure arithmetic
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_wrap() {
        let r = Ring::new(4);
        assert_eq!(r.next(3), 0);
        assert_eq!(r.prev(0), 3);
        assert_eq!(r.next(1), 2);
    }

    #[test]
    fn chunk_schedule_is_contention_free() {
        // at every step, the n sent chunks are distinct (each node sends a
        // different chunk) — the property that makes ring bandwidth-optimal
        for n in [2usize, 3, 4, 6, 8] {
            let r = Ring::new(n);
            for s in 0..r.allreduce_steps() {
                let mut seen = vec![false; n];
                for node in 0..n {
                    let c = r.send_chunk(node, s);
                    assert!(!seen[c], "n={n} step={s}");
                    seen[c] = true;
                }
            }
        }
    }

    #[test]
    fn recv_is_upstream_send() {
        let r = Ring::new(6);
        for s in 0..r.allreduce_steps() {
            for node in 0..6 {
                assert_eq!(r.recv_chunk(node, s), r.send_chunk(r.prev(node), s));
            }
        }
    }

    #[test]
    fn reduce_scatter_covers_all_chunks() {
        // after n-1 reduce steps, node i has fully reduced chunk (i+1) mod n
        // (it received every other node's contribution exactly once)
        let n = 5;
        let r = Ring::new(n);
        for node in 0..n {
            let mut received: Vec<usize> = (0..r.reduce_scatter_steps())
                .map(|s| r.recv_chunk(node, s))
                .collect();
            received.sort_unstable();
            received.dedup();
            assert_eq!(received.len(), n - 1, "node {node} got {received:?}");
        }
    }

    #[test]
    fn step_count() {
        assert_eq!(Ring::new(6).allreduce_steps(), 10);
        assert_eq!(Ring::new(2).allreduce_steps(), 2);
        assert_eq!(Ring::new(1).allreduce_steps(), 0);
    }

    #[test]
    fn flat_topology_is_one_leaf() {
        let t = Topology::flat(8);
        assert_eq!(t.nodes(), 8);
        assert_eq!(t.leaves(), 1);
        assert_eq!(t.leaf_of(7), 0);
        assert_eq!(t.leaf_port(7), 7);
        assert_eq!(t.hops(0, 7), 1);
        assert_eq!(t.oversubscription(), 1.0);
        assert_eq!(t.contiguous_ranks(4), vec![0, 1, 2, 3]);
        assert_eq!(t.strided_ranks(4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn leaf_spine_addressing() {
        let t = Topology::leaf_spine(4, 8, 4.0);
        assert_eq!(t.nodes(), 32);
        assert_eq!(t.leaves(), 4);
        assert_eq!(t.leaf_of(0), 0);
        assert_eq!(t.leaf_of(7), 0);
        assert_eq!(t.leaf_of(8), 1);
        assert_eq!(t.leaf_port(8), 0);
        assert_eq!(t.leaf_port(31), 7);
        assert!(t.same_leaf(3, 5));
        assert!(!t.same_leaf(7, 8));
        assert_eq!(t.hops(3, 5), 1);
        assert_eq!(t.hops(7, 8), 3);
        // 4:1 oversubscription: 8 ports of downlink share 2 ports of uplink
        assert_eq!(t.uplink_bw(5e9), 8.0 * 5e9 / 4.0);
    }

    #[test]
    fn strided_placement_crosses_leaves_every_edge() {
        let t = Topology::leaf_spine(4, 4, 2.0);
        let ranks = t.strided_ranks(16);
        // distinct, in range
        let mut sorted = ranks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 16);
        assert!(ranks.iter().all(|&r| r < 16));
        // every consecutive (ring-neighbor) pair sits on different leaves
        for w in ranks.windows(2) {
            assert!(!t.same_leaf(w[0], w[1]), "{w:?} share a leaf");
        }
        // contiguous placement keeps a 4-rank ring on one leaf
        let small = t.contiguous_ranks(4);
        for w in small.windows(2) {
            assert!(t.same_leaf(w[0], w[1]));
        }
    }

    #[test]
    #[should_panic(expected = "needs")]
    fn placement_larger_than_fabric_panics() {
        let _ = Topology::flat(4).strided_ranks(5);
    }
}
