//! Calendar-queue discrete-event engine.
//!
//! Events are boxed closures scheduled at absolute virtual times; ties are
//! broken by insertion sequence so execution order is fully deterministic.

use super::Time;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

type Action<S> = Box<dyn FnOnce(&mut Sim<S>, &mut S)>;

struct Scheduled<S> {
    time: Time,
    seq: u64,
    action: Action<S>,
}

impl<S> PartialEq for Scheduled<S> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<S> Eq for Scheduled<S> {}
impl<S> PartialOrd for Scheduled<S> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<S> Ord for Scheduled<S> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first.  `total_cmp`
        // is a total order over f64 (schedule_at rejects non-finite times,
        // so NaN can never corrupt the heap invariant).
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The simulation executive.  `S` is the user's world state, threaded by
/// &mut into every event so closures never capture aliased state.
pub struct Sim<S> {
    now: Time,
    seq: u64,
    queue: BinaryHeap<Scheduled<S>>,
    events_run: u64,
}

impl<S> Default for Sim<S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S> Sim<S> {
    pub fn new() -> Self {
        Self {
            now: 0.0,
            seq: 0,
            queue: BinaryHeap::new(),
            events_run: 0,
        }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn events_run(&self) -> u64 {
        self.events_run
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule `action` to run `delay` seconds from now.
    pub fn schedule(&mut self, delay: Time, action: impl FnOnce(&mut Sim<S>, &mut S) + 'static) {
        assert!(
            delay.is_finite() && delay >= 0.0,
            "delay must be finite and non-negative, got {delay}"
        );
        self.schedule_at(self.now + delay, action);
    }

    /// Schedule `action` at an absolute time (>= now, finite — a NaN or
    /// infinite time would silently corrupt the heap order).
    pub fn schedule_at(&mut self, time: Time, action: impl FnOnce(&mut Sim<S>, &mut S) + 'static) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        assert!(
            time >= self.now,
            "cannot schedule into the past: {time} < {}",
            self.now
        );
        self.queue.push(Scheduled {
            time,
            seq: self.seq,
            action: Box::new(action),
        });
        self.seq += 1;
    }

    /// Run until the queue drains; returns final virtual time.
    pub fn run(&mut self, state: &mut S) -> Time {
        while self.step(state) {}
        self.now
    }

    /// Run at most until virtual time `t_end` (events at exactly t_end run).
    pub fn run_until(&mut self, state: &mut S, t_end: Time) -> Time {
        while let Some(head) = self.queue.peek() {
            if head.time > t_end {
                break;
            }
            self.step(state);
        }
        self.now = self.now.max(t_end.min(self.now + 0.0));
        self.now
    }

    /// Execute the single earliest event.  Returns false when empty.
    pub fn step(&mut self, state: &mut S) -> bool {
        match self.queue.pop() {
            None => false,
            Some(ev) => {
                debug_assert!(ev.time >= self.now);
                self.now = ev.time;
                self.events_run += 1;
                (ev.action)(self, state);
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_run_in_time_order() {
        let mut sim: Sim<Vec<u32>> = Sim::new();
        let mut log = Vec::new();
        sim.schedule(3.0, |_, s: &mut Vec<u32>| s.push(3));
        sim.schedule(1.0, |_, s| s.push(1));
        sim.schedule(2.0, |_, s| s.push(2));
        sim.run(&mut log);
        assert_eq!(log, vec![1, 2, 3]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut sim: Sim<Vec<u32>> = Sim::new();
        let mut log = Vec::new();
        for i in 0..10 {
            sim.schedule(1.0, move |_, s: &mut Vec<u32>| s.push(i));
        }
        sim.run(&mut log);
        assert_eq!(log, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_events() {
        let mut sim: Sim<Vec<f64>> = Sim::new();
        let mut log = Vec::new();
        sim.schedule(1.0, |sim, _s: &mut Vec<f64>| {
            sim.schedule(0.5, |sim2, s2: &mut Vec<f64>| s2.push(sim2.now()));
        });
        let end = sim.run(&mut log);
        assert_eq!(log, vec![1.5]);
        assert_eq!(end, 1.5);
    }

    #[test]
    fn run_until_stops() {
        let mut sim: Sim<u32> = Sim::new();
        let mut count = 0u32;
        for i in 1..=10 {
            sim.schedule(i as f64, |_, c: &mut u32| *c += 1);
        }
        sim.run_until(&mut count, 5.0);
        assert_eq!(count, 5);
        assert_eq!(sim.pending(), 5);
        sim.run(&mut count);
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn scheduling_nan_time_panics() {
        let mut sim: Sim<()> = Sim::new();
        sim.schedule_at(f64::NAN, |_, _| {});
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn scheduling_infinite_delay_panics() {
        let mut sim: Sim<()> = Sim::new();
        sim.schedule(f64::INFINITY, |_, _| {});
    }

    #[test]
    #[should_panic(expected = "past")]
    fn scheduling_into_past_panics() {
        let mut sim: Sim<()> = Sim::new();
        sim.schedule(1.0, |sim, _| {
            sim.schedule_at(0.5, |_, _| {});
        });
        sim.run(&mut ());
    }

    #[test]
    fn event_count_tracked() {
        let mut sim: Sim<()> = Sim::new();
        for _ in 0..100 {
            sim.schedule(1.0, |_, _| {});
        }
        sim.run(&mut ());
        assert_eq!(sim.events_run(), 100);
    }
}
