//! Typed-event discrete-event engine, sequential and parallel.
//!
//! Until PR 5 every event was a `Box<dyn FnOnce>` on one `BinaryHeap`;
//! the 512-node ring sweep scheduled tens of millions of them, and the
//! allocation + deep-heap traffic was the wall-clock bottleneck on the
//! road to 2k-node sweeps.  The engine now runs on three pieces:
//!
//! * a **typed event vocabulary** per simulation: the [`World`] trait
//!   couples a mutable state type with a compact (ideally `Copy`)
//!   [`World::Event`] enum and the match-loop dispatcher
//!   [`World::handle`] — no closure captures, no virtual calls;
//! * an **index-based arena** holding pending events: slots are recycled
//!   through a free list, so steady-state scheduling performs no heap
//!   allocation at all;
//! * a **hierarchical calendar queue**: a bucketed wheel over the near
//!   future (the current bucket drains through a small binary heap) with
//!   a heap overflow for far-future events, keyed on finite `f64`
//!   virtual time.  Ties break by insertion sequence — the *same* total
//!   order as the boxed engine, so virtual-time results are
//!   bit-identical across representations.
//!
//! On top of the sequential engine sits a **conservative parallel
//! executive** ([`EngineKind::Parallel`] / [`Sim::run_parallel`]): a
//! [`PartitionedWorld`] declares how events map onto partitions (for the
//! cluster simulation, one partition per leaf switch) and a lookahead
//! window derived from the minimum cross-partition delay.  Partition
//! calendars advance independently inside each window on
//! `std::thread::scope` workers; cross-partition and coordinator-bound
//! events are deferred into bounded channels and merged at the window
//! barrier in a deterministic `(time, merge-key)` order — the key is a
//! thread-independent function of the event itself, so the result is
//! bit-identical for any thread count even when *which* worker emits an
//! event is decided by an atomic race.  See the "Parallel engine"
//! section of `docs/ARCHITECTURE.md` for the safety argument.
//!
//! The PR-3 boxed-closure representation and the `Sim::schedule_closure`
//! escape hatch are compiled only for tests (`cfg(test)` or the
//! `testing` cargo feature): the typed path is the only production entry
//! point.
//!
//! [`EngineKind::Checked`] runs the same typed engine (sequential or
//! parallel) with the [`audit`](super::audit) invariant auditor
//! attached: scheduling preconditions, dispatch monotonicity, queue
//! total order, arena slot integrity and the PDES contract are validated
//! at run time and breaches reported as structured
//! [`AuditViolation`](super::audit::AuditViolation)s — see
//! `docs/INVARIANTS.md` for the full catalog.
//!
//! ```
//! use ai_smartnic::netsim::engine::{Sim, World};
//!
//! /// A world is state + an event vocabulary + a dispatcher.
//! struct Counter {
//!     fired: Vec<u32>,
//! }
//! impl World for Counter {
//!     type Event = u32;
//!     fn handle(_sim: &mut Sim<Self>, state: &mut Self, event: u32) {
//!         state.fired.push(event);
//!     }
//! }
//!
//! let mut sim: Sim<Counter> = Sim::new();
//! let mut world = Counter { fired: Vec::new() };
//! sim.schedule(2.0e-6, 2);
//! sim.schedule(1.0e-6, 1);
//! let end = sim.run(&mut world);
//! assert_eq!(end, 2.0e-6);
//! assert_eq!(world.fired, vec![1, 2]);
//! ```

use super::audit::{AuditReport, AuditState, AuditViolation, CheckedWorld};
use super::Time;
use std::cell::UnsafeCell;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// A simulation world: the mutable state threaded through every event,
/// its typed event vocabulary, and the dispatcher that executes one
/// event at its scheduled virtual time.
pub trait World: Sized + 'static {
    /// The compact event representation.  Keep it small and `Copy`: the
    /// engine stores events by value in the arena (and ships them across
    /// partition workers, hence `Send`).
    type Event: Send + 'static;

    /// Execute `event` at its fire time.  `sim.now()` is the event's
    /// scheduled time; the handler may schedule further events.
    fn handle(sim: &mut Sim<Self>, state: &mut Self, event: Self::Event);
}

/// Partition id of events the coordinator runner must execute between
/// windows of a parallel run (job control, collective barriers, spine
/// resources — anything not owned by a single partition).
pub const GLOBAL_PARTITION: u32 = u32::MAX;

/// A [`World`] that additionally knows how to shard itself for the
/// conservative parallel executive ([`Sim::run_parallel`]).
///
/// # Safety
///
/// The *safe* function [`Sim::run_parallel`] executes different
/// partitions' events concurrently against one shared state with no
/// synchronization, deriving the disjointness of their accesses from
/// the routing contract below — an implementation that breaks the
/// contract causes a data race, not merely wrong numbers, which is why
/// the trait is `unsafe` to implement.  The engine's
/// schedule-into-the-past panic and the barrier's lookahead check are
/// runtime *detectors* for violations, not the proof; under
/// [`EngineKind::Checked`] the [`audit`](super::audit) module checks
/// every clause below at run time and reports breaches as structured
/// violations (the full invariant catalog, with each clause's
/// source-of-truth contract, is `docs/INVARIANTS.md`).  Implementors
/// must guarantee:
///
/// * an event routed to partition `p` must, when handled, mutate only
///   state owned by `p` (plus state no other partition's events touch;
///   atomics are fine);
/// * any event a handler schedules into a *different partition* must be
///   at least [`PartitionedWorld::lookahead`] seconds in the future;
/// * events routed to [`GLOBAL_PARTITION`] may touch anything and may
///   be scheduled with **any** delay >= 0 (the coordinator carve-out):
///   they run on the coordinator thread, never concurrently with
///   partition workers.  The carve-out is sound because the
///   coordinator's head clamps every window end (no partition drains
///   past a pending global event) and the coordinator's clock never
///   passes the earliest un-drained partition event, so a merged global
///   emission is never in the coordinator's past.  Mind the ordering
///   consequence: a global event emitted mid-window executes only at
///   the barrier, after sibling partitions have drained events *later*
///   than it — its effects must therefore feed back into partitions
///   only through future events, which the first two rules already
///   force to be at least one lookahead away.
pub unsafe trait PartitionedWorld: World {
    /// Immutable routing table captured once per run (cheap to copy into
    /// every worker's router closure).
    type Map: Copy + Send + 'static;

    /// Snapshot the routing table.
    fn partition_map(&self) -> Self::Map;

    /// Number of partitions the map shards events into.
    fn partition_count(map: &Self::Map) -> usize;

    /// Owning partition of `event`, or [`GLOBAL_PARTITION`].
    fn route(map: &Self::Map, event: &Self::Event) -> u32;

    /// Conservative lookahead: the minimum virtual-time delay of any
    /// cross-partition scheduling path.  Zero degrades the executive to
    /// same-timestamp cohort draining (still correct, less parallel).
    fn lookahead(&self) -> Time;

    /// Thread-independent tie-break for same-time deferred emissions at
    /// the window barrier.  Which *partition* carries an emission can
    /// itself be interleaving-dependent — e.g. an atomic countdown where
    /// whichever rank decrements to zero posts the completion event — so
    /// the merge orders equal-time events by this key, never by source
    /// partition index.  Two distinct events that can legally share a
    /// timestamp must either map to distinct keys or be interchangeable
    /// (identical handler effect); otherwise the run is not reproducible
    /// across thread counts.
    fn merge_key(map: &Self::Map, event: &Self::Event) -> u128;
}

/// Per-runner counters of a parallel run ([`Sim::partition_stats`]):
/// entry 0 is the coordinator, entries 1.. the partitions in index
/// order.  The spread of `events` across partitions is the load-balance
/// signal `smartnic engine-bench` reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PartitionStats {
    /// events this runner executed
    pub events: u64,
    /// high-water mark of this runner's pending-event count
    pub peak_queue_depth: usize,
}

/// A boxed action: the unit of the test-only boxed-closure baseline.
#[cfg(any(test, feature = "testing"))]
type Action<W> = Box<dyn FnOnce(&mut Sim<W>, &mut W) + Send>;

/// One pending queue entry: a typed event or (tests only) a closure.
enum Stored<W: World> {
    Event(W::Event),
    #[cfg(any(test, feature = "testing"))]
    Closure(Action<W>),
}

/// Which queue representation / executive a [`Sim`] runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// typed-event arena + hierarchical calendar queue (the default)
    Typed,
    /// the typed engine under the leaf-partitioned conservative parallel
    /// executive: [`Sim::run_parallel`] with this many worker threads
    /// (1 = the windowed executive without thread spawns)
    Parallel {
        /// worker threads partitions are chunked across
        threads: usize,
    },
    /// the typed engine with the runtime invariant auditor attached
    /// ([`super::audit`]): sequential when `threads == 0`, the parallel
    /// executive otherwise.  Contract breaches are recorded as
    /// structured [`super::audit::AuditViolation`]s on
    /// [`Sim::audit_report`] instead of panicking; results stay
    /// bit-identical to the unchecked engine on contract-clean worlds.
    Checked {
        /// worker threads (0 = sequential audited run)
        threads: usize,
    },
    /// the PR-3 representation — one boxed closure per event on a
    /// `BinaryHeap` — kept as the benchmark and equivalence baseline
    /// (tests and the `testing` feature only)
    #[cfg(any(test, feature = "testing"))]
    BoxedBaseline,
}

// ---------------------------------------------------------------------
// Calendar queue: (time, seq) keys over an index arena
// ---------------------------------------------------------------------

/// Queue key.  `(time, seq)` is the engine's total order (`total_cmp`
/// is safe because scheduling rejects non-finite times); `slot` indexes
/// the event arena.
#[derive(Clone, Copy)]
struct Key {
    time: Time,
    seq: u64,
    slot: u32,
}

impl PartialEq for Key {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Pending-event storage: slots recycled through a LIFO free list, so
/// steady-state scheduling reuses hot memory instead of allocating.
struct Arena<W: World> {
    slots: Vec<Option<Stored<W>>>,
    free: Vec<u32>,
}

impl<W: World> Arena<W> {
    fn new() -> Self {
        Self {
            slots: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Store an event; the `bool` reports whether the free list handed
    /// out a slot that was still occupied (the old entry is clobbered —
    /// an engine bug the audited executive records as
    /// [`AuditViolation::SlotAliased`]).
    fn insert(&mut self, stored: Stored<W>) -> (u32, bool) {
        match self.free.pop() {
            Some(slot) => {
                let aliased = self
                    .slots
                    .get_mut(slot as usize)
                    .expect("free-list slot outside the arena (engine bug)")
                    .replace(stored)
                    .is_some();
                (slot, aliased)
            }
            None => {
                assert!(
                    self.slots.len() < u32::MAX as usize,
                    "event arena exhausted (more than 2^32-1 pending events)"
                );
                self.slots.push(Some(stored));
                ((self.slots.len() - 1) as u32, false)
            }
        }
    }

    fn take(&mut self, slot: u32) -> Stored<W> {
        let stored = self
            .slots
            .get_mut(slot as usize)
            .expect("popped key's slot outside the arena (engine bug)")
            .take()
            .expect("empty arena slot (engine bug)");
        self.free.push(slot);
        stored
    }
}

/// Buckets in the wheel.
const BUCKETS: usize = 512;
/// Maximum overflow events moved per wheel rebase.
const REFILL_BATCH: usize = 8192;

/// The hierarchical calendar queue.
///
/// Bucket `i` covers virtual times `[base + i·width, base + (i+1)·width)`;
/// buckets below `next_bucket` have been drained into the `front` heap
/// (which therefore holds the global minimum once non-empty), and events
/// past the wheel horizon wait in the `overflow` heap.  When the wheel
/// empties, `refill` rebases it on the earliest overflow batch and
/// re-derives `width` from that batch's span, so bucket granularity
/// tracks the simulation's actual event density.
///
/// Placement is decided purely by the bucket index a time maps to, and a
/// whole bucket moves into `front` at once — so every pending event with
/// a key below any bucketed event's key is always in `front`, and pops
/// follow the exact `(time, seq)` order of a single global heap.
struct Calendar {
    /// events already past the wheel frontier, drained in key order
    front: BinaryHeap<Reverse<Key>>,
    /// wheel origin: bucket 0 starts here
    base: Time,
    /// bucket granularity (seconds); always finite and > 0
    width: Time,
    /// buckets below this index have been drained into `front`
    next_bucket: usize,
    buckets: Vec<Vec<Key>>,
    /// events at or beyond the wheel horizon
    overflow: BinaryHeap<Reverse<Key>>,
    len: usize,
}

impl Calendar {
    fn new() -> Self {
        Self {
            front: BinaryHeap::new(),
            base: 0.0,
            width: 1e-6,
            next_bucket: 0,
            buckets: (0..BUCKETS).map(|_| Vec::new()).collect(),
            overflow: BinaryHeap::new(),
            len: 0,
        }
    }

    /// Wheel index `time` maps to under the current `(base, width)`.
    /// The saturating float→usize cast sends negatives to 0 (such times
    /// sit below the frontier and belong in `front`) and huge quotients
    /// to `usize::MAX` (beyond the horizon: overflow).
    fn index_of(&self, time: Time) -> usize {
        ((time - self.base) / self.width) as usize
    }

    fn push(&mut self, key: Key) {
        self.len += 1;
        let idx = self.index_of(key.time);
        if idx < self.next_bucket {
            self.front.push(Reverse(key));
        } else if let Some(bucket) = self.buckets.get_mut(idx) {
            bucket.push(key);
        } else {
            self.overflow.push(Reverse(key));
        }
    }

    /// Advance buckets into `front` until it holds the global minimum
    /// (no-op when it already does; returns with `front` empty only when
    /// the whole queue is empty).
    fn ensure_front(&mut self) {
        while self.front.is_empty() {
            while self
                .buckets
                .get(self.next_bucket)
                .is_some_and(Vec::is_empty)
            {
                self.next_bucket += 1;
            }
            if let Some(bucket) = self.buckets.get_mut(self.next_bucket) {
                self.next_bucket += 1;
                while let Some(key) = bucket.pop() {
                    self.front.push(Reverse(key));
                }
            } else if !self.refill() {
                return;
            }
        }
    }

    /// The wheel is exhausted: rebase it on the earliest overflow batch.
    /// Returns false when the overflow is empty too.
    fn refill(&mut self) -> bool {
        let Some(Reverse(first)) = self.overflow.pop() else {
            return false;
        };
        let mut batch = Vec::with_capacity(REFILL_BATCH.min(self.overflow.len() + 1));
        batch.push(first);
        while batch.len() < REFILL_BATCH {
            match self.overflow.pop() {
                Some(Reverse(key)) => batch.push(key),
                None => break,
            }
        }
        // Heap pops arrive in key order, so the batch is time-sorted:
        // size the wheel to its span.  A zero span (all ties) keeps the
        // previous width — everything lands in bucket 0.
        let span = batch.last().expect("refill batch holds at least `first`").time - first.time;
        if span > 0.0 {
            self.width = span / BUCKETS as f64;
        }
        self.base = first.time;
        self.next_bucket = 0;
        for key in batch {
            let idx = self.index_of(key.time);
            if let Some(bucket) = self.buckets.get_mut(idx) {
                bucket.push(key);
            } else {
                // float rounding at the horizon (or a degenerate width):
                // spill back.  `first` always maps to bucket 0, so every
                // refill makes progress.
                self.overflow.push(Reverse(key));
            }
        }
        true
    }

    fn pop(&mut self) -> Option<Key> {
        self.ensure_front();
        let Reverse(key) = self.front.pop()?;
        self.len -= 1;
        Some(key)
    }

    fn peek_time(&mut self) -> Option<Time> {
        self.ensure_front();
        self.front.peek().map(|Reverse(key)| key.time)
    }
}

// ---------------------------------------------------------------------
// Boxed-closure baseline representation (PR 3, tests only)
// ---------------------------------------------------------------------

#[cfg(any(test, feature = "testing"))]
struct BoxedScheduled<W: World> {
    time: Time,
    seq: u64,
    action: Action<W>,
}

#[cfg(any(test, feature = "testing"))]
impl<W: World> PartialEq for BoxedScheduled<W> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
#[cfg(any(test, feature = "testing"))]
impl<W: World> Eq for BoxedScheduled<W> {}
#[cfg(any(test, feature = "testing"))]
impl<W: World> PartialOrd for BoxedScheduled<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
#[cfg(any(test, feature = "testing"))]
impl<W: World> Ord for BoxedScheduled<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first, exactly
        // as the PR-3 engine did.
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

enum QueueImpl<W: World> {
    Typed {
        calendar: Calendar,
        arena: Arena<W>,
    },
    #[cfg(any(test, feature = "testing"))]
    Boxed(BinaryHeap<BoxedScheduled<W>>),
}

// ---------------------------------------------------------------------
// The executive
// ---------------------------------------------------------------------

/// Shared-state handle for window workers.  The coordinator's exclusive
/// borrow is reinterpreted as a shared [`UnsafeCell`] reference for the
/// span of one window, so no worker ever materializes a long-lived
/// `&mut W`: [`Sim::run_window_shared`] forms an exclusive reference
/// only for the duration of a single handler call, and the accesses
/// those calls make are disjoint across workers by the (`unsafe`)
/// [`PartitionedWorld`] routing contract.
struct SharedState<'a, W>(&'a UnsafeCell<W>);

impl<W> Clone for SharedState<'_, W> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<W> Copy for SharedState<'_, W> {}

// SAFETY: the cell is dereferenced only inside `run_window_shared`,
// whose per-handler accesses are disjoint across concurrent workers by
// the `PartitionedWorld` routing contract; the coordinator is parked at
// the thread-scope join while workers run, and the referent outlives
// the scope.  `Send` is what the worker closures need (each captures
// its own copy); `W: Send` bounds both, as handing the handle to
// another thread hands it mutable access to `W`.
unsafe impl<W: Send> Send for SharedState<'_, W> {}
// SAFETY: as for `Send` above — a `&SharedState` grants nothing a copy
// of the handle doesn't, and every dereference stays inside
// `run_window_shared`.
unsafe impl<W: Send> Sync for SharedState<'_, W> {}

/// The simulation executive.  `W` is the simulation world: its state is
/// threaded by `&mut` into every event, so handlers never capture
/// aliased state.
pub struct Sim<W: World> {
    now: Time,
    seq: u64,
    events_run: u64,
    peak_pending: usize,
    kind: EngineKind,
    queue: QueueImpl<W>,
    /// partition this runner owns ([`GLOBAL_PARTITION`] outside a
    /// parallel run, and for the coordinator inside one)
    my_partition: u32,
    /// when set, `schedule_at` diverts events owned by other partitions
    /// into `deferred` instead of this runner's queue
    #[allow(clippy::type_complexity)]
    router: Option<Box<dyn Fn(&W::Event) -> u32 + Send>>,
    /// cross-partition emissions awaiting the next window barrier
    deferred: Vec<(Time, W::Event)>,
    /// per-runner counters of the last parallel run
    part_stats: Vec<PartitionStats>,
    /// stop running once this many events executed (bench event cap)
    budget: Option<u64>,
    /// the invariant auditor ([`EngineKind::Checked`] only) — `None`
    /// costs one branch per operation, the zero-cost-when-off contract
    audit: Option<Box<AuditState>>,
}

impl<W: World> Default for Sim<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W: World> Sim<W> {
    /// A typed-event calendar-queue engine (the production default).
    pub fn new() -> Self {
        Self::with_engine(EngineKind::Typed)
    }

    /// An engine on an explicit queue representation.
    pub fn with_engine(kind: EngineKind) -> Self {
        let queue = match kind {
            #[cfg(any(test, feature = "testing"))]
            EngineKind::BoxedBaseline => QueueImpl::Boxed(BinaryHeap::new()),
            _ => QueueImpl::Typed {
                calendar: Calendar::new(),
                arena: Arena::new(),
            },
        };
        Self {
            now: 0.0,
            seq: 0,
            events_run: 0,
            peak_pending: 0,
            kind,
            queue,
            my_partition: GLOBAL_PARTITION,
            router: None,
            deferred: Vec::new(),
            part_stats: Vec::new(),
            budget: None,
            audit: matches!(kind, EngineKind::Checked { .. })
                .then(|| Box::new(AuditState::new())),
        }
    }

    /// Which representation this engine runs on.
    pub fn engine_kind(&self) -> EngineKind {
        self.kind
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn events_run(&self) -> u64 {
        self.events_run
    }

    pub fn pending(&self) -> usize {
        match &self.queue {
            QueueImpl::Typed { calendar, .. } => calendar.len,
            #[cfg(any(test, feature = "testing"))]
            QueueImpl::Boxed(heap) => heap.len(),
        }
    }

    /// High-water mark of the pending-event count (the benchmark's
    /// peak-queue-depth metric).  After a parallel run: the worst single
    /// runner's high-water mark.
    pub fn peak_pending(&self) -> usize {
        self.peak_pending
    }

    /// Per-runner counters of the last [`Sim::run_parallel`] call
    /// (entry 0 = coordinator, 1.. = partitions); empty after a
    /// sequential run.
    pub fn partition_stats(&self) -> &[PartitionStats] {
        &self.part_stats
    }

    /// Whether the invariant auditor is attached
    /// ([`EngineKind::Checked`]).
    pub fn audited(&self) -> bool {
        self.audit.is_some()
    }

    /// The auditor's report so far (`None` unless
    /// [`EngineKind::Checked`]).  After a parallel run, every
    /// partition's findings are already merged in.
    pub fn audit_report(&self) -> Option<&AuditReport> {
        self.audit.as_deref().map(|a| &a.report)
    }

    /// Move the auditor's report out, leaving a fresh empty one
    /// (`None` unless [`EngineKind::Checked`]).
    pub fn take_audit_report(&mut self) -> Option<AuditReport> {
        self.audit.as_deref_mut().map(|a| std::mem::take(&mut a.report))
    }

    /// Test hook: duplicate the top free-list entry so two subsequent
    /// schedules land in one arena slot — seeds the `SlotAliased`
    /// violation for the auditor's negative tests.
    #[cfg(test)]
    fn alias_free_slot_for_test(&mut self) {
        if let QueueImpl::Typed { arena, .. } = &mut self.queue {
            let slot = *arena.free.last().expect("free list empty; run an event first");
            arena.free.push(slot);
        }
    }

    /// Cap the total number of events a subsequent run executes (`None`
    /// = unbounded).  The benchmark's big-N sweeps use this to measure
    /// steady-state throughput without draining quadratically many ring
    /// events; a parallel run checks the cap at window granularity, so
    /// it may overshoot by one window (deterministically).
    pub fn set_event_budget(&mut self, budget: Option<u64>) {
        self.budget = budget;
    }

    /// Schedule a typed event `delay` seconds from now.
    pub fn schedule(&mut self, delay: Time, event: W::Event) {
        if self.audit.is_none() {
            self.assert_delay(delay);
        }
        // audited: `now + delay` funnels a bad delay into `schedule_at`'s
        // checks (NaN/∞ → non-finite time, negative → past), so every
        // violation is recorded at one choke point
        self.schedule_at(self.now + delay, event);
    }

    /// Schedule a typed event at an absolute time (>= now, finite — a
    /// NaN or infinite time would corrupt the queue order).  Unchecked
    /// engines panic on a precondition breach; [`EngineKind::Checked`]
    /// records a structured violation instead and keeps the run alive
    /// (non-finite times drop the event, past times clamp to `now`).
    pub fn schedule_at(&mut self, time: Time, event: W::Event) {
        let time = if let Some(audit) = self.audit.as_deref_mut() {
            match audit.on_schedule(time, self.now) {
                Some(time) => time,
                None => return, // recorded and dropped
            }
        } else {
            self.check_time(time);
            time
        };
        if let Some(router) = &self.router {
            let p = router(&event);
            if p != self.my_partition {
                // another runner owns this event: hold it for the next
                // window barrier (the coordinator drains its buffer
                // after every global step)
                self.deferred.push((time, event));
                return;
            }
        }
        self.push_stored(time, Stored::Event(event));
    }

    /// Escape hatch (tests only): schedule a boxed closure `delay`
    /// seconds from now.  Production scheduler clients post typed
    /// events via [`Sim::schedule`] / [`Sim::schedule_at`].
    #[cfg(any(test, feature = "testing"))]
    pub fn schedule_closure(
        &mut self,
        delay: Time,
        action: impl FnOnce(&mut Sim<W>, &mut W) + Send + 'static,
    ) {
        self.assert_delay(delay);
        self.schedule_closure_at(self.now + delay, action);
    }

    /// Escape hatch (tests only): [`Sim::schedule_closure`] at an
    /// absolute time.
    #[cfg(any(test, feature = "testing"))]
    pub fn schedule_closure_at(
        &mut self,
        time: Time,
        action: impl FnOnce(&mut Sim<W>, &mut W) + Send + 'static,
    ) {
        self.check_time(time);
        self.push_stored(time, Stored::Closure(Box::new(action)));
    }

    fn assert_delay(&self, delay: Time) {
        assert!(
            delay.is_finite() && delay >= 0.0,
            "delay must be finite and non-negative, got {delay}"
        );
    }

    fn check_time(&self, time: Time) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        assert!(
            time >= self.now,
            "cannot schedule into the past: {time} < {}",
            self.now
        );
    }

    fn push_stored(&mut self, time: Time, stored: Stored<W>) {
        let seq = self.seq;
        self.seq += 1;
        match &mut self.queue {
            QueueImpl::Typed { calendar, arena } => {
                let (slot, aliased) = arena.insert(stored);
                if aliased {
                    match self.audit.as_deref_mut() {
                        Some(audit) => audit.report.record(AuditViolation::SlotAliased { slot }),
                        None => debug_assert!(false, "arena slot {slot} aliased (engine bug)"),
                    }
                }
                calendar.push(Key { time, seq, slot });
            }
            #[cfg(any(test, feature = "testing"))]
            QueueImpl::Boxed(heap) => {
                let action: Action<W> = match stored {
                    Stored::Closure(action) => action,
                    Stored::Event(event) => {
                        Box::new(move |sim: &mut Sim<W>, state: &mut W| {
                            W::handle(sim, state, event)
                        })
                    }
                };
                heap.push(BoxedScheduled { time, seq, action });
            }
        }
        self.peak_pending = self.peak_pending.max(self.pending());
    }

    fn pop_next(&mut self) -> Option<(Time, u64, Stored<W>)> {
        match &mut self.queue {
            QueueImpl::Typed { calendar, arena } => {
                let key = calendar.pop()?;
                Some((key.time, key.seq, arena.take(key.slot)))
            }
            #[cfg(any(test, feature = "testing"))]
            QueueImpl::Boxed(heap) => {
                heap.pop().map(|s| (s.time, s.seq, Stored::Closure(s.action)))
            }
        }
    }

    /// Virtual time of the earliest pending event.
    fn peek_time(&mut self) -> Option<Time> {
        match &mut self.queue {
            QueueImpl::Typed { calendar, .. } => calendar.peek_time(),
            #[cfg(any(test, feature = "testing"))]
            QueueImpl::Boxed(heap) => heap.peek().map(|s| s.time),
        }
    }

    /// Run until the queue drains (or the event budget is hit); returns
    /// final virtual time.
    pub fn run(&mut self, state: &mut W) -> Time {
        let budget = self.budget.unwrap_or(u64::MAX);
        while self.events_run < budget && self.step(state) {}
        self.now
    }

    /// Run at most until virtual time `t_end` (events at exactly t_end
    /// run).
    pub fn run_until(&mut self, state: &mut W, t_end: Time) -> Time {
        while let Some(head) = self.peek_time() {
            if head > t_end {
                break;
            }
            self.step(state);
        }
        self.now
    }

    /// Drain events strictly below `end` (or, when `inclusive`, up to
    /// and including it — the same-timestamp cohort mode).
    fn run_window(&mut self, state: &mut W, end: Time, inclusive: bool) {
        if inclusive {
            self.run_until(state, end);
        } else {
            while let Some(head) = self.peek_time() {
                if head >= end {
                    break;
                }
                self.step(state);
            }
        }
    }

    /// [`Sim::run_window`] for parallel window workers: the state is
    /// shared behind an [`UnsafeCell`], and an exclusive reference is
    /// materialized per handler call only — no `&mut W` is live across
    /// two events, let alone across the whole window, while sibling
    /// workers run.
    ///
    /// # Safety
    ///
    /// Every concurrent accessor of the shared state must be another
    /// `run_window_shared` worker draining a *different* partition of a
    /// [`PartitionedWorld`] whose (unsafe-trait) routing contract holds,
    /// and the referent must outlive the call.
    unsafe fn run_window_shared(
        &mut self,
        shared: SharedState<'_, W>,
        end: Time,
        inclusive: bool,
    ) {
        while let Some(head) = self.peek_time() {
            let past_end = if inclusive { head > end } else { head >= end };
            if past_end {
                break;
            }
            let Some((time, seq, stored)) = self.pop_next() else {
                break;
            };
            match self.audit.as_deref_mut() {
                Some(audit) => audit.on_pop(time, seq, self.now),
                None => debug_assert!(time >= self.now),
            }
            self.now = time;
            self.events_run += 1;
            // SAFETY: exclusive for the span of this one handler call —
            // sibling workers' handlers touch disjoint state by the
            // routing contract, and the reference dies before the next
            // pop.
            let state = unsafe { &mut *shared.0.get() };
            match stored {
                Stored::Event(event) => W::handle(self, state, event),
                #[cfg(any(test, feature = "testing"))]
                Stored::Closure(action) => action(self, state),
            }
        }
    }

    /// Execute the single earliest event.  Returns false when empty.
    pub fn step(&mut self, state: &mut W) -> bool {
        match self.pop_next() {
            None => false,
            Some((time, seq, stored)) => {
                match self.audit.as_deref_mut() {
                    Some(audit) => audit.on_pop(time, seq, self.now),
                    None => debug_assert!(time >= self.now),
                }
                self.now = time;
                self.events_run += 1;
                match stored {
                    Stored::Event(event) => W::handle(self, state, event),
                    #[cfg(any(test, feature = "testing"))]
                    Stored::Closure(action) => action(self, state),
                }
                true
            }
        }
    }

    /// Hand this runner's deferred emissions to their owning partitions
    /// (coordinator side: called after every global step, so partitions
    /// see globally produced events before their next window).
    fn flush_deferred(&mut self, parts: &mut [Sim<W>]) {
        if self.deferred.is_empty() {
            return;
        }
        let drained = std::mem::take(&mut self.deferred);
        for (time, event) in drained {
            let p = self.router.as_ref().map_or(GLOBAL_PARTITION, |r| r(&event));
            debug_assert_ne!(p, self.my_partition, "deferred event routed back to its source");
            parts
                .get_mut(p as usize)
                .expect("routed partition outside the partition table")
                .schedule_at(time, event);
        }
    }

    /// Run to completion under the leaf-partitioned conservative
    /// parallel executive.
    ///
    /// The loop alternates two phases:
    ///
    /// 1. while the coordinator's head event is not later than every
    ///    partition's head, execute it alone with full `&mut W` access
    ///    (global events may touch anything);
    /// 2. otherwise open a window `[T, T + lookahead)` at the minimum
    ///    partition head `T` (clamped below the coordinator's head) and
    ///    drain every partition's events inside it concurrently on
    ///    `threads` scoped workers — safe because, by the
    ///    [`PartitionedWorld`] contract, no event inside the window can
    ///    affect another partition earlier than the window's end.
    ///
    /// Cross-partition/coordinator emissions are deferred during the
    /// window and merged at the barrier in ascending
    /// `(time, merge-key)` order — [`PartitionedWorld::merge_key`] is a
    /// function of the event alone, so the executed order, and
    /// therefore every virtual-time result, is identical for any
    /// `threads` (including 1) even when which partition carries an
    /// emission is decided by an atomic race.
    pub fn run_parallel(&mut self, state: &mut W, threads: usize) -> Time
    where
        W: PartitionedWorld + Send,
    {
        assert!(threads >= 1, "parallel engine needs at least one thread");
        let map = state.partition_map();
        let nparts = W::partition_count(&map);
        assert!(nparts >= 1, "parallel engine needs at least one partition");
        let lookahead = state.lookahead();
        assert!(
            lookahead.is_finite() && lookahead >= 0.0,
            "lookahead must be finite and non-negative, got {lookahead}"
        );

        // The checker snapshots the routing table for the barrier-side
        // contract checks; partitions get their own auditors, merged
        // back into this runner's report at the end of the run.
        let checker: Option<CheckedWorld<W>> =
            self.audit.is_some().then(|| CheckedWorld::new(&*state));
        let mut parts: Vec<Sim<W>> = (0..nparts)
            .map(|p| {
                let pmap = map;
                let mut part = Sim::with_engine(EngineKind::Typed);
                part.my_partition = p as u32;
                part.router = Some(Box::new(move |ev: &W::Event| W::route(&pmap, ev)));
                if self.audit.is_some() {
                    part.audit = Some(Box::new(AuditState::new()));
                }
                part
            })
            .collect();
        self.my_partition = GLOBAL_PARTITION;
        self.router = Some(Box::new(move |ev: &W::Event| W::route(&map, ev)));

        // Re-route everything scheduled before the run (job seeds): pop
        // in (time, seq) order, push through the router.
        let mut seeds: Vec<(Time, W::Event)> = Vec::new();
        while let Some((time, _seq, stored)) = self.pop_next() {
            match stored {
                Stored::Event(event) => seeds.push((time, event)),
                #[cfg(any(test, feature = "testing"))]
                Stored::Closure(_) => {
                    panic!("EngineKind::Parallel cannot route closures; post typed events")
                }
            }
        }
        for (time, event) in seeds {
            self.schedule_at(time, event);
        }
        self.flush_deferred(&mut parts);

        let budget = self.budget.unwrap_or(u64::MAX);
        loop {
            let total: u64 = self.events_run + parts.iter().map(|p| p.events_run).sum::<u64>();
            if total >= budget {
                break;
            }
            let t_global = self.peek_time();
            let t_local = parts
                .iter_mut()
                .filter_map(|p| p.peek_time())
                .min_by(|a, b| a.total_cmp(b));
            if let Some(audit) = self.audit.as_deref_mut() {
                // LBTS — the lower bound on the next executed timestamp
                // (min over every runner's head) — must never regress:
                // window starts and global steps both consume it in
                // non-decreasing order or the conservative argument is
                // broken.
                let lbts = match (t_global, t_local) {
                    (Some(g), Some(l)) => Some(g.min(l)),
                    (g, l) => g.or(l),
                };
                if let Some(lbts) = lbts {
                    audit.on_lbts(lbts);
                }
            }
            let window_start = match (t_global, t_local) {
                (None, None) => break,
                (Some(_), None) => {
                    self.step(state);
                    self.flush_deferred(&mut parts);
                    continue;
                }
                (Some(g), Some(l)) if g <= l => {
                    self.step(state);
                    self.flush_deferred(&mut parts);
                    continue;
                }
                (_, Some(l)) => l,
            };

            // A window: [start, end) exclusive when lookahead > 0, the
            // same-timestamp cohort {start} otherwise.  The coordinator's
            // head caps the end so no partition overruns a pending
            // global event.
            let cap = t_global.unwrap_or(f64::INFINITY);
            let (end, inclusive) = if lookahead > 0.0 {
                ((window_start + lookahead).min(cap), false)
            } else {
                (window_start, true)
            };

            let workers = threads.min(parts.len());
            if workers <= 1 {
                for part in parts.iter_mut() {
                    part.run_window(state, end, inclusive);
                }
            } else {
                let chunk = parts.len().div_ceil(workers);
                // SAFETY: `UnsafeCell<W>` is
                // `repr(transparent)` over `W`, so reborrowing the
                // exclusive reference as a shared cell reference is the
                // standard `UnsafeCell::from_mut` construction.  It
                // routes all further access through raw pointers: any
                // `&mut W` is confined to a single handler call inside
                // `run_window_shared`, so no two live `&mut W` span
                // each other across threads.
                let shared = SharedState(unsafe { &*(state as *mut W as *const UnsafeCell<W>) });
                std::thread::scope(|scope| {
                    for slice in parts.chunks_mut(chunk) {
                        scope.spawn(move || {
                            for part in slice.iter_mut() {
                                // SAFETY: concurrent workers drain
                                // disjoint partition slices of an
                                // `unsafe impl PartitionedWorld` world
                                // (whose routing contract guarantees
                                // their handlers touch disjoint state),
                                // and the coordinator is parked at the
                                // scope join until all workers finish.
                                unsafe { part.run_window_shared(shared, end, inclusive) };
                            }
                        });
                    }
                });
            }

            // Barrier: merge the window's cross-partition emissions in
            // ascending (time, merge-key) order.  The key — a function
            // of the event alone — breaks same-time ties, never the
            // source partition index: which partition carries an
            // emission can itself be interleaving-dependent (e.g. the
            // ring's completion event is posted by whichever rank
            // retires the last writeback), so source order would not
            // reproduce across thread counts.
            let mut moved: Vec<(Time, u128, W::Event)> = Vec::new();
            for part in parts.iter_mut() {
                for (time, event) in part.deferred.drain(..) {
                    moved.push((time, W::merge_key(&map, &event), event));
                }
            }
            moved.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            if let (Some(checker), Some(audit)) = (&checker, self.audit.as_deref_mut()) {
                // merge_key must be a total order over the batch: two
                // same-(time, key) emissions would be ordered by the
                // sort's whims, not by anything thread-independent
                checker.check_merge_batch(&moved, &mut audit.report);
            }
            for (time, _key, event) in moved {
                let p = match (&checker, self.audit.as_deref_mut()) {
                    // audited: route twice, so a route() that is not a
                    // pure function of the event is caught here
                    (Some(checker), Some(audit)) => {
                        let p = checker.checked_route(&event, &mut audit.report);
                        checker.check_emission(p, time, end, &mut audit.report);
                        p
                    }
                    _ => W::route(&map, &event),
                };
                if p == GLOBAL_PARTITION {
                    // coordinator carve-out: any delay >= 0 is legal
                    self.schedule_at(time, event);
                } else {
                    // the PartitionedWorld lookahead contract: a
                    // partition-bound emission from inside the window
                    // must land at or past the window's end.  Audited
                    // runs record the breach above (and in release
                    // builds too — the PR 6 assert promoted); unchecked
                    // ones keep the debug assertion.
                    if self.audit.is_none() {
                        debug_assert!(
                            time >= end,
                            "cross-partition event violates the lookahead contract: \
                             scheduled at {time}, inside the window ending at {end}"
                        );
                    }
                    parts
                        .get_mut(p as usize)
                        .expect("routed partition outside the partition table")
                        .schedule_at(time, event);
                }
            }
        }

        // Fold the partitions back into this runner's counters.
        self.part_stats = Vec::with_capacity(parts.len() + 1);
        self.part_stats.push(PartitionStats {
            events: self.events_run,
            peak_queue_depth: self.peak_pending,
        });
        for part in parts.iter_mut() {
            self.part_stats.push(PartitionStats {
                events: part.events_run,
                peak_queue_depth: part.peak_pending,
            });
            self.events_run += part.events_run;
            self.now = self.now.max(part.now);
            self.peak_pending = self.peak_pending.max(part.peak_pending);
            // audited: fold every partition's findings into the
            // coordinator's report, so callers read one report
            if let (Some(pa), Some(audit)) = (part.audit.take(), self.audit.as_deref_mut()) {
                audit.report.merge(pa.report);
            }
        }
        self.router = None;
        self.now
    }
}

#[cfg(test)]
// tests index fixed-size logs and pin exact float times by construction
#[allow(clippy::indexing_slicing, clippy::float_cmp)]
mod tests {
    use super::*;

    /// Typed test world: events are plain tags, logged at dispatch.
    struct Log {
        fired: Vec<u32>,
        times: Vec<Time>,
    }

    impl Log {
        fn new() -> Self {
            Self {
                fired: Vec::new(),
                times: Vec::new(),
            }
        }
    }

    impl World for Log {
        type Event = u32;
        fn handle(sim: &mut Sim<Self>, state: &mut Self, event: u32) {
            state.fired.push(event);
            state.times.push(sim.now());
        }
    }

    fn both_kinds() -> [EngineKind; 2] {
        [EngineKind::Typed, EngineKind::BoxedBaseline]
    }

    #[test]
    fn events_run_in_time_order() {
        for kind in both_kinds() {
            let mut sim: Sim<Log> = Sim::with_engine(kind);
            let mut log = Log::new();
            sim.schedule(3.0, 3);
            sim.schedule(1.0, 1);
            sim.schedule(2.0, 2);
            sim.run(&mut log);
            assert_eq!(log.fired, vec![1, 2, 3], "{kind:?}");
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for kind in both_kinds() {
            let mut sim: Sim<Log> = Sim::with_engine(kind);
            let mut log = Log::new();
            for i in 0..10 {
                sim.schedule(1.0, i);
            }
            sim.run(&mut log);
            assert_eq!(log.fired, (0..10).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn events_can_schedule_events() {
        // the closure escape hatch still composes with typed dispatch
        let mut sim: Sim<Log> = Sim::new();
        let mut log = Log::new();
        sim.schedule_closure(1.0, |sim, _state| {
            sim.schedule(0.5, 7);
        });
        let end = sim.run(&mut log);
        assert_eq!(log.fired, vec![7]);
        assert_eq!(log.times, vec![1.5]);
        assert_eq!(end, 1.5);
    }

    #[test]
    fn run_until_stops() {
        for kind in both_kinds() {
            let mut sim: Sim<Log> = Sim::with_engine(kind);
            let mut log = Log::new();
            for i in 1..=10 {
                sim.schedule(f64::from(i), i as u32);
            }
            sim.run_until(&mut log, 5.0);
            assert_eq!(log.fired.len(), 5, "{kind:?}");
            assert_eq!(sim.pending(), 5, "{kind:?}");
            sim.run(&mut log);
            assert_eq!(log.fired.len(), 10, "{kind:?}");
        }
    }

    #[test]
    fn event_budget_caps_a_run() {
        let mut sim: Sim<Log> = Sim::new();
        let mut log = Log::new();
        for i in 0..100 {
            sim.schedule(f64::from(i), i as u32);
        }
        sim.set_event_budget(Some(7));
        sim.run(&mut log);
        assert_eq!(log.fired.len(), 7);
        assert_eq!(sim.pending(), 93);
        sim.set_event_budget(None);
        sim.run(&mut log);
        assert_eq!(log.fired.len(), 100);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn scheduling_nan_time_panics() {
        let mut sim: Sim<Log> = Sim::new();
        sim.schedule_at(f64::NAN, 0);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn scheduling_infinite_delay_panics() {
        let mut sim: Sim<Log> = Sim::new();
        sim.schedule(f64::INFINITY, 0);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn scheduling_into_past_panics() {
        let mut sim: Sim<Log> = Sim::new();
        sim.schedule_closure(1.0, |sim, _state| {
            sim.schedule_at(0.5, 0);
        });
        sim.run(&mut Log::new());
    }

    #[test]
    fn event_count_and_peak_depth_tracked() {
        let mut sim: Sim<Log> = Sim::new();
        for _ in 0..100 {
            sim.schedule(1.0, 0);
        }
        sim.run(&mut Log::new());
        assert_eq!(sim.events_run(), 100);
        assert_eq!(sim.peak_pending(), 100);
        assert_eq!(sim.pending(), 0);
    }

    #[test]
    fn far_future_events_cross_the_overflow_heap() {
        // spread times far past the initial wheel horizon so pushes land
        // in the overflow and pops exercise the rebase path
        let mut sim: Sim<Log> = Sim::new();
        let mut log = Log::new();
        for i in (0..200).rev() {
            sim.schedule_at(f64::from(i) * 10.0, i as u32);
        }
        sim.run(&mut log);
        assert_eq!(log.fired, (0..200).collect::<Vec<_>>());
        assert_eq!(log.times.last().copied(), Some(1990.0));
    }

    #[test]
    fn typed_and_boxed_execute_identically_under_stress() {
        // a deterministic pseudo-random cascade: every event schedules
        // up to two children at quasi-random offsets; both
        // representations must fire the same tags at the same times in
        // the same order
        struct Cascade {
            order: Vec<(u64, u32)>,
            budget: u32,
        }
        impl World for Cascade {
            type Event = u32;
            fn handle(sim: &mut Sim<Self>, state: &mut Self, event: u32) {
                state.order.push((sim.now().to_bits(), event));
                if state.budget == 0 {
                    return;
                }
                state.budget -= 1;
                // xorshift-style offsets: identical for both engines
                let mix = (u64::from(event)).wrapping_mul(0x9e37_79b9_7f4a_7c15);
                let a = (mix >> 33) % 1000;
                let b = (mix >> 13) % 1000;
                sim.schedule(a as f64 * 1e-7, event.wrapping_mul(3).wrapping_add(1));
                if event % 3 != 0 {
                    sim.schedule(b as f64 * 1e-4, event.wrapping_mul(5).wrapping_add(2));
                }
            }
        }
        let run = |kind: EngineKind| {
            let mut sim: Sim<Cascade> = Sim::with_engine(kind);
            let mut world = Cascade {
                order: Vec::new(),
                budget: 20_000,
            };
            for i in 0..64 {
                sim.schedule(f64::from(i % 7) * 1e-5, i);
            }
            sim.run(&mut world);
            world.order
        };
        assert_eq!(run(EngineKind::Typed), run(EngineKind::BoxedBaseline));
    }

    #[test]
    fn simultaneous_ties_at_the_refill_boundary_stay_ordered() {
        // many events at exactly the same far-future instant: the wheel
        // rebases with a zero span and must still drain in seq order
        let mut sim: Sim<Log> = Sim::new();
        let mut log = Log::new();
        for i in 0..100 {
            sim.schedule_at(5.0, i);
        }
        sim.run(&mut log);
        assert_eq!(log.fired, (0..100).collect::<Vec<_>>());
    }

    // -----------------------------------------------------------------
    // Parallel executive
    // -----------------------------------------------------------------

    const PARTS: usize = 3;
    const LOOKAHEAD: Time = 1e-6;

    /// A partitioned toy world: events tagged `v` with `v % 5 == 0` are
    /// global, the rest belong to partition `v % PARTS`.  Local handlers
    /// only touch their own partition's log and schedule same-partition
    /// or global children; global handlers fan out to partitions with
    /// delays >= LOOKAHEAD — the full PartitionedWorld contract.
    struct Sharded {
        logs: Vec<Vec<(u64, u32)>>,
        glog: Vec<(u64, u32)>,
    }

    impl Sharded {
        fn new() -> Self {
            Self {
                logs: (0..PARTS).map(|_| Vec::new()).collect(),
                glog: Vec::new(),
            }
        }
    }

    fn shard_of(event: u32) -> u32 {
        if event % 5 == 0 {
            GLOBAL_PARTITION
        } else {
            event % PARTS as u32
        }
    }

    impl World for Sharded {
        type Event = u32;
        fn handle(sim: &mut Sim<Self>, state: &mut Self, event: u32) {
            let stamp = sim.now().to_bits();
            if event % 5 == 0 {
                state.glog.push((stamp, event));
                if event < 1000 {
                    // fan out to every partition, one lookahead away
                    for k in 1..=3u32 {
                        sim.schedule(LOOKAHEAD * f64::from(k), event + k);
                    }
                }
            } else {
                state.logs[(event % PARTS as u32) as usize].push((stamp, event));
                if event < 1000 {
                    // a same-partition child (any delay is fine) and a
                    // global child (the coordinator owns no clock bound)
                    sim.schedule(LOOKAHEAD * 0.25, event + PARTS as u32 * 3);
                    if event % 7 == 0 {
                        sim.schedule(LOOKAHEAD * 0.5, event * 5);
                    }
                }
            }
        }
    }

    // SAFETY: `route` sends each event to the partition whose log it
    // mutates, global fan-outs re-enter partitions >= LOOKAHEAD in the
    // future, and same-partition children never leave their shard.
    unsafe impl PartitionedWorld for Sharded {
        type Map = ();
        fn partition_map(&self) -> Self::Map {}
        fn partition_count(_map: &Self::Map) -> usize {
            PARTS
        }
        fn route(_map: &Self::Map, event: &Self::Event) -> u32 {
            shard_of(*event)
        }
        fn lookahead(&self) -> Time {
            LOOKAHEAD
        }
        fn merge_key(_map: &Self::Map, event: &Self::Event) -> u128 {
            u128::from(*event)
        }
    }

    fn run_sharded(threads: Option<usize>) -> (Sharded, Time, u64) {
        let mut sim: Sim<Sharded> = match threads {
            None => Sim::new(),
            Some(t) => Sim::with_engine(EngineKind::Parallel { threads: t }),
        };
        let mut world = Sharded::new();
        for i in 1..40u32 {
            sim.schedule_at(f64::from(i) * 1e-7, i);
        }
        let end = match threads {
            None => sim.run(&mut world),
            Some(t) => sim.run_parallel(&mut world, t),
        };
        (world, end, sim.events_run())
    }

    #[test]
    fn parallel_executive_is_thread_count_invariant() {
        // bit-identical logs (values and times) for 1, 2 and 4 threads
        let (w1, end1, n1) = run_sharded(Some(1));
        for threads in [2, 4] {
            let (w, end, n) = run_sharded(Some(threads));
            assert_eq!(w.logs, w1.logs, "threads={threads}");
            assert_eq!(w.glog, w1.glog, "threads={threads}");
            assert_eq!(end.to_bits(), end1.to_bits(), "threads={threads}");
            assert_eq!(n, n1, "threads={threads}");
        }
    }

    #[test]
    fn parallel_executive_matches_sequential_results() {
        // same events, same per-event times and same final clock as the
        // sequential engine; only cross-runner tie order may differ, so
        // compare per-partition logs as sorted multisets
        let (seq, seq_end, seq_n) = run_sharded(None);
        let (par, par_end, par_n) = run_sharded(Some(4));
        assert_eq!(par_n, seq_n, "event counts diverged");
        assert_eq!(par_end.to_bits(), seq_end.to_bits(), "final clocks diverged");
        let sorted = |mut v: Vec<(u64, u32)>| {
            v.sort_unstable();
            v
        };
        for p in 0..PARTS {
            assert_eq!(
                sorted(par.logs[p].clone()),
                sorted(seq.logs[p].clone()),
                "partition {p} diverged"
            );
        }
        assert_eq!(sorted(par.glog), sorted(seq.glog), "global log diverged");
    }

    #[test]
    fn parallel_partition_stats_are_reported() {
        let mut sim: Sim<Sharded> = Sim::with_engine(EngineKind::Parallel { threads: 2 });
        let mut world = Sharded::new();
        for i in 1..40u32 {
            sim.schedule_at(f64::from(i) * 1e-7, i);
        }
        sim.run_parallel(&mut world, 2);
        let stats = sim.partition_stats();
        assert_eq!(stats.len(), PARTS + 1, "coordinator + one entry per partition");
        let total: u64 = stats.iter().map(|s| s.events).sum();
        assert_eq!(total, sim.events_run());
        assert!(stats.iter().skip(1).any(|s| s.events > 0), "no partition ran events");
    }

    // -----------------------------------------------------------------
    // Checked executive (the invariant auditor)
    // -----------------------------------------------------------------

    use std::sync::atomic::{AtomicU32, Ordering as AtomicOrdering};

    #[test]
    fn checked_sequential_is_bit_identical_and_clean() {
        let mut sim: Sim<Sharded> = Sim::with_engine(EngineKind::Checked { threads: 0 });
        let mut world = Sharded::new();
        for i in 1..40u32 {
            sim.schedule_at(f64::from(i) * 1e-7, i);
        }
        let end = sim.run(&mut world);
        let n = sim.events_run();
        let report = sim.take_audit_report().expect("checked engine carries a report");
        assert!(report.is_clean(), "{}", report.summary());
        assert_eq!(report.events_checked(), n, "every dispatch must be checked");
        let (seq, seq_end, seq_n) = run_sharded(None);
        assert_eq!(end.to_bits(), seq_end.to_bits(), "audited clock diverged");
        assert_eq!(n, seq_n);
        assert_eq!(world.logs, seq.logs, "auditing must not perturb execution");
        assert_eq!(world.glog, seq.glog);
    }

    #[test]
    fn checked_parallel_is_thread_invariant_and_clean() {
        let (w1, end1, n1) = run_sharded(Some(1));
        for threads in [1, 2, 4] {
            let mut sim: Sim<Sharded> = Sim::with_engine(EngineKind::Checked { threads });
            let mut world = Sharded::new();
            for i in 1..40u32 {
                sim.schedule_at(f64::from(i) * 1e-7, i);
            }
            let end = sim.run_parallel(&mut world, threads);
            let report = sim.take_audit_report().expect("checked engine carries a report");
            assert!(report.is_clean(), "threads={threads}: {}", report.summary());
            assert_eq!(world.logs, w1.logs, "threads={threads}");
            assert_eq!(world.glog, w1.glog, "threads={threads}");
            assert_eq!(end.to_bits(), end1.to_bits(), "threads={threads}");
            assert_eq!(sim.events_run(), n1, "threads={threads}");
        }
    }

    #[test]
    fn audited_non_finite_schedule_reports_and_drops() {
        let mut sim: Sim<Log> = Sim::with_engine(EngineKind::Checked { threads: 0 });
        let mut log = Log::new();
        sim.schedule_at(f64::NAN, 1);
        sim.schedule(f64::INFINITY, 2);
        sim.schedule(1.0, 3);
        sim.run(&mut log);
        assert_eq!(log.fired, vec![3], "non-finite events must be dropped");
        let report = sim.audit_report().expect("checked engine carries a report");
        assert_eq!(report.total(), 2);
        assert!(report.violations().iter().all(|v| v.kind() == "non-finite-time"));
    }

    #[test]
    fn audited_past_schedule_clamps_and_reports() {
        let mut sim: Sim<Log> = Sim::with_engine(EngineKind::Checked { threads: 0 });
        let mut log = Log::new();
        sim.schedule_closure(1.0, |sim, _state| {
            sim.schedule_at(0.25, 9); // into the scheduler's past
        });
        sim.run(&mut log);
        assert_eq!(log.fired, vec![9], "the clamped event still runs");
        assert_eq!(log.times, vec![1.0], "clamped to the scheduler's now");
        let report = sim.audit_report().expect("checked engine carries a report");
        assert!(matches!(
            report.violations().first(),
            Some(AuditViolation::SchedulePast { .. })
        ));
    }

    #[test]
    fn audited_slot_aliasing_is_reported() {
        let mut sim: Sim<Log> = Sim::with_engine(EngineKind::Checked { threads: 0 });
        sim.schedule(1.0, 1);
        sim.run(&mut Log::new()); // the slot is now recycled via the free list
        sim.alias_free_slot_for_test();
        sim.schedule(1.0, 2);
        sim.schedule(2.0, 3); // lands in the aliased slot, clobbering 2
        let report = sim.audit_report().expect("checked engine carries a report");
        assert!(matches!(
            report.violations().first(),
            Some(AuditViolation::SlotAliased { slot: 0 })
        ));
    }

    /// A world that *claims* `LOOKAHEAD` but bounces events to the other
    /// partition a tenth of it in the future — the lookahead-contract
    /// breach the auditor must catch without killing the run.  Only ever
    /// executed with `threads = 1` (no worker spawns), so the broken
    /// contract cannot produce an actual data race.
    struct ShortLookahead {
        hops: u32,
    }

    impl World for ShortLookahead {
        type Event = u32;
        fn handle(sim: &mut Sim<Self>, state: &mut Self, event: u32) {
            state.hops += 1;
            if state.hops < 10 {
                sim.schedule(LOOKAHEAD * 0.1, event ^ 1);
            }
        }
    }

    // SAFETY: deliberately violates the lookahead clause (that is the
    // point of the negative test); sound only because the test drives it
    // with a single worker thread, so no two handlers ever run
    // concurrently.
    unsafe impl PartitionedWorld for ShortLookahead {
        type Map = ();
        fn partition_map(&self) -> Self::Map {}
        fn partition_count(_map: &Self::Map) -> usize {
            2
        }
        fn route(_map: &Self::Map, event: &Self::Event) -> u32 {
            event % 2
        }
        fn lookahead(&self) -> Time {
            LOOKAHEAD // overclaimed: emissions use a tenth of this
        }
        fn merge_key(_map: &Self::Map, event: &Self::Event) -> u128 {
            u128::from(*event)
        }
    }

    #[test]
    fn audited_lookahead_violation_is_reported_not_fatal() {
        let mut sim: Sim<ShortLookahead> = Sim::with_engine(EngineKind::Checked { threads: 1 });
        let mut world = ShortLookahead { hops: 0 };
        sim.schedule_at(1e-7, 0);
        sim.run_parallel(&mut world, 1);
        assert_eq!(world.hops, 10, "the violating run must still complete");
        let report = sim.take_audit_report().expect("checked engine carries a report");
        assert!(
            report
                .violations()
                .iter()
                .any(|v| matches!(v, AuditViolation::LookaheadViolation { .. })),
            "expected a lookahead violation, got: {}",
            report.summary()
        );
    }

    /// A world whose `route` consults a global counter for event 7 — not
    /// a pure function of the event, which the audited barrier detects
    /// by routing twice.  `threads = 1` only, as above.
    struct FlakyRoute {
        seen: u32,
    }

    static FLAKY_ROUTE_CALLS: AtomicU32 = AtomicU32::new(0);

    impl World for FlakyRoute {
        type Event = u32;
        fn handle(sim: &mut Sim<Self>, state: &mut Self, _event: u32) {
            state.seen += 1;
            if state.seen < 6 {
                sim.schedule(LOOKAHEAD, 7);
            }
        }
    }

    // SAFETY: deliberately violates route stability (the point of the
    // negative test); sound only under a single worker thread.
    unsafe impl PartitionedWorld for FlakyRoute {
        type Map = ();
        fn partition_map(&self) -> Self::Map {}
        fn partition_count(_map: &Self::Map) -> usize {
            2
        }
        fn route(_map: &Self::Map, event: &Self::Event) -> u32 {
            if *event == 7 {
                FLAKY_ROUTE_CALLS.fetch_add(1, AtomicOrdering::Relaxed) % 2
            } else {
                0
            }
        }
        fn lookahead(&self) -> Time {
            LOOKAHEAD
        }
        fn merge_key(_map: &Self::Map, event: &Self::Event) -> u128 {
            u128::from(*event)
        }
    }

    #[test]
    fn audited_unstable_route_is_reported() {
        let mut sim: Sim<FlakyRoute> = Sim::with_engine(EngineKind::Checked { threads: 1 });
        let mut world = FlakyRoute { seen: 0 };
        sim.schedule_at(1e-7, 0);
        sim.run_parallel(&mut world, 1);
        let report = sim.take_audit_report().expect("checked engine carries a report");
        assert!(
            report
                .violations()
                .iter()
                .any(|v| matches!(v, AuditViolation::UnstableRoute { .. })),
            "expected an unstable-route violation, got: {}",
            report.summary()
        );
    }

    /// A world emitting two *distinct* same-time cross-partition events
    /// under one constant merge key — `merge_key` fails to totally order
    /// the barrier batch.
    struct KeyClash {
        got: Vec<u32>,
    }

    impl World for KeyClash {
        type Event = u32;
        fn handle(sim: &mut Sim<Self>, state: &mut Self, event: u32) {
            state.got.push(event);
            if event == 0 {
                sim.schedule(LOOKAHEAD, 100);
                sim.schedule(LOOKAHEAD, 101);
            }
        }
    }

    // SAFETY: routing is partition-pure and emissions respect the
    // lookahead; only the merge-key totality clause is (deliberately)
    // broken, which risks cross-thread reordering, not a data race —
    // and the test runs single-threaded anyway.
    unsafe impl PartitionedWorld for KeyClash {
        type Map = ();
        fn partition_map(&self) -> Self::Map {}
        fn partition_count(_map: &Self::Map) -> usize {
            2
        }
        fn route(_map: &Self::Map, event: &Self::Event) -> u32 {
            u32::from(*event != 0)
        }
        fn lookahead(&self) -> Time {
            LOOKAHEAD
        }
        fn merge_key(_map: &Self::Map, _event: &Self::Event) -> u128 {
            42 // constant: same-time emissions collide
        }
    }

    #[test]
    fn audited_merge_key_collision_is_reported() {
        let mut sim: Sim<KeyClash> = Sim::with_engine(EngineKind::Checked { threads: 1 });
        let mut world = KeyClash { got: Vec::new() };
        sim.schedule_at(1e-7, 0);
        sim.run_parallel(&mut world, 1);
        assert_eq!(world.got, vec![0, 100, 101], "all three events still execute");
        let report = sim.take_audit_report().expect("checked engine carries a report");
        assert!(
            report
                .violations()
                .iter()
                .any(|v| matches!(v, AuditViolation::MergeKeyCollision { key: 42, .. })),
            "expected a merge-key collision, got: {}",
            report.summary()
        );
    }
}
