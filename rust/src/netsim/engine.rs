//! Typed-event discrete-event engine.
//!
//! Until PR 5 every event was a `Box<dyn FnOnce>` on one `BinaryHeap`;
//! the 512-node ring sweep scheduled tens of millions of them, and the
//! allocation + deep-heap traffic was the wall-clock bottleneck on the
//! road to 2k-node sweeps.  The engine now runs on three pieces:
//!
//! * a **typed event vocabulary** per simulation: the [`World`] trait
//!   couples a mutable state type with a compact (ideally `Copy`)
//!   [`World::Event`] enum and the match-loop dispatcher
//!   [`World::handle`] — no closure captures, no virtual calls;
//! * an **index-based arena** holding pending events: slots are recycled
//!   through a free list, so steady-state scheduling performs no heap
//!   allocation at all;
//! * a **hierarchical calendar queue**: a bucketed wheel over the near
//!   future (the current bucket drains through a small binary heap) with
//!   a heap overflow for far-future events, keyed on finite `f64`
//!   virtual time.  Ties break by insertion sequence — the *same* total
//!   order as the boxed engine, so virtual-time results are
//!   bit-identical across representations.
//!
//! The PR-3 representation is retained behind
//! [`EngineKind::BoxedBaseline`] (one boxed closure per event on a
//! `BinaryHeap`): `smartnic engine-bench` measures the typed engine
//! against it and `rust/tests/engine_equiv.rs` pins the two to identical
//! virtual time.  [`Sim::schedule_closure`] remains as a thin escape
//! hatch for tests; every production scheduler client posts typed
//! events.
//!
//! ```
//! use ai_smartnic::netsim::engine::{Sim, World};
//!
//! /// A world is state + an event vocabulary + a dispatcher.
//! struct Counter {
//!     fired: Vec<u32>,
//! }
//! impl World for Counter {
//!     type Event = u32;
//!     fn handle(_sim: &mut Sim<Self>, state: &mut Self, event: u32) {
//!         state.fired.push(event);
//!     }
//! }
//!
//! let mut sim: Sim<Counter> = Sim::new();
//! let mut world = Counter { fired: Vec::new() };
//! sim.schedule(2.0e-6, 2);
//! sim.schedule(1.0e-6, 1);
//! let end = sim.run(&mut world);
//! assert_eq!(end, 2.0e-6);
//! assert_eq!(world.fired, vec![1, 2]);
//! ```

use super::Time;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// A simulation world: the mutable state threaded through every event,
/// its typed event vocabulary, and the dispatcher that executes one
/// event at its scheduled virtual time.
pub trait World: Sized + 'static {
    /// The compact event representation.  Keep it small and `Copy`: the
    /// engine stores events by value in the arena.
    type Event: 'static;

    /// Execute `event` at its fire time.  `sim.now()` is the event's
    /// scheduled time; the handler may schedule further events.
    fn handle(sim: &mut Sim<Self>, state: &mut Self, event: Self::Event);
}

/// A boxed action: the test escape hatch, and the unit of the
/// [`EngineKind::BoxedBaseline`] representation.
type Action<W> = Box<dyn FnOnce(&mut Sim<W>, &mut W)>;

/// One pending queue entry: a typed event, or an escape-hatch closure.
enum Stored<W: World> {
    Event(W::Event),
    Closure(Action<W>),
}

/// Which queue representation a [`Sim`] runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// typed-event arena + hierarchical calendar queue (the default)
    Typed,
    /// the PR-3 representation — one boxed closure per event on a
    /// `BinaryHeap` — kept as the benchmark and equivalence baseline
    BoxedBaseline,
}

// ---------------------------------------------------------------------
// Calendar queue: (time, seq) keys over an index arena
// ---------------------------------------------------------------------

/// Queue key.  `(time, seq)` is the engine's total order (`total_cmp`
/// is safe because scheduling rejects non-finite times); `slot` indexes
/// the event arena.
#[derive(Clone, Copy)]
struct Key {
    time: Time,
    seq: u64,
    slot: u32,
}

impl PartialEq for Key {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Pending-event storage: slots recycled through a LIFO free list, so
/// steady-state scheduling reuses hot memory instead of allocating.
struct Arena<W: World> {
    slots: Vec<Option<Stored<W>>>,
    free: Vec<u32>,
}

impl<W: World> Arena<W> {
    fn new() -> Self {
        Self {
            slots: Vec::new(),
            free: Vec::new(),
        }
    }

    fn insert(&mut self, stored: Stored<W>) -> u32 {
        match self.free.pop() {
            Some(slot) => {
                self.slots[slot as usize] = Some(stored);
                slot
            }
            None => {
                assert!(
                    self.slots.len() < u32::MAX as usize,
                    "event arena exhausted (more than 2^32-1 pending events)"
                );
                self.slots.push(Some(stored));
                (self.slots.len() - 1) as u32
            }
        }
    }

    fn take(&mut self, slot: u32) -> Stored<W> {
        let stored = self.slots[slot as usize]
            .take()
            .expect("empty arena slot (engine bug)");
        self.free.push(slot);
        stored
    }
}

/// Buckets in the wheel.
const BUCKETS: usize = 512;
/// Maximum overflow events moved per wheel rebase.
const REFILL_BATCH: usize = 8192;

/// The hierarchical calendar queue.
///
/// Bucket `i` covers virtual times `[base + i·width, base + (i+1)·width)`;
/// buckets below `next_bucket` have been drained into the `front` heap
/// (which therefore holds the global minimum once non-empty), and events
/// past the wheel horizon wait in the `overflow` heap.  When the wheel
/// empties, `refill` rebases it on the earliest overflow batch and
/// re-derives `width` from that batch's span, so bucket granularity
/// tracks the simulation's actual event density.
///
/// Placement is decided purely by the bucket index a time maps to, and a
/// whole bucket moves into `front` at once — so every pending event with
/// a key below any bucketed event's key is always in `front`, and pops
/// follow the exact `(time, seq)` order of a single global heap.
struct Calendar {
    /// events already past the wheel frontier, drained in key order
    front: BinaryHeap<Reverse<Key>>,
    /// wheel origin: bucket 0 starts here
    base: Time,
    /// bucket granularity (seconds); always finite and > 0
    width: Time,
    /// buckets below this index have been drained into `front`
    next_bucket: usize,
    buckets: Vec<Vec<Key>>,
    /// events at or beyond the wheel horizon
    overflow: BinaryHeap<Reverse<Key>>,
    len: usize,
}

impl Calendar {
    fn new() -> Self {
        Self {
            front: BinaryHeap::new(),
            base: 0.0,
            width: 1e-6,
            next_bucket: 0,
            buckets: (0..BUCKETS).map(|_| Vec::new()).collect(),
            overflow: BinaryHeap::new(),
            len: 0,
        }
    }

    /// Wheel index `time` maps to under the current `(base, width)`.
    /// The saturating float→usize cast sends negatives to 0 (such times
    /// sit below the frontier and belong in `front`) and huge quotients
    /// to `usize::MAX` (beyond the horizon: overflow).
    fn index_of(&self, time: Time) -> usize {
        ((time - self.base) / self.width) as usize
    }

    fn push(&mut self, key: Key) {
        self.len += 1;
        let idx = self.index_of(key.time);
        if idx < self.next_bucket {
            self.front.push(Reverse(key));
        } else if idx < BUCKETS {
            self.buckets[idx].push(key);
        } else {
            self.overflow.push(Reverse(key));
        }
    }

    /// Advance buckets into `front` until it holds the global minimum
    /// (no-op when it already does; returns with `front` empty only when
    /// the whole queue is empty).
    fn ensure_front(&mut self) {
        while self.front.is_empty() {
            while self.next_bucket < BUCKETS && self.buckets[self.next_bucket].is_empty() {
                self.next_bucket += 1;
            }
            if self.next_bucket < BUCKETS {
                let idx = self.next_bucket;
                self.next_bucket += 1;
                while let Some(key) = self.buckets[idx].pop() {
                    self.front.push(Reverse(key));
                }
            } else if !self.refill() {
                return;
            }
        }
    }

    /// The wheel is exhausted: rebase it on the earliest overflow batch.
    /// Returns false when the overflow is empty too.
    fn refill(&mut self) -> bool {
        let Some(Reverse(first)) = self.overflow.pop() else {
            return false;
        };
        let mut batch = Vec::with_capacity(REFILL_BATCH.min(self.overflow.len() + 1));
        batch.push(first);
        while batch.len() < REFILL_BATCH {
            match self.overflow.pop() {
                Some(Reverse(key)) => batch.push(key),
                None => break,
            }
        }
        // Heap pops arrive in key order, so the batch is time-sorted:
        // size the wheel to its span.  A zero span (all ties) keeps the
        // previous width — everything lands in bucket 0.
        let span = batch[batch.len() - 1].time - first.time;
        if span > 0.0 {
            self.width = span / BUCKETS as f64;
        }
        self.base = first.time;
        self.next_bucket = 0;
        for key in batch {
            let idx = self.index_of(key.time);
            if idx < BUCKETS {
                self.buckets[idx].push(key);
            } else {
                // float rounding at the horizon (or a degenerate width):
                // spill back.  `first` always maps to bucket 0, so every
                // refill makes progress.
                self.overflow.push(Reverse(key));
            }
        }
        true
    }

    fn pop(&mut self) -> Option<Key> {
        self.ensure_front();
        let Reverse(key) = self.front.pop()?;
        self.len -= 1;
        Some(key)
    }

    fn peek_time(&mut self) -> Option<Time> {
        self.ensure_front();
        self.front.peek().map(|Reverse(key)| key.time)
    }
}

// ---------------------------------------------------------------------
// Boxed-closure baseline representation (PR 3)
// ---------------------------------------------------------------------

struct BoxedScheduled<W: World> {
    time: Time,
    seq: u64,
    action: Action<W>,
}

impl<W: World> PartialEq for BoxedScheduled<W> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<W: World> Eq for BoxedScheduled<W> {}
impl<W: World> PartialOrd for BoxedScheduled<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<W: World> Ord for BoxedScheduled<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first, exactly
        // as the PR-3 engine did.
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

enum QueueImpl<W: World> {
    Typed {
        calendar: Calendar,
        arena: Arena<W>,
    },
    Boxed(BinaryHeap<BoxedScheduled<W>>),
}

// ---------------------------------------------------------------------
// The executive
// ---------------------------------------------------------------------

/// The simulation executive.  `W` is the simulation world: its state is
/// threaded by `&mut` into every event, so handlers never capture
/// aliased state.
pub struct Sim<W: World> {
    now: Time,
    seq: u64,
    events_run: u64,
    peak_pending: usize,
    queue: QueueImpl<W>,
}

impl<W: World> Default for Sim<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W: World> Sim<W> {
    /// A typed-event calendar-queue engine (the production default).
    pub fn new() -> Self {
        Self::with_engine(EngineKind::Typed)
    }

    /// An engine on an explicit queue representation.
    pub fn with_engine(kind: EngineKind) -> Self {
        let queue = match kind {
            EngineKind::Typed => QueueImpl::Typed {
                calendar: Calendar::new(),
                arena: Arena::new(),
            },
            EngineKind::BoxedBaseline => QueueImpl::Boxed(BinaryHeap::new()),
        };
        Self {
            now: 0.0,
            seq: 0,
            events_run: 0,
            peak_pending: 0,
            queue,
        }
    }

    /// Which representation this engine runs on.
    pub fn engine_kind(&self) -> EngineKind {
        match &self.queue {
            QueueImpl::Typed { .. } => EngineKind::Typed,
            QueueImpl::Boxed(_) => EngineKind::BoxedBaseline,
        }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn events_run(&self) -> u64 {
        self.events_run
    }

    pub fn pending(&self) -> usize {
        match &self.queue {
            QueueImpl::Typed { calendar, .. } => calendar.len,
            QueueImpl::Boxed(heap) => heap.len(),
        }
    }

    /// High-water mark of the pending-event count (the benchmark's
    /// peak-queue-depth metric).
    pub fn peak_pending(&self) -> usize {
        self.peak_pending
    }

    /// Schedule a typed event `delay` seconds from now.
    pub fn schedule(&mut self, delay: Time, event: W::Event) {
        self.assert_delay(delay);
        self.schedule_at(self.now + delay, event);
    }

    /// Schedule a typed event at an absolute time (>= now, finite — a
    /// NaN or infinite time would corrupt the queue order).
    pub fn schedule_at(&mut self, time: Time, event: W::Event) {
        self.check_time(time);
        self.push_stored(time, Stored::Event(event));
    }

    /// Escape hatch (tests only): schedule a boxed closure `delay`
    /// seconds from now.  Production scheduler clients post typed
    /// events via [`Sim::schedule`] / [`Sim::schedule_at`].
    pub fn schedule_closure(
        &mut self,
        delay: Time,
        action: impl FnOnce(&mut Sim<W>, &mut W) + 'static,
    ) {
        self.assert_delay(delay);
        self.schedule_closure_at(self.now + delay, action);
    }

    /// Escape hatch (tests only): [`Sim::schedule_closure`] at an
    /// absolute time.
    pub fn schedule_closure_at(
        &mut self,
        time: Time,
        action: impl FnOnce(&mut Sim<W>, &mut W) + 'static,
    ) {
        self.check_time(time);
        self.push_stored(time, Stored::Closure(Box::new(action)));
    }

    fn assert_delay(&self, delay: Time) {
        assert!(
            delay.is_finite() && delay >= 0.0,
            "delay must be finite and non-negative, got {delay}"
        );
    }

    fn check_time(&self, time: Time) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        assert!(
            time >= self.now,
            "cannot schedule into the past: {time} < {}",
            self.now
        );
    }

    fn push_stored(&mut self, time: Time, stored: Stored<W>) {
        let seq = self.seq;
        self.seq += 1;
        match &mut self.queue {
            QueueImpl::Typed { calendar, arena } => {
                let slot = arena.insert(stored);
                calendar.push(Key { time, seq, slot });
            }
            QueueImpl::Boxed(heap) => {
                let action: Action<W> = match stored {
                    Stored::Closure(action) => action,
                    Stored::Event(event) => {
                        Box::new(move |sim: &mut Sim<W>, state: &mut W| {
                            W::handle(sim, state, event)
                        })
                    }
                };
                heap.push(BoxedScheduled { time, seq, action });
            }
        }
        self.peak_pending = self.peak_pending.max(self.pending());
    }

    fn pop_next(&mut self) -> Option<(Time, Stored<W>)> {
        match &mut self.queue {
            QueueImpl::Typed { calendar, arena } => {
                let key = calendar.pop()?;
                Some((key.time, arena.take(key.slot)))
            }
            QueueImpl::Boxed(heap) => {
                heap.pop().map(|s| (s.time, Stored::Closure(s.action)))
            }
        }
    }

    /// Virtual time of the earliest pending event.
    fn peek_time(&mut self) -> Option<Time> {
        match &mut self.queue {
            QueueImpl::Typed { calendar, .. } => calendar.peek_time(),
            QueueImpl::Boxed(heap) => heap.peek().map(|s| s.time),
        }
    }

    /// Run until the queue drains; returns final virtual time.
    pub fn run(&mut self, state: &mut W) -> Time {
        while self.step(state) {}
        self.now
    }

    /// Run at most until virtual time `t_end` (events at exactly t_end
    /// run).
    pub fn run_until(&mut self, state: &mut W, t_end: Time) -> Time {
        while let Some(head) = self.peek_time() {
            if head > t_end {
                break;
            }
            self.step(state);
        }
        self.now
    }

    /// Execute the single earliest event.  Returns false when empty.
    pub fn step(&mut self, state: &mut W) -> bool {
        match self.pop_next() {
            None => false,
            Some((time, stored)) => {
                debug_assert!(time >= self.now);
                self.now = time;
                self.events_run += 1;
                match stored {
                    Stored::Event(event) => W::handle(self, state, event),
                    Stored::Closure(action) => action(self, state),
                }
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Typed test world: events are plain tags, logged at dispatch.
    struct Log {
        fired: Vec<u32>,
        times: Vec<Time>,
    }

    impl Log {
        fn new() -> Self {
            Self {
                fired: Vec::new(),
                times: Vec::new(),
            }
        }
    }

    impl World for Log {
        type Event = u32;
        fn handle(sim: &mut Sim<Self>, state: &mut Self, event: u32) {
            state.fired.push(event);
            state.times.push(sim.now());
        }
    }

    fn both_kinds() -> [EngineKind; 2] {
        [EngineKind::Typed, EngineKind::BoxedBaseline]
    }

    #[test]
    fn events_run_in_time_order() {
        for kind in both_kinds() {
            let mut sim: Sim<Log> = Sim::with_engine(kind);
            let mut log = Log::new();
            sim.schedule(3.0, 3);
            sim.schedule(1.0, 1);
            sim.schedule(2.0, 2);
            sim.run(&mut log);
            assert_eq!(log.fired, vec![1, 2, 3], "{kind:?}");
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for kind in both_kinds() {
            let mut sim: Sim<Log> = Sim::with_engine(kind);
            let mut log = Log::new();
            for i in 0..10 {
                sim.schedule(1.0, i);
            }
            sim.run(&mut log);
            assert_eq!(log.fired, (0..10).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn events_can_schedule_events() {
        // the closure escape hatch still composes with typed dispatch
        let mut sim: Sim<Log> = Sim::new();
        let mut log = Log::new();
        sim.schedule_closure(1.0, |sim, _state| {
            sim.schedule(0.5, 7);
        });
        let end = sim.run(&mut log);
        assert_eq!(log.fired, vec![7]);
        assert_eq!(log.times, vec![1.5]);
        assert_eq!(end, 1.5);
    }

    #[test]
    fn run_until_stops() {
        for kind in both_kinds() {
            let mut sim: Sim<Log> = Sim::with_engine(kind);
            let mut log = Log::new();
            for i in 1..=10 {
                sim.schedule(f64::from(i), i as u32);
            }
            sim.run_until(&mut log, 5.0);
            assert_eq!(log.fired.len(), 5, "{kind:?}");
            assert_eq!(sim.pending(), 5, "{kind:?}");
            sim.run(&mut log);
            assert_eq!(log.fired.len(), 10, "{kind:?}");
        }
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn scheduling_nan_time_panics() {
        let mut sim: Sim<Log> = Sim::new();
        sim.schedule_at(f64::NAN, 0);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn scheduling_infinite_delay_panics() {
        let mut sim: Sim<Log> = Sim::new();
        sim.schedule(f64::INFINITY, 0);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn scheduling_into_past_panics() {
        let mut sim: Sim<Log> = Sim::new();
        sim.schedule_closure(1.0, |sim, _state| {
            sim.schedule_at(0.5, 0);
        });
        sim.run(&mut Log::new());
    }

    #[test]
    fn event_count_and_peak_depth_tracked() {
        let mut sim: Sim<Log> = Sim::new();
        for _ in 0..100 {
            sim.schedule(1.0, 0);
        }
        sim.run(&mut Log::new());
        assert_eq!(sim.events_run(), 100);
        assert_eq!(sim.peak_pending(), 100);
        assert_eq!(sim.pending(), 0);
    }

    #[test]
    fn far_future_events_cross_the_overflow_heap() {
        // spread times far past the initial wheel horizon so pushes land
        // in the overflow and pops exercise the rebase path
        let mut sim: Sim<Log> = Sim::new();
        let mut log = Log::new();
        for i in (0..200).rev() {
            sim.schedule_at(f64::from(i) * 10.0, i as u32);
        }
        sim.run(&mut log);
        assert_eq!(log.fired, (0..200).collect::<Vec<_>>());
        assert_eq!(log.times.last().copied(), Some(1990.0));
    }

    #[test]
    fn typed_and_boxed_execute_identically_under_stress() {
        // a deterministic pseudo-random cascade: every event schedules
        // up to two children at quasi-random offsets; both
        // representations must fire the same tags at the same times in
        // the same order
        struct Cascade {
            order: Vec<(u64, u32)>,
            budget: u32,
        }
        impl World for Cascade {
            type Event = u32;
            fn handle(sim: &mut Sim<Self>, state: &mut Self, event: u32) {
                state.order.push((sim.now().to_bits(), event));
                if state.budget == 0 {
                    return;
                }
                state.budget -= 1;
                // xorshift-style offsets: identical for both engines
                let mix = (u64::from(event)).wrapping_mul(0x9e37_79b9_7f4a_7c15);
                let a = (mix >> 33) % 1000;
                let b = (mix >> 13) % 1000;
                sim.schedule(a as f64 * 1e-7, event.wrapping_mul(3).wrapping_add(1));
                if event % 3 != 0 {
                    sim.schedule(b as f64 * 1e-4, event.wrapping_mul(5).wrapping_add(2));
                }
            }
        }
        let run = |kind: EngineKind| {
            let mut sim: Sim<Cascade> = Sim::with_engine(kind);
            let mut world = Cascade {
                order: Vec::new(),
                budget: 20_000,
            };
            for i in 0..64 {
                sim.schedule(f64::from(i % 7) * 1e-5, i);
            }
            sim.run(&mut world);
            world.order
        };
        assert_eq!(run(EngineKind::Typed), run(EngineKind::BoxedBaseline));
    }

    #[test]
    fn simultaneous_ties_at_the_refill_boundary_stay_ordered() {
        // many events at exactly the same far-future instant: the wheel
        // rebases with a zero span and must still drain in seq order
        let mut sim: Sim<Log> = Sim::new();
        let mut log = Log::new();
        for i in 0..100 {
            sim.schedule_at(5.0, i);
        }
        sim.run(&mut log);
        assert_eq!(log.fired, (0..100).collect::<Vec<_>>());
    }
}
