//! Discrete-event network/compute simulation substrate.
//!
//! Replaces the paper's physical testbed (6 Xeon nodes + Arria-10 NICs +
//! a Dell S6100 switch) with a deterministic simulator.  Two layers:
//!
//! * [`engine`] — a classic calendar-queue DES (schedule closures at
//!   virtual times) for control-flow-heavy simulations;
//! * [`link`] — FIFO *servers* (links, PCIe, adders) with busy-until
//!   semantics, composed max-plus style for pipelined dataflows (this is
//!   how the chunked ring all-reduce is simulated; the paper's Sec. IV-C
//!   closed form is the steady-state limit of the same composition).
//!
//! All time is `f64` seconds of *virtual* time; everything is pure
//! arithmetic, so simulations are exactly reproducible.

pub mod engine;
pub mod link;
pub mod switch;
pub mod topology;

pub type Time = f64;
