//! Discrete-event network/compute simulation substrate.
//!
//! Replaces the paper's physical testbed (6 Xeon nodes + Arria-10 NICs +
//! a Dell S6100 switch) with a deterministic simulator.  Three layers:
//!
//! * [`engine`] — the typed-event DES every simulation in the crate runs
//!   on: compact events in an index arena, ordered by a hierarchical
//!   calendar queue with a total event order (finite times enforced,
//!   ties broken by insertion sequence), dispatched by each world's
//!   match loop;
//! * [`link`] — FIFO *servers* (links, PCIe, adders) with busy-until
//!   semantics.  Events call `serve`/`transmit`/`reserve` at their fire
//!   times, so anything sharing a server — concurrent all-reduces, other
//!   jobs' traffic — contends through the same FIFO queue.  The paper's
//!   Sec. IV-C closed form is the steady-state limit of this composition;
//! * [`fabric`] — one struct owning every node's resources plus the
//!   [`switch`], the shared world state of the unified cluster engine.
//!
//! All time is `f64` seconds of *virtual* time; everything is pure
//! arithmetic, so simulations are exactly reproducible.  The [`audit`]
//! module machine-checks that claim: `EngineKind::Checked` validates the
//! engine's scheduling and PDES invariants at dispatch time (see
//! `docs/INVARIANTS.md`).

#[forbid(unsafe_code)]
pub mod audit;
// `engine` is one of the two modules allowed to contain `unsafe`: the
// parallel executive's shared-state machinery lives here, under
// `clippy::indexing_slicing` so every hot-path index carries a message.
#[warn(clippy::indexing_slicing)]
pub mod engine;
#[forbid(unsafe_code)]
pub mod fabric;
#[forbid(unsafe_code)]
pub mod link;
#[forbid(unsafe_code)]
pub mod switch;
#[forbid(unsafe_code)]
pub mod topology;

pub type Time = f64;
