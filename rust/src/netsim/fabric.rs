//! The shared cluster fabric: every timing resource of every node plus the
//! interconnect, owned by a single struct so that *all* in-flight activity —
//! concurrent all-reduces of one job, collectives of different jobs, host
//! MPI traffic — contends on the same FIFO servers.
//!
//! Per node (paper Fig. 3a datapath):
//! * `tx` — the NIC's Ethernet uplink serialization stage (latency lives
//!   on the switch, so the link's own latency is zero);
//! * `pcie` — full-duplex host<->NIC DMA;
//! * `adder` — the FPGA FP32 reduction engine;
//! * `comm` — the host's communication cores as a *normalized* rate-1.0
//!   server: callers enqueue seconds of software all-reduce work, which
//!   makes jobs with different effective bandwidths shareable on one FIFO.
//!   A straggling node's comm server runs at `node_scale`, so host-MPI
//!   rounds on that node drain proportionally slower.
//!
//! The interconnect follows the [`Topology`]: the paper's single
//! non-blocking crossbar, or a two-tier leaf–spine fabric where inter-leaf
//! flows additionally reserve leaf-uplink and spine-egress bundle capacity
//! (each an aggregated FIFO server sized by the oversubscription factor).
//! Every stage uses cut-through reservations ([`Switch::forward_cut_through`]
//! / [`Server::reserve`]) so an uncontended intra-leaf hop costs exactly
//! `hop_latency` beyond the sender's Tx serialization — matching the
//! serialized NIC DES — an uncontended inter-leaf hop costs three switch
//! latencies, and converging flows queue-delay each other wherever their
//! paths share a reservation stage.
//!
//! Fault injection is bidirectional: a degraded link scales both the
//! victim's Tx uplink *and* the switch egress port toward the victim, so
//! incast to a flapping port slows down just like traffic out of it.

use super::link::{Link, Pcie, Server};
use super::switch::Switch;
use super::topology::Topology;
use super::Time;
use crate::sysconfig::{ClusterFaults, SystemParams};

/// All timing resources of one physical node.
#[derive(Clone, Debug)]
pub struct NodeDevices {
    pub tx: Link,
    pub pcie: Pcie,
    pub adder: Server,
    /// normalized (rate `node_scale`, 1.0 when healthy) host comm-core
    /// server; serves seconds of software all-reduce work
    pub comm: Server,
}

/// The switching tier between the nodes' Tx links and their egress ports.
#[derive(Clone, Debug)]
pub enum Interconnect {
    /// one non-blocking crossbar (flat topology)
    Flat(Switch),
    /// two-tier leaf–spine fabric
    LeafSpine {
        /// per-leaf edge switch; port `p` of leaf `l` serves node
        /// `l * nodes_per_leaf + p`
        leaves: Vec<Switch>,
        /// aggregated leaf→spine uplink bundle, one per leaf
        uplinks: Vec<Server>,
        /// aggregated spine→leaf egress bundle, one per leaf
        downlinks: Vec<Server>,
        /// per-stage switching latency (same constant as the leaf
        /// switches'; an inter-leaf path pays it three times)
        latency: Time,
    },
}

/// The whole cluster's shared resources: one entry per node, plus the
/// topology-shaped interconnect.
#[derive(Clone, Debug)]
pub struct Fabric {
    pub nodes: Vec<NodeDevices>,
    pub topology: Topology,
    pub interconnect: Interconnect,
}

impl Fabric {
    /// Build an `n`-node flat-crossbar fabric from one hardware
    /// description, applying cluster-level fault injection to the affected
    /// nodes' resources.
    pub fn new(sys: &SystemParams, n: usize, faults: &ClusterFaults) -> Self {
        Self::with_topology(sys, Topology::flat(n), faults)
    }

    /// Build the fabric for an arbitrary [`Topology`].
    pub fn with_topology(sys: &SystemParams, topology: Topology, faults: &ClusterFaults) -> Self {
        let n = topology.nodes();
        assert!(n >= 1, "fabric needs at least one node");
        let port_bw = sys.net.effective_bw();
        let nodes = (0..n)
            .map(|i| {
                let link_scale = faults.link_scale(i);
                let node_scale = faults.node_scale(i);
                NodeDevices {
                    tx: Link::new(port_bw * link_scale, 0.0),
                    pcie: Pcie::new(sys.nic.pcie_bw * node_scale, sys.nic.pcie_latency),
                    adder: Server::new(sys.nic.add_flops * node_scale),
                    comm: Server::new(node_scale),
                }
            })
            .collect();
        let latency = sys.net.hop_latency;
        let interconnect = match topology {
            Topology::Flat { nodes } => Interconnect::Flat(Switch::new_scaled(
                nodes,
                port_bw,
                latency,
                |p| faults.link_scale(p),
            )),
            Topology::LeafSpine { leaves, nodes_per_leaf, .. } => {
                let bundle_bw = topology.uplink_bw(port_bw);
                Interconnect::LeafSpine {
                    leaves: (0..leaves)
                        .map(|l| {
                            Switch::new_scaled(nodes_per_leaf, port_bw, latency, |p| {
                                faults.link_scale(l * nodes_per_leaf + p)
                            })
                        })
                        .collect(),
                    uplinks: (0..leaves).map(|_| Server::new(bundle_bw)).collect(),
                    downlinks: (0..leaves).map(|_| Server::new(bundle_bw)).collect(),
                    latency,
                }
            }
        };
        Self {
            nodes,
            topology,
            interconnect,
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// One wire path from `src` to `dst`: Tx serialization on the sender's
    /// uplink, then cut-through switching along the topology's route —
    /// directly to the destination port inside one leaf (or on the
    /// crossbar), or via the sender leaf's uplink bundle and the receiver
    /// leaf's spine-egress bundle across the spine.  Returns the delivery
    /// time at the destination NIC.
    #[must_use]
    pub fn hop(&mut self, src: usize, dst: usize, ready: Time, bytes: f64) -> Time {
        let src_leaf = self.topology.leaf_of(src);
        let dst_leaf = self.topology.leaf_of(dst);
        let dst_port = self.topology.leaf_port(dst);
        let serialized = self.nodes[src].tx.transmit(ready, bytes);
        match &mut self.interconnect {
            Interconnect::Flat(sw) => sw.forward_cut_through(dst, serialized, bytes),
            Interconnect::LeafSpine { leaves, uplinks, downlinks, latency } => {
                if src_leaf == dst_leaf {
                    leaves[dst_leaf].forward_cut_through(dst_port, serialized, bytes)
                } else {
                    let at_spine = uplinks[src_leaf].reserve(serialized, bytes) + *latency;
                    let at_leaf = downlinks[dst_leaf].reserve(at_spine, bytes) + *latency;
                    leaves[dst_leaf].forward_cut_through(dst_port, at_leaf, bytes)
                }
            }
        }
    }

    /// Utilization of the egress port toward `node` over [0, horizon].
    #[must_use]
    pub fn port_utilization(&self, node: usize, horizon: Time) -> f64 {
        match &self.interconnect {
            Interconnect::Flat(sw) => sw.port_utilization(node, horizon),
            Interconnect::LeafSpine { leaves, .. } => leaves[self.topology.leaf_of(node)]
                .port_utilization(self.topology.leaf_port(node), horizon),
        }
    }

    /// Configured bandwidth of the egress port toward `node` (bytes/s).
    #[must_use]
    pub fn port_rate(&self, node: usize) -> f64 {
        match &self.interconnect {
            Interconnect::Flat(sw) => sw.port_rate(node),
            Interconnect::LeafSpine { leaves, .. } => {
                leaves[self.topology.leaf_of(node)].port_rate(self.topology.leaf_port(node))
            }
        }
    }

    /// Utilization of `leaf`'s spine uplink bundle over [0, horizon]
    /// (always 0 on the flat crossbar — there are no uplinks).
    #[must_use]
    pub fn uplink_utilization(&self, leaf: usize, horizon: Time) -> f64 {
        match &self.interconnect {
            Interconnect::Flat(_) => 0.0,
            Interconnect::LeafSpine { uplinks, .. } => uplinks[leaf].utilization(horizon),
        }
    }

    /// Mean Tx-link utilization across nodes over [0, horizon].
    pub fn mean_eth_util(&self, horizon: Time) -> f64 {
        let n = self.nodes.len() as f64;
        self.nodes.iter().map(|nd| nd.tx.utilization(horizon)).sum::<f64>() / n
    }

    /// Mean PCIe utilization (both directions averaged) over [0, horizon].
    pub fn mean_pcie_util(&self, horizon: Time) -> f64 {
        let n = self.nodes.len() as f64;
        self.nodes
            .iter()
            .map(|nd| {
                (nd.pcie.to_device.utilization(horizon) + nd.pcie.to_host.utilization(horizon))
                    / 2.0
            })
            .sum::<f64>()
            / n
    }

    /// Mean adder utilization over [0, horizon].
    pub fn mean_adder_util(&self, horizon: Time) -> f64 {
        let n = self.nodes.len() as f64;
        self.nodes.iter().map(|nd| nd.adder.utilization(horizon)).sum::<f64>() / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::gbps;

    #[test]
    fn uncontended_hop_costs_serialization_plus_latency() {
        let sys = SystemParams::smartnic_40g();
        let mut f = Fabric::new(&sys, 4, &ClusterFaults::none());
        let bytes = 1e6;
        let t = f.hop(0, 1, 0.0, bytes);
        let expect = bytes / gbps(40.0) + sys.net.hop_latency;
        assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
    }

    #[test]
    fn faults_scale_the_right_node() {
        let sys = SystemParams::smartnic_40g();
        let faults = ClusterFaults::none()
            .with_degraded_link(1, 0.5)
            .with_straggler(2, 0.25);
        let f = Fabric::new(&sys, 3, &faults);
        assert_eq!(f.nodes[1].tx.server.rate, gbps(40.0) * 0.5);
        assert_eq!(f.nodes[0].tx.server.rate, gbps(40.0));
        assert_eq!(f.nodes[2].adder.rate, sys.nic.add_flops * 0.25);
        assert_eq!(f.nodes[2].pcie.to_device.server.rate, sys.nic.pcie_bw * 0.25);
        // regression: a straggler's host comm cores slow down too
        assert_eq!(f.nodes[2].comm.rate, 0.25);
        assert_eq!(f.nodes[0].comm.rate, 1.0);
        // regression: the switch egress port toward the degraded node is
        // scaled, so incast to it slows down as well
        assert_eq!(f.port_rate(1), gbps(40.0) * 0.5);
        assert_eq!(f.port_rate(0), gbps(40.0));
    }

    #[test]
    fn converging_hops_contend_on_egress() {
        let sys = SystemParams::smartnic_40g();
        let mut f = Fabric::new(&sys, 4, &ClusterFaults::none());
        let bytes = 1e6;
        let ser = bytes / gbps(40.0);
        // two different senders, same destination, same instant
        let t1 = f.hop(0, 2, 0.0, bytes);
        let t2 = f.hop(1, 2, 0.0, bytes);
        assert!((t1 - (ser + sys.net.hop_latency)).abs() < 1e-12);
        // the second flow's egress reservation queues behind the first
        assert!((t2 - (2.0 * ser + sys.net.hop_latency)).abs() < 1e-12);
    }

    #[test]
    fn incast_toward_degraded_node_slows_down() {
        // the victim's *egress* port runs slow, so traffic converging on it
        // queues 4x longer — even though every sender's Tx link is healthy
        let sys = SystemParams::smartnic_40g();
        let faults = ClusterFaults::none().with_degraded_link(2, 0.25);
        let mut f = Fabric::with_topology(&sys, Topology::flat(4), &faults);
        let bytes = 1e6;
        let ser = bytes / gbps(40.0);
        let _ = f.hop(0, 2, 0.0, bytes);
        let second = f.hop(1, 2, 0.0, bytes);
        // first reservation occupies 4x the healthy drain time
        let expect = ser + 4.0 * ser + sys.net.hop_latency;
        assert!((second - expect).abs() < 1e-12, "{second} vs {expect}");
    }

    #[test]
    fn intra_leaf_hop_is_single_latency() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::leaf_spine(2, 3, 4.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let t = f.hop(0, 2, 0.0, bytes); // both on leaf 0
        let expect = bytes / gbps(40.0) + sys.net.hop_latency;
        assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
    }

    #[test]
    fn inter_leaf_hop_pays_three_latencies_when_uncontended() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::leaf_spine(2, 3, 1.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let t = f.hop(0, 4, 0.0, bytes); // leaf 0 -> leaf 1
        let expect = bytes / gbps(40.0) + 3.0 * sys.net.hop_latency;
        assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
    }

    #[test]
    fn oversubscribed_uplink_queues_converging_leaf_exits() {
        let sys = SystemParams::smartnic_40g();
        // 3 nodes per leaf, 3:1 oversubscribed: the uplink bundle drains at
        // exactly one port's rate
        let topo = Topology::leaf_spine(2, 3, 3.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let ser = bytes / gbps(40.0);
        let lat = sys.net.hop_latency;
        // all three leaf-0 nodes send cross-leaf to distinct destinations
        // at t=0: no egress-port contention, but the shared uplink bundle
        // serializes them
        let t0 = f.hop(0, 3, 0.0, bytes);
        let t1 = f.hop(1, 4, 0.0, bytes);
        let t2 = f.hop(2, 5, 0.0, bytes);
        assert!((t0 - (ser + 3.0 * lat)).abs() < 1e-12, "{t0}");
        assert!((t1 - (2.0 * ser + 3.0 * lat)).abs() < 1e-12, "{t1}");
        assert!((t2 - (3.0 * ser + 3.0 * lat)).abs() < 1e-12, "{t2}");
        assert!(f.uplink_utilization(0, t2) > 0.0);
        assert_eq!(f.uplink_utilization(1, t2), 0.0);
    }

    #[test]
    fn non_blocking_uplink_does_not_queue_a_single_flow_train() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::leaf_spine(2, 2, 1.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let ser = bytes / gbps(40.0);
        let lat = sys.net.hop_latency;
        // back-to-back segments of one cross-leaf flow: each is delayed
        // only by its own Tx serialization (the 2-port bundle drains two
        // port-rates' worth, so the train never backs up)
        let t0 = f.hop(0, 2, 0.0, bytes);
        let t1 = f.hop(0, 2, 0.0, bytes);
        assert!((t0 - (ser + 3.0 * lat)).abs() < 1e-12);
        assert!((t1 - (2.0 * ser + 3.0 * lat)).abs() < 1e-12);
    }
}
