//! The shared cluster fabric: every timing resource of every node plus the
//! one switch, owned by a single struct so that *all* in-flight activity —
//! concurrent all-reduces of one job, collectives of different jobs, host
//! MPI traffic — contends on the same FIFO servers.
//!
//! Per node (paper Fig. 3a datapath):
//! * `tx` — the NIC's Ethernet uplink serialization stage (latency lives
//!   on the switch, so the link's own latency is zero);
//! * `pcie` — full-duplex host<->NIC DMA;
//! * `adder` — the FPGA FP32 reduction engine;
//! * `comm` — the host's communication cores as a *normalized* rate-1.0
//!   server: callers enqueue seconds of software all-reduce work, which
//!   makes jobs with different effective bandwidths shareable on one FIFO.
//!
//! The switch uses cut-through forwarding ([`Switch::forward_cut_through`])
//! so an uncontended hop costs exactly `hop_latency` — matching the
//! serialized NIC DES, which models a hop as Tx serialization + latency —
//! while flows that converge on one egress port queue-delay each other.

use super::link::{Link, Pcie, Server};
use super::switch::Switch;
use super::Time;
use crate::sysconfig::{ClusterFaults, SystemParams};

/// All timing resources of one physical node.
#[derive(Clone, Debug)]
pub struct NodeDevices {
    pub tx: Link,
    pub pcie: Pcie,
    pub adder: Server,
    /// normalized (rate 1.0) host comm-core server; serves seconds of work
    pub comm: Server,
}

/// The whole cluster's shared resources: one entry per node, one switch.
#[derive(Clone, Debug)]
pub struct Fabric {
    pub nodes: Vec<NodeDevices>,
    pub switch: Switch,
}

impl Fabric {
    /// Build an `n`-node fabric from one hardware description, applying
    /// cluster-level fault injection to the affected nodes' resources.
    pub fn new(sys: &SystemParams, n: usize, faults: &ClusterFaults) -> Self {
        assert!(n >= 1, "fabric needs at least one node");
        let nodes = (0..n)
            .map(|i| {
                let link_scale = faults.link_scale(i);
                let node_scale = faults.node_scale(i);
                NodeDevices {
                    tx: Link::new(sys.net.eth_bw * sys.net.alpha * link_scale, 0.0),
                    pcie: Pcie::new(sys.nic.pcie_bw * node_scale, sys.nic.pcie_latency),
                    adder: Server::new(sys.nic.add_flops * node_scale),
                    comm: Server::new(1.0),
                }
            })
            .collect();
        Self {
            nodes,
            switch: Switch::new(n, sys.net.eth_bw * sys.net.alpha, sys.net.hop_latency),
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// One wire hop from `src` to `dst`: Tx serialization on the sender's
    /// uplink, then cut-through switching to the destination port.
    /// Returns the delivery time at the destination NIC.
    #[must_use]
    pub fn hop(&mut self, src: usize, dst: usize, ready: Time, bytes: f64) -> Time {
        let serialized = self.nodes[src].tx.transmit(ready, bytes);
        self.switch.forward_cut_through(dst, serialized, bytes)
    }

    /// Mean Tx-link utilization across nodes over [0, horizon].
    pub fn mean_eth_util(&self, horizon: Time) -> f64 {
        let n = self.nodes.len() as f64;
        self.nodes.iter().map(|nd| nd.tx.utilization(horizon)).sum::<f64>() / n
    }

    /// Mean PCIe utilization (both directions averaged) over [0, horizon].
    pub fn mean_pcie_util(&self, horizon: Time) -> f64 {
        let n = self.nodes.len() as f64;
        self.nodes
            .iter()
            .map(|nd| {
                (nd.pcie.to_device.utilization(horizon) + nd.pcie.to_host.utilization(horizon))
                    / 2.0
            })
            .sum::<f64>()
            / n
    }

    /// Mean adder utilization over [0, horizon].
    pub fn mean_adder_util(&self, horizon: Time) -> f64 {
        let n = self.nodes.len() as f64;
        self.nodes.iter().map(|nd| nd.adder.utilization(horizon)).sum::<f64>() / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::gbps;

    #[test]
    fn uncontended_hop_costs_serialization_plus_latency() {
        let sys = SystemParams::smartnic_40g();
        let mut f = Fabric::new(&sys, 4, &ClusterFaults::none());
        let bytes = 1e6;
        let t = f.hop(0, 1, 0.0, bytes);
        let expect = bytes / gbps(40.0) + sys.net.hop_latency;
        assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
    }

    #[test]
    fn faults_scale_the_right_node() {
        let sys = SystemParams::smartnic_40g();
        let faults = ClusterFaults::none()
            .with_degraded_link(1, 0.5)
            .with_straggler(2, 0.25);
        let f = Fabric::new(&sys, 3, &faults);
        assert_eq!(f.nodes[1].tx.server.rate, gbps(40.0) * 0.5);
        assert_eq!(f.nodes[0].tx.server.rate, gbps(40.0));
        assert_eq!(f.nodes[2].adder.rate, sys.nic.add_flops * 0.25);
        assert_eq!(f.nodes[2].pcie.to_device.server.rate, sys.nic.pcie_bw * 0.25);
    }

    #[test]
    fn converging_hops_contend_on_egress() {
        let sys = SystemParams::smartnic_40g();
        let mut f = Fabric::new(&sys, 4, &ClusterFaults::none());
        let bytes = 1e6;
        let ser = bytes / gbps(40.0);
        // two different senders, same destination, same instant
        let t1 = f.hop(0, 2, 0.0, bytes);
        let t2 = f.hop(1, 2, 0.0, bytes);
        assert!((t1 - (ser + sys.net.hop_latency)).abs() < 1e-12);
        // the second flow's egress reservation queues behind the first
        assert!((t2 - (2.0 * ser + sys.net.hop_latency)).abs() < 1e-12);
    }
}
