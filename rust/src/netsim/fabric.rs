//! The shared cluster fabric: every timing resource of every node plus the
//! interconnect, owned by a single struct so that *all* in-flight activity —
//! concurrent all-reduces of one job, collectives of different jobs, host
//! MPI traffic — contends on the same FIFO servers.
//!
//! Per node (paper Fig. 3a datapath):
//! * `tx` — the NIC's Ethernet uplink serialization stage (latency lives
//!   on the switch, so the link's own latency is zero);
//! * `pcie` — full-duplex host<->NIC DMA;
//! * `adder` — the FPGA FP32 reduction engine;
//! * `comm` — the host's communication cores as a *normalized* rate-1.0
//!   server: callers enqueue seconds of software all-reduce work, which
//!   makes jobs with different effective bandwidths shareable on one FIFO.
//!   A straggling node's comm server runs at `node_scale`, so host-MPI
//!   rounds on that node drain proportionally slower.
//!
//! The interconnect follows the [`Topology`]: the paper's single
//! non-blocking crossbar, or a two-tier leaf–spine fabric where inter-leaf
//! flows additionally reserve leaf-uplink and spine-egress bundle capacity
//! (each an aggregated FIFO server sized by the oversubscription factor).
//! Every stage uses cut-through reservations ([`Switch::forward_cut_through`]
//! / [`Server::reserve`]) so an uncontended intra-leaf hop costs exactly
//! `hop_latency` beyond the sender's Tx serialization — matching the
//! serialized NIC DES — an uncontended inter-leaf hop costs three switch
//! latencies, and converging flows queue-delay each other wherever their
//! paths share a reservation stage.
//!
//! Fault injection is bidirectional: a degraded link scales both the
//! victim's Tx uplink *and* the switch egress port toward the victim, so
//! incast to a flapping port slows down just like traffic out of it.

use super::link::{Link, Pcie, Server};
use super::switch::{Switch, TableAllocator};
use super::topology::Topology;
use super::Time;
use crate::sysconfig::{ClusterFaults, PfcParams, SystemParams};

/// All timing resources of one physical node.
#[derive(Clone, Debug)]
pub struct NodeDevices {
    pub tx: Link,
    pub pcie: Pcie,
    pub adder: Server,
    /// normalized (rate `node_scale`, 1.0 when healthy) host comm-core
    /// server; serves seconds of software all-reduce work
    pub comm: Server,
}

/// The switching tier between the nodes' Tx links and their egress ports.
#[derive(Clone, Debug)]
pub enum Interconnect {
    /// one non-blocking crossbar (flat topology)
    Flat(Switch),
    /// two-tier leaf–spine fabric
    LeafSpine {
        /// per-leaf edge switch; port `p` of leaf `l` serves node
        /// `l * nodes_per_leaf + p`
        leaves: Vec<Switch>,
        /// aggregated leaf→spine uplink bundle, one per leaf
        uplinks: Vec<Server>,
        /// aggregated spine→leaf egress bundle, one per leaf
        downlinks: Vec<Server>,
        /// aggregation engine on each leaf's spine-facing port (in-switch
        /// reduction, NetReduce-style); empty when the switch tier has no
        /// reduction capability
        uplink_reducers: Vec<Server>,
        /// aggregation engine on the spine's egress port toward each leaf
        spine_reducers: Vec<Server>,
        /// engine-occupancy server per spine engine (port line rate):
        /// drains the reduced segment out of the engine before
        /// multicast — tenants folding through one root egress serialize
        /// here.  Empty without reduction capability.
        spine_occupancy: Vec<Server>,
        /// per-stage switching latency (same constant as the leaf
        /// switches'; an inter-leaf path pays it three times)
        latency: Time,
    },
}

/// The whole cluster's shared resources: one entry per node, plus the
/// topology-shaped interconnect.
#[derive(Clone, Debug)]
pub struct Fabric {
    pub nodes: Vec<NodeDevices>,
    pub topology: Topology,
    pub interconnect: Interconnect,
    /// count of member-segment replications egressed by the switch tier
    /// in multicast (replication) mode — the observed side of the
    /// conservation audit's ledger for switch multicast, which the
    /// reduction ledgers cannot see (replication folds nothing)
    mcast_delivered: f64,
    /// finite aggregation-table pool of the switching tier, shared by all
    /// tenants (`None` without reduction capability).  Modeled as one
    /// fabric-wide pool: every in-switch plan folds through the root
    /// egress engine's table, so a single shared SRAM budget is the
    /// first-order contention model.
    table: Option<TableAllocator>,
    /// PFC pause behavior of the switching tier (off ⇒ duty 1.0)
    pfc: PfcParams,
    /// recorded pause-propagation edges `(cid, from_leaf, to_leaf)`:
    /// a paused downstream port toward `to_leaf` throttles the uplink out
    /// of `from_leaf` for priority class `cid`.  The `pause-deadlock-free`
    /// audit checks each class's graph for cycles.
    pause_edges: Vec<(u32, usize, usize)>,
}

/// Result of the source half of a wire path ([`Fabric::hop_split`]):
/// either the hop stayed inside the source partition and finished, or it
/// reached the spine and the destination half must be timed separately
/// (by the destination leaf's owner — this is the cut the parallel
/// engine's cross-partition messages ride on).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HopOutcome {
    /// intra-leaf (or flat-crossbar) hop: delivery time at the
    /// destination NIC
    Delivered(Time),
    /// inter-leaf hop: arrival time at the spine, after the sender's Tx
    /// serialization, its leaf's uplink bundle and one switch latency
    AtSpine(Time),
}

impl Fabric {
    /// Build an `n`-node flat-crossbar fabric from one hardware
    /// description, applying cluster-level fault injection to the affected
    /// nodes' resources.
    pub fn new(sys: &SystemParams, n: usize, faults: &ClusterFaults) -> Self {
        Self::with_topology(sys, Topology::flat(n), faults)
    }

    /// Build the fabric for an arbitrary [`Topology`].
    pub fn with_topology(sys: &SystemParams, topology: Topology, faults: &ClusterFaults) -> Self {
        let n = topology.nodes();
        assert!(n >= 1, "fabric needs at least one node");
        let port_bw = sys.net.effective_bw();
        let nodes = (0..n)
            .map(|i| {
                let link_scale = faults.link_scale(i);
                let node_scale = faults.node_scale(i);
                NodeDevices {
                    tx: Link::new(port_bw * link_scale, 0.0),
                    pcie: Pcie::new(sys.nic.pcie_bw * node_scale, sys.nic.pcie_latency),
                    adder: Server::new(sys.nic.add_flops * node_scale),
                    comm: Server::new(node_scale),
                }
            })
            .collect();
        let latency = sys.net.hop_latency;
        let reduce = sys.switch;
        let interconnect = match topology {
            Topology::Flat { nodes } => Interconnect::Flat(
                Switch::new_scaled(nodes, port_bw, latency, |p| faults.link_scale(p))
                    .with_reduction(reduce.reduce_flops, reduce.reduce_table_bytes),
            ),
            Topology::LeafSpine { leaves, nodes_per_leaf, .. } => {
                let bundle_bw = topology.uplink_bw(port_bw);
                let engines = || -> Vec<Server> {
                    if reduce.enabled() {
                        (0..leaves).map(|_| Server::new(reduce.reduce_flops)).collect()
                    } else {
                        Vec::new()
                    }
                };
                let occupancy = || -> Vec<Server> {
                    if reduce.enabled() {
                        (0..leaves).map(|_| Server::new(port_bw)).collect()
                    } else {
                        Vec::new()
                    }
                };
                Interconnect::LeafSpine {
                    // leaf switches stay plain forwarders: on a leaf–spine
                    // fabric the aggregation engines live on the
                    // spine-facing ports (uplink_reducers / spine_reducers
                    // below), not on the down-ports
                    leaves: (0..leaves)
                        .map(|l| {
                            Switch::new_scaled(nodes_per_leaf, port_bw, latency, |p| {
                                faults.link_scale(l * nodes_per_leaf + p)
                            })
                        })
                        .collect(),
                    uplinks: (0..leaves).map(|_| Server::new(bundle_bw)).collect(),
                    downlinks: (0..leaves).map(|_| Server::new(bundle_bw)).collect(),
                    uplink_reducers: engines(),
                    spine_reducers: engines(),
                    spine_occupancy: occupancy(),
                    latency,
                }
            }
        };
        Self {
            nodes,
            topology,
            interconnect,
            mcast_delivered: 0.0,
            table: reduce.enabled().then(|| TableAllocator::new(reduce.reduce_table_bytes)),
            pfc: sys.pfc,
            pause_edges: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// One wire path from `src` to `dst`: Tx serialization on the sender's
    /// uplink, then cut-through switching along the topology's route —
    /// directly to the destination port inside one leaf (or on the
    /// crossbar), or via the sender leaf's uplink bundle and the receiver
    /// leaf's spine-egress bundle across the spine.  Returns the delivery
    /// time at the destination NIC.
    #[must_use]
    pub fn hop(&mut self, src: usize, dst: usize, ready: Time, bytes: f64) -> Time {
        let src_leaf = self.topology.leaf_of(src);
        let dst_leaf = self.topology.leaf_of(dst);
        let dst_port = self.topology.leaf_port(dst);
        let serialized = self.nodes[src].tx.transmit(ready, bytes);
        match &mut self.interconnect {
            Interconnect::Flat(sw) => sw.forward_cut_through(dst, serialized, bytes),
            Interconnect::LeafSpine { leaves, uplinks, downlinks, latency, .. } => {
                if src_leaf == dst_leaf {
                    leaves[dst_leaf].forward_cut_through(dst_port, serialized, bytes)
                } else {
                    let at_spine = uplinks[src_leaf].reserve(serialized, bytes) + *latency;
                    let at_leaf = downlinks[dst_leaf].reserve(at_spine, bytes) + *latency;
                    leaves[dst_leaf].forward_cut_through(dst_port, at_leaf, bytes)
                }
            }
        }
    }

    /// The source half of [`Fabric::hop`]: Tx serialization plus the
    /// route up to (but not across) the spine.  Touches only resources
    /// owned by `src`'s leaf, so a partitioned run may call it from the
    /// leaf's worker; the destination half ([`Fabric::hop_deliver`]) is
    /// then timed by the destination leaf when the cross-partition
    /// message arrives.  `hop_split` + `hop_deliver` compose to exactly
    /// one [`Fabric::hop`] when the calls are made in the same order.
    #[must_use]
    pub fn hop_split(&mut self, src: usize, dst: usize, ready: Time, bytes: f64) -> HopOutcome {
        let src_leaf = self.topology.leaf_of(src);
        let dst_leaf = self.topology.leaf_of(dst);
        let dst_port = self.topology.leaf_port(dst);
        let serialized = self.nodes[src].tx.transmit(ready, bytes);
        match &mut self.interconnect {
            Interconnect::Flat(sw) => {
                HopOutcome::Delivered(sw.forward_cut_through(dst, serialized, bytes))
            }
            Interconnect::LeafSpine { leaves, uplinks, latency, .. } => {
                if src_leaf == dst_leaf {
                    HopOutcome::Delivered(leaves[dst_leaf].forward_cut_through(
                        dst_port, serialized, bytes,
                    ))
                } else {
                    HopOutcome::AtSpine(uplinks[src_leaf].reserve(serialized, bytes) + *latency)
                }
            }
        }
    }

    /// The destination half of a spine crossing: reserve the destination
    /// leaf's spine-egress bundle from `at_spine` and cut through the
    /// leaf switch to `dst`'s port.  Touches only resources owned by
    /// `dst`'s leaf.
    #[must_use]
    pub fn hop_deliver(&mut self, dst: usize, at_spine: Time, bytes: f64) -> Time {
        let dst_leaf = self.topology.leaf_of(dst);
        let dst_port = self.topology.leaf_port(dst);
        match &mut self.interconnect {
            Interconnect::Flat(_) => unreachable!("no spine crossing on a flat crossbar"),
            Interconnect::LeafSpine { leaves, downlinks, latency, .. } => {
                let at_leaf = downlinks[dst_leaf].reserve(at_spine, bytes) + *latency;
                leaves[dst_leaf].forward_cut_through(dst_port, at_leaf, bytes)
            }
        }
    }

    /// Does the switching tier of this fabric have an in-switch reduction
    /// capability (engines built from [`crate::sysconfig::SwitchParams`])?
    #[must_use]
    pub fn switch_reduce_capable(&self) -> bool {
        match &self.interconnect {
            Interconnect::Flat(sw) => sw.reduce_capable(),
            Interconnect::LeafSpine { uplink_reducers, .. } => !uplink_reducers.is_empty(),
        }
    }

    /// In-switch reduction stage 1: Tx-serialize `src`'s contribution of
    /// `wire_bytes` / `elems` and fold it into the aggregation engine
    /// serving the group rooted at `root` — the root's egress-port engine
    /// on the crossbar, or `src`'s leaf's spine-facing engine on a
    /// leaf–spine fabric.  Returns the fold completion time.
    #[must_use]
    pub fn reduce_fold_local(
        &mut self,
        src: usize,
        root: usize,
        ready: Time,
        wire_bytes: f64,
        elems: f64,
    ) -> Time {
        let at_switch = self.nodes[src].tx.transmit(ready, wire_bytes);
        match &mut self.interconnect {
            Interconnect::Flat(sw) => sw.reduce_contribution(root, at_switch, elems),
            Interconnect::LeafSpine { uplink_reducers, .. } => {
                uplink_reducers[self.topology.leaf_of(src)].serve(at_switch, elems)
            }
        }
    }

    /// In-switch reduction stage 2 (groups spanning leaves only): ship
    /// `leaf`'s aggregated segment through its uplink bundle and fold it
    /// into the spine engine on the egress toward `root`'s leaf.  Returns
    /// the spine fold completion time.
    ///
    /// With PFC enabled, the uplink is throttled by the pause duty cycle
    /// (a paused spine egress propagates `pause_window`-long pauses up the
    /// reduction tree, first-order: effective uplink bandwidth × duty),
    /// and the pause edge `(cid, leaf → root's leaf)` is recorded for the
    /// `pause-deadlock-free` audit.  Each collective's edges form a star
    /// into its root leaf, so a single class can never cycle — only a
    /// forged edge set can.
    #[must_use]
    pub fn reduce_fold_spine(
        &mut self,
        cid: u32,
        leaf: usize,
        root: usize,
        ready: Time,
        wire_bytes: f64,
        elems: f64,
    ) -> Time {
        let root_leaf = self.topology.leaf_of(root);
        let derate = self.pfc.derate();
        if self.pfc.enabled() && leaf != root_leaf {
            self.record_pause_edge(cid, leaf, root_leaf);
        }
        match &mut self.interconnect {
            Interconnect::Flat(_) => unreachable!("no spine on a flat crossbar"),
            Interconnect::LeafSpine { uplinks, spine_reducers, latency, .. } => {
                let at_spine = uplinks[leaf].reserve(ready, wire_bytes * derate) + *latency;
                spine_reducers[root_leaf].serve(at_spine, elems)
            }
        }
    }

    /// In-switch reduction stage 3a (spanning groups): multicast one copy
    /// of the reduced segment from the spine down `leaf`'s bundle.
    /// Returns arrival at the leaf switch.  PFC throttles the downlink by
    /// the same pause duty cycle as the uplink.
    #[must_use]
    pub fn reduce_downlink(&mut self, leaf: usize, ready: Time, wire_bytes: f64) -> Time {
        let derate = self.pfc.derate();
        match &mut self.interconnect {
            Interconnect::Flat(_) => unreachable!("no spine on a flat crossbar"),
            Interconnect::LeafSpine { downlinks, latency, .. } => {
                downlinks[leaf].reserve(ready, wire_bytes * derate) + *latency
            }
        }
    }

    /// Occupy the aggregation engine that served the group rooted at
    /// `root` for the drain of one reduced segment of `wire_bytes`:
    /// the root port's engine on the crossbar, the spine engine toward
    /// the root's leaf on a leaf–spine fabric.  Called once per segment
    /// when its fold completes, before multicast — two tenants folding
    /// through one root egress serialize on this server.
    #[must_use]
    pub fn reduce_engine_occupancy(&mut self, root: usize, ready: Time, wire_bytes: f64) -> Time {
        let root_leaf = self.topology.leaf_of(root);
        match &mut self.interconnect {
            Interconnect::Flat(sw) => sw.engine_occupancy(root, ready, wire_bytes),
            Interconnect::LeafSpine { spine_occupancy, .. } => {
                spine_occupancy[root_leaf].serve(ready, wire_bytes)
            }
        }
    }

    /// PFC pause duty cycle of the switching tier (1.0 with PFC off).
    #[must_use]
    pub fn pfc_duty(&self) -> f64 {
        self.pfc.duty()
    }

    /// Record a pause-propagation edge for priority class `cid` (also the
    /// forge hook for the `pause-deadlock-free` audit's negative tests).
    pub fn record_pause_edge(&mut self, cid: u32, from_leaf: usize, to_leaf: usize) {
        if !self.pause_edges.contains(&(cid, from_leaf, to_leaf)) {
            self.pause_edges.push((cid, from_leaf, to_leaf));
        }
    }

    /// Every recorded pause-propagation edge `(cid, from_leaf, to_leaf)`.
    #[must_use]
    pub fn pause_edges(&self) -> &[(u32, usize, usize)] {
        &self.pause_edges
    }

    /// The switching tier's shared aggregation-table allocator (`None`
    /// without reduction capability).
    #[must_use]
    pub fn table(&self) -> Option<&TableAllocator> {
        self.table.as_ref()
    }

    /// Mutable access to the table allocator — admission control
    /// (`request`/`release`/`take_eviction_debt`) and forged-state tests.
    #[must_use]
    pub fn table_mut(&mut self) -> Option<&mut TableAllocator> {
        self.table.as_mut()
    }

    /// Table bytes a new flow of `job` could obtain right now —
    /// `INFINITY` on a fabric without in-switch reduction (nothing to
    /// contend for; the planner's capability gate rejects those plans
    /// elsewhere).
    #[must_use]
    pub fn table_available_to(&self, job: u32) -> f64 {
        self.table.as_ref().map_or(f64::INFINITY, |t| t.available_to(job))
    }

    /// Switch-multicast uplink stage (spanning groups only): ship the
    /// root's segment from its leaf through the uplink bundle toward the
    /// spine replication point.  The dual of [`Fabric::reduce_fold_spine`]
    /// with the fold removed — replication moves bytes but folds nothing.
    /// Returns arrival at the spine.
    #[must_use]
    pub fn mcast_to_spine(&mut self, leaf: usize, ready: Time, wire_bytes: f64) -> Time {
        match &mut self.interconnect {
            Interconnect::Flat(_) => unreachable!("no spine on a flat crossbar"),
            Interconnect::LeafSpine { uplinks, latency, .. } => {
                uplinks[leaf].reserve(ready, wire_bytes) + *latency
            }
        }
    }

    /// Switch-multicast final egress: one replicated copy of the segment
    /// toward member `dst` (same wire path as [`Fabric::reduce_deliver`]),
    /// counted into the multicast conservation ledger.  Returns arrival
    /// at `dst`'s NIC.
    #[must_use]
    pub fn mcast_deliver(&mut self, dst: usize, ready: Time, wire_bytes: f64) -> Time {
        self.mcast_delivered += 1.0;
        self.reduce_deliver(dst, ready, wire_bytes)
    }

    /// Total member-segment copies egressed in multicast mode — the
    /// observed side of the audit's replication ledger.
    #[must_use]
    pub fn mcast_delivered(&self) -> f64 {
        self.mcast_delivered
    }

    /// In-switch reduction stage 3b: final egress of the reduced segment
    /// toward member `dst`.  Returns arrival at `dst`'s NIC.
    #[must_use]
    pub fn reduce_deliver(&mut self, dst: usize, ready: Time, wire_bytes: f64) -> Time {
        let dst_port = self.topology.leaf_port(dst);
        match &mut self.interconnect {
            Interconnect::Flat(sw) => sw.forward_cut_through(dst, ready, wire_bytes),
            Interconnect::LeafSpine { leaves, .. } => {
                leaves[self.topology.leaf_of(dst)].forward_cut_through(dst_port, ready, wire_bytes)
            }
        }
    }

    /// Utilization of the egress port toward `node` over [0, horizon].
    #[must_use]
    pub fn port_utilization(&self, node: usize, horizon: Time) -> f64 {
        match &self.interconnect {
            Interconnect::Flat(sw) => sw.port_utilization(node, horizon),
            Interconnect::LeafSpine { leaves, .. } => leaves[self.topology.leaf_of(node)]
                .port_utilization(self.topology.leaf_port(node), horizon),
        }
    }

    /// Configured bandwidth of the egress port toward `node` (bytes/s).
    #[must_use]
    pub fn port_rate(&self, node: usize) -> f64 {
        match &self.interconnect {
            Interconnect::Flat(sw) => sw.port_rate(node),
            Interconnect::LeafSpine { leaves, .. } => {
                leaves[self.topology.leaf_of(node)].port_rate(self.topology.leaf_port(node))
            }
        }
    }

    /// Utilization of `leaf`'s spine uplink bundle over [0, horizon]
    /// (always 0 on the flat crossbar — there are no uplinks).
    #[must_use]
    pub fn uplink_utilization(&self, leaf: usize, horizon: Time) -> f64 {
        match &self.interconnect {
            Interconnect::Flat(_) => 0.0,
            Interconnect::LeafSpine { uplinks, .. } => uplinks[leaf].utilization(horizon),
        }
    }

    /// Mean Tx-link utilization across nodes over [0, horizon].
    pub fn mean_eth_util(&self, horizon: Time) -> f64 {
        let n = self.nodes.len() as f64;
        self.nodes.iter().map(|nd| nd.tx.utilization(horizon)).sum::<f64>() / n
    }

    /// Mean PCIe utilization (both directions averaged) over [0, horizon].
    pub fn mean_pcie_util(&self, horizon: Time) -> f64 {
        let n = self.nodes.len() as f64;
        self.nodes
            .iter()
            .map(|nd| {
                (nd.pcie.to_device.utilization(horizon) + nd.pcie.to_host.utilization(horizon))
                    / 2.0
            })
            .sum::<f64>()
            / n
    }

    /// Mean adder utilization over [0, horizon].
    pub fn mean_adder_util(&self, horizon: Time) -> f64 {
        let n = self.nodes.len() as f64;
        self.nodes.iter().map(|nd| nd.adder.utilization(horizon)).sum::<f64>() / n
    }

    /// Total f32 elements folded by the nodes' FPGA adders — the observed
    /// side of the conservation audit's exactly-once ledger for
    /// NIC-offloaded reductions.
    #[must_use]
    pub fn adders_served(&self) -> f64 {
        self.nodes.iter().map(|nd| nd.adder.served()).sum()
    }

    /// Total f32 elements folded by the switching tier's aggregation
    /// engines (0 without in-switch reduction capability) — the observed
    /// side of the conservation audit's ledger for in-switch reductions.
    #[must_use]
    pub fn reduce_engines_served(&self) -> f64 {
        match &self.interconnect {
            Interconnect::Flat(sw) => sw.engines_served(),
            Interconnect::LeafSpine { uplink_reducers, spine_reducers, .. } => uplink_reducers
                .iter()
                .chain(spine_reducers.iter())
                .map(Server::served)
                .sum(),
        }
    }

    /// Every FIFO server in the fabric — each node's Tx, PCIe (both
    /// directions), adder and comm servers, then the whole interconnect —
    /// enumerated by the quiescence audit's leaked-reservation scan.
    pub fn servers(&self) -> impl Iterator<Item = &Server> + '_ {
        let node_servers = self.nodes.iter().flat_map(|nd| {
            [
                &nd.tx.server,
                &nd.pcie.to_device.server,
                &nd.pcie.to_host.server,
                &nd.adder,
                &nd.comm,
            ]
        });
        let interconnect: Box<dyn Iterator<Item = &Server>> = match &self.interconnect {
            Interconnect::Flat(sw) => Box::new(sw.servers()),
            Interconnect::LeafSpine {
                leaves,
                uplinks,
                downlinks,
                uplink_reducers,
                spine_reducers,
                spine_occupancy,
                ..
            } => Box::new(
                leaves
                    .iter()
                    .flat_map(Switch::servers)
                    .chain(uplinks)
                    .chain(downlinks)
                    .chain(uplink_reducers)
                    .chain(spine_reducers)
                    .chain(spine_occupancy),
            ),
        };
        node_servers.chain(interconnect)
    }
}

#[cfg(test)]
// exact float equalities are deliberate here: the fabric model is pure
// arithmetic and the tests pin bit-exact results
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;

    #[test]
    fn uncontended_hop_costs_serialization_plus_latency() {
        let sys = SystemParams::smartnic_40g();
        let mut f = Fabric::new(&sys, 4, &ClusterFaults::none());
        let bytes = 1e6;
        let t = f.hop(0, 1, 0.0, bytes);
        let expect = bytes / sys.net.effective_bw() + sys.net.hop_latency;
        assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
    }

    #[test]
    fn faults_scale_the_right_node() {
        let sys = SystemParams::smartnic_40g();
        let faults = ClusterFaults::none()
            .with_degraded_link(1, 0.5)
            .with_straggler(2, 0.25);
        let f = Fabric::new(&sys, 3, &faults);
        assert_eq!(f.nodes[1].tx.server.rate, sys.net.effective_bw() * 0.5);
        assert_eq!(f.nodes[0].tx.server.rate, sys.net.effective_bw());
        assert_eq!(f.nodes[2].adder.rate, sys.nic.add_flops * 0.25);
        assert_eq!(f.nodes[2].pcie.to_device.server.rate, sys.nic.pcie_bw * 0.25);
        // regression: a straggler's host comm cores slow down too
        assert_eq!(f.nodes[2].comm.rate, 0.25);
        assert_eq!(f.nodes[0].comm.rate, 1.0);
        // regression: the switch egress port toward the degraded node is
        // scaled, so incast to it slows down as well
        assert_eq!(f.port_rate(1), sys.net.effective_bw() * 0.5);
        assert_eq!(f.port_rate(0), sys.net.effective_bw());
    }

    #[test]
    fn converging_hops_contend_on_egress() {
        let sys = SystemParams::smartnic_40g();
        let mut f = Fabric::new(&sys, 4, &ClusterFaults::none());
        let bytes = 1e6;
        let ser = bytes / sys.net.effective_bw();
        // two different senders, same destination, same instant
        let t1 = f.hop(0, 2, 0.0, bytes);
        let t2 = f.hop(1, 2, 0.0, bytes);
        assert!((t1 - (ser + sys.net.hop_latency)).abs() < 1e-12);
        // the second flow's egress reservation queues behind the first
        assert!((t2 - (2.0 * ser + sys.net.hop_latency)).abs() < 1e-12);
    }

    #[test]
    fn incast_toward_degraded_node_slows_down() {
        // the victim's *egress* port runs slow, so traffic converging on it
        // queues 4x longer — even though every sender's Tx link is healthy
        let sys = SystemParams::smartnic_40g();
        let faults = ClusterFaults::none().with_degraded_link(2, 0.25);
        let mut f = Fabric::with_topology(&sys, Topology::flat(4), &faults);
        let bytes = 1e6;
        let ser = bytes / sys.net.effective_bw();
        let _ = f.hop(0, 2, 0.0, bytes);
        let second = f.hop(1, 2, 0.0, bytes);
        // first reservation occupies 4x the healthy drain time
        let expect = ser + 4.0 * ser + sys.net.hop_latency;
        assert!((second - expect).abs() < 1e-12, "{second} vs {expect}");
    }

    #[test]
    fn intra_leaf_hop_is_single_latency() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::leaf_spine(2, 3, 4.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let t = f.hop(0, 2, 0.0, bytes); // both on leaf 0
        let expect = bytes / sys.net.effective_bw() + sys.net.hop_latency;
        assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
    }

    #[test]
    fn inter_leaf_hop_pays_three_latencies_when_uncontended() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::leaf_spine(2, 3, 1.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let t = f.hop(0, 4, 0.0, bytes); // leaf 0 -> leaf 1
        let expect = bytes / sys.net.effective_bw() + 3.0 * sys.net.hop_latency;
        assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
    }

    #[test]
    fn oversubscribed_uplink_queues_converging_leaf_exits() {
        let sys = SystemParams::smartnic_40g();
        // 3 nodes per leaf, 3:1 oversubscribed: the uplink bundle drains at
        // exactly one port's rate
        let topo = Topology::leaf_spine(2, 3, 3.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let ser = bytes / sys.net.effective_bw();
        let lat = sys.net.hop_latency;
        // all three leaf-0 nodes send cross-leaf to distinct destinations
        // at t=0: no egress-port contention, but the shared uplink bundle
        // serializes them
        let t0 = f.hop(0, 3, 0.0, bytes);
        let t1 = f.hop(1, 4, 0.0, bytes);
        let t2 = f.hop(2, 5, 0.0, bytes);
        assert!((t0 - (ser + 3.0 * lat)).abs() < 1e-12, "{t0}");
        assert!((t1 - (2.0 * ser + 3.0 * lat)).abs() < 1e-12, "{t1}");
        assert!((t2 - (3.0 * ser + 3.0 * lat)).abs() < 1e-12, "{t2}");
        assert!(f.uplink_utilization(0, t2) > 0.0);
        assert_eq!(f.uplink_utilization(1, t2), 0.0);
    }

    #[test]
    fn non_blocking_uplink_does_not_queue_a_single_flow_train() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::leaf_spine(2, 2, 1.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let ser = bytes / sys.net.effective_bw();
        let lat = sys.net.hop_latency;
        // back-to-back segments of one cross-leaf flow: each is delayed
        // only by its own Tx serialization (the 2-port bundle drains two
        // port-rates' worth, so the train never backs up)
        let t0 = f.hop(0, 2, 0.0, bytes);
        let t1 = f.hop(0, 2, 0.0, bytes);
        assert!((t0 - (ser + 3.0 * lat)).abs() < 1e-12);
        assert!((t1 - (2.0 * ser + 3.0 * lat)).abs() < 1e-12);
    }

    #[test]
    fn hop_split_plus_deliver_compose_to_exactly_one_hop() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::leaf_spine(2, 3, 3.0);
        let mut whole = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let mut halves = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        // a mixed train: intra-leaf, then two converging spine crossings
        let flows = [(0usize, 2usize), (0, 4), (1, 4)];
        for (src, dst) in flows {
            let direct = whole.hop(src, dst, 0.0, bytes);
            let split = match halves.hop_split(src, dst, 0.0, bytes) {
                HopOutcome::Delivered(t) => t,
                HopOutcome::AtSpine(at_spine) => halves.hop_deliver(dst, at_spine, bytes),
            };
            assert_eq!(direct.to_bits(), split.to_bits(), "{src}->{dst}");
        }
    }

    #[test]
    fn flat_hop_split_always_delivers() {
        let sys = SystemParams::smartnic_40g();
        let mut f = Fabric::new(&sys, 4, &ClusterFaults::none());
        let bytes = 1e6;
        let expect = bytes / sys.net.effective_bw() + sys.net.hop_latency;
        match f.hop_split(0, 1, 0.0, bytes) {
            HopOutcome::Delivered(t) => assert!((t - expect).abs() < 1e-12, "{t} vs {expect}"),
            HopOutcome::AtSpine(_) => panic!("flat crossbar has no spine"),
        }
    }

    #[test]
    fn audit_accessors_enumerate_every_server() {
        let sys = SystemParams::smartnic_40g();
        let mut f = Fabric::new(&sys, 4, &ClusterFaults::none());
        // flat crossbar, no in-switch reduction: 5 servers per node
        // (tx, pcie x2, adder, comm) + one egress port per node
        assert_eq!(f.servers().count(), 4 * 5 + 4);
        assert_eq!(f.adders_served(), 0.0);
        assert_eq!(f.reduce_engines_served(), 0.0);
        let _ = f.nodes[0].adder.serve(0.0, 1e6);
        assert_eq!(f.adders_served(), 1e6);
        // leaf–spine: per-leaf down-ports plus uplink/downlink bundles
        let topo = Topology::leaf_spine(2, 3, 3.0);
        let ls = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        assert_eq!(ls.servers().count(), 6 * 5 + 2 * (3 + 1 + 1));
    }

    #[test]
    fn plain_fabric_cannot_reduce_in_switch() {
        let sys = SystemParams::smartnic_40g();
        let flat = Fabric::new(&sys, 4, &ClusterFaults::none());
        assert!(!flat.switch_reduce_capable());
        let topo = Topology::leaf_spine(2, 2, 2.0);
        let ls = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        assert!(!ls.switch_reduce_capable());
    }

    #[test]
    fn flat_reduce_path_times_fold_and_delivery() {
        use crate::sysconfig::SwitchParams;
        let rate = 1e9; // 1 G adds/s
        let sys = SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: rate,
            reduce_table_bytes: 16.0 * 1024.0 * 1024.0,
        });
        let mut f = Fabric::new(&sys, 3, &ClusterFaults::none());
        assert!(f.switch_reduce_capable());
        let bytes = 1e6;
        let elems = bytes / 4.0;
        let ser = bytes / sys.net.effective_bw();
        // three contributions converging on root 0's engine: they all
        // arrive at `ser` and fold FIFO at 0.25 ms apiece
        let folds: Vec<f64> =
            (0..3).map(|src| f.reduce_fold_local(src, 0, 0.0, bytes, elems)).collect();
        for (k, t) in folds.iter().enumerate() {
            let expect = ser + (k as f64 + 1.0) * elems / rate;
            assert!((t - expect).abs() < 1e-12, "{k}: {t} vs {expect}");
        }
        // delivery of the reduced segment pays egress + one switch latency
        let d = f.reduce_deliver(1, folds[2], bytes);
        assert!((d - (folds[2] + sys.net.hop_latency)).abs() < 1e-12);
    }

    #[test]
    fn leaf_spine_reduce_path_uses_uplink_and_spine_engines() {
        use crate::sysconfig::SwitchParams;
        let rate = 1e9;
        let sys = SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: rate,
            reduce_table_bytes: 16.0 * 1024.0 * 1024.0,
        });
        let topo = Topology::leaf_spine(2, 2, 2.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let elems = bytes / 4.0;
        let ser = bytes / sys.net.effective_bw();
        let lat = sys.net.hop_latency;
        // leaf 0's two members fold into leaf 0's spine-facing engine
        let f0 = f.reduce_fold_local(0, 0, 0.0, bytes, elems);
        let f1 = f.reduce_fold_local(1, 0, 0.0, bytes, elems);
        assert!((f0 - (ser + elems / rate)).abs() < 1e-12);
        assert!((f1 - (ser + 2.0 * elems / rate)).abs() < 1e-12);
        // leaf 1's members use their own leaf engine — no cross-queueing
        let g0 = f.reduce_fold_local(2, 0, 0.0, bytes, elems);
        assert!((g0 - (ser + elems / rate)).abs() < 1e-12);
        // each leaf ships its aggregate up and folds at the spine engine
        // toward the root's leaf (uncontended uplink: cut-through start +
        // one latency, then the fold)
        let s0 = f.reduce_fold_spine(0, 0, 0, f1, bytes, elems);
        assert!((s0 - (f1 + lat + elems / rate)).abs() < 1e-12);
        // multicast down and final egress pay one latency per stage
        let down = f.reduce_downlink(1, s0, bytes);
        assert!((down - (s0 + lat)).abs() < 1e-12);
        let at_nic = f.reduce_deliver(3, down, bytes);
        assert!((at_nic - (down + lat)).abs() < 1e-12);
    }

    #[test]
    fn pfc_derates_spine_stages_and_records_a_star_of_pause_edges() {
        use crate::sysconfig::{PfcParams, SwitchParams};
        // near-infinite fold rate so the uplink bundle, not the engine, is
        // the pinned bottleneck
        let rate = 1e15;
        let mk = |pfc: PfcParams| {
            let sys = SystemParams::smartnic_40g()
                .with_switch_reduction(SwitchParams {
                    reduce_flops: rate,
                    reduce_table_bytes: 16.0 * 1024.0 * 1024.0,
                })
                .with_pfc(pfc);
            (Fabric::with_topology(&sys, Topology::leaf_spine(2, 2, 1.0), &ClusterFaults::none()), sys)
        };
        // duty 0.8 (1000 pauses/s x 200 us): uplink/downlink work inflates 1.25x
        let pfc = PfcParams { pause_rate: 1000.0, pause_window: 200e-6 };
        let (mut f, sys) = mk(pfc);
        let (mut f_off, _) = mk(PfcParams::off());
        assert_eq!(f.pfc_duty(), pfc.duty());
        assert_eq!(f_off.pfc_duty(), 1.0);
        let bytes = 1e6;
        let elems = bytes / 4.0;
        let bundle = 2.0 * sys.net.effective_bw(); // non-blocking 2-port bundle
        let lat = sys.net.hop_latency;
        // contributing leaf 1 folds toward root 0 (leaf 0): a second
        // reservation on the same uplink queues behind 1.25x the bytes
        let _ = f.reduce_fold_spine(7, 1, 0, 0.0, bytes, elems);
        let s = f.reduce_fold_spine(7, 1, 0, 0.0, bytes, elems);
        let expect = 2.0 * (bytes * pfc.derate()) / bundle + lat + elems / rate;
        assert!((s - expect).abs() < 1e-12, "{s} vs {expect}");
        // the pause edge is the star into the root's leaf, deduplicated
        assert_eq!(f.pause_edges(), &[(7, 1, 0)]);
        // downlink derates identically; PFC off records nothing
        let _ = f.reduce_downlink(1, 0.0, bytes);
        let d = f.reduce_downlink(1, 0.0, bytes);
        assert!((d - (2.0 * bytes * pfc.derate() / bundle + lat)).abs() < 1e-12);
        let _ = f_off.reduce_fold_spine(7, 1, 0, 0.0, bytes, elems);
        assert!(f_off.pause_edges().is_empty());
        // same-leaf fold never records an edge (no spine pause to see)
        let (mut f2, _) = mk(pfc);
        let _ = f2.reduce_fold_spine(3, 0, 0, 0.0, bytes, elems);
        assert_eq!(f2.pause_edges(), &[] as &[(u32, usize, usize)]);
    }

    #[test]
    fn engine_occupancy_serializes_across_the_fabric_api() {
        use crate::sysconfig::SwitchParams;
        let sys = SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: 1e9,
            reduce_table_bytes: 16.0 * 1024.0 * 1024.0,
        });
        let bytes = 1e6;
        let port = sys.net.effective_bw();
        // flat: two tenants' segments drain the root-0 engine FIFO
        let mut flat = Fabric::new(&sys, 4, &ClusterFaults::none());
        assert_eq!(flat.reduce_engine_occupancy(0, 0.0, bytes), bytes / port);
        assert_eq!(flat.reduce_engine_occupancy(0, 0.0, bytes), 2.0 * bytes / port);
        assert_eq!(flat.reduce_engine_occupancy(1, 0.0, bytes), bytes / port);
        // leaf–spine: the spine engine toward the root's leaf serializes
        let topo = Topology::leaf_spine(2, 2, 1.0);
        let mut ls = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        assert_eq!(ls.reduce_engine_occupancy(0, 0.0, bytes), bytes / port);
        // root 1 lives on the same leaf: same occupancy server
        assert_eq!(ls.reduce_engine_occupancy(1, 0.0, bytes), 2.0 * bytes / port);
        // roots on leaf 1 are independent
        assert_eq!(ls.reduce_engine_occupancy(2, 0.0, bytes), bytes / port);
        // occupancy servers join the audit enumeration (4 + 4 + 4 flat;
        // per-leaf down-ports + bundles + engines + occupancy on LS)
        assert_eq!(Fabric::new(&sys, 4, &ClusterFaults::none()).servers().count(), 4 * 5 + 12);
        let ls2 = Fabric::with_topology(&sys, Topology::leaf_spine(2, 3, 3.0), &ClusterFaults::none());
        assert_eq!(ls2.servers().count(), 6 * 5 + 2 * (3 + 1 + 1 + 1 + 1 + 1));
    }

    #[test]
    fn table_pool_is_shared_and_absent_without_reduction() {
        use crate::sysconfig::SwitchParams;
        let plain = Fabric::new(&SystemParams::smartnic_40g(), 4, &ClusterFaults::none());
        assert!(plain.table().is_none());
        assert_eq!(plain.table_available_to(0), f64::INFINITY);
        let cap = 1024.0;
        let sys = SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: 1e9,
            reduce_table_bytes: cap,
        });
        let mut f = Fabric::new(&sys, 4, &ClusterFaults::none());
        assert_eq!(f.table().unwrap().capacity(), cap);
        assert_eq!(f.table_available_to(0), cap);
        let got = f.table_mut().unwrap().request(0, cap, 256.0);
        assert_eq!(got, cap);
        // the pool is fabric-wide: a second tenant sees nothing left
        assert_eq!(f.table_available_to(1), 0.0);
        f.table_mut().unwrap().release(0);
        assert_eq!(f.table_available_to(1), cap, "idle slot is evictable");
    }

    #[test]
    // the delivery counter increments by exactly 1.0 per copy, so the
    // pinned values are exact
    #[allow(clippy::float_cmp)]
    fn multicast_path_replicates_without_folding_and_counts_deliveries() {
        use crate::sysconfig::SwitchParams;
        let sys = SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: 1e9,
            reduce_table_bytes: 16.0 * 1024.0 * 1024.0,
        });
        let topo = Topology::leaf_spine(2, 2, 2.0);
        let mut f = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let bytes = 1e6;
        let ser = bytes / sys.net.effective_bw();
        let lat = sys.net.hop_latency;
        assert_eq!(f.mcast_delivered(), 0.0);
        // root 0 serializes up: Tx + uplink cut-through + one latency,
        // with no engine fold anywhere on the path
        let at_sw = f.nodes[0].tx.transmit(0.0, bytes);
        let at_spine = f.mcast_to_spine(0, at_sw, bytes);
        assert!((at_spine - (ser + lat)).abs() < 1e-12);
        // replication down both leaves reuses the reduction downlink stage
        let d0 = f.reduce_downlink(0, at_spine, bytes);
        let d1 = f.reduce_downlink(1, at_spine, bytes);
        assert!((d0 - (at_spine + lat)).abs() < 1e-12);
        assert!((d1 - (at_spine + lat)).abs() < 1e-12);
        // final egress to three non-root members, each counted once
        for (dst, down) in [(1usize, d0), (2, d1), (3, d1)] {
            let _ = f.mcast_deliver(dst, down, bytes);
        }
        assert_eq!(f.mcast_delivered(), 3.0);
        // replication folded exactly nothing
        assert_eq!(f.reduce_engines_served(), 0.0);
        assert_eq!(f.adders_served(), 0.0);
    }
}
