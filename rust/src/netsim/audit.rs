//! Runtime invariant auditor for the event engine ([`EngineKind::Checked`]).
//!
//! PR 6's parallel executive rests on contracts that live in prose: the
//! `# Safety` section of [`PartitionedWorld`], the scheduling
//! preconditions (finite times, never into the past), and the calendar
//! queue's `(time, seq)` total order.  This module turns those contracts
//! into *executable checks*: under [`EngineKind::Checked`] the executive
//! validates every schedule, every dispatch and every window barrier,
//! and records breaches as structured [`AuditViolation`] values instead
//! of panicking — so a violating run completes and reports *what* broke,
//! and a clean run proves the contracts held for that workload.
//!
//! The auditor costs exactly one `Option` branch per operation when off
//! (`Sim` holds `Option<Box<AuditState>>`, `None` for every unchecked
//! engine kind), and the checked equivalence suite pins the audited
//! engine bit-identical to the unchecked one — auditing observes, never
//! perturbs.
//!
//! Every invariant checked here is enumerated, with its source-of-truth
//! contract, in `docs/INVARIANTS.md`.
//!
//! [`EngineKind::Checked`]: super::engine::EngineKind::Checked
//! [`PartitionedWorld`]: super::engine::PartitionedWorld

use super::engine::{PartitionedWorld, GLOBAL_PARTITION};
use super::Time;
use std::fmt;

/// One breach of an engine or PDES invariant, as primitives — no
/// payloads borrowed from the run, so reports outlive the simulation
/// and serialize trivially.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AuditViolation {
    /// a schedule call carried a NaN or infinite time (the event was
    /// dropped — a non-finite key would corrupt the queue order)
    NonFiniteTime {
        /// the offending virtual time
        time: Time,
    },
    /// a schedule call targeted the scheduler's past (the event was
    /// clamped to `now` and kept)
    SchedulePast {
        /// requested fire time
        time: Time,
        /// the scheduler's clock at the call
        now: Time,
    },
    /// a popped event carried a time below the executing runner's clock
    /// — the clock would have run backwards
    DispatchRegression {
        /// the popped event's time
        time: Time,
        /// the runner's clock before the pop
        now: Time,
    },
    /// the calendar queue popped keys out of `(time, seq)` order — the
    /// total order the determinism argument rests on
    QueueOrderViolation {
        /// the out-of-order key's time
        time: Time,
        /// the out-of-order key's insertion sequence
        seq: u64,
        /// the previously popped key's time
        prev_time: Time,
        /// the previously popped key's insertion sequence
        prev_seq: u64,
    },
    /// the arena's free list handed out a slot that still held a pending
    /// event (the older event was clobbered)
    SlotAliased {
        /// the doubly-allocated arena slot
        slot: u32,
    },
    /// a cross-partition emission landed inside the emitting window —
    /// the [`PartitionedWorld::lookahead`] contract (PR 6's merge-path
    /// `debug_assert`, promoted so it fires in release audited runs too)
    LookaheadViolation {
        /// the emission's fire time
        time: Time,
        /// the window end it was required to reach
        window_end: Time,
    },
    /// [`PartitionedWorld::route`] returned two different partitions for
    /// the same event — routing must be a pure function of the event
    UnstableRoute {
        /// partition returned by the first call
        first: u32,
        /// partition returned by the second call
        second: u32,
    },
    /// the lower bound on the next executed timestamp (LBTS) moved
    /// backwards between scheduler iterations
    LbtsRegression {
        /// the regressed bound
        lbts: Time,
        /// the previous (higher) bound
        prev: Time,
    },
    /// two same-time deferred emissions in one window batch compared
    /// equal under [`PartitionedWorld::merge_key`] — the key must be a
    /// total order over each batch or thread counts can reorder them
    MergeKeyCollision {
        /// the shared fire time
        time: Time,
        /// the colliding key
        key: u128,
    },
    /// a collective never reached `t_done` although the run drained
    UnfinishedCollective {
        /// index into the cluster's collective table
        cid: usize,
    },
    /// reduction work conservation: the elements actually folded by the
    /// fabric's adders / switch engines differ from what the posted
    /// collectives require (each gradient element reduced exactly once
    /// per peer)
    ReduceConservation {
        /// elements the collectives' algorithms must fold
        expected: f64,
        /// elements the fabric's servers actually folded
        actual: f64,
        /// which reducer population: 0 = node adders, 1 = switch engines
        pool: u8,
    },
    /// replication work conservation: the member-segment copies the
    /// switch tier egressed in multicast mode differ from what the
    /// posted switch-multicast phases require (`members − 1` copies per
    /// segment — replication is not reduction, so neither reduce ledger
    /// can account for these)
    MulticastConservation {
        /// copies the collectives' multicast phases must deliver
        expected: f64,
        /// copies the fabric's replication engines actually delivered
        actual: f64,
    },
    /// a server reservation extends past quiescence — capacity was
    /// reserved but the releasing event chain never completed
    LeakedReservation {
        /// the server's busy-until horizon
        busy_until: Time,
        /// the run's final virtual time
        end: Time,
    },
    /// the gang scheduler's per-node table still assigns a node to a job
    /// after quiescence — the job left the cluster without releasing it
    LeakedAllocation {
        /// the fabric node still marked busy
        node: usize,
        /// the job the table says holds it
        job: usize,
    },
    /// a job's scheduler ledger does not balance: every arrived job must
    /// terminate with exactly its demanded iterations completed (a
    /// checkpoint-restart that double-counted an iteration, or a job
    /// that vanished without completing, both land here)
    JobConservation {
        /// index into the trace's job table
        job: usize,
        /// iterations the runtime recorded as completed
        done: usize,
        /// iterations the trace demanded
        demand: usize,
    },
    /// the switching tier's aggregation table is over-committed: tenant
    /// reservations exceed the table's capacity, or two tenants hold
    /// overlapping byte ranges — admission control must make this
    /// impossible, so any occurrence is a forged or corrupted allocator
    TableOvercommit {
        /// bytes reserved across all tenants
        reserved: f64,
        /// the table's capacity in bytes
        capacity: f64,
        /// true when two reservations' byte ranges overlap (slot
        /// aliasing between tenants), false for a pure capacity breach
        overlapping: bool,
    },
    /// PFC pause propagation formed a cycle within one priority class
    /// (the classic PFC deadlock: every port in the cycle waits for the
    /// next to unpause), or the configured pause duty cycle is ≤ 0 (a
    /// pause storm that throttles the reduction tree to a standstill,
    /// recorded with `cid = u32::MAX` and `cycle_len = 0`)
    PauseDeadlock {
        /// the priority class whose pause graph cycles
        cid: u32,
        /// number of edges in the detected cycle (0 for a duty-cycle
        /// storm)
        cycle_len: u32,
    },
}

impl AuditViolation {
    /// Stable short name of the violated invariant (the `docs/INVARIANTS.md`
    /// anchor).
    pub fn kind(&self) -> &'static str {
        match self {
            AuditViolation::NonFiniteTime { .. } => "non-finite-time",
            AuditViolation::SchedulePast { .. } => "schedule-past",
            AuditViolation::DispatchRegression { .. } => "dispatch-regression",
            AuditViolation::QueueOrderViolation { .. } => "queue-order",
            AuditViolation::SlotAliased { .. } => "slot-aliased",
            AuditViolation::LookaheadViolation { .. } => "lookahead",
            AuditViolation::UnstableRoute { .. } => "unstable-route",
            AuditViolation::LbtsRegression { .. } => "lbts-regression",
            AuditViolation::MergeKeyCollision { .. } => "merge-key-collision",
            AuditViolation::UnfinishedCollective { .. } => "unfinished-collective",
            AuditViolation::ReduceConservation { .. } => "reduce-conservation",
            AuditViolation::MulticastConservation { .. } => "multicast-conservation",
            AuditViolation::LeakedReservation { .. } => "leaked-reservation",
            AuditViolation::LeakedAllocation { .. } => "leaked-allocation",
            AuditViolation::JobConservation { .. } => "job-conservation",
            AuditViolation::TableOvercommit { .. } => "table-overcommit",
            AuditViolation::PauseDeadlock { .. } => "pause-deadlock-free",
        }
    }
}

impl fmt::Display for AuditViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AuditViolation::NonFiniteTime { time } => {
                write!(f, "non-finite event time {time} (event dropped)")
            }
            AuditViolation::SchedulePast { time, now } => {
                write!(f, "schedule into the past: {time} < now {now} (clamped)")
            }
            AuditViolation::DispatchRegression { time, now } => {
                write!(f, "dispatched event at {time} behind the clock {now}")
            }
            AuditViolation::QueueOrderViolation { time, seq, prev_time, prev_seq } => write!(
                f,
                "queue popped ({time}, seq {seq}) after ({prev_time}, seq {prev_seq})"
            ),
            AuditViolation::SlotAliased { slot } => {
                write!(f, "arena slot {slot} handed out while still occupied")
            }
            AuditViolation::LookaheadViolation { time, window_end } => write!(
                f,
                "cross-partition emission at {time} inside the window ending at {window_end}"
            ),
            AuditViolation::UnstableRoute { first, second } => {
                write!(f, "route() returned {first} then {second} for one event")
            }
            AuditViolation::LbtsRegression { lbts, prev } => {
                write!(f, "LBTS regressed to {lbts} from {prev}")
            }
            AuditViolation::MergeKeyCollision { time, key } => write!(
                f,
                "two deferred emissions at {time} share merge key {key:#034x}"
            ),
            AuditViolation::UnfinishedCollective { cid } => {
                write!(f, "collective {cid} never completed")
            }
            AuditViolation::ReduceConservation { expected, actual, pool } => {
                let name = if *pool == 0 { "node adders" } else { "switch engines" };
                write!(f, "{name} folded {actual} elements, collectives require {expected}")
            }
            AuditViolation::MulticastConservation { expected, actual } => write!(
                f,
                "multicast engines delivered {actual} copies, collectives require {expected}"
            ),
            AuditViolation::LeakedReservation { busy_until, end } => write!(
                f,
                "server reserved until {busy_until}, past quiescence at {end}"
            ),
            AuditViolation::LeakedAllocation { node, job } => write!(
                f,
                "node {node} still allocated to job {job} after quiescence"
            ),
            AuditViolation::JobConservation { job, done, demand } => write!(
                f,
                "job {job} finished {done} iterations but the trace demanded {demand}"
            ),
            AuditViolation::TableOvercommit { reserved, capacity, overlapping } => {
                if *overlapping {
                    write!(
                        f,
                        "aggregation table slots overlap ({reserved} bytes reserved of {capacity})"
                    )
                } else {
                    write!(
                        f,
                        "aggregation table over-committed: {reserved} bytes reserved of {capacity}"
                    )
                }
            }
            AuditViolation::PauseDeadlock { cid, cycle_len } => {
                if *cid == u32::MAX {
                    write!(f, "PFC pause storm: duty cycle <= 0 stalls the reduction tree")
                } else {
                    write!(
                        f,
                        "PFC pause cycle of {cycle_len} edge(s) in priority class {cid}"
                    )
                }
            }
        }
    }
}

/// Recorded violations are capped here; the total count keeps counting.
pub const MAX_RECORDED: usize = 64;

/// The outcome of an audited run: every violation observed (the first
/// [`MAX_RECORDED`], plus a total), and how many dispatches were
/// checked.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AuditReport {
    violations: Vec<AuditViolation>,
    total: u64,
    events_checked: u64,
}

impl AuditReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one violation (kept verbatim up to [`MAX_RECORDED`];
    /// always counted).
    pub fn record(&mut self, violation: AuditViolation) {
        self.total += 1;
        if self.violations.len() < MAX_RECORDED {
            self.violations.push(violation);
        }
    }

    /// True when no invariant was breached.
    pub fn is_clean(&self) -> bool {
        self.total == 0
    }

    /// Total violations observed (may exceed `violations().len()`).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// The recorded violations, in observation order.
    pub fn violations(&self) -> &[AuditViolation] {
        &self.violations
    }

    /// Dispatches the auditor checked.
    pub fn events_checked(&self) -> u64 {
        self.events_checked
    }

    pub(crate) fn count_event(&mut self) {
        self.events_checked += 1;
    }

    /// Fold another runner's report into this one (parallel runs merge
    /// every partition's report into the coordinator's).
    pub fn merge(&mut self, other: AuditReport) {
        self.total += other.total;
        self.events_checked += other.events_checked;
        for v in other.violations {
            if self.violations.len() >= MAX_RECORDED {
                break;
            }
            self.violations.push(v);
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        if self.is_clean() {
            format!("audit clean: {} events checked, 0 violations", self.events_checked)
        } else {
            let first = self
                .violations
                .first()
                .map_or_else(String::new, |v| format!(" (first: {v})"));
            format!(
                "audit FAILED: {} violation(s) over {} events checked{first}",
                self.total, self.events_checked
            )
        }
    }
}

/// Per-runner auditor state: the report plus the last popped key and
/// LBTS watermark the order checks compare against.
#[derive(Debug, Default)]
pub struct AuditState {
    /// violations and counters accumulated by this runner
    pub report: AuditReport,
    last_pop: Option<(Time, u64)>,
    last_lbts: Option<Time>,
}

impl AuditState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Validate a schedule time: returns the (possibly clamped) time to
    /// use, or `None` when the event must be dropped (non-finite key).
    pub fn on_schedule(&mut self, time: Time, now: Time) -> Option<Time> {
        if !time.is_finite() {
            self.report.record(AuditViolation::NonFiniteTime { time });
            return None;
        }
        if time < now {
            self.report.record(AuditViolation::SchedulePast { time, now });
            return Some(now);
        }
        Some(time)
    }

    /// Validate one dispatch: clock monotonicity against `now` and
    /// `(time, seq)` total-order consistency against the previous pop.
    pub fn on_pop(&mut self, time: Time, seq: u64, now: Time) {
        self.report.count_event();
        if !time.is_finite() {
            self.report.record(AuditViolation::NonFiniteTime { time });
        }
        if time < now {
            self.report.record(AuditViolation::DispatchRegression { time, now });
        }
        if let Some((prev_time, prev_seq)) = self.last_pop {
            let ord = time.total_cmp(&prev_time).then(seq.cmp(&prev_seq));
            if ord != std::cmp::Ordering::Greater {
                self.report.record(AuditViolation::QueueOrderViolation {
                    time,
                    seq,
                    prev_time,
                    prev_seq,
                });
            }
        }
        self.last_pop = Some((time, seq));
    }

    /// Validate that the lower bound on the next executed timestamp
    /// never regresses across scheduler iterations.
    pub fn on_lbts(&mut self, lbts: Time) {
        if let Some(prev) = self.last_lbts {
            if lbts < prev {
                self.report.record(AuditViolation::LbtsRegression { lbts, prev });
                return; // keep the higher watermark
            }
        }
        self.last_lbts = Some(lbts);
    }
}

/// Contract-probing wrapper over a [`PartitionedWorld`]: a snapshot of
/// the world's routing table plus the barrier-side checks of the PDES
/// contract (route stability, lookahead, merge-key totality).  The
/// parallel executive constructs one per audited run
/// ([`EngineKind::Checked`]) and consults it at every window barrier;
/// unchecked runs never build it.
///
/// [`EngineKind::Checked`]: super::engine::EngineKind::Checked
pub struct CheckedWorld<W: PartitionedWorld> {
    map: W::Map,
    lookahead: Time,
}

impl<W: PartitionedWorld> CheckedWorld<W> {
    /// Snapshot the world's routing table and lookahead.
    pub fn new(state: &W) -> Self {
        Self {
            map: state.partition_map(),
            lookahead: state.lookahead(),
        }
    }

    /// The lookahead the contract promises.
    pub fn lookahead(&self) -> Time {
        self.lookahead
    }

    /// Route an event, calling [`PartitionedWorld::route`] twice: a
    /// routing function that is not a pure function of the event value
    /// would shard state differently than the barrier re-route assumes
    /// (recorded as [`AuditViolation::UnstableRoute`]).
    pub fn checked_route(&self, event: &W::Event, report: &mut AuditReport) -> u32 {
        let first = W::route(&self.map, event);
        let second = W::route(&self.map, event);
        if first != second {
            report.record(AuditViolation::UnstableRoute { first, second });
        }
        first
    }

    /// Check one merged emission against the lookahead contract: a
    /// partition-bound event must land at or past the window's end (the
    /// coordinator carve-out exempts [`GLOBAL_PARTITION`]).
    pub fn check_emission(
        &self,
        partition: u32,
        time: Time,
        window_end: Time,
        report: &mut AuditReport,
    ) {
        if partition != GLOBAL_PARTITION && time < window_end {
            report.record(AuditViolation::LookaheadViolation { time, window_end });
        }
    }

    /// Check that `merge_key` is a total order over one sorted barrier
    /// batch: adjacent entries sharing `(time, key)` are not ordered by
    /// anything thread-independent, so the run is not reproducible
    /// across thread counts.
    pub fn check_merge_batch(
        &self,
        batch: &[(Time, u128, W::Event)],
        report: &mut AuditReport,
    ) {
        for pair in batch.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            if a.0.to_bits() == b.0.to_bits() && a.1 == b.1 {
                report.record(AuditViolation::MergeKeyCollision { time: a.0, key: a.1 });
            }
        }
    }
}

#[cfg(test)]
// exact float comparison is the point in these tests: the auditor must
// hand times through unmodified
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;

    #[test]
    fn report_caps_recorded_but_counts_all() {
        let mut r = AuditReport::new();
        for slot in 0..(MAX_RECORDED as u32 + 10) {
            r.record(AuditViolation::SlotAliased { slot });
        }
        assert_eq!(r.violations().len(), MAX_RECORDED);
        assert_eq!(r.total(), MAX_RECORDED as u64 + 10);
        assert!(!r.is_clean());
    }

    #[test]
    fn on_pop_detects_queue_order_violation() {
        let mut a = AuditState::new();
        a.on_pop(1.0, 0, 0.0);
        a.on_pop(1.0, 1, 1.0); // same time, later seq: fine
        assert!(a.report.is_clean());
        a.on_pop(1.0, 0, 1.0); // same time, earlier seq: out of order
        assert_eq!(a.report.total(), 1);
        assert!(matches!(
            a.report.violations()[0],
            AuditViolation::QueueOrderViolation { seq: 0, prev_seq: 1, .. }
        ));
    }

    #[test]
    fn on_pop_detects_clock_regression() {
        let mut a = AuditState::new();
        a.on_pop(2.0, 0, 0.0);
        a.on_pop(1.0, 1, 2.0); // behind the runner's clock
        let kinds: Vec<_> = a.report.violations().iter().map(|v| v.kind()).collect();
        assert!(kinds.contains(&"dispatch-regression"));
        assert!(kinds.contains(&"queue-order"));
    }

    #[test]
    fn on_lbts_detects_regression_and_keeps_watermark() {
        let mut a = AuditState::new();
        a.on_lbts(1.0);
        a.on_lbts(2.0);
        a.on_lbts(1.5); // regression
        assert_eq!(a.report.total(), 1);
        assert!(matches!(
            a.report.violations()[0],
            AuditViolation::LbtsRegression { prev, .. } if prev == 2.0
        ));
        a.on_lbts(2.0); // back at the watermark: not a second regression
        assert_eq!(a.report.total(), 1);
    }

    #[test]
    fn on_schedule_drops_non_finite_and_clamps_past() {
        let mut a = AuditState::new();
        assert_eq!(a.on_schedule(f64::NAN, 0.0), None);
        assert_eq!(a.on_schedule(f64::INFINITY, 0.0), None);
        assert_eq!(a.on_schedule(0.5, 1.0), Some(1.0));
        assert_eq!(a.on_schedule(2.0, 1.0), Some(2.0));
        assert_eq!(a.report.total(), 3);
    }

    #[test]
    fn tenancy_violations_have_stable_kinds_and_messages() {
        let over = AuditViolation::TableOvercommit {
            reserved: 10.0,
            capacity: 8.0,
            overlapping: false,
        };
        assert_eq!(over.kind(), "table-overcommit");
        assert_eq!(
            over.to_string(),
            "aggregation table over-committed: 10 bytes reserved of 8"
        );
        let alias = AuditViolation::TableOvercommit {
            reserved: 6.0,
            capacity: 8.0,
            overlapping: true,
        };
        assert_eq!(alias.kind(), "table-overcommit");
        assert_eq!(
            alias.to_string(),
            "aggregation table slots overlap (6 bytes reserved of 8)"
        );
        let cycle = AuditViolation::PauseDeadlock { cid: 3, cycle_len: 2 };
        assert_eq!(cycle.kind(), "pause-deadlock-free");
        assert_eq!(cycle.to_string(), "PFC pause cycle of 2 edge(s) in priority class 3");
        let storm = AuditViolation::PauseDeadlock { cid: u32::MAX, cycle_len: 0 };
        assert_eq!(
            storm.to_string(),
            "PFC pause storm: duty cycle <= 0 stalls the reduction tree"
        );
    }

    #[test]
    fn merged_reports_accumulate() {
        let mut a = AuditReport::new();
        a.count_event();
        let mut b = AuditReport::new();
        b.count_event();
        b.record(AuditViolation::SlotAliased { slot: 7 });
        a.merge(b);
        assert_eq!(a.events_checked(), 2);
        assert_eq!(a.total(), 1);
        assert!(a.summary().contains("FAILED"));
    }
}
