//! Miniature property-testing framework (proptest is unavailable offline).
//!
//! `forall` runs a property over N generated cases; on failure it performs
//! greedy shrinking through the generator's `shrink` method and reports the
//! minimal failing input together with the seed that reproduces it.
//!
//! ```ignore
//! use ai_smartnic::prop::{forall, gens};
//! forall(&gens::vec_f32(1..=1000, 8.0), 100, |xs| xs.len() <= 1000);
//! ```

use crate::util::rng::Rng;

/// A random-value generator with optional shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate "smaller" values, most aggressive first.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run `prop` over `cases` generated inputs; panics with the minimal
/// counterexample on failure.  Seed comes from `SMARTNIC_PROP_SEED` env var
/// (default 0xC0FFEE) so failures replay exactly.
pub fn forall<G: Gen>(gen: &G, cases: usize, prop: impl Fn(&G::Value) -> bool) {
    let seed = std::env::var("SMARTNIC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let minimal = shrink_loop(gen, v, &prop);
            panic!(
                "property failed (seed {seed}, case {case}); minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(gen: &G, mut v: G::Value, prop: &impl Fn(&G::Value) -> bool) -> G::Value {
    // up to 1000 shrink steps of greedy descent
    'outer: for _ in 0..1000 {
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        break;
    }
    v
}

/// Ready-made generators.
pub mod gens {
    use super::Gen;
    use crate::util::rng::Rng;
    use std::ops::RangeInclusive;

    pub struct USize(pub RangeInclusive<usize>);

    impl Gen for USize {
        type Value = usize;
        fn generate(&self, rng: &mut Rng) -> usize {
            let (lo, hi) = (*self.0.start(), *self.0.end());
            lo + rng.below((hi - lo + 1) as u64) as usize
        }
        fn shrink(&self, v: &usize) -> Vec<usize> {
            let lo = *self.0.start();
            let mut out = Vec::new();
            if *v > lo {
                out.push(lo);
                out.push(lo + (*v - lo) / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        }
    }

    pub fn usize_in(r: RangeInclusive<usize>) -> USize {
        USize(r)
    }

    /// Vec<f32> of random length with magnitudes spread over ±2^mag_exp.
    pub struct VecF32 {
        pub len: RangeInclusive<usize>,
        pub mag_exp: f32,
    }

    impl Gen for VecF32 {
        type Value = Vec<f32>;
        fn generate(&self, rng: &mut Rng) -> Vec<f32> {
            let n = USize(self.len.clone()).generate(rng);
            (0..n)
                .map(|_| {
                    let e = rng.range_f64(-self.mag_exp as f64, self.mag_exp as f64);
                    (rng.normal() as f32) * (e as f32).exp2()
                })
                .collect()
        }
        fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
            let lo = *self.len.start();
            let mut out = Vec::new();
            if v.len() > lo {
                out.push(v[..lo.max(v.len() / 2)].to_vec());
                out.push(v[..v.len() - 1].to_vec());
            }
            // also try zeroing elements (simplest values)
            if v.iter().any(|&x| x != 0.0) {
                out.push(v.iter().map(|_| 0.0).collect());
            }
            out
        }
    }

    pub fn vec_f32(len: RangeInclusive<usize>, mag_exp: f32) -> VecF32 {
        VecF32 { len, mag_exp }
    }

    /// Pair of independent generators.
    pub struct Pair<A, B>(pub A, pub B);

    impl<A: Gen, B: Gen> Gen for Pair<A, B> {
        type Value = (A::Value, B::Value);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (self.0.generate(rng), self.1.generate(rng))
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let mut out: Vec<Self::Value> = self
                .0
                .shrink(&v.0)
                .into_iter()
                .map(|a| (a, v.1.clone()))
                .collect();
            out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
            out
        }
    }

    pub fn pair<A: Gen, B: Gen>(a: A, b: B) -> Pair<A, B> {
        Pair(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::gens::*;
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(&usize_in(0..=100), 200, |&n| n <= 100);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        forall(&usize_in(0..=1000), 200, |&n| n < 500);
    }

    #[test]
    fn vec_gen_respects_len() {
        forall(&vec_f32(2..=64, 4.0), 100, |v| (2..=64).contains(&v.len()));
    }

    #[test]
    fn pair_generates_both() {
        forall(&pair(usize_in(1..=8), usize_in(1..=8)), 50, |&(a, b)| {
            a >= 1 && b >= 1
        });
    }

    #[test]
    fn shrink_finds_boundary() {
        // the minimal failing case for n >= 500 in 0..=1000 is 500
        let g = usize_in(0..=1000);
        let minimal = super::shrink_loop(&g, 987, &|&n: &usize| n < 500);
        assert_eq!(minimal, 500);
    }
}
