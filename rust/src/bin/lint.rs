//! `smartnic-lint` — the project's determinism/soundness lint pass.
//!
//! Scans `rust/src` for constructs that have historically broken the
//! simulator's determinism or soundness guarantees (docs/INVARIANTS.md,
//! "Correctness tooling").  Entirely offline, no dependencies; CI runs it
//! and fails on any finding not justified in `rust/lint-allow.txt`.
//!
//! Rules (each finding names one):
//!
//! * `float-ord` — raw `f64` ordering (`partial_cmp`, or `sort_by`
//!   without `total_cmp`) anywhere outside `netsim/engine.rs`.  NaN-blind
//!   comparators panic or reorder nondeterministically; the engine owns
//!   the one vetted `(time, seq)` comparator, everything else must use
//!   `total_cmp`.
//! * `undocumented-unsafe` — an `unsafe` block or `unsafe impl` whose
//!   contiguous preceding comment block lacks a `SAFETY:` line.
//! * `hash-iteration` — `HashMap`/`HashSet` in the simulation modules
//!   (`netsim/`, `cluster/`).  Iteration order is randomized per process,
//!   so any event emission fed from it diverges run to run; the sim uses
//!   index-addressed `Vec`s instead.
//! * `non-finite-schedule` — a `schedule` call whose argument expression
//!   mentions `INFINITY`/`NAN` on the call line.  Non-finite times poison
//!   the calendar's total order (the checked executive catches the
//!   dynamic case; this catches the static one).
//! * `wall-clock` — `Instant::now`/`SystemTime::now` in the simulation
//!   modules.  Virtual time must never observe the host clock.
//!
//! Test code (everything from the first `#[cfg(test)]` line down) is
//! exempt: negative tests deliberately construct violations.
//!
//! Output: one line per finding, a summary, and a `LINT.json` report;
//! exit 1 on un-allowlisted findings, 2 on stale allowlist entries.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const RULES: [&str; 5] = [
    "float-ord",
    "undocumented-unsafe",
    "hash-iteration",
    "non-finite-schedule",
    "wall-clock",
];

/// Modules whose virtual-time discipline the sim-scoped rules guard.
const SIM_SCOPES: [&str; 2] = ["netsim/", "cluster/"];

/// The one file allowed to order raw event times: it owns the vetted
/// `(time, seq)` calendar comparator.
const FLOAT_ORD_EXEMPT: &str = "netsim/engine.rs";

struct Finding {
    path: String,
    line: usize,
    rule: &'static str,
    excerpt: String,
}

fn main() -> ExitCode {
    let mut root = PathBuf::from("rust/src");
    let mut allow_path = PathBuf::from("rust/lint-allow.txt");
    let mut out_path = PathBuf::from("LINT.json");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = PathBuf::from(next_arg(&mut args, "--root")),
            "--allow" => allow_path = PathBuf::from(next_arg(&mut args, "--allow")),
            "--out" => out_path = PathBuf::from(next_arg(&mut args, "--out")),
            "--help" | "-h" => {
                println!(
                    "usage: smartnic-lint [--root rust/src] [--allow rust/lint-allow.txt] \
                     [--out LINT.json]"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown option '{other}' (see --help)");
                return ExitCode::from(2);
            }
        }
    }

    let mut files = Vec::new();
    collect_rs_files(&root, &mut files);
    files.sort();
    if files.is_empty() {
        eprintln!("smartnic-lint: no .rs files under {}", root.display());
        return ExitCode::from(2);
    }

    let mut findings = Vec::new();
    for path in &files {
        match std::fs::read_to_string(path) {
            Ok(text) => scan_file(path, &text, &mut findings),
            Err(e) => {
                eprintln!("smartnic-lint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        }
    }

    let allow = match load_allowlist(&allow_path) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("smartnic-lint: {e}");
            return ExitCode::from(2);
        }
    };

    let mut used = vec![false; allow.len()];
    let mut reported = Vec::new();
    let mut allowed = 0usize;
    for f in findings {
        let key = (f.path.as_str(), f.rule);
        if let Some(i) = allow.iter().position(|(p, r)| (p.as_str(), r.as_str()) == key) {
            used[i] = true;
            allowed += 1;
        } else {
            reported.push(f);
        }
    }

    for f in &reported {
        println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.excerpt);
    }
    let stale: Vec<&(String, String)> =
        allow.iter().zip(&used).filter(|(_, &u)| !u).map(|(e, _)| e).collect();
    for (p, r) in &stale {
        eprintln!("stale allowlist entry (no matching finding): {p}:{r}");
    }

    if let Err(e) = std::fs::write(&out_path, report_json(&files, &reported, allowed)) {
        eprintln!("smartnic-lint: cannot write {}: {e}", out_path.display());
        return ExitCode::from(2);
    }
    println!(
        "smartnic-lint: {} file(s), {} finding(s), {} allowlisted -> {}",
        files.len(),
        reported.len(),
        allowed,
        out_path.display()
    );
    if !stale.is_empty() {
        ExitCode::from(2)
    } else if !reported.is_empty() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn next_arg(args: &mut impl Iterator<Item = String>, name: &str) -> String {
    args.next().unwrap_or_else(|| {
        eprintln!("{name} needs a value");
        std::process::exit(2);
    })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            // the lint binary itself quotes the patterns it searches for
            if path.file_name().is_some_and(|n| n == "bin") {
                continue;
            }
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Strip an inline `//` comment (good enough for matching: a pattern
/// hidden this way could only mask a finding on its own line, never
/// invent one).
fn code_of(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

fn scan_file(path: &Path, text: &str, findings: &mut Vec<Finding>) {
    let rel = path.to_string_lossy().replace('\\', "/");
    let in_sim_scope = SIM_SCOPES.iter().any(|s| rel.contains(s));
    let float_ord_applies = !rel.ends_with(FLOAT_ORD_EXEMPT);
    let lines: Vec<&str> = text.lines().collect();
    for (i, raw) in lines.iter().enumerate() {
        let trimmed = raw.trim_start();
        // negative tests construct violations on purpose; everything from
        // the first test-only region down is out of scope
        if trimmed.starts_with("#[cfg(test)]") {
            break;
        }
        if trimmed.starts_with("//") {
            continue;
        }
        let code = code_of(raw);
        let mut hit = |rule: &'static str| {
            findings.push(Finding {
                path: rel.clone(),
                line: i + 1,
                rule,
                excerpt: raw.trim().chars().take(90).collect(),
            });
        };
        if float_ord_applies
            && (code.contains(".partial_cmp(")
                || (code.contains(".sort_by(") && !code.contains("total_cmp")))
        {
            hit("float-ord");
        }
        if (code.contains("unsafe {") || code.contains("unsafe impl"))
            && !safety_comment_above(&lines, i)
        {
            hit("undocumented-unsafe");
        }
        if in_sim_scope && (code.contains("HashMap") || code.contains("HashSet")) {
            hit("hash-iteration");
        }
        if code.contains(".schedule") && (code.contains("INFINITY") || code.contains("NAN")) {
            hit("non-finite-schedule");
        }
        if in_sim_scope && (code.contains("Instant::now") || code.contains("SystemTime::now")) {
            hit("wall-clock");
        }
    }
}

/// Walk the contiguous comment block directly above line `i` (skipping
/// attribute lines) and report whether it contains a `SAFETY:` marker.
fn safety_comment_above(lines: &[&str], i: usize) -> bool {
    if lines.get(i).is_some_and(|l| l.contains("SAFETY:")) {
        return true;
    }
    let mut k = i;
    while k > 0 {
        k -= 1;
        let t = lines[k].trim_start();
        if t.starts_with("//") {
            if t.contains("SAFETY:") {
                return true;
            }
        } else if t.starts_with("#[") || t.starts_with("#![") {
            // attributes may sit between the comment and the item
        } else {
            return false;
        }
    }
    false
}

/// `path:rule  # justification` per line; `#` lines and blanks ignored.
/// Every entry must carry an inline justification.
fn load_allowlist(path: &Path) -> Result<Vec<(String, String)>, String> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(format!("cannot read {}: {e}", path.display())),
    };
    let mut entries = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (entry, justification) = match line.split_once('#') {
            Some((e, j)) if !j.trim().is_empty() => (e.trim(), j.trim()),
            _ => {
                return Err(format!(
                    "{}:{}: allowlist entry needs an inline '# justification'",
                    path.display(),
                    i + 1
                ));
            }
        };
        let _ = justification;
        let Some((p, rule)) = entry.rsplit_once(':') else {
            return Err(format!("{}:{}: expected 'path:rule'", path.display(), i + 1));
        };
        if !RULES.contains(&rule.trim()) {
            return Err(format!(
                "{}:{}: unknown rule '{}' (known: {})",
                path.display(),
                i + 1,
                rule.trim(),
                RULES.join(", ")
            ));
        }
        entries.push((p.trim().to_string(), rule.trim().to_string()));
    }
    if entries.len() > 5 {
        return Err(format!(
            "{}: {} entries — the allowlist is capped at 5; fix the code instead",
            path.display(),
            entries.len()
        ));
    }
    Ok(entries)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn report_json(files: &[PathBuf], reported: &[Finding], allowed: usize) -> String {
    let mut per_rule = String::new();
    for (k, rule) in RULES.iter().enumerate() {
        let n = reported.iter().filter(|f| f.rule == *rule).count();
        if k > 0 {
            per_rule.push_str(", ");
        }
        let _ = write!(per_rule, "\"{rule}\": {n}");
    }
    let mut list = String::new();
    for (k, f) in reported.iter().enumerate() {
        if k > 0 {
            list.push_str(", ");
        }
        let _ = write!(
            list,
            "{{\"path\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"excerpt\": \"{}\"}}",
            json_escape(&f.path),
            f.line,
            f.rule,
            json_escape(&f.excerpt)
        );
    }
    format!(
        "{{\n  \"files_scanned\": {},\n  \"findings\": {},\n  \"allowlisted\": {},\n  \
         \"per_rule\": {{{per_rule}}},\n  \"findings_list\": [{list}]\n}}\n",
        files.len(),
        reported.len(),
        allowed
    )
}
