//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `make artifacts`) and executes them on the
//! CPU PJRT client from the Rust hot path.  Python is never involved at
//! runtime.
//!
//! Interchange is HLO *text* — jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;
pub mod engine;
pub mod tensor;

pub use artifacts::{ArtifactMeta, Manifest};
pub use engine::Engine;
pub use tensor::Tensor;
