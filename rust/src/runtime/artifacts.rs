//! Artifact manifest: the contract written by `python/compile/aot.py`.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Metadata for one AOT-lowered entry point.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub entry: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
    pub hidden: Option<usize>,
    pub batch: Option<usize>,
}

/// The parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    pub bfp_block_size: usize,
    pub bfp_mant_bits: u32,
}

fn shapes(j: &Json) -> Result<Vec<Vec<usize>>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected shape array"))?
        .iter()
        .map(|s| {
            s.num_vec(|x| x as usize)
                .ok_or_else(|| anyhow!("expected dim array"))
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let bfp = j.get("bfp").ok_or_else(|| anyhow!("manifest missing 'bfp'"))?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let artifacts = arts
            .iter()
            .map(|a| -> Result<ArtifactMeta> {
                Ok(ArtifactMeta {
                    name: a
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("artifact missing name"))?
                        .to_string(),
                    file: a
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("artifact missing file"))?
                        .to_string(),
                    entry: a
                        .get("entry")
                        .and_then(|v| v.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    inputs: shapes(a.get("inputs").ok_or_else(|| anyhow!("missing inputs"))?)?,
                    outputs: shapes(a.get("outputs").ok_or_else(|| anyhow!("missing outputs"))?)?,
                    hidden: a.get("hidden").and_then(|v| v.as_usize()),
                    batch: a.get("batch").and_then(|v| v.as_usize()),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir,
            artifacts,
            bfp_block_size: bfp
                .get("block_size")
                .and_then(|v| v.as_usize())
                .unwrap_or(16),
            bfp_mant_bits: bfp
                .get("mant_bits")
                .and_then(|v| v.as_usize())
                .unwrap_or(7) as u32,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// (hidden, batch) pairs available for the layer entry points.
    pub fn shape_pairs(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .artifacts
            .iter()
            .filter(|a| a.entry == "layer_fwd")
            .filter_map(|a| Some((a.hidden?, a.batch?)))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "bfp": {"block_size": 16, "mant_bits": 7, "exp_bits": 8},
      "artifacts": [
        {"name": "layer_fwd_m64_b16", "file": "layer_fwd_m64_b16.hlo.txt",
         "entry": "layer_fwd", "hidden": 64, "batch": 16,
         "inputs": [[16,64],[64,64],[64]], "outputs": [[16,64],[16,64]],
         "sha256": "abc"},
        {"name": "sgd_update_m64", "file": "sgd_update_m64.hlo.txt",
         "entry": "sgd_update", "hidden": 64,
         "inputs": [[64,64],[64,64],[1,1]], "outputs": [[64,64]],
         "sha256": "def"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/x")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.bfp_block_size, 16);
        assert_eq!(m.bfp_mant_bits, 7);
        let a = m.get("layer_fwd_m64_b16").unwrap();
        assert_eq!(a.inputs, vec![vec![16, 64], vec![64, 64], vec![64]]);
        assert_eq!(a.outputs.len(), 2);
        assert_eq!(a.hidden, Some(64));
        assert_eq!(m.shape_pairs(), vec![(64, 16)]);
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/x")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", PathBuf::from("/tmp")).is_err());
        assert!(Manifest::parse("not json", PathBuf::from("/tmp")).is_err());
    }
}
