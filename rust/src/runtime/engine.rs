//! The PJRT execution engine: compiles HLO-text artifacts once (lazily,
//! cached) and executes them with host tensors.  Follows the pattern of
//! /opt/xla-example/load_hlo.rs.

use super::artifacts::Manifest;
use super::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::time::Instant;

/// Cumulative execution statistics (per entry point).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

/// PJRT engine: one CPU client + compiled-executable cache.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    execs: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<HashMap<String, ExecStats>>,
}

impl Engine {
    /// Open the artifact directory (reads manifest.json, creates the PJRT
    /// CPU client; compilation happens lazily per entry point).
    pub fn open(artifact_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine {
            manifest,
            client,
            execs: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure `name` is compiled; returns compile time if it compiled now.
    pub fn warmup(&self, name: &str) -> Result<Option<f64>> {
        if self.execs.borrow().contains_key(name) {
            return Ok(None);
        }
        let meta = self.manifest.get(name)?;
        let path = self.manifest.path_of(meta);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.execs.borrow_mut().insert(name.to_string(), exe);
        Ok(Some(t0.elapsed().as_secs_f64()))
    }

    /// Execute entry point `name` with `inputs`; returns the output tuple
    /// as host tensors.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.warmup(name)?;
        let meta = self.manifest.get(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, want)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if &t.shape != want {
                return Err(anyhow!(
                    "{name}: input {i} shape {:?} != expected {:?}",
                    t.shape,
                    want
                ));
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| -> Result<xla::Literal> {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("literal reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let execs = self.execs.borrow();
        let exe = execs.get(name).expect("warmed up above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let elapsed = t0.elapsed().as_secs_f64();
        drop(execs);
        {
            let mut stats = self.stats.borrow_mut();
            let s = stats.entry(name.to_string()).or_default();
            s.calls += 1;
            s.total_secs += elapsed;
        }

        // aot.py lowers with return_tuple=True: always a tuple
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        if parts.len() != meta.outputs.len() {
            return Err(anyhow!(
                "{name}: expected {} outputs, got {}",
                meta.outputs.len(),
                parts.len()
            ));
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, shape)| -> Result<Tensor> {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("reading output of {name}: {e:?}"))?;
                Ok(Tensor::new(shape.clone(), data))
            })
            .collect()
    }

    /// Per-entry-point cumulative execution stats (for profiling).
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<(String, ExecStats)> = self
            .stats
            .borrow()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect();
        v.sort_by(|a, b| b.1.total_secs.total_cmp(&a.1.total_secs));
        v
    }

    /// Average seconds per call of an entry point (None if never run).
    pub fn mean_time(&self, name: &str) -> Option<f64> {
        let stats = self.stats.borrow();
        let s = stats.get(name)?;
        if s.calls == 0 {
            None
        } else {
            Some(s.total_secs / s.calls as f64)
        }
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Engine({} artifacts, {} compiled)",
            self.manifest.artifacts.len(),
            self.execs.borrow().len()
        )
    }
}
