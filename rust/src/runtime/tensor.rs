//! Host-side f32 tensors: the currency between the coordinator, the NIC
//! data path and the PJRT executables.

use crate::util::rng::Rng;

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with {} elements",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![1, 1],
            data: vec![v],
        }
    }

    /// He-style normal init (matches model.init_params scale).
    pub fn randn(shape: &[usize], scale: f32, rng: &mut Rng) -> Self {
        Self {
            shape: shape.to_vec(),
            data: rng.normal_vec_f32(shape.iter().product(), scale),
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.len() * 4
    }

    /// Frobenius / L2 norm.
    pub fn norm(&self) -> f64 {
        self.data
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Max |a - b| against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// a += b
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// a -= lr * b   (host-side SGD reference)
    pub fn axpy_neg(&mut self, lr: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= lr * b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape_check() {
        let t = Tensor::new(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![1.0; 5]);
    }

    #[test]
    fn norm_and_diff() {
        let a = Tensor::new(vec![2], vec![3.0, 4.0]);
        assert!((a.norm() - 5.0).abs() < 1e-12);
        let b = Tensor::new(vec![2], vec![3.0, 5.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    #[test]
    fn axpy() {
        let mut w = Tensor::new(vec![2], vec![1.0, 1.0]);
        let g = Tensor::new(vec![2], vec![0.5, 1.0]);
        w.axpy_neg(0.1, &g);
        assert!((w.data[0] - 0.95).abs() < 1e-7);
        assert!((w.data[1] - 0.9).abs() < 1e-7);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(
            Tensor::randn(&[4, 4], 0.1, &mut r1),
            Tensor::randn(&[4, 4], 0.1, &mut r2)
        );
    }
}
