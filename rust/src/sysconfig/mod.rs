//! Hardware/system parameter sets — the constants of the paper's testbed
//! (Sec. III and V-A) expressed in SI units, used by both the DES and the
//! analytical model.
//!
//! Calibration notes:
//! * Workers: Intel Xeon Platinum 8280, 28 cores, AVX-512 @ 2.4 GHz turbo.
//!   Peak f32 FMA throughput/core = 2 FMA units × 16 f32 × 2 FLOP × 2.4 GHz
//!   ≈ 153.6 GFLOPS; sustained GEMM efficiency ~70% → ~107 GFLOPS/core.
//! * Baseline NICs: 100 GbE; software (MPI) all-reduce reaches a fraction
//!   `host_alpha` of line rate.
//! * Smart NIC: 40 GbE inter-FPGA, α ≈ 1 (paper footnote 1); PCIe Gen3 x8
//!   ≈ 7.88 GB/s/dir; Arria 10 @ ~300 MHz with 8 f32 adder lanes → 2.4
//!   GFLOP/s... the paper's P_FPGA is per-NIC reduction throughput: 8 lanes
//!   × 0.3 GHz = 2.4 G adds/s = line rate for 40 GbE f32 streams (5 GB/s =
//!   1.25 G elem/s), so addition is never the bottleneck at 40G.
//! * Weight update: memory-bandwidth bound on the worker (T_U term),
//!   modeled as bytes_touched / update_membw.

use crate::util::units::{gbps, gbytes_per_s, gflops};

/// Worker (compute node) parameters.
#[derive(Clone, Copy, Debug)]
pub struct WorkerParams {
    /// total cores per node
    pub cores: usize,
    /// sustained GEMM FLOPS per core (f32)
    pub flops_per_core: f64,
    /// memory bandwidth available to weight updates (bytes/s)
    pub update_membw: f64,
    /// backward-pass interference factor when k comm cores are stolen:
    /// T_B scales by cores/(cores-k) * (1 + eta) — eta captures cache and
    /// memory-bandwidth pollution from the comm threads (fitted to the
    /// paper's 11% at k=2, Sec. III).
    pub comm_interference: f64,
    /// effective all-reduce bandwidth per dedicated comm core (bytes/s)
    /// at the 2-node reference point — an MPI progress core sustains a
    /// couple of GB/s through the software network stack (calibrated so
    /// the baseline's exposed all-reduce matches Figs. 2a/4a)
    pub comm_core_bw: f64,
    /// effective bandwidth of the *naive* strategy's single volunteer
    /// thread driving an asynchronous MPI all-reduce while every other
    /// thread waits (calibrated to the paper's "51% of naive iteration
    /// time is exposed all-reduce" at 6 nodes, B=1792)
    pub naive_comm_bw: f64,
    /// per-node decay of software all-reduce efficiency: effective
    /// bandwidth divides by (1 + decay*(N-2)).  Captures MPI progress
    /// noise/stragglers at scale; calibrated to the growing gap to ideal
    /// in Fig. 2b and the baseline degradation in Fig. 4b.
    pub host_comm_decay: f64,
}

impl WorkerParams {
    pub fn xeon_8280() -> Self {
        Self {
            cores: 28,
            flops_per_core: gflops(107.0),
            update_membw: gbytes_per_s(80.0),
            comm_interference: 0.029, // 28/26*(1+eta) = 1.11 -> eta = 0.0307
            comm_core_bw: gbytes_per_s(2.46),
            naive_comm_bw: gbytes_per_s(2.06),
            host_comm_decay: 0.05,
        }
    }

    /// Effective FLOPS with `compute_cores` of `cores` doing tensor work.
    pub fn flops(&self, compute_cores: usize) -> f64 {
        self.flops_per_core * compute_cores as f64
    }

    /// Effective host all-reduce bandwidth cap for `comm_cores` dedicated
    /// cores (None = naive single volunteer thread) on an `n`-node job.
    pub fn host_comm_bw(&self, comm_cores: Option<usize>, n: usize) -> f64 {
        let base = match comm_cores {
            Some(k) => k as f64 * self.comm_core_bw,
            None => self.naive_comm_bw,
        };
        base / (1.0 + self.host_comm_decay * (n.saturating_sub(2)) as f64)
    }
}

/// Network parameters for one system variant.
#[derive(Clone, Copy, Debug)]
pub struct NetParams {
    /// raw Ethernet line rate (bytes/s)
    pub eth_bw: f64,
    /// achievable fraction of line rate (α)
    pub alpha: f64,
    /// wire-protocol efficiency (β): the fraction of α·BW_eth left after
    /// framing/preamble/FCS overhead.  Sec. IV-C's ring term divides by
    /// α·BW_eth·β; every timing path (closed form, serialized NIC DES,
    /// unified fabric, host software model) must apply the same factor —
    /// use [`NetParams::effective_bw`] rather than multiplying by hand.
    pub beta: f64,
    /// one-hop propagation + switch latency (s)
    pub hop_latency: f64,
}

impl NetParams {
    /// Effective payload bandwidth of one port: α·BW_eth·β (bytes/s).
    /// The single source of truth shared by the analytic model, the
    /// serialized NIC DES, the unified fabric and the host MPI model.
    #[must_use]
    pub fn effective_bw(&self) -> f64 {
        self.eth_bw * self.alpha * self.beta
    }

    /// Same parameters with a different wire-protocol efficiency.
    #[must_use]
    pub fn with_beta(mut self, beta: f64) -> Self {
        assert!(beta > 0.0 && beta <= 1.0, "beta {beta} not in (0, 1]");
        self.beta = beta;
        self
    }

    /// Wire-protocol efficiency of Ethernet framing at a given MTU: the
    /// fraction of line rate left for payload after the per-frame preamble
    /// + SFD (8 B), Ethernet header (14 B), FCS (4 B) and inter-frame gap
    /// (12 B) on the wire, and a 40 B L3/L4 (or equivalent custom
    /// transport) header inside the MTU:
    ///
    ///   β(mtu) = (mtu − 40) / (mtu + 38)
    ///
    /// ≈ 0.949 at MTU 1500 and ≈ 0.991 at MTU 9000 — the 0.94–0.99 band
    /// real Ethernet fabrics sit in, instead of the seed's β = 1.0.
    #[must_use]
    pub fn ethernet_framing_beta(mtu_bytes: f64) -> f64 {
        assert!(mtu_bytes > 40.0, "MTU {mtu_bytes} cannot carry a 40 B transport header");
        (mtu_bytes - 40.0) / (mtu_bytes + 38.0)
    }
}

/// Smart-NIC-specific parameters.
#[derive(Clone, Copy, Debug)]
pub struct NicHwParams {
    /// PCIe bandwidth per direction (bytes/s)
    pub pcie_bw: f64,
    pub pcie_latency: f64,
    /// FPGA reduction throughput (FLOP/s == f32 adds/s)
    pub add_flops: f64,
    /// segment size for chunk pipelining through the NIC (bytes)
    pub segment_bytes: f64,
}

impl NicHwParams {
    pub fn arria10_40g() -> Self {
        Self {
            pcie_bw: gbytes_per_s(7.88),
            pcie_latency: 1.0e-6,
            add_flops: gflops(2.4), // 8 lanes x 300 MHz
            segment_bytes: 256.0 * 1024.0,
        }
    }

    /// Scaled variant for faster interfaces (16 lanes at 100G, 4×16 at
    /// 400G — Sec. V-A).
    pub fn arria10_at(eth_gbps: f64) -> Self {
        let lanes = if eth_gbps <= 40.0 {
            8.0
        } else if eth_gbps <= 100.0 {
            16.0
        } else {
            16.0 * (eth_gbps / 100.0).ceil()
        };
        Self {
            add_flops: gflops(0.3) * lanes,
            ..Self::arria10_40g()
        }
    }
}

/// In-switch (NetReduce-style) reduction capability of the switching tier:
/// every egress port can own an aggregation engine that folds arriving f32
/// streams into an on-chip table and forwards the reduced stream, instead
/// of the NICs reducing at the ring hops.  `passthrough()` (both fields 0)
/// models a plain forwarding switch — the seed behavior.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SwitchParams {
    /// aggregation throughput of one egress-port engine (f32 adds/s);
    /// every contribution folded into the table costs `elems` adds,
    /// including the first (the table write-in shares the same datapath)
    pub reduce_flops: f64,
    /// per-port aggregation table capacity (bytes of f32 accumulators):
    /// bounds how many segments may be in flight through the switch at
    /// once; 0 disables in-switch reduction regardless of `reduce_flops`
    pub reduce_table_bytes: f64,
}

impl SwitchParams {
    /// A plain forwarding switch with no reduction capability.
    pub fn passthrough() -> Self {
        Self {
            reduce_flops: 0.0,
            reduce_table_bytes: 0.0,
        }
    }

    /// NetReduce-style provisioning (arXiv:2009.09736): each egress engine
    /// keeps line rate for a full `radix`-port incast of f32 streams
    /// (radix × line-rate elements/s) with a few MB of on-chip table.
    pub fn netreduce(radix: usize, net: &NetParams) -> Self {
        assert!(radix >= 1, "switch needs at least one port");
        Self {
            reduce_flops: radix as f64 * net.eth_bw / 4.0,
            reduce_table_bytes: 4.0 * 1024.0 * 1024.0,
        }
    }

    /// Is in-switch reduction usable at all (positive rate *and* table)?
    #[must_use]
    pub fn enabled(&self) -> bool {
        self.reduce_flops > 0.0 && self.reduce_table_bytes > 0.0
    }
}

/// First-order RoCE-style priority flow control on the switching tier:
/// a congested downstream port asserts PFC pause frames at `pause_rate`
/// per second, each stalling the upstream stage for one `pause_window`.
/// The fabric applies the resulting duty cycle as a deterministic
/// derating of the reduction tree's spine legs
/// (`Fabric::{reduce_fold_spine,reduce_downlink}`), and
/// `analytic::model::inswitch_ar_time_contended` prices the same factor
/// so the planner sees it.  `off()` (both fields 0, duty 1.0) is the
/// seed behavior.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PfcParams {
    /// pause assertions per second seen by a switch-tier port
    pub pause_rate: f64,
    /// duration of one pause window (s)
    pub pause_window: f64,
}

impl PfcParams {
    /// No flow-control backpressure (duty 1.0) — the seed behavior.
    pub fn off() -> Self {
        Self {
            pause_rate: 0.0,
            pause_window: 0.0,
        }
    }

    /// Is any pause throttling configured?
    #[must_use]
    pub fn enabled(&self) -> bool {
        self.pause_rate > 0.0 && self.pause_window > 0.0
    }

    /// Transmitting fraction of wall-clock under the pause pattern:
    /// `1 − rate·window`.  Not clamped — a non-positive duty is a
    /// saturated pause storm, which the audit reports as a
    /// `pause-deadlock-free` violation rather than silently flooring.
    #[must_use]
    pub fn duty(&self) -> f64 {
        1.0 - self.pause_rate * self.pause_window
    }

    /// Work-inflation factor for a paused stage (`1/duty`); infinite
    /// when the duty is non-positive, so a pause storm surfaces as a
    /// non-finite time instead of a silently wrong one.
    #[must_use]
    pub fn derate(&self) -> f64 {
        let d = self.duty();
        if d > 0.0 {
            1.0 / d
        } else {
            f64::INFINITY
        }
    }
}

/// Full system description for one experiment configuration.
#[derive(Clone, Copy, Debug)]
pub struct SystemParams {
    pub worker: WorkerParams,
    pub net: NetParams,
    pub nic: NicHwParams,
    /// reduction capability of the switching tier (passthrough = none)
    pub switch: SwitchParams,
    /// PFC pause behavior of the switching tier (off = none)
    pub pfc: PfcParams,
    /// MPI/software per-message overhead for host all-reduce (s per step)
    pub host_step_overhead: f64,
    /// driver overhead for launching one non-blocking NIC all-reduce (s)
    pub nic_request_overhead: f64,
}

impl SystemParams {
    /// Jumbo-frame MTU both testbeds run at (Sec. V-A: large-message
    /// all-reduce traffic), used to derive the presets' framing β.
    pub const MTU_BYTES: f64 = 9000.0;

    /// The paper's baseline: conventional 100 GbE NICs, host MPI all-reduce.
    pub fn baseline_100g() -> Self {
        // β carries the real Ethernet framing overhead at MTU 9000; α is
        // re-fitted so α·β keeps the calibrated 0.85 software efficiency —
        // the paper-point validations (Figs. 2a/4a) are pinned to α·β, not
        // to either factor alone.
        let beta = NetParams::ethernet_framing_beta(Self::MTU_BYTES);
        Self {
            worker: WorkerParams::xeon_8280(),
            net: NetParams {
                eth_bw: gbps(100.0),
                alpha: 0.85 / beta, // re-fit: α·β == the calibrated 0.85
                beta,
                hop_latency: 5.0e-6,
            },
            nic: NicHwParams::arria10_40g(), // unused in baseline
            switch: SwitchParams::passthrough(),
            pfc: PfcParams::off(),
            host_step_overhead: 15.0e-6,
            nic_request_overhead: 5.0e-6,
        }
    }

    /// The paper's prototype: Arria-10 smart NICs on 40 GbE (α≈1).
    pub fn smartnic_40g() -> Self {
        Self {
            worker: WorkerParams::xeon_8280(),
            net: NetParams {
                eth_bw: gbps(40.0),
                alpha: 1.0, // footnote 1: α very close to 1 (DMA/protocol)
                // the custom lightweight framing still rides Ethernet
                // frames (preamble/IFG/FCS + a small transport header), so
                // the jumbo-MTU framing efficiency applies: ≈ 0.991.
                // smartnic_effective_fraction_pinned guards the E6 points.
                beta: NetParams::ethernet_framing_beta(Self::MTU_BYTES),
                hop_latency: 2.0e-6,
            },
            nic: NicHwParams::arria10_40g(),
            switch: SwitchParams::passthrough(),
            pfc: PfcParams::off(),
            host_step_overhead: 15.0e-6,
            nic_request_overhead: 5.0e-6,
        }
    }

    /// Faster smart-NIC variants discussed in Sec. V-A.
    pub fn smartnic_at(eth_gbps: f64) -> Self {
        let mut s = Self::smartnic_40g();
        s.net.eth_bw = gbps(eth_gbps);
        s.nic = NicHwParams::arria10_at(eth_gbps);
        s
    }

    /// Same system with an in-switch reduction capability on the fabric.
    #[must_use]
    pub fn with_switch_reduction(mut self, switch: SwitchParams) -> Self {
        self.switch = switch;
        self
    }

    /// Same system with a PFC pause pattern on the switching tier.
    #[must_use]
    pub fn with_pfc(mut self, pfc: PfcParams) -> Self {
        assert!(
            pfc.pause_rate >= 0.0 && pfc.pause_window >= 0.0,
            "PFC pause rate/window must be non-negative"
        );
        self.pfc = pfc;
        self
    }
}

/// Cluster-level fault injection for the unified event engine: unlike the
/// per-ring knobs on `nic::NicConfig`, these scale *shared* fabric
/// resources, so a flapping port or thermally-throttled node degrades
/// every in-flight collective of every job that touches it.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterFaults {
    /// (node, bandwidth multiplier in (0, 1]) on that node's Tx uplink
    pub degraded_links: Vec<(usize, f64)>,
    /// (node, speed multiplier in (0, 1]) on that node's PCIe + NIC adder
    pub stragglers: Vec<(usize, f64)>,
}

impl ClusterFaults {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_degraded_link(mut self, node: usize, scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0, "link scale {scale} not in (0, 1]");
        self.degraded_links.push((node, scale));
        self
    }

    pub fn with_straggler(mut self, node: usize, scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0, "node scale {scale} not in (0, 1]");
        self.stragglers.push((node, scale));
        self
    }

    /// Combined Tx-bandwidth multiplier for `node`.
    pub fn link_scale(&self, node: usize) -> f64 {
        self.degraded_links
            .iter()
            .filter(|(n, _)| *n == node)
            .map(|(_, s)| s)
            .product()
    }

    /// Combined PCIe/adder speed multiplier for `node`.
    pub fn node_scale(&self, node: usize) -> f64 {
        self.stragglers
            .iter()
            .filter(|(n, _)| *n == node)
            .map(|(_, s)| s)
            .product()
    }
}

/// Training workload description (paper Sec. III: L-layer MLP, symmetric
/// M×M layers, mini-batch B per node).
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub layers: usize,
    pub hidden: usize,
    pub batch_per_node: usize,
}

impl Workload {
    /// The paper's experiment: 20-layer 2048x2048 MLP.
    pub fn paper_mlp(batch_per_node: usize) -> Self {
        Self {
            layers: 20,
            hidden: 2048,
            batch_per_node,
        }
    }

    /// Gradient elements per layer (weights only; biases are negligible
    /// and carried with the layer gradient).
    pub fn grad_elems_per_layer(&self) -> usize {
        self.hidden * self.hidden
    }

    pub fn grad_bytes_per_layer(&self) -> f64 {
        self.grad_elems_per_layer() as f64 * 4.0
    }

    /// Forward FLOPs for one layer on one node: 2 M^2 B.
    pub fn fwd_flops_per_layer(&self) -> f64 {
        2.0 * (self.hidden as f64).powi(2) * self.batch_per_node as f64
    }

    /// Backward FLOPs for one layer (dX and dW GEMMs): 4 M^2 B.
    pub fn bwd_flops_per_layer(&self) -> f64 {
        2.0 * self.fwd_flops_per_layer()
    }

    /// Total parameters (weights).
    pub fn params(&self) -> usize {
        self.layers * self.hidden * self.hidden
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_mlp_is_84m_params() {
        let w = Workload::paper_mlp(448);
        assert_eq!(w.params(), 20 * 2048 * 2048); // 83.9 M
        assert!((w.params() as f64 / 1e6 - 83.9).abs() < 0.1);
    }

    #[test]
    fn flops_formulas() {
        let w = Workload::paper_mlp(448);
        assert_eq!(w.fwd_flops_per_layer(), 2.0 * 2048.0 * 2048.0 * 448.0);
        assert_eq!(w.bwd_flops_per_layer(), 2.0 * w.fwd_flops_per_layer());
        assert_eq!(w.grad_bytes_per_layer(), 2048.0 * 2048.0 * 4.0);
    }

    #[test]
    fn interference_matches_papers_11pct() {
        // 2 comm cores on 28: T_B ratio = 28/26 * (1+eta) ≈ 1.11
        let w = WorkerParams::xeon_8280();
        let ratio = 28.0 / 26.0 * (1.0 + w.comm_interference);
        assert!((ratio - 1.11).abs() < 0.005, "ratio {ratio}");
    }

    #[test]
    fn nic_scaling_lanes() {
        let n40 = NicHwParams::arria10_40g();
        let n100 = NicHwParams::arria10_at(100.0);
        let n400 = NicHwParams::arria10_at(400.0);
        assert!((n100.add_flops / n40.add_flops - 2.0).abs() < 1e-9);
        assert!((n400.add_flops / n40.add_flops - 8.0).abs() < 1e-9);
    }

    #[test]
    fn cluster_faults_scale_by_node() {
        let f = ClusterFaults::none()
            .with_degraded_link(2, 0.25)
            .with_straggler(1, 0.5)
            .with_straggler(1, 0.5);
        assert_eq!(f.link_scale(2), 0.25);
        assert_eq!(f.link_scale(0), 1.0);
        assert_eq!(f.node_scale(1), 0.25); // stacked faults multiply
        assert_eq!(f.node_scale(2), 1.0);
    }

    #[test]
    fn effective_bw_applies_alpha_and_beta() {
        // α was re-fitted against the framing β so the product stays the
        // calibrated 0.85 of line rate
        let s = SystemParams::baseline_100g();
        assert!((s.net.effective_bw() - s.net.eth_bw * 0.85).abs() < 1.0);
        let capped = s.net.with_beta(0.9);
        assert!((capped.effective_bw() - s.net.eth_bw * s.net.alpha * 0.9).abs() < 1.0);
    }

    #[test]
    fn ethernet_framing_beta_matches_known_mtus() {
        // MTU 1500: 1460 payload / 1538 wire bytes ≈ 0.9493
        let b1500 = NetParams::ethernet_framing_beta(1500.0);
        assert!((b1500 - 1460.0 / 1538.0).abs() < 1e-12);
        assert!((0.94..0.96).contains(&b1500), "{b1500}");
        // MTU 9000 (jumbo): ≈ 0.9914
        let b9000 = NetParams::ethernet_framing_beta(9000.0);
        assert!((b9000 - 8960.0 / 9038.0).abs() < 1e-12);
        assert!((0.985..0.995).contains(&b9000), "{b9000}");
        // monotone in MTU: framing amortizes over larger frames
        assert!(b9000 > b1500);
    }

    #[test]
    fn presets_carry_real_framing_beta() {
        // both presets now run β ≠ 1.0 — the seed pinned 1.0 and the
        // ROADMAP calibration item closes here
        let base = SystemParams::baseline_100g();
        let nic = SystemParams::smartnic_40g();
        let b = NetParams::ethernet_framing_beta(SystemParams::MTU_BYTES);
        assert_eq!(base.net.beta, b);
        assert_eq!(nic.net.beta, b);
        assert!(base.net.beta < 1.0 && base.net.beta > 0.98);
    }

    #[test]
    fn smartnic_effective_fraction_pinned() {
        // the smart NIC's α stays 1.0 (paper footnote 1); β costs 0.86% of
        // line rate — pin the band so a future β change cannot silently
        // shift every E6 operating point
        let s = SystemParams::smartnic_40g();
        let frac = s.net.effective_bw() / s.net.eth_bw;
        assert!((0.985..1.0).contains(&frac), "effective fraction {frac}");
    }

    #[test]
    fn switch_params_enablement() {
        let off = SwitchParams::passthrough();
        assert!(!off.enabled());
        let net = SystemParams::smartnic_40g().net;
        let on = SwitchParams::netreduce(8, &net);
        assert!(on.enabled());
        // line-rate provisioning: 8 ports x 5 GB/s of f32 = 10 G adds/s
        assert!((on.reduce_flops - 8.0 * gbps(40.0) / 4.0).abs() < 1.0);
        // rate without table is still disabled (the fallback guard)
        let no_table = SwitchParams { reduce_table_bytes: 0.0, ..on };
        assert!(!no_table.enabled());
    }

    #[test]
    #[should_panic(expected = "not in (0, 1]")]
    fn beta_out_of_range_panics() {
        let _ = SystemParams::smartnic_40g().net.with_beta(1.5);
    }

    #[test]
    fn pfc_duty_and_derate() {
        let off = PfcParams::off();
        assert!(!off.enabled());
        assert_eq!(off.duty(), 1.0);
        assert_eq!(off.derate(), 1.0);
        // presets ship with PFC off — the seed behavior is pinned
        assert_eq!(SystemParams::smartnic_40g().pfc, PfcParams::off());
        assert_eq!(SystemParams::baseline_100g().pfc, PfcParams::off());
        // 1000 pauses/s x 200 us pause window: 20% of wall-clock paused
        let pfc = PfcParams { pause_rate: 1000.0, pause_window: 200.0e-6 };
        assert!(pfc.enabled());
        assert!((pfc.duty() - 0.8).abs() < 1e-12);
        assert!((pfc.derate() - 1.25).abs() < 1e-12);
        // a saturated pause storm derates to infinity, not a negative time
        let storm = PfcParams { pause_rate: 1000.0, pause_window: 2.0e-3 };
        assert!(storm.duty() <= 0.0);
        assert_eq!(storm.derate(), f64::INFINITY);
        let sys = SystemParams::smartnic_40g().with_pfc(pfc);
        assert_eq!(sys.pfc, pfc);
    }

    #[test]
    fn adder_keeps_up_with_40g_line_rate() {
        // 40 GbE = 5 GB/s = 1.25 G f32/s < 2.4 G adds/s
        let s = SystemParams::smartnic_40g();
        assert!(s.nic.add_flops > s.net.eth_bw / 4.0);
    }
}
