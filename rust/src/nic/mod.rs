//! The FPGA-based AI smart NIC (paper Sec. IV): a cycle-approximate timing
//! model of the Rx/Tx/input/output FIFO + FP32 adder + control FSM
//! datapath (Fig. 3a), its in-network pipelined ring all-reduce, and the
//! Table-I resource estimator.
//!
//! [`simulate_ring_allreduce`] is the serialized one-ring-at-a-time
//! compatibility path used by the E6 closed-form validation; the unified
//! event engine in `cluster` runs the same datapath (sharing
//! [`SegmentPlan`]) as events on the cluster-wide calendar queue.

pub mod resources;
pub mod smartnic;

pub use smartnic::{simulate_ring_allreduce, AllReduceTiming, NicConfig, SegmentPlan};
