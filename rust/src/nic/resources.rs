//! FPGA resource model — regenerates Table I and the Sec. V-A scaling
//! claims without a synthesis run.
//!
//! Anchors: the paper's Quartus 17.1 results on Arria 10 GX 1150 at
//! 40 Gbps (Table I).  Scaling to 100/400 Gbps follows the paper's
//! description (16 SIMD lanes at 100G, 4×100G at 400G) with sub-linear
//! logic/RAM growth — control logic amortizes across wider datapaths and
//! aggregate FIFO capacity is set by the bandwidth-delay product, while
//! adder DSPs scale linearly with lane count.  Exponents are fitted so the
//! model reproduces Table I exactly at 40G and satisfies the paper's
//! "<2% / <9% / <5%" claim at 400G (checked in tests).

/// Arria 10 GX 1150 totals (paper's percentages in Table I confirm these).
pub const A10_ALMS: u32 = 427_200;
pub const A10_M20KS: u32 = 2_713;
pub const A10_DSPS: u32 = 1_518;

/// Resource triple.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resources {
    pub alms: u32,
    pub m20ks: u32,
    pub dsps: u32,
}

impl Resources {
    pub const fn new(alms: u32, m20ks: u32, dsps: u32) -> Self {
        Self { alms, m20ks, dsps }
    }

    pub fn plus(&self, o: &Resources) -> Resources {
        Resources::new(self.alms + o.alms, self.m20ks + o.m20ks, self.dsps + o.dsps)
    }

    pub fn pct_alms(&self) -> f64 {
        100.0 * self.alms as f64 / A10_ALMS as f64
    }
    pub fn pct_m20ks(&self) -> f64 {
        100.0 * self.m20ks as f64 / A10_M20KS as f64
    }
    pub fn pct_dsps(&self) -> f64 {
        100.0 * self.dsps as f64 / A10_DSPS as f64
    }
}

/// 40G anchor values (Table I).
pub const SHIM_40G: Resources = Resources::new(64_480, 368, 0);
pub const ALLREDUCE_40G: Resources = Resources::new(2_233, 46, 8);
pub const BFP_40G: Resources = Resources::new(2_857, 120, 0);

/// Scaling exponents: cost(bw) = cost40 × (bw/40)^γ per resource class.
const GAMMA_ALM: f64 = 0.22;
const GAMMA_M20K: f64 = 0.16;

/// SIMD lanes at a given line rate, following Sec. V-A: 8 lanes (256-bit)
/// at 40G, 16 lanes (512-bit) at 100G, and 400G as 4×100G → 64 lanes.
pub fn lanes_at(eth_gbps: f64) -> u32 {
    if eth_gbps <= 40.0 {
        8
    } else if eth_gbps <= 100.0 {
        16
    } else {
        16 * (eth_gbps / 100.0).ceil() as u32
    }
}

fn scale(base: u32, ratio: f64, gamma: f64) -> u32 {
    (base as f64 * ratio.powf(gamma)).round() as u32
}

fn scale_res(base: &Resources, eth_gbps: f64) -> Resources {
    let r = eth_gbps / 40.0;
    // DSPs are one FP32 adder per SIMD lane — they scale with lane count,
    // not with the bandwidth exponent.
    let lane_ratio = lanes_at(eth_gbps) as f64 / 8.0;
    Resources::new(
        scale(base.alms, r, GAMMA_ALM),
        scale(base.m20ks, r, GAMMA_M20K),
        (base.dsps as f64 * lane_ratio).round() as u32,
    )
}

/// One row set of the resource breakdown at a given line rate.
#[derive(Clone, Debug)]
pub struct Breakdown {
    pub eth_gbps: f64,
    pub shim: Resources,
    pub allreduce: Resources,
    pub bfp: Resources,
}

impl Breakdown {
    pub fn at(eth_gbps: f64) -> Self {
        Self {
            eth_gbps,
            // the OPAE+IKL shim is infrastructure; the paper scales only
            // the AI-specific engines
            shim: SHIM_40G,
            allreduce: scale_res(&ALLREDUCE_40G, eth_gbps),
            bfp: scale_res(&BFP_40G, eth_gbps),
        }
    }

    /// AI-specific additions only (the paper's 1.2%/6.1%/0.5% numbers).
    pub fn ai_only(&self) -> Resources {
        self.allreduce.plus(&self.bfp)
    }

    pub fn total(&self) -> Resources {
        self.shim.plus(&self.ai_only())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_exact_at_40g() {
        let b = Breakdown::at(40.0);
        assert_eq!(b.shim, SHIM_40G);
        assert_eq!(b.allreduce, ALLREDUCE_40G);
        assert_eq!(b.bfp, BFP_40G);
        let t = b.total();
        assert_eq!(t, Resources::new(69_570, 534, 8));
    }

    #[test]
    fn table1_percentages_match_paper() {
        let b = Breakdown::at(40.0);
        // Table I column percentages
        assert_eq!(format!("{:.1}", b.shim.pct_alms()), "15.1");
        assert_eq!(format!("{:.1}", b.shim.pct_m20ks()), "13.6");
        assert_eq!(format!("{:.1}", b.allreduce.pct_alms()), "0.5");
        assert_eq!(format!("{:.1}", b.allreduce.pct_m20ks()), "1.7");
        assert_eq!(format!("{:.1}", b.allreduce.pct_dsps()), "0.5");
        assert_eq!(format!("{:.1}", b.bfp.pct_alms()), "0.7");
        assert_eq!(format!("{:.1}", b.bfp.pct_m20ks()), "4.4");
        assert_eq!(format!("{:.1}", b.total().pct_alms()), "16.3");
        assert_eq!(format!("{:.1}", b.total().pct_m20ks()), "19.7");
        // Sec. V-A: AI-only = 1.2% / 6.1% / 0.5%
        let ai = b.ai_only();
        assert_eq!(format!("{:.1}", ai.pct_alms()), "1.2");
        assert_eq!(format!("{:.1}", ai.pct_m20ks()), "6.1");
        assert_eq!(format!("{:.1}", ai.pct_dsps()), "0.5");
    }

    #[test]
    fn sec5a_claim_holds_at_400g() {
        // "even at 400 Gbps ... less than 2%, 9%, and 5% of the FPGA
        // logic, RAM, and DSP resources"
        let ai = Breakdown::at(400.0).ai_only();
        assert!(ai.pct_alms() < 2.0, "alm {:.2}%", ai.pct_alms());
        assert!(ai.pct_m20ks() < 9.0, "m20k {:.2}%", ai.pct_m20ks());
        assert!(ai.pct_dsps() < 5.0, "dsp {:.2}%", ai.pct_dsps());
    }

    #[test]
    fn monotone_in_bandwidth() {
        let b40 = Breakdown::at(40.0).ai_only();
        let b100 = Breakdown::at(100.0).ai_only();
        let b400 = Breakdown::at(400.0).ai_only();
        assert!(b40.alms < b100.alms && b100.alms < b400.alms);
        assert!(b40.m20ks < b100.m20ks && b100.m20ks < b400.m20ks);
        assert!(b40.dsps < b100.dsps && b100.dsps < b400.dsps);
    }

    #[test]
    fn dsps_scale_with_lanes() {
        assert_eq!(lanes_at(40.0), 8);
        assert_eq!(lanes_at(100.0), 16);
        assert_eq!(lanes_at(400.0), 64);
        assert_eq!(Breakdown::at(100.0).allreduce.dsps, 16);
        assert_eq!(Breakdown::at(400.0).allreduce.dsps, 64);
    }
}
