//! Chunk-level timing model of the smart NIC's pipelined ring all-reduce.
//!
//! This is the *serialized compatibility path*: one ring at a time on a
//! private set of servers, composed max-plus style in a step loop.  It is
//! the reference the Sec. IV-C closed form is validated against (E6,
//! `analytic::validate`).  The unified event engine
//! (`cluster::collective`) runs the identical per-segment arithmetic as
//! events on the shared calendar queue, which is what allows several
//! all-reduces (and several jobs) to be in flight at once; for a single
//! uncontended ring the two produce the same times.
//!
//! Models the Fig. 3a datapath per node:
//!
//!   host --PCIe--> input FIFO --+
//!                               +--> FP32 adders --> Tx FIFO --eth--> next
//!   prev --eth--> Rx FIFO ------+              \--> output FIFO --PCIe--> host
//!
//! The gradient (R bytes) is padded and split into N ring chunks; each
//! chunk is further segmented (`segment_bytes`) so PCIe fetch, reduction,
//! and link serialization pipeline against each other exactly like the
//! FIFOs in the RTL.  Over 2(N−1) ring steps the simulation produces the
//! all-reduce completion time *emergently*; Sec. IV-C's closed form
//! T_AR = max(T_ring, T_add, T_mem) is its steady-state limit and the two
//! must agree within 3% (checked in `analytic::validate`).
//!
//! With BFP compression enabled only mantissa+sign+shared-exponent bits
//! cross the wire (β = 3.76 for BFP16); decompress→add→compress is
//! line-rate in the RTL and therefore adds latency but not bandwidth cost.

use crate::bfp::BfpCodec;
use crate::netsim::link::{Link, Pcie, Server};
use crate::netsim::topology::Ring;
use crate::netsim::Time;
use crate::sysconfig::SystemParams;

/// Per-all-reduce NIC configuration.
#[derive(Clone, Copy, Debug)]
pub struct NicConfig {
    pub sys: SystemParams,
    /// BFP wire compression (None = raw FP32 on the wire)
    pub bfp: Option<BfpCodec>,
    /// failure injection: (node, bandwidth multiplier) degrades one Tx
    /// link (e.g. a flapping 40G port running at 10G → 0.25)
    pub degraded_link: Option<(usize, f64)>,
    /// failure injection: (node, speed multiplier) slows one node's PCIe
    /// + adder (a straggling host or thermally-throttled FPGA)
    pub straggler: Option<(usize, f64)>,
}

impl NicConfig {
    pub fn new(sys: SystemParams, bfp: Option<BfpCodec>) -> Self {
        Self {
            sys,
            bfp,
            degraded_link: None,
            straggler: None,
        }
    }

    pub fn with_degraded_link(mut self, node: usize, scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0);
        self.degraded_link = Some((node, scale));
        self
    }

    pub fn with_straggler(mut self, node: usize, scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0);
        self.straggler = Some((node, scale));
        self
    }

    /// Wire bytes for `bytes` of FP32 payload.
    pub fn wire_bytes(&self, bytes: f64) -> f64 {
        match &self.bfp {
            Some(c) => bytes / c.compression_ratio(),
            None => bytes,
        }
    }
}

/// How a gradient is padded, chunked and segmented through the NIC
/// (Sec. IV-C: R_l = b · N · ⌈M²/N⌉, further cut into FIFO-sized segments
/// so PCIe fetch, reduction and link serialization pipeline).  Shared by
/// the serialized path and the unified event engine so both simulate the
/// exact same dataflow.
#[derive(Clone, Copy, Debug)]
pub struct SegmentPlan {
    /// elements per ring chunk (= ⌈elems/N⌉, padded)
    pub chunk_elems: usize,
    /// bytes per ring chunk (FP32)
    pub chunk_bytes: f64,
    /// segments per chunk after equalization
    pub segs_per_chunk: usize,
    /// bytes per segment (uncompressed, host-side)
    pub seg_bytes: f64,
    /// elements per segment (for adder costing)
    pub seg_elems: f64,
}

impl SegmentPlan {
    /// Plan `elems` f32 gradients across an `n`-node ring with the NIC's
    /// configured segment size.
    pub fn new(segment_bytes: f64, n: usize, elems: usize) -> Self {
        assert!(n >= 1);
        let chunk_elems = elems.div_ceil(n);
        let chunk_bytes = chunk_elems as f64 * 4.0;
        let seg_bytes = segment_bytes.min(chunk_bytes).max(1.0);
        // at least one (possibly empty) segment, so a zero-element
        // gradient still flows through the event pipeline and completes
        let segs_per_chunk = ((chunk_bytes / seg_bytes).ceil() as usize).max(1);
        let seg_bytes = chunk_bytes / segs_per_chunk as f64; // equalize
        let seg_elems = chunk_elems as f64 / segs_per_chunk as f64;
        Self {
            chunk_elems,
            chunk_bytes,
            segs_per_chunk,
            seg_bytes,
            seg_elems,
        }
    }
}

/// Timing result of one simulated all-reduce.
#[derive(Clone, Debug)]
pub struct AllReduceTiming {
    /// completion time (all nodes have the reduced gradient in host memory)
    pub t_total: Time,
    /// per-node completion times
    pub t_node: Vec<Time>,
    /// utilization of the bottleneck resources over [0, t_total]
    pub eth_util: f64,
    pub pcie_util: f64,
    pub adder_util: f64,
    /// bytes actually sent on each node's Tx link
    pub wire_bytes_per_node: f64,
    /// ring steps executed
    pub steps: usize,
}

struct NodeState {
    tx: Link,
    pcie: Pcie,
    adder: Server,
}

/// Simulate one pipelined ring all-reduce of `elems` f32 gradients across
/// `n` nodes starting at t=0.  Returns the emergent timing.
pub fn simulate_ring_allreduce(cfg: &NicConfig, n: usize, elems: usize) -> AllReduceTiming {
    assert!(n >= 1);
    let sys = &cfg.sys;
    let ring = Ring::new(n);

    // Padded chunking (Sec. IV-C: R_l = b * N * ceil(M^2 / N))
    let plan = SegmentPlan::new(sys.nic.segment_bytes, n, elems);
    let segs_per_chunk = plan.segs_per_chunk;
    let seg_bytes = plan.seg_bytes;
    let seg_elems = plan.seg_elems;

    let mut nodes: Vec<NodeState> = (0..n)
        .map(|i| {
            let link_scale = match cfg.degraded_link {
                Some((node, s)) if node == i => s,
                _ => 1.0,
            };
            let node_scale = match cfg.straggler {
                Some((node, s)) if node == i => s,
                _ => 1.0,
            };
            NodeState {
                tx: Link::new(sys.net.effective_bw() * link_scale, sys.net.hop_latency),
                pcie: Pcie::new(sys.nic.pcie_bw * node_scale, sys.nic.pcie_latency),
                adder: Server::new(sys.nic.add_flops * node_scale),
            }
        })
        .collect();

    if n == 1 {
        // single node: no communication, gradient is already reduced
        return AllReduceTiming {
            t_total: 0.0,
            t_node: vec![0.0],
            eth_util: 0.0,
            pcie_util: 0.0,
            adder_util: 0.0,
            wire_bytes_per_node: 0.0,
            steps: 0,
        };
    }

    // fetch[i][c][s]: time segment s of chunk c is available in node i's
    // input FIFO (PCIe fetch, issued in the order the schedule consumes
    // chunks: the chunk sent at step 0 first, then received chunks' local
    // counterparts).
    let t0 = sys.nic_request_overhead;
    let mut fetch = vec![vec![vec![0.0f64; segs_per_chunk]; n]; n];
    for node in 0..n {
        // fetch order: chunk sent at step 0, then chunks reduced at steps
        // 0..n-2 (i.e. recv_chunk(node, s))
        let mut order = vec![ring.send_chunk(node, 0)];
        for s in 0..ring.reduce_scatter_steps() {
            order.push(ring.recv_chunk(node, s));
        }
        order.dedup();
        for c in order {
            for s in 0..segs_per_chunk {
                fetch[node][c][s] = nodes[node].pcie.to_device.transmit(t0, seg_bytes);
            }
        }
    }

    // ready[i][s_seg]: the time each segment of the chunk node i sends at
    // the current ring step is ready in its Tx path.
    // Initialize for step 0 from the fetch times.
    let mut ready: Vec<Vec<Time>> = (0..n)
        .map(|i| fetch[i][ring.send_chunk(i, 0)].clone())
        .collect();

    let wire_seg = cfg.wire_bytes(seg_bytes);
    let mut writeback_done = vec![0.0f64; n];
    let total_steps = ring.allreduce_steps();

    for step in 0..total_steps {
        let reduce_phase = step < ring.reduce_scatter_steps();
        let mut next_ready: Vec<Vec<Time>> = vec![Vec::new(); n];
        // iterate senders; receiver j = next(i)
        for i in 0..n {
            let j = ring.next(i);
            let mut out = Vec::with_capacity(segs_per_chunk);
            for s in 0..segs_per_chunk {
                // Tx serialization on i's link, then hop latency
                let arrive = nodes[i].tx.transmit(ready[i][s], wire_seg);
                let t = if reduce_phase {
                    // receiver reduces with its local (fetched) segment
                    let local = fetch[j][ring.recv_chunk(j, step)][s];
                    nodes[j].adder.serve(arrive.max(local), seg_elems)
                } else {
                    // allgather: store & forward (forward doesn't wait for
                    // the host writeback)
                    arrive
                };
                // store-to-host when this node's copy becomes final:
                // after the reduce at step n-2 (it then owns the fully
                // reduced chunk) and on every allgather receive.
                if step >= ring.reduce_scatter_steps() - 1 {
                    let wb = nodes[j].pcie.to_host.transmit(t, seg_bytes);
                    writeback_done[j] = writeback_done[j].max(wb);
                }
                out.push(t);
            }
            next_ready[j] = out;
        }
        ready = next_ready;
    }

    let t_node: Vec<Time> = writeback_done;
    let t_total = t_node.iter().cloned().fold(0.0, f64::max);
    let eth_util = nodes
        .iter()
        .map(|nd| nd.tx.server.utilization(t_total))
        .sum::<f64>()
        / n as f64;
    let pcie_util = nodes
        .iter()
        .map(|nd| {
            (nd.pcie.to_device.server.utilization(t_total)
                + nd.pcie.to_host.server.utilization(t_total))
                / 2.0
        })
        .sum::<f64>()
        / n as f64;
    let adder_util = nodes
        .iter()
        .map(|nd| nd.adder.utilization(t_total))
        .sum::<f64>()
        / n as f64;
    let wire = nodes[0].tx.bytes_sent();
    AllReduceTiming {
        t_total,
        t_node,
        eth_util,
        pcie_util,
        adder_util,
        wire_bytes_per_node: wire,
        steps: total_steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysconfig::SystemParams;
    use crate::util::units::gbps;

    fn cfg(bfp: bool) -> NicConfig {
        NicConfig::new(
            SystemParams::smartnic_40g(),
            if bfp { Some(BfpCodec::bfp16()) } else { None },
        )
    }

    #[test]
    fn segment_plan_equalizes() {
        let p = SegmentPlan::new(256.0 * 1024.0, 6, 2048 * 2048);
        assert_eq!(p.chunk_elems, 2048 * 2048 / 6 + 1); // padded
        assert_eq!(p.seg_bytes * p.segs_per_chunk as f64, p.chunk_bytes);
        assert!(p.seg_bytes <= 256.0 * 1024.0);
        // tiny tensors collapse to one segment
        let tiny = SegmentPlan::new(256.0 * 1024.0, 4, 64);
        assert_eq!(tiny.segs_per_chunk, 1);
        assert_eq!(tiny.chunk_elems, 16);
        // degenerate zero-element gradients keep one empty segment
        // (NaN here would deadlock the unified ring executor)
        let empty = SegmentPlan::new(256.0 * 1024.0, 4, 0);
        assert_eq!(empty.segs_per_chunk, 1);
        assert_eq!(empty.seg_bytes, 0.0);
        assert_eq!(empty.seg_elems, 0.0);
    }

    #[test]
    fn single_node_is_free() {
        let t = simulate_ring_allreduce(&cfg(false), 1, 1 << 20);
        assert_eq!(t.t_total, 0.0);
    }

    #[test]
    fn time_approaches_bandwidth_optimal() {
        // T_ring = R * 2(N-1) / (N * αBW) for large tensors
        let c = cfg(false);
        let elems = 4 * 1024 * 1024; // 16 MiB
        let n = 6;
        let t = simulate_ring_allreduce(&c, n, elems);
        let r = elems as f64 * 4.0;
        let t_ring = r * 2.0 * (n as f64 - 1.0) / (n as f64 * gbps(40.0));
        assert!(t.t_total > t_ring, "{} !> {}", t.t_total, t_ring);
        assert!(
            t.t_total < t_ring * 1.15,
            "sim {} vs ideal {t_ring}",
            t.t_total
        );
    }

    #[test]
    fn bfp_speeds_up_until_pcie_bound() {
        let elems = 4 * 1024 * 1024;
        let raw = simulate_ring_allreduce(&cfg(false), 6, elems);
        let comp = simulate_ring_allreduce(&cfg(true), 6, elems);
        let speedup = raw.t_total / comp.t_total;
        // β = 3.76 on the wire, but the uncompressed PCIe fetch+writeback
        // (T_mem) becomes the bottleneck once the ring is compressed
        assert!(speedup > 1.3, "speedup {speedup}");
        assert!(speedup <= 3.8, "speedup {speedup}");
        // and the compressed run must indeed be PCIe-bound, not eth-bound
        assert!(comp.pcie_util > comp.eth_util, "{comp:?}");
    }

    #[test]
    fn wire_bytes_match_compression() {
        let elems = 1 << 20;
        let raw = simulate_ring_allreduce(&cfg(false), 4, elems);
        let comp = simulate_ring_allreduce(&cfg(true), 4, elems);
        let ratio = raw.wire_bytes_per_node / comp.wire_bytes_per_node;
        assert!((ratio - 512.0 / 136.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn scaling_with_nodes_follows_2n1_over_n() {
        let c = cfg(false);
        let elems = 1 << 22;
        let t4 = simulate_ring_allreduce(&c, 4, elems).t_total;
        let t8 = simulate_ring_allreduce(&c, 8, elems).t_total;
        // ratio of 2(N-1)/N factors: (2*7/8)/(2*3/4) = 1.1667
        let expect = (2.0 * 7.0 / 8.0) / (2.0 * 3.0 / 4.0);
        let got = t8 / t4;
        assert!((got - expect).abs() / expect < 0.1, "got {got} want {expect}");
    }

    #[test]
    fn eth_is_bottleneck_at_40g() {
        let t = simulate_ring_allreduce(&cfg(false), 6, 4 * 1024 * 1024);
        assert!(t.eth_util > 0.75, "eth util {}", t.eth_util);
        assert!(t.adder_util < t.eth_util);
    }

    #[test]
    fn deterministic() {
        let a = simulate_ring_allreduce(&cfg(true), 6, 123_457);
        let b = simulate_ring_allreduce(&cfg(true), 6, 123_457);
        assert_eq!(a.t_total, b.t_total);
    }

    #[test]
    fn two_nodes_work() {
        let t = simulate_ring_allreduce(&cfg(false), 2, 1 << 16);
        assert!(t.t_total > 0.0);
        assert_eq!(t.steps, 2);
    }

    #[test]
    fn degraded_link_gates_the_whole_ring() {
        // the ring is only as fast as its slowest link: a 4x-degraded
        // port slows the (bandwidth-bound) all-reduce by ~4x
        let elems = 4 * 1024 * 1024;
        let healthy = simulate_ring_allreduce(&cfg(false), 6, elems).t_total;
        let degraded_cfg = cfg(false).with_degraded_link(2, 0.25);
        let degraded = simulate_ring_allreduce(&degraded_cfg, 6, elems).t_total;
        let slowdown = degraded / healthy;
        assert!(
            (2.0..=4.5).contains(&slowdown),
            "slowdown {slowdown} (expected ~4x, pipeline effects allowed)"
        );
    }

    #[test]
    fn straggler_node_hurts_less_than_slow_link_when_pcie_has_headroom() {
        let elems = 4 * 1024 * 1024;
        let healthy = simulate_ring_allreduce(&cfg(false), 6, elems).t_total;
        // raw FP32 at 40G is ethernet-bound; a mildly slow PCIe (0.8x)
        // stays hidden
        let mild = cfg(false).with_straggler(3, 0.8);
        let t_mild = simulate_ring_allreduce(&mild, 6, elems).t_total;
        assert!(t_mild < healthy * 1.15, "{t_mild} vs {healthy}");
        // but a severely slow node (0.2x) becomes the bottleneck
        let severe = cfg(false).with_straggler(3, 0.2);
        let t_severe = simulate_ring_allreduce(&severe, 6, elems).t_total;
        assert!(t_severe > healthy * 1.5, "{t_severe} vs {healthy}");
    }

    #[test]
    fn tiny_tensor_dominated_by_latency() {
        let c = cfg(false);
        let t = simulate_ring_allreduce(&c, 6, 64);
        // 10 steps of ~2us hops plus overheads: order 20-100 us
        assert!(t.t_total > 10.0 * c.sys.net.hop_latency);
        assert!(t.t_total < 1e-3);
    }
}
