//! Closed-form cost models of software (host/MPI) all-reduce schemes
//! (Thakur et al. [20] forms, with per-step software overhead), evaluated
//! over the baseline 100 GbE network.  These regenerate Fig. 2b's ordering:
//! default ≈ ring ≈ Rabenseifner > binomial for large gradients.

use super::Scheme;
use crate::sysconfig::NetParams;

/// Software all-reduce environment: network + per-step software cost.
#[derive(Clone, Copy, Debug)]
pub struct HostNet {
    pub net: NetParams,
    /// per-step software/MPI overhead (s): progress engine, matching, ...
    pub step_overhead: f64,
    /// cap from the host side: how fast the dedicated comm cores can push
    /// the software stack (f64::INFINITY = NIC line rate is the limit)
    pub comm_bw_cap: f64,
}

impl HostNet {
    pub fn effective_bw(&self) -> f64 {
        (self.net.eth_bw * self.net.alpha).min(self.comm_bw_cap)
    }

    fn step_cost(&self) -> f64 {
        self.step_overhead + self.net.hop_latency
    }
}

/// Time for one all-reduce of `bytes` across `n` nodes with `scheme`.
pub fn allreduce_time(scheme: Scheme, n: usize, bytes: f64, env: &HostNet) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    let bw = env.effective_bw();
    let lg = (n as f64).log2().ceil();
    match scheme {
        Scheme::Ring => {
            // 2(N-1) steps, each moving bytes/N
            let steps = 2.0 * (nf - 1.0);
            steps * (bytes / nf) / bw + steps * env.step_cost()
        }
        Scheme::Rabenseifner => {
            // recursive halving + doubling: volume 2(N-1)/N * bytes over
            // 2*ceil(log2 N) steps; non-power-of-two pays a preparation
            // exchange proportional to the surplus ranks folded away
            let extra = if n.is_power_of_two() {
                0.0
            } else {
                let pow = 1usize << (usize::BITS - 1 - n.leading_zeros());
                let frac = (n - pow) as f64 / nf;
                frac * bytes / bw + env.step_cost()
            };
            2.0 * (nf - 1.0) / nf * bytes / bw + 2.0 * lg * env.step_cost() + extra
        }
        Scheme::Binomial => {
            // gather-to-root: each of log2(N) rounds moves the full vector
            // on the critical path (reduce happens at receivers), then a
            // binomial broadcast of the result: ~2*log2(N)*bytes/bw
            2.0 * lg * bytes / bw + 2.0 * lg * env.step_cost()
        }
        Scheme::Tree => {
            // pipelined binary tree: up + down, each ~bytes/bw at depth
            // log2(N) of latency once the pipe fills
            2.0 * bytes / bw + 2.0 * lg * env.step_cost()
        }
        Scheme::Default => {
            // MPICH-style: short messages use binomial, large messages use
            // the best of ring/Rabenseifner
            if bytes < 64.0 * 1024.0 {
                allreduce_time(Scheme::Binomial, n, bytes, env)
            } else {
                allreduce_time(Scheme::Ring, n, bytes, env)
                    .min(allreduce_time(Scheme::Rabenseifner, n, bytes, env))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysconfig::SystemParams;

    fn env() -> HostNet {
        let s = SystemParams::baseline_100g();
        HostNet {
            net: s.net,
            step_overhead: s.host_step_overhead,
            comm_bw_cap: f64::INFINITY,
        }
    }

    const MB16: f64 = 16.0 * 1024.0 * 1024.0;

    #[test]
    fn single_node_is_free() {
        assert_eq!(allreduce_time(Scheme::Ring, 1, MB16, &env()), 0.0);
    }

    #[test]
    fn ring_is_bandwidth_optimal_for_large_messages() {
        let e = env();
        let ring = allreduce_time(Scheme::Ring, 8, MB16, &e);
        let binom = allreduce_time(Scheme::Binomial, 8, MB16, &e);
        let tree = allreduce_time(Scheme::Tree, 8, MB16, &e);
        assert!(ring < binom, "ring {ring} binom {binom}");
        assert!(ring < tree, "ring {ring} tree {tree}");
    }

    #[test]
    fn rabenseifner_close_to_ring_at_powers_of_two() {
        let e = env();
        let ring = allreduce_time(Scheme::Ring, 16, MB16, &e);
        let rab = allreduce_time(Scheme::Rabenseifner, 16, MB16, &e);
        // same bandwidth term; Rabenseifner has fewer latency steps
        assert!((ring - rab).abs() / ring < 0.15, "ring {ring} rab {rab}");
        assert!(rab <= ring);
    }

    #[test]
    fn binomial_wins_for_tiny_messages() {
        let e = env();
        let small = 4.0 * 1024.0;
        let ring = allreduce_time(Scheme::Ring, 16, small, &e);
        let binom = allreduce_time(Scheme::Binomial, 16, small, &e);
        assert!(binom < ring, "binom {binom} ring {ring}");
        // and the heuristic picks it up
        let def = allreduce_time(Scheme::Default, 16, small, &e);
        assert_eq!(def, binom);
    }

    #[test]
    fn default_matches_best_large(){
        let e = env();
        let def = allreduce_time(Scheme::Default, 12, MB16, &e);
        let ring = allreduce_time(Scheme::Ring, 12, MB16, &e);
        let rab = allreduce_time(Scheme::Rabenseifner, 12, MB16, &e);
        assert_eq!(def, ring.min(rab));
    }

    #[test]
    fn time_grows_with_nodes() {
        let e = env();
        let mut prev = 0.0;
        for n in [2usize, 4, 8, 16, 32] {
            let t = allreduce_time(Scheme::Ring, n, MB16, &e);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn bandwidth_term_dominates_large_n() {
        // as N -> inf, ring time -> 2*bytes/bw (plus 62 step latencies)
        let e = env();
        let t = allreduce_time(Scheme::Ring, 32, MB16, &e);
        let asymptote = 2.0 * MB16 / e.effective_bw();
        assert!(t > asymptote * 0.9);
        assert!(t < asymptote * 1.5, "t {t} asym {asymptote}");
    }

    #[test]
    fn comm_bw_cap_binds() {
        let mut e = env();
        e.comm_bw_cap = 2.0e9;
        assert_eq!(e.effective_bw(), 2.0e9);
        let capped = allreduce_time(Scheme::Ring, 8, MB16, &e);
        let uncapped = allreduce_time(Scheme::Ring, 8, MB16, &env());
        assert!(capped > uncapped * 4.0, "capped {capped} uncapped {uncapped}");
    }

    #[test]
    fn nonpow2_rabenseifner_penalty() {
        let e = env();
        let t8 = allreduce_time(Scheme::Rabenseifner, 8, MB16, &e);
        let t6 = allreduce_time(Scheme::Rabenseifner, 6, MB16, &e);
        // 6 nodes pays the extra exchange: more than the pure (N-1)/N drop
        assert!(t6 > t8 * 0.9, "t6 {t6} t8 {t8}");
    }
}
