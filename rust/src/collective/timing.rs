//! Closed-form cost models of software (host/MPI) all-reduce schemes
//! (Thakur et al. [20] forms, with per-step software overhead), evaluated
//! over the baseline 100 GbE network.  These regenerate Fig. 2b's ordering:
//! default ≈ ring ≈ Rabenseifner > binomial for large gradients.

use super::Scheme;
use crate::sysconfig::NetParams;

/// Software all-reduce environment: network + per-step software cost.
#[derive(Clone, Copy, Debug)]
pub struct HostNet {
    pub net: NetParams,
    /// per-step software/MPI overhead (s): progress engine, matching, ...
    pub step_overhead: f64,
    /// cap from the host side: how fast the dedicated comm cores can push
    /// the software stack (f64::INFINITY = NIC line rate is the limit)
    pub comm_bw_cap: f64,
}

impl HostNet {
    /// Achievable software all-reduce bandwidth: the wire's α·β-derated
    /// line rate ([`crate::sysconfig::NetParams::effective_bw`]) capped by
    /// what the host comm cores can push.
    pub fn effective_bw(&self) -> f64 {
        self.net.effective_bw().min(self.comm_bw_cap)
    }

    /// Per-step fixed cost: software overhead + one network hop.
    pub fn step_cost(&self) -> f64 {
        self.step_overhead + self.net.hop_latency
    }
}

/// Time for one all-reduce of `bytes` across `n` nodes with `scheme`.
pub fn allreduce_time(scheme: Scheme, n: usize, bytes: f64, env: &HostNet) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    let bw = env.effective_bw();
    let lg = (n as f64).log2().ceil();
    match scheme {
        Scheme::Ring => {
            // 2(N-1) steps, each moving bytes/N
            let steps = 2.0 * (nf - 1.0);
            steps * (bytes / nf) / bw + steps * env.step_cost()
        }
        Scheme::Rabenseifner => {
            // recursive halving + doubling: volume 2(N-1)/N * bytes over
            // 2*ceil(log2 N) steps; non-power-of-two pays a preparation
            // exchange proportional to the surplus ranks folded away
            let extra = if n.is_power_of_two() {
                0.0
            } else {
                let pow = 1usize << (usize::BITS - 1 - n.leading_zeros());
                let frac = (n - pow) as f64 / nf;
                frac * bytes / bw + env.step_cost()
            };
            2.0 * (nf - 1.0) / nf * bytes / bw + 2.0 * lg * env.step_cost() + extra
        }
        Scheme::Binomial => {
            // gather-to-root: each of log2(N) rounds moves the full vector
            // on the critical path (reduce happens at receivers), then a
            // binomial broadcast of the result: ~2*log2(N)*bytes/bw
            2.0 * lg * bytes / bw + 2.0 * lg * env.step_cost()
        }
        Scheme::Tree => {
            // pipelined binary tree: up + down, each ~bytes/bw at depth
            // log2(N) of latency once the pipe fills
            2.0 * bytes / bw + 2.0 * lg * env.step_cost()
        }
        Scheme::Default => pick_default(n, bytes, env).1,
    }
}

/// The MPICH-style `Scheme::Default` selection: short messages use
/// binomial, large messages the best of ring/Rabenseifner.  Returns the
/// chosen scheme with its closed-form cost; shared by `allreduce_time`
/// and [`scheme_rounds`] so the event engine always executes exactly the
/// scheme the closed form prices, without evaluating any form twice.
fn pick_default(n: usize, bytes: f64, env: &HostNet) -> (Scheme, f64) {
    if bytes < 64.0 * 1024.0 {
        (
            Scheme::Binomial,
            allreduce_time(Scheme::Binomial, n, bytes, env),
        )
    } else {
        let ring = allreduce_time(Scheme::Ring, n, bytes, env);
        let rab = allreduce_time(Scheme::Rabenseifner, n, bytes, env);
        if ring <= rab {
            (Scheme::Ring, ring)
        } else {
            (Scheme::Rabenseifner, rab)
        }
    }
}

/// Per-round decomposition of a scheme's closed-form cost, consumed by the
/// unified event engine's host-collective executor: `rounds` barrier-
/// synchronized rounds, each moving `bytes_per_round` per node and paying
/// one [`HostNet::step_cost`], plus `extra_step_costs` latency-only steps.
/// By construction
///
///   rounds·(bytes_per_round/bw + step_cost) + extra_step_costs·step_cost
///     == allreduce_time(scheme, n, bytes, env)
///
/// exactly, so an uncontended event-driven host all-reduce reproduces the
/// closed form to float precision while contended ones (two jobs sharing a
/// node's comm cores) queue per round on the shared server.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HostRoundPlan {
    pub rounds: usize,
    pub bytes_per_round: f64,
    pub extra_step_costs: usize,
}

impl HostRoundPlan {
    const EMPTY: HostRoundPlan = HostRoundPlan {
        rounds: 0,
        bytes_per_round: 0.0,
        extra_step_costs: 0,
    };

    /// Closed-form total of this plan (equals `allreduce_time`).
    pub fn total_time(&self, env: &HostNet) -> f64 {
        self.rounds as f64 * (self.bytes_per_round / env.effective_bw() + env.step_cost())
            + self.extra_step_costs as f64 * env.step_cost()
    }
}

/// Decompose `scheme` into the round plan executed by the event engine.
pub fn scheme_rounds(scheme: Scheme, n: usize, bytes: f64, env: &HostNet) -> HostRoundPlan {
    if n <= 1 {
        return HostRoundPlan::EMPTY;
    }
    let nf = n as f64;
    let lg = (n as f64).log2().ceil() as usize;
    match scheme {
        Scheme::Ring => HostRoundPlan {
            rounds: 2 * (n - 1),
            bytes_per_round: bytes / nf,
            extra_step_costs: 0,
        },
        Scheme::Rabenseifner => {
            let mut total = 2.0 * (nf - 1.0) / nf * bytes;
            let mut rounds = 2 * lg;
            if !n.is_power_of_two() {
                let pow = 1usize << (usize::BITS - 1 - n.leading_zeros());
                total += (n - pow) as f64 / nf * bytes;
                rounds += 1;
            }
            HostRoundPlan {
                rounds,
                bytes_per_round: total / rounds as f64,
                extra_step_costs: 0,
            }
        }
        Scheme::Binomial => HostRoundPlan {
            rounds: 2 * lg,
            bytes_per_round: bytes,
            extra_step_costs: 0,
        },
        Scheme::Tree => HostRoundPlan {
            rounds: 2,
            bytes_per_round: bytes,
            extra_step_costs: 2 * lg - 2,
        },
        Scheme::Default => scheme_rounds(pick_default(n, bytes, env).0, n, bytes, env),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysconfig::SystemParams;

    fn env() -> HostNet {
        let s = SystemParams::baseline_100g();
        HostNet {
            net: s.net,
            step_overhead: s.host_step_overhead,
            comm_bw_cap: f64::INFINITY,
        }
    }

    const MB16: f64 = 16.0 * 1024.0 * 1024.0;

    #[test]
    fn single_node_is_free() {
        assert_eq!(allreduce_time(Scheme::Ring, 1, MB16, &env()), 0.0);
    }

    #[test]
    fn ring_is_bandwidth_optimal_for_large_messages() {
        let e = env();
        let ring = allreduce_time(Scheme::Ring, 8, MB16, &e);
        let binom = allreduce_time(Scheme::Binomial, 8, MB16, &e);
        let tree = allreduce_time(Scheme::Tree, 8, MB16, &e);
        assert!(ring < binom, "ring {ring} binom {binom}");
        assert!(ring < tree, "ring {ring} tree {tree}");
    }

    #[test]
    fn rabenseifner_close_to_ring_at_powers_of_two() {
        let e = env();
        let ring = allreduce_time(Scheme::Ring, 16, MB16, &e);
        let rab = allreduce_time(Scheme::Rabenseifner, 16, MB16, &e);
        // same bandwidth term; Rabenseifner has fewer latency steps
        assert!((ring - rab).abs() / ring < 0.15, "ring {ring} rab {rab}");
        assert!(rab <= ring);
    }

    #[test]
    fn binomial_wins_for_tiny_messages() {
        let e = env();
        let small = 4.0 * 1024.0;
        let ring = allreduce_time(Scheme::Ring, 16, small, &e);
        let binom = allreduce_time(Scheme::Binomial, 16, small, &e);
        assert!(binom < ring, "binom {binom} ring {ring}");
        // and the heuristic picks it up
        let def = allreduce_time(Scheme::Default, 16, small, &e);
        assert_eq!(def, binom);
    }

    #[test]
    fn default_matches_best_large(){
        let e = env();
        let def = allreduce_time(Scheme::Default, 12, MB16, &e);
        let ring = allreduce_time(Scheme::Ring, 12, MB16, &e);
        let rab = allreduce_time(Scheme::Rabenseifner, 12, MB16, &e);
        assert_eq!(def, ring.min(rab));
    }

    #[test]
    fn time_grows_with_nodes() {
        let e = env();
        let mut prev = 0.0;
        for n in [2usize, 4, 8, 16, 32] {
            let t = allreduce_time(Scheme::Ring, n, MB16, &e);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn bandwidth_term_dominates_large_n() {
        // as N -> inf, ring time -> 2*bytes/bw (plus 62 step latencies)
        let e = env();
        let t = allreduce_time(Scheme::Ring, 32, MB16, &e);
        let asymptote = 2.0 * MB16 / e.effective_bw();
        assert!(t > asymptote * 0.9);
        assert!(t < asymptote * 1.5, "t {t} asym {asymptote}");
    }

    #[test]
    fn comm_bw_cap_binds() {
        let mut e = env();
        e.comm_bw_cap = 2.0e9;
        assert_eq!(e.effective_bw(), 2.0e9);
        let capped = allreduce_time(Scheme::Ring, 8, MB16, &e);
        let uncapped = allreduce_time(Scheme::Ring, 8, MB16, &env());
        assert!(capped > uncapped * 4.0, "capped {capped} uncapped {uncapped}");
    }

    #[test]
    fn round_plans_reproduce_closed_form_exactly() {
        let e = env();
        for scheme in Scheme::ALL {
            for n in [1usize, 2, 3, 4, 5, 6, 8, 12, 16, 24, 32] {
                for bytes in [4.0 * 1024.0, MB16, 64.0 * 1024.0 * 1024.0] {
                    let plan = scheme_rounds(scheme, n, bytes, &e);
                    let want = allreduce_time(scheme, n, bytes, &e);
                    let got = plan.total_time(&e);
                    assert!(
                        (got - want).abs() <= want.abs() * 1e-12 + 1e-15,
                        "{} n={n} bytes={bytes}: plan {got} closed {want}",
                        scheme.name()
                    );
                }
            }
        }
    }

    #[test]
    fn nonpow2_rabenseifner_penalty() {
        let e = env();
        let t8 = allreduce_time(Scheme::Rabenseifner, 8, MB16, &e);
        let t6 = allreduce_time(Scheme::Rabenseifner, 6, MB16, &e);
        // 6 nodes pays the extra exchange: more than the pure (N-1)/N drop
        assert!(t6 > t8 * 0.9, "t6 {t6} t8 {t8}");
    }
}
