//! Real data paths for the non-ring all-reduce algorithms of Fig. 2b:
//! binomial-tree reduce+broadcast and Rabenseifner (recursive-halving
//! reduce-scatter + recursive-doubling allgather), including the standard
//! non-power-of-two pre/post folding.
//!
//! These complement `data::ring_allreduce` (the NIC's algorithm): the
//! baselines the paper compares against are real here too, so the
//! correctness property (== serial sum up to summation order) is tested
//! for every scheme.

/// Binomial-tree all-reduce: reduce to rank 0, then broadcast.
pub fn binomial_allreduce(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "ragged buffers");
    // reduce: in round k, ranks with bit k set send to (rank - 2^k)
    let mut k = 1usize;
    while k < n {
        for dst in (0..n).step_by(2 * k) {
            let src = dst + k;
            if src < n {
                let (a, b) = bufs.split_at_mut(src);
                let dst_buf = &mut a[dst];
                for (d, s) in dst_buf.iter_mut().zip(&b[0]) {
                    *d += s;
                }
            }
        }
        k *= 2;
    }
    // broadcast rank 0's result
    let root = bufs[0].clone();
    for b in bufs[1..].iter_mut() {
        b.copy_from_slice(&root);
    }
}

/// Rabenseifner all-reduce: recursive halving reduce-scatter followed by
/// recursive doubling allgather, with surplus ranks folded in/out for
/// non-powers-of-two.
pub fn rabenseifner_allreduce(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "ragged buffers");

    // --- fold surplus ranks: p = 2^k <= n, r = n - p ------------------
    let p = if n.is_power_of_two() {
        n
    } else {
        1usize << (usize::BITS - 1 - n.leading_zeros())
    };
    let r = n - p;
    // odd ranks among the first 2r send everything to their even partner
    for i in 0..r {
        let (even, odd) = (2 * i, 2 * i + 1);
        let (a, b) = bufs.split_at_mut(odd);
        for (d, s) in a[even].iter_mut().zip(&b[0]) {
            *d += s;
        }
    }
    // active set: evens of the folded prefix + the tail
    let active: Vec<usize> = (0..r).map(|i| 2 * i).chain(2 * r..n).collect();
    debug_assert_eq!(active.len(), p);

    // --- recursive halving reduce-scatter over `active` ----------------
    // own[v] = (lo, hi) range of the vector active[v] currently owns
    let mut own = vec![(0usize, len); p];
    let mut dist = p / 2;
    while dist >= 1 {
        for v in 0..p {
            let peer = v ^ dist;
            if peer < v {
                continue; // handle each pair once
            }
            let (lo, hi) = own[v];
            debug_assert_eq!(own[peer], own[v]);
            let mid = lo + (hi - lo) / 2;
            // lower-half owner: the rank with the 0 bit (v); upper: peer
            // v reduces [lo, mid) — it receives peer's [lo, mid)
            // peer reduces [mid, hi) — it receives v's [mid, hi)
            let (i, j) = (active[v], active[peer]);
            let (first, second) = if i < j {
                let (a, b) = bufs.split_at_mut(j);
                (&mut a[i], &mut b[0])
            } else {
                unreachable!("active is sorted")
            };
            for idx in lo..mid {
                first[idx] += second[idx];
            }
            for idx in mid..hi {
                second[idx] += first[idx];
            }
            own[v] = (lo, mid);
            own[peer] = (mid, hi);
        }
        dist /= 2;
    }

    // --- recursive doubling allgather ----------------------------------
    dist = 1;
    while dist < p {
        for v in 0..p {
            let peer = v ^ dist;
            if peer < v {
                continue;
            }
            let (i, j) = (active[v], active[peer]);
            let (lo_v, hi_v) = own[v];
            let (lo_p, hi_p) = own[peer];
            let (a, b) = bufs.split_at_mut(j);
            // exchange owned ranges
            b[0][lo_v..hi_v].copy_from_slice(&a[i][lo_v..hi_v]);
            let tmp = b[0][lo_p..hi_p].to_vec();
            a[i][lo_p..hi_p].copy_from_slice(&tmp);
            let merged = (lo_v.min(lo_p), hi_v.max(hi_p));
            own[v] = merged;
            own[peer] = merged;
        }
        dist *= 2;
    }

    // --- unfold: evens copy the result back to their odd partner -------
    for i in 0..r {
        let (even, odd) = (2 * i, 2 * i + 1);
        let src = bufs[even].clone();
        bufs[odd].copy_from_slice(&src);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::data::serial_sum;
    use crate::prop::{forall, gens};
    use crate::util::rng::Rng;

    fn make_bufs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    fn assert_close(got: &[Vec<f32>], want: &[f32], tag: &str) {
        for (wi, b) in got.iter().enumerate() {
            for (g, w) in b.iter().zip(want) {
                assert!(
                    (g - w).abs() <= w.abs() * 1e-5 + 1e-5,
                    "{tag} worker {wi}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn binomial_matches_serial() {
        for n in [2usize, 3, 4, 5, 6, 7, 8, 12] {
            for len in [1usize, 17, 256] {
                let mut bufs = make_bufs(n, len, (n * 7 + len) as u64);
                let want = serial_sum(&bufs);
                binomial_allreduce(&mut bufs);
                assert_close(&bufs, &want, &format!("binomial n={n} len={len}"));
                for b in &bufs[1..] {
                    assert_eq!(b, &bufs[0]);
                }
            }
        }
    }

    #[test]
    fn rabenseifner_matches_serial_pow2() {
        for n in [2usize, 4, 8, 16] {
            for len in [16usize, 100, 1024] {
                let mut bufs = make_bufs(n, len, (n * 13 + len) as u64);
                let want = serial_sum(&bufs);
                rabenseifner_allreduce(&mut bufs);
                assert_close(&bufs, &want, &format!("rab n={n} len={len}"));
                for b in &bufs[1..] {
                    assert_eq!(b, &bufs[0]);
                }
            }
        }
    }

    #[test]
    fn rabenseifner_matches_serial_nonpow2() {
        for n in [3usize, 5, 6, 7, 12, 24] {
            for len in [8usize, 129, 1000] {
                let mut bufs = make_bufs(n, len, (n * 31 + len) as u64);
                let want = serial_sum(&bufs);
                rabenseifner_allreduce(&mut bufs);
                assert_close(&bufs, &want, &format!("rab n={n} len={len}"));
                for b in &bufs[1..] {
                    assert_eq!(b, &bufs[0]);
                }
            }
        }
    }

    #[test]
    fn tiny_vectors_and_single_node() {
        let mut one = make_bufs(1, 5, 1);
        let orig = one[0].clone();
        rabenseifner_allreduce(&mut one);
        binomial_allreduce(&mut one);
        assert_eq!(one[0], orig);

        // len < n
        let mut bufs = make_bufs(6, 2, 2);
        let want = serial_sum(&bufs);
        rabenseifner_allreduce(&mut bufs);
        assert_close(&bufs, &want, "rab len<n");
    }

    #[test]
    fn prop_all_schemes_agree_with_serial() {
        forall(
            &gens::pair(gens::usize_in(2..=10), gens::usize_in(1..=257)),
            40,
            |&(n, len)| {
                let make = || make_bufs(n, len, (n * 97 + len) as u64);
                let want = serial_sum(&make());
                let ok = |bufs: &[Vec<f32>]| {
                    bufs.iter().all(|b| {
                        b.iter()
                            .zip(&want)
                            .all(|(g, w)| (g - w).abs() <= w.abs() * 1e-5 + 1e-5)
                    })
                };
                let mut a = make();
                binomial_allreduce(&mut a);
                let mut b = make();
                rabenseifner_allreduce(&mut b);
                let mut c = make();
                crate::collective::data::ring_allreduce(&mut c, None);
                ok(&a) && ok(&b) && ok(&c)
            },
        );
    }
}
