//! The *real* all-reduce data path: exact pipelined ring all-reduce over
//! worker gradient buffers, with optional BFP quantization at every hop —
//! precisely the NIC datapath of Fig. 3a (decompress → FP32 add →
//! compress), so the training runtime experiences the same numerics the
//! hardware would produce.
//!
//! Summation order is fixed by the ring schedule, making results exactly
//! reproducible (and matching what the FPGA ring produces, which differs
//! from a serial left-to-right sum only in associativity order).

use crate::bfp::BfpCodec;
use crate::netsim::topology::Ring;

/// In-place ring all-reduce (sum) across `bufs` (one gradient buffer per
/// worker, all the same length).  `bfp` quantizes each chunk before every
/// wire crossing.  Returns bytes that crossed the wire per node.
pub fn ring_allreduce(bufs: &mut [Vec<f32>], bfp: Option<&BfpCodec>) -> f64 {
    let n = bufs.len();
    assert!(n >= 1);
    if n == 1 {
        return 0.0;
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "ragged buffers");
    let ring = Ring::new(n);
    let chunk = len.div_ceil(n);
    // chunks past the end are empty (the padded region of Sec. IV-C)
    let bounds =
        |c: usize| -> (usize, usize) { ((c * chunk).min(len), ((c + 1) * chunk).min(len)) };

    let mut wire_bytes = 0f64;
    // in-flight payloads: what node i sends this step
    let mut inflight: Vec<Vec<f32>> = vec![Vec::new(); n];

    // reduce-scatter: n-1 steps
    for step in 0..ring.reduce_scatter_steps() {
        for (i, payload) in inflight.iter_mut().enumerate() {
            let c = ring.send_chunk(i, step);
            let (lo, hi) = bounds(c);
            let mut data = if step == 0 {
                bufs[i][lo..hi].to_vec()
            } else {
                std::mem::take(payload)
            };
            if let Some(codec) = bfp {
                codec.quantize_slice(&mut data);
                wire_bytes += codec.wire_bytes(data.len()) as f64;
            } else {
                wire_bytes += data.len() as f64 * 4.0;
            }
            *payload = data;
        }
        // deliver: receiver j = next(i) reduces into its local chunk copy
        let mut next_inflight: Vec<Vec<f32>> = vec![Vec::new(); n];
        for i in 0..n {
            let j = ring.next(i);
            let c = ring.recv_chunk(j, step);
            let (lo, hi) = bounds(c);
            let mut acc = std::mem::take(&mut inflight[i]);
            for (a, &b) in acc.iter_mut().zip(&bufs[j][lo..hi]) {
                *a += b;
            }
            next_inflight[j] = acc;
        }
        inflight = next_inflight;
    }

    // after reduce-scatter, node j holds the fully reduced chunk it last
    // received in `inflight[j]`; write it back and run the allgather phase
    for j in 0..n {
        let c = ring.recv_chunk(j, ring.reduce_scatter_steps() - 1);
        let (lo, hi) = bounds(c);
        // quantize once more if compressed: the final value written to
        // every host is the BFP-decoded reduced chunk (it crosses the
        // wire to every other node)
        if let Some(codec) = bfp {
            codec.quantize_slice(&mut inflight[j]);
        }
        bufs[j][lo..hi].copy_from_slice(&inflight[j]);
    }

    // allgather: n-1 steps of store-and-forward of the reduced chunks
    for step in ring.reduce_scatter_steps()..ring.allreduce_steps() {
        let mut moves: Vec<(usize, usize, Vec<f32>)> = Vec::with_capacity(n);
        for i in 0..n {
            let j = ring.next(i);
            let c = ring.send_chunk(i, step);
            let (lo, hi) = bounds(c);
            let data = bufs[i][lo..hi].to_vec();
            wire_bytes += match bfp {
                // already quantized: re-quantization is idempotent, costs
                // only compressed bytes on the wire
                Some(codec) => codec.wire_bytes(data.len()) as f64,
                None => data.len() as f64 * 4.0,
            };
            moves.push((j, c, data));
        }
        for (j, c, data) in moves {
            let (lo, hi) = bounds(c);
            debug_assert_eq!(hi - lo, data.len());
            bufs[j][lo..hi].copy_from_slice(&data);
        }
    }
    wire_bytes / n as f64
}

/// Reference: serial sum of all buffers (the oracle for tests).
pub fn serial_sum(bufs: &[Vec<f32>]) -> Vec<f32> {
    let len = bufs[0].len();
    let mut out = vec![0f32; len];
    for b in bufs {
        for (o, &x) in out.iter_mut().zip(b) {
            *o += x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, gens};
    use crate::util::rng::Rng;

    fn make_bufs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn matches_serial_sum_fp32() {
        for n in [2usize, 3, 4, 6, 8] {
            for len in [1usize, 5, 16, 100, 1024, 1000] {
                let mut bufs = make_bufs(n, len, (n * 1000 + len) as u64);
                let want = serial_sum(&bufs);
                ring_allreduce(&mut bufs, None);
                for b in &bufs {
                    for (got, want) in b.iter().zip(&want) {
                        assert!(
                            (got - want).abs() <= want.abs() * 1e-5 + 1e-5,
                            "n={n} len={len}: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_workers_agree_exactly() {
        let mut bufs = make_bufs(6, 999, 42);
        ring_allreduce(&mut bufs, None);
        for b in &bufs[1..] {
            assert_eq!(b, &bufs[0]);
        }
    }

    #[test]
    fn all_workers_agree_with_bfp() {
        let codec = BfpCodec::bfp16();
        let mut bufs = make_bufs(6, 1024, 43);
        ring_allreduce(&mut bufs, Some(&codec));
        for b in &bufs[1..] {
            assert_eq!(b, &bufs[0]);
        }
    }

    #[test]
    fn bfp_error_is_bounded() {
        let codec = BfpCodec::bfp16();
        let mut bufs = make_bufs(6, 4096, 44);
        let want = serial_sum(&bufs);
        ring_allreduce(&mut bufs, Some(&codec));
        // relative L2 error of the reduced tensor should be small
        let num: f64 = bufs[0]
            .iter()
            .zip(&want)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let den: f64 = want.iter().map(|x| (*x as f64).powi(2)).sum();
        let rel = (num / den).sqrt();
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn single_worker_untouched() {
        let mut bufs = make_bufs(1, 64, 45);
        let orig = bufs[0].clone();
        let wire = ring_allreduce(&mut bufs, None);
        assert_eq!(bufs[0], orig);
        assert_eq!(wire, 0.0);
    }

    #[test]
    fn wire_bytes_accounting() {
        let len = 6 * 160; // chunks divide evenly into whole BFP blocks
        let mut a = make_bufs(6, len, 46);
        let raw = ring_allreduce(&mut a, None);
        // per node: 2(N-1) sends of len/N elems * 4 bytes
        let expect = 2.0 * 5.0 * (len as f64 / 6.0) * 4.0;
        assert!((raw - expect).abs() < 1e-9, "raw {raw} expect {expect}");
        let codec = BfpCodec::bfp16();
        let mut b = make_bufs(6, len, 46);
        let comp = ring_allreduce(&mut b, Some(&codec));
        assert!(
            (raw / comp - codec.compression_ratio()).abs() < 0.3,
            "ratio {}",
            raw / comp
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = make_bufs(5, 777, 47);
        let mut b = make_bufs(5, 777, 47);
        ring_allreduce(&mut a, None);
        ring_allreduce(&mut b, None);
        assert_eq!(a, b);
    }

    #[test]
    fn prop_allreduce_matches_serial_any_shape() {
        forall(
            &gens::pair(gens::usize_in(2..=8), gens::usize_in(1..=300)),
            40,
            |&(n, len)| {
                let mut bufs = make_bufs(n, len, (n * 31 + len) as u64);
                let want = serial_sum(&bufs);
                ring_allreduce(&mut bufs, None);
                bufs.iter().all(|b| {
                    b.iter()
                        .zip(&want)
                        .all(|(g, w)| (g - w).abs() <= w.abs() * 1e-5 + 1e-5)
                })
            },
        );
    }

    #[test]
    fn prop_bfp_allreduce_workers_agree() {
        let codec = BfpCodec::bfp16();
        forall(
            &gens::pair(gens::usize_in(2..=7), gens::usize_in(1..=200)),
            30,
            |&(n, len)| {
                let mut bufs = make_bufs(n, len, (n * 97 + len) as u64);
                ring_allreduce(&mut bufs, Some(&codec));
                bufs[1..].iter().all(|b| b == &bufs[0])
            },
        );
    }
}
