//! Host-side all-reduce execution models (Sec. III): how the *baseline*
//! system (conventional NICs) spends worker resources on communication.
//!
//! Two strategies, matching the paper's profiling experiment:
//! * **Naive** — all cores compute; one thread fires an asynchronous
//!   all-reduce and everyone waits: the full all-reduce latency lands on
//!   the critical path (Fig. 2a left).
//! * **Overlapped** — `comm_cores` cores are dedicated to communication +
//!   weight update management; the remaining cores run the backward pass,
//!   which slows down by cores/(cores−k)·(1+η) (Fig. 2a right, the black
//!   shaded 11%).

use crate::sysconfig::WorkerParams;

/// Host all-reduce execution strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HostStrategy {
    Naive,
    /// overlapped with `comm_cores` dedicated communication cores
    Overlapped { comm_cores: usize },
}

impl HostStrategy {
    /// Cores left for tensor compute.
    pub fn compute_cores(&self, w: &WorkerParams) -> usize {
        match self {
            HostStrategy::Naive => w.cores,
            HostStrategy::Overlapped { comm_cores } => {
                assert!(*comm_cores < w.cores, "cannot dedicate every core");
                w.cores - comm_cores
            }
        }
    }

    /// Multiplier on backward-pass time relative to all-cores compute.
    pub fn bwd_slowdown(&self, w: &WorkerParams) -> f64 {
        match self {
            HostStrategy::Naive => 1.0,
            HostStrategy::Overlapped { comm_cores } => {
                let c = w.cores as f64;
                let k = *comm_cores as f64;
                c / (c - k) * (1.0 + w.comm_interference)
            }
        }
    }

    /// Does the all-reduce overlap with backward compute?
    pub fn overlaps(&self) -> bool {
        matches!(self, HostStrategy::Overlapped { .. })
    }
}

/// Pick the best comm-core count for an overlapped host all-reduce by
/// minimizing modeled iteration time over a candidate range (the paper's
/// "balance ... is workload dependent and needs to be tuned"; they found
/// 2 for their workload).
pub fn tune_comm_cores(
    w: &WorkerParams,
    iter_time: impl Fn(HostStrategy) -> f64,
    max_comm: usize,
) -> (usize, f64) {
    let mut best = (1usize, f64::INFINITY);
    for k in 1..=max_comm.min(w.cores - 1) {
        let t = iter_time(HostStrategy::Overlapped { comm_cores: k });
        if t < best.1 {
            best = (k, t);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysconfig::WorkerParams;

    #[test]
    fn naive_uses_all_cores() {
        let w = WorkerParams::xeon_8280();
        assert_eq!(HostStrategy::Naive.compute_cores(&w), 28);
        assert_eq!(HostStrategy::Naive.bwd_slowdown(&w), 1.0);
    }

    #[test]
    fn overlapped_2_cores_gives_papers_11pct() {
        let w = WorkerParams::xeon_8280();
        let s = HostStrategy::Overlapped { comm_cores: 2 };
        assert_eq!(s.compute_cores(&w), 26);
        let slow = s.bwd_slowdown(&w);
        assert!((slow - 1.11).abs() < 0.005, "slowdown {slow}");
    }

    #[test]
    fn slowdown_grows_with_comm_cores() {
        let w = WorkerParams::xeon_8280();
        let s2 = HostStrategy::Overlapped { comm_cores: 2 }.bwd_slowdown(&w);
        let s8 = HostStrategy::Overlapped { comm_cores: 8 }.bwd_slowdown(&w);
        assert!(s8 > s2);
    }

    #[test]
    fn tune_finds_minimum() {
        let w = WorkerParams::xeon_8280();
        // toy objective: compute term shrinks with comm cores' AR speedup,
        // compute slows down: minimum interior
        let obj = |s: HostStrategy| {
            let k = match s {
                HostStrategy::Overlapped { comm_cores } => comm_cores as f64,
                _ => 0.0,
            };
            s.bwd_slowdown(&w) * 10.0 + 8.0 / k
        };
        let (k, t) = tune_comm_cores(&w, obj, 27);
        assert!(k >= 1 && k < 28);
        assert!(t.is_finite());
        // check neighbourhood optimality
        let t_prev = obj(HostStrategy::Overlapped { comm_cores: (k - 1).max(1) });
        let t_next = obj(HostStrategy::Overlapped { comm_cores: k + 1 });
        assert!(t <= t_prev && t <= t_next);
    }

    #[test]
    #[should_panic(expected = "every core")]
    fn cannot_steal_all_cores() {
        let w = WorkerParams::xeon_8280();
        HostStrategy::Overlapped { comm_cores: 28 }.compute_cores(&w);
    }
}
