//! Collective communication: the all-reduce algorithms of Sec. II-B / III.
//!
//! Three faces, deliberately separated:
//! * [`timing`] — closed-form software (MPI-style) all-reduce cost models
//!   for ring, Rabenseifner, binomial gather/scatter, pipelined tree and
//!   the MPICH-style size heuristic (regenerates Fig. 2b), plus the
//!   [`timing::scheme_rounds`] decomposition that lets the unified event
//!   engine execute each scheme round-by-round on the shared clock;
//! * [`algorithms`] / [`data`] — the *real* data paths: exact ring,
//!   binomial and Rabenseifner all-reduces over worker gradient buffers
//!   (the ring with optional per-hop BFP quantization), used by the real
//!   training runtime (numerics included);
//! * timing *execution* lives in `cluster::collective`, where rings,
//!   trees and host schemes all run as events contending on one fabric.

pub mod algorithms;
pub mod data;
pub mod host;
pub mod timing;

/// All-reduce algorithm selector (paper Fig. 2b legend).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// pipelined ring (bandwidth optimal, linear latency)
    Ring,
    /// Rabenseifner: recursive-halving reduce-scatter + recursive-doubling
    /// allgather
    Rabenseifner,
    /// binomial-tree gather to root + scatter/broadcast
    Binomial,
    /// pipelined binary tree
    Tree,
    /// MPICH-style heuristic choosing by message size / node count
    Default,
}

impl Scheme {
    pub const ALL: [Scheme; 5] = [
        Scheme::Default,
        Scheme::Ring,
        Scheme::Rabenseifner,
        Scheme::Binomial,
        Scheme::Tree,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Ring => "ring",
            Scheme::Rabenseifner => "rabenseifner",
            Scheme::Binomial => "binomial",
            Scheme::Tree => "tree",
            Scheme::Default => "default",
        }
    }
}

impl std::str::FromStr for Scheme {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "ring" => Ok(Scheme::Ring),
            "rabenseifner" => Ok(Scheme::Rabenseifner),
            "binomial" => Ok(Scheme::Binomial),
            "tree" => Ok(Scheme::Tree),
            "default" => Ok(Scheme::Default),
            other => Err(format!("unknown all-reduce scheme '{other}'")),
        }
    }
}
