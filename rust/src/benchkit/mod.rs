//! Miniature benchmarking harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations, per-iteration statistics, throughput
//! reporting and a `black_box`.  `cargo bench` targets use
//! `harness = false` and drive this directly; each paper table/figure bench
//! prints its rows through `util::table` after timing the underlying code.

use crate::util::stats::{summarize, Summary};
use std::time::{Duration, Instant};

#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub per_iter: Summary,
    /// optional bytes processed per iteration (for throughput)
    pub bytes_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.per_iter;
        let mut line = format!(
            "{:<40} {:>12} /iter  (min {}, p95 {}, n={})",
            self.name,
            crate::util::units::fmt_time(s.mean),
            crate::util::units::fmt_time(s.min),
            crate::util::units::fmt_time(s.p95),
            self.iters,
        );
        if let Some(b) = self.bytes_per_iter {
            line.push_str(&format!(
                "  [{}]",
                crate::util::units::fmt_rate(b / s.mean)
            ));
        }
        line
    }
}

pub struct Bencher {
    /// target measurement time per benchmark
    pub measure_time: Duration,
    pub warmup_time: Duration,
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            measure_time: Duration::from_millis(700),
            warmup_time: Duration::from_millis(150),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            measure_time: Duration::from_millis(200),
            warmup_time: Duration::from_millis(50),
            ..Default::default()
        }
    }

    /// Time `f` repeatedly; returns and records per-iteration stats.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_bytes(name, None, move || {
            black_box(f());
        })
    }

    /// Like `bench` but annotates throughput as bytes/iteration.
    pub fn bench_bytes<T>(
        &mut self,
        name: &str,
        bytes: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_bytes(name, Some(bytes), move || {
            black_box(f());
        })
    }

    fn bench_with_bytes(
        &mut self,
        name: &str,
        bytes: Option<f64>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        // warmup + per-iteration cost estimate
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_start.elapsed() < self.warmup_time || warm_iters < 3 {
            f();
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let est = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // choose a batch size so one sample costs ~1/50 of measure_time
        let target_sample = self.measure_time.as_secs_f64() / 50.0;
        let batch = ((target_sample / est).ceil() as usize).clamp(1, self.max_iters);

        let mut samples = Vec::new();
        let mut total_iters = 0usize;
        let start = Instant::now();
        while start.elapsed() < self.measure_time && total_iters < self.max_iters {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            per_iter: summarize(&samples),
            bytes_per_iter: bytes,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// True when `cargo bench` was invoked with `--quick` (or the env var is
/// set) — used by bench mains to trim sweeps.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SMARTNIC_BENCH_QUICK").is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(30),
            warmup_time: Duration::from_millis(5),
            ..Default::default()
        };
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.per_iter.mean > 0.0);
        assert!(r.iters > 10);
    }

    #[test]
    fn throughput_annotation() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            ..Default::default()
        };
        let r = b.bench_bytes("copy", 1024.0, || vec![0u8; 1024]);
        assert_eq!(r.bytes_per_iter, Some(1024.0));
        assert!(r.report().contains("/s"));
    }
}
