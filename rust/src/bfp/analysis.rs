//! Quantization-error analysis used by the BFP-accuracy experiment (E7)
//! and the block-size/mantissa ablations: SNR, relative tensor error, and
//! parameter sweeps over the (block_size, mant_bits) design space that the
//! paper's "FPGA flexibility" argument opens up.

use super::codec::BfpCodec;

#[derive(Clone, Copy, Debug)]
pub struct QuantStats {
    /// signal-to-quantization-noise ratio in dB
    pub snr_db: f64,
    /// ||x - q|| / ||x||
    pub rel_l2: f64,
    /// max |x - q|
    pub max_abs: f64,
    /// mean |x - q|
    pub mean_abs: f64,
}

/// Measure quantization error of codec `c` over signal `x`.
pub fn measure(c: &BfpCodec, x: &[f32]) -> QuantStats {
    let q = c.quantize(x);
    let mut sig = 0f64;
    let mut noise = 0f64;
    let mut max_abs = 0f64;
    let mut sum_abs = 0f64;
    for (a, b) in x.iter().zip(&q) {
        let d = (*a - *b) as f64;
        sig += (*a as f64) * (*a as f64);
        noise += d * d;
        max_abs = max_abs.max(d.abs());
        sum_abs += d.abs();
    }
    let snr_db = if noise == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (sig / noise).log10()
    };
    QuantStats {
        snr_db,
        rel_l2: if sig == 0.0 { 0.0 } else { (noise / sig).sqrt() },
        max_abs,
        mean_abs: sum_abs / x.len().max(1) as f64,
    }
}

/// One row of the (block_size, mant_bits) ablation sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub block_size: usize,
    pub mant_bits: u32,
    pub ratio: f64,
    pub snr_db: f64,
    pub rel_l2: f64,
}

/// Sweep the BFP design space over a given signal — regenerates the
/// "tunable for different workloads" argument of Sec. IV-B.
pub fn sweep(x: &[f32], block_sizes: &[usize], mant_bits: &[u32]) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &bs in block_sizes {
        for &mb in mant_bits {
            let c = BfpCodec::new(bs, mb);
            let s = measure(&c, x);
            out.push(SweepPoint {
                block_size: bs,
                mant_bits: mb,
                ratio: c.compression_ratio(),
                snr_db: s.snr_db,
                rel_l2: s.rel_l2,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn bfp16_snr_is_high_on_gaussian() {
        // 7-bit mantissa on gaussian data: expect > 25 dB SNR
        let s = measure(&BfpCodec::bfp16(), &gaussian(1 << 14, 1));
        assert!(s.snr_db > 25.0, "snr {}", s.snr_db);
        assert!(s.rel_l2 < 0.06, "rel {}", s.rel_l2);
    }

    #[test]
    fn zero_signal_has_zero_error() {
        let s = measure(&BfpCodec::bfp16(), &vec![0f32; 256]);
        assert!(s.snr_db.is_infinite());
        assert_eq!(s.rel_l2, 0.0);
        assert_eq!(s.max_abs, 0.0);
    }

    #[test]
    fn snr_monotone_in_mantissa_bits() {
        let x = gaussian(1 << 13, 2);
        let pts = sweep(&x, &[16], &[3, 5, 7, 9]);
        for w in pts.windows(2) {
            assert!(w[1].snr_db > w[0].snr_db, "{pts:?}");
        }
    }

    #[test]
    fn snr_degrades_with_block_size() {
        // larger blocks share one exponent over more dynamic range
        let x = gaussian(1 << 13, 3);
        let pts = sweep(&x, &[4, 16, 64], &[7]);
        assert!(pts[0].snr_db >= pts[1].snr_db);
        assert!(pts[1].snr_db >= pts[2].snr_db);
    }

    #[test]
    fn ratio_improves_with_block_size() {
        let x = gaussian(256, 4);
        let pts = sweep(&x, &[4, 16, 64], &[7]);
        assert!(pts[0].ratio < pts[1].ratio);
        assert!(pts[1].ratio < pts[2].ratio);
    }
}
