//! Wire packing for BFP blocks: the exact bit layout that crosses the
//! Ethernet link between smart NICs.
//!
//! Layout per block (little-endian bit order within the stream):
//!   [ exp_bits shared exponent ][ block_size × (1 sign + mant_bits mag) ]
//!
//! For BFP16 that is 8 + 16×8 = 136 bits per 16 elements — β = 3.76×.
//! The real runtime moves gradients through this packer so the measured
//! bytes-on-wire match the analytical β exactly.

use super::codec::{BfpBlock, BfpCodec};

/// LSB-first bit stream writer with a 64-bit staging accumulator (fields
/// are <= 32 bits, so the accumulator never holds more than 63+32 bits
/// before flushing whole bytes).
struct BitWriter {
    bytes: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    fn with_capacity(cap_bytes: usize) -> Self {
        Self {
            bytes: Vec::with_capacity(cap_bytes),
            acc: 0,
            nbits: 0,
        }
    }

    #[inline]
    fn push(&mut self, value: u32, nbits: u32) {
        debug_assert!(nbits <= 32 && (nbits == 32 || value < (1 << nbits)));
        self.acc |= (value as u64) << self.nbits;
        self.nbits += nbits;
        while self.nbits >= 8 {
            self.bytes.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.bytes.push(self.acc as u8);
        }
        self.bytes
    }
}

struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Self {
            bytes,
            pos: 0,
            acc: 0,
            nbits: 0,
        }
    }

    #[inline]
    fn pull(&mut self, nbits: u32) -> Option<u32> {
        while self.nbits < nbits {
            let byte = *self.bytes.get(self.pos)?;
            self.acc |= (byte as u64) << self.nbits;
            self.nbits += 8;
            self.pos += 1;
        }
        let v = (self.acc & ((1u64 << nbits) - 1)) as u32;
        self.acc >>= nbits;
        self.nbits -= nbits;
        Some(v)
    }
}

/// Pack encoded blocks into wire bytes.
pub fn pack(codec: &BfpCodec, blocks: &[BfpBlock]) -> Vec<u8> {
    let mut w = BitWriter::with_capacity(
        (blocks.len() * codec.wire_bits_per_block()).div_ceil(8),
    );
    for b in blocks {
        w.push(b.e_shared as u32, codec.exp_bits);
        for i in 0..codec.block_size {
            w.push(
                ((b.mag[i] as u32) << 1) | b.sign[i] as u32,
                1 + codec.mant_bits,
            );
        }
    }
    w.finish()
}

/// Unpack `n_blocks` blocks from wire bytes.
pub fn unpack(codec: &BfpCodec, bytes: &[u8], n_blocks: usize) -> Option<Vec<BfpBlock>> {
    let mut r = BitReader::new(bytes);
    let mut out = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        let e_shared = r.pull(codec.exp_bits)? as u8;
        let mut sign = Vec::with_capacity(codec.block_size);
        let mut mag = Vec::with_capacity(codec.block_size);
        for _ in 0..codec.block_size {
            sign.push(r.pull(1)? as u8);
            mag.push(r.pull(codec.mant_bits)? as u8);
        }
        out.push(BfpBlock {
            e_shared,
            sign,
            mag,
        });
    }
    Some(out)
}

/// Compress a gradient slice straight to wire bytes (single pass, no
/// intermediate `BfpBlock` allocation — the hot path the NIC data plane
/// uses).
pub fn compress(codec: &BfpCodec, x: &[f32]) -> Vec<u8> {
    let bs = codec.block_size;
    let mb = codec.mant_bits;
    let max_mag = (1u32 << mb) - 1;
    let mut w = BitWriter::with_capacity(codec.wire_bytes(x.len()));
    let mut chunks = x.chunks_exact(bs);
    let block = |blk: &[f32], w: &mut BitWriter| {
        let mut e_shared: u32 = 0;
        for &v in blk {
            e_shared = e_shared.max((v.to_bits() >> 23) & 0xFF);
        }
        w.push(e_shared, codec.exp_bits);
        for &v in blk {
            let bits = v.to_bits();
            let e = (bits >> 23) & 0xFF;
            let sig = if e > 0 { (bits & 0x7F_FFFF) | 0x80_0000 } else { 0 };
            let shift = ((e_shared - e) + (24 - mb)).min(31);
            let m = ((sig + (1u32 << (shift - 1))) >> shift).min(max_mag);
            w.push((m << 1) | (bits >> 31), 1 + mb);
        }
    };
    for blk in &mut chunks {
        block(blk, &mut w);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut tmp = vec![0f32; bs];
        tmp[..rem.len()].copy_from_slice(rem);
        block(&tmp, &mut w);
    }
    w.finish()
}

/// Decompress wire bytes back to `n` f32 values (single pass).
pub fn decompress(codec: &BfpCodec, bytes: &[u8], n: usize) -> Option<Vec<f32>> {
    let bs = codec.block_size;
    let mb = codec.mant_bits;
    let n_blocks = n.div_ceil(bs);
    let mut out = Vec::with_capacity(n_blocks * bs);
    let mut r = BitReader::new(bytes);
    for _ in 0..n_blocks {
        let e_shared = r.pull(codec.exp_bits)?;
        let scale = super::codec::exp2i_pub(e_shared as i32 - 127 - (mb as i32 - 1));
        for _ in 0..bs {
            let field = r.pull(1 + mb)?;
            let m = (field >> 1) as f32;
            out.push(if field & 1 == 1 { -m } else { m } * scale);
        }
    }
    out.truncate(n);
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, gens};
    use crate::util::rng::Rng;

    #[test]
    fn pack_size_matches_wire_bytes() {
        let c = BfpCodec::bfp16();
        for n in [16usize, 32, 160, 17, 1000] {
            let x = vec![1.0f32; n];
            let bytes = compress(&c, &x);
            assert_eq!(bytes.len(), c.wire_bytes(n), "n={n}");
        }
    }

    #[test]
    fn wire_roundtrip_is_exact_quantization() {
        let c = BfpCodec::bfp16();
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..320).map(|_| rng.normal() as f32).collect();
        let bytes = compress(&c, &x);
        let back = decompress(&c, &bytes, x.len()).unwrap();
        assert_eq!(back, c.quantize(&x));
    }

    #[test]
    fn truncated_stream_fails_cleanly() {
        let c = BfpCodec::bfp16();
        let x = vec![1.0f32; 32];
        let mut bytes = compress(&c, &x);
        bytes.truncate(bytes.len() - 1);
        assert!(decompress(&c, &bytes, 32).is_none());
    }

    #[test]
    fn measured_compression_ratio() {
        let c = BfpCodec::bfp16();
        let n = 4096;
        let raw = n * 4;
        let wire = c.wire_bytes(n);
        let ratio = raw as f64 / wire as f64;
        assert!((ratio - c.compression_ratio()).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn prop_wire_roundtrip() {
        let c = BfpCodec::bfp16();
        forall(&gens::vec_f32(1..=300, 20.0), 50, |x| {
            decompress(&c, &compress(&c, x), x.len())
                .map(|back| back == c.quantize(x))
                .unwrap_or(false)
        });
    }

    #[test]
    fn odd_codec_parameters_roundtrip() {
        // block 8, 5-bit mantissa (an ablation point)
        let c = BfpCodec::new(8, 5);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..80).map(|_| rng.normal() as f32).collect();
        let back = decompress(&c, &compress(&c, &x), x.len()).unwrap();
        assert_eq!(back, c.quantize(&x));
    }
}
