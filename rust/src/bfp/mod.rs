//! Block Floating Point (BFP) — the smart NIC's wire compression
//! (paper Sec. IV-B).
//!
//! Bit-for-bit identical to the Pallas kernel in
//! `python/compile/kernels/bfp.py` (the contract is written out there);
//! golden vectors emitted by the AOT pipeline are checked in
//! `rust/tests/golden_bfp.rs`.

mod codec;
pub mod analysis;
pub mod wire;

pub use codec::{BfpCodec, BfpBlock, DEFAULT_BLOCK_SIZE, DEFAULT_MANT_BITS, DEFAULT_EXP_BITS};
