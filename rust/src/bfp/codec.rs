//! The BFP integer datapath, mirroring the FPGA compression engine.
//!
//! Specification (identical to python/compile/kernels/bfp.py):
//!
//! ```text
//! bits  = bitcast_u32(x)
//! sign  = bits >> 31
//! e     = (bits >> 23) & 0xFF                  # biased FP32 exponent
//! sig   = e > 0 ? (bits & 0x7FFFFF) | 0x800000 : 0   # flush subnormals
//! E     = max(e) over the block
//! shift = min((E - e) + (24 - mant_bits), 31)
//! m     = min((sig + (1 << (shift-1))) >> shift, 2^mant_bits - 1)
//! decode: x_hat = (-1)^sign * m * 2^(E - 127 - (mant_bits-1))
//! ```

pub const DEFAULT_BLOCK_SIZE: usize = 16;
pub const DEFAULT_MANT_BITS: u32 = 7;
pub const DEFAULT_EXP_BITS: u32 = 8;

/// One encoded block: shared exponent + per-element sign/magnitude.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BfpBlock {
    pub e_shared: u8,
    /// sign-magnitude packed as (sign << 7) | mag for mant_bits <= 7;
    /// kept unpacked here for clarity, packing happens in `wire`.
    pub sign: Vec<u8>,
    pub mag: Vec<u8>,
}

/// A (block_size, mant_bits) BFP codec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BfpCodec {
    pub block_size: usize,
    pub mant_bits: u32,
    pub exp_bits: u32,
}

impl Default for BfpCodec {
    fn default() -> Self {
        Self::bfp16()
    }
}

impl BfpCodec {
    /// The paper's BFP16: block 16, 7-bit mantissa, 8-bit shared exponent.
    pub const fn bfp16() -> Self {
        Self {
            block_size: DEFAULT_BLOCK_SIZE,
            mant_bits: DEFAULT_MANT_BITS,
            exp_bits: DEFAULT_EXP_BITS,
        }
    }

    pub const fn new(block_size: usize, mant_bits: u32) -> Self {
        Self {
            block_size,
            mant_bits,
            exp_bits: DEFAULT_EXP_BITS,
        }
    }

    /// Wire-format compression ratio β = 32·B / (B·(1+mb) + eb).
    /// BFP16 gives 512/136 ≈ 3.76 (the paper's "3.8×").
    pub fn compression_ratio(&self) -> f64 {
        (32.0 * self.block_size as f64)
            / (self.block_size as f64 * (1.0 + self.mant_bits as f64) + self.exp_bits as f64)
    }

    /// Bits per block on the wire.
    pub fn wire_bits_per_block(&self) -> usize {
        self.block_size * (1 + self.mant_bits as usize) + self.exp_bits as usize
    }

    /// Compressed wire bytes for `n` f32 elements (whole blocks, padded).
    pub fn wire_bytes(&self, n: usize) -> usize {
        let blocks = n.div_ceil(self.block_size);
        (blocks * self.wire_bits_per_block()).div_ceil(8)
    }

    // ------------------------------------------------------------------
    // Scalar-block encode/decode (the exact integer datapath)
    // ------------------------------------------------------------------

    /// Encode one block of exactly `block_size` values.
    pub fn encode_block(&self, x: &[f32]) -> BfpBlock {
        debug_assert_eq!(x.len(), self.block_size);
        let mut e_shared: u32 = 0;
        for &v in x {
            let e = (v.to_bits() >> 23) & 0xFF;
            e_shared = e_shared.max(e);
        }
        let mut sign = Vec::with_capacity(x.len());
        let mut mag = Vec::with_capacity(x.len());
        let max_mag = (1u32 << self.mant_bits) - 1;
        for &v in x {
            let bits = v.to_bits();
            let e = (bits >> 23) & 0xFF;
            let sig = if e > 0 { (bits & 0x7F_FFFF) | 0x80_0000 } else { 0 };
            let shift = ((e_shared - e) + (24 - self.mant_bits)).min(31);
            let m = ((sig + (1u32 << (shift - 1))) >> shift).min(max_mag);
            sign.push((bits >> 31) as u8);
            mag.push(m as u8);
        }
        BfpBlock {
            e_shared: e_shared as u8,
            sign,
            mag,
        }
    }

    /// Decode one block back to f32.
    pub fn decode_block(&self, b: &BfpBlock, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.block_size);
        let scale = exp2i(b.e_shared as i32 - 127 - (self.mant_bits as i32 - 1));
        for i in 0..out.len() {
            let m = b.mag[i] as f32;
            out[i] = if b.sign[i] == 1 { -m } else { m } * scale;
        }
    }

    // ------------------------------------------------------------------
    // Slice-level quantize (the hot path used by the NIC data plane)
    // ------------------------------------------------------------------

    /// In-place quantize-dequantize of a gradient slice: what the values
    /// experience crossing one compressed link.  Trailing partial block is
    /// padded with zeros (paper Sec. IV-C pads gradients), which never
    /// changes the shared exponent (a zero pad has e = 0).
    ///
    /// Hot path notes (§Perf): the integer datapath below auto-vectorizes
    /// fully under `-C target-cpu=native` (AVX-512: the 16-element block
    /// is exactly one zmm vector) and measured *faster* than a
    /// bit-equivalent float-multiply formulation (4.55 vs 4.34 GB/s), so
    /// one code path is kept — the same integer pipeline the FPGA RTL
    /// implements.
    pub fn quantize_slice(&self, x: &mut [f32]) {
        let bs = self.block_size;
        let len = x.len();
        let mut i = 0;
        while i + bs <= len {
            let blk = &mut x[i..i + bs];
            // pass 1: shared exponent
            let mut e_shared: u32 = 0;
            for &v in blk.iter() {
                e_shared = e_shared.max((v.to_bits() >> 23) & 0xFF);
            }
            self.quantize_block_int(blk, e_shared);
            i += bs;
        }
        if i < len {
            // trailing partial block: pad conceptually with zeros
            let rem = len - i;
            let mut tmp = vec![0f32; bs];
            tmp[..rem].copy_from_slice(&x[i..]);
            let b = self.encode_block(&tmp);
            let mut dec = vec![0f32; bs];
            self.decode_block(&b, &mut dec);
            x[i..].copy_from_slice(&dec[..rem]);
        }
    }

    /// Integer-datapath quantization of one block (the edge-case fallback
    /// and the reference the fast path is checked against).
    fn quantize_block_int(&self, blk: &mut [f32], e_shared: u32) {
        let mb = self.mant_bits;
        let max_mag = (1u32 << mb) - 1;
        let scale = exp2i(e_shared as i32 - 127 - (mb as i32 - 1));
        for v in blk.iter_mut() {
            let bits = v.to_bits();
            let e = (bits >> 23) & 0xFF;
            let sig = if e > 0 { (bits & 0x7F_FFFF) | 0x80_0000 } else { 0 };
            let shift = ((e_shared - e) + (24 - mb)).min(31);
            let m = ((sig + (1u32 << (shift - 1))) >> shift).min(max_mag) as f32;
            *v = if bits >> 31 == 1 { -m } else { m } * scale;
        }
    }

    /// Out-of-place version.
    pub fn quantize(&self, x: &[f32]) -> Vec<f32> {
        let mut out = x.to_vec();
        self.quantize_slice(&mut out);
        out
    }

    /// Encode a slice into blocks (padding the tail with zeros).
    pub fn encode(&self, x: &[f32]) -> Vec<BfpBlock> {
        let bs = self.block_size;
        let mut out = Vec::with_capacity(x.len().div_ceil(bs));
        let mut i = 0;
        while i + bs <= x.len() {
            out.push(self.encode_block(&x[i..i + bs]));
            i += bs;
        }
        if i < x.len() {
            let mut tmp = vec![0f32; bs];
            tmp[..x.len() - i].copy_from_slice(&x[i..]);
            out.push(self.encode_block(&tmp));
        }
        out
    }

    /// Decode blocks into `n` values (dropping tail padding).
    pub fn decode(&self, blocks: &[BfpBlock], n: usize) -> Vec<f32> {
        let bs = self.block_size;
        let mut out = vec![0f32; blocks.len() * bs];
        for (i, b) in blocks.iter().enumerate() {
            self.decode_block(b, &mut out[i * bs..(i + 1) * bs]);
        }
        out.truncate(n);
        out
    }

    /// Worst-case absolute error of one quantized element given the block's
    /// shared exponent: half a quantization step (plus one step for the
    /// saturated max element).
    pub fn error_bound(&self, e_shared: u8) -> f32 {
        2.0 * exp2i(e_shared as i32 - 127 - self.mant_bits as i32)
    }
}

/// Crate-internal exact 2^k (used by the wire fast path).
#[inline]
pub(crate) fn exp2i_pub(k: i32) -> f32 {
    exp2i(k)
}

/// 2^k as f32 for the full f32 exponent range (including subnormal results).
#[inline]
fn exp2i(k: i32) -> f32 {
    if k >= -126 {
        f32::from_bits(((k + 127) as u32) << 23)
    } else {
        // subnormal or underflow-to-zero range: go through f64
        (k as f64).exp2() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, gens};
    use crate::util::rng::Rng;

    #[test]
    fn ratio_is_papers_3p8() {
        let c = BfpCodec::bfp16();
        assert!((c.compression_ratio() - 512.0 / 136.0).abs() < 1e-12);
        assert_eq!(format!("{:.1}", c.compression_ratio()), "3.8");
        assert_eq!(c.wire_bits_per_block(), 136);
    }

    #[test]
    fn zeros_stay_zero() {
        let c = BfpCodec::bfp16();
        let x = vec![0f32; 16];
        assert_eq!(c.quantize(&x), x);
    }

    #[test]
    fn exact_powers_of_two_roundtrip() {
        // values with <= 7 significant bits relative to the block max are
        // representable exactly when aligned
        let c = BfpCodec::bfp16();
        let x: Vec<f32> = (0..16).map(|i| if i < 8 { 1.0 } else { 0.5 }).collect();
        assert_eq!(c.quantize(&x), x);
    }

    #[test]
    fn max_element_relative_error() {
        let c = BfpCodec::bfp16();
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let x: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            let q = c.quantize(&x);
            let (i, &xm) = x
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().total_cmp(&b.1.abs()))
                .unwrap();
            let rel = (q[i] - xm).abs() / xm.abs();
            assert!(rel <= 2.0f32.powi(-7) + 1e-6, "rel {rel} at {xm}");
        }
    }

    #[test]
    fn error_bound_holds() {
        let c = BfpCodec::bfp16();
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let x: Vec<f32> = (0..16)
                .map(|_| rng.normal() as f32 * (rng.range_f64(-20.0, 20.0) as f32).exp2())
                .collect();
            let blocks = c.encode(&x);
            let q = c.decode(&blocks, 16);
            let bound = c.error_bound(blocks[0].e_shared);
            for (a, b) in x.iter().zip(&q) {
                assert!((a - b).abs() <= bound, "{a} -> {b}, bound {bound}");
            }
        }
    }

    #[test]
    fn idempotent() {
        let c = BfpCodec::bfp16();
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let once = c.quantize(&x);
        let twice = c.quantize(&once);
        assert_eq!(once, twice);
    }

    #[test]
    fn subnormals_flush_to_zero() {
        let c = BfpCodec::bfp16();
        let x = vec![1e-41f32; 16];
        assert!(c.quantize(&x).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn signs_preserved() {
        let c = BfpCodec::bfp16();
        let x: Vec<f32> = (0..16).map(|i| if i % 2 == 0 { 1.5 } else { -1.5 }).collect();
        let q = c.quantize(&x);
        for (a, b) in x.iter().zip(&q) {
            assert_eq!(a.signum(), b.signum());
        }
    }

    #[test]
    fn partial_tail_block() {
        let c = BfpCodec::bfp16();
        let x: Vec<f32> = (0..19).map(|i| i as f32 * 0.25).collect();
        let q = c.quantize(&x);
        assert_eq!(q.len(), 19);
        // first block exact multiples survive; tail decodes near-exactly
        for (a, b) in x.iter().zip(&q) {
            assert!((a - b).abs() <= 0.2, "{a} vs {b}");
        }
    }

    #[test]
    fn encode_decode_matches_quantize() {
        let c = BfpCodec::bfp16();
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..160).map(|_| rng.normal() as f32).collect();
        let via_blocks = c.decode(&c.encode(&x), x.len());
        assert_eq!(via_blocks, c.quantize(&x));
    }

    #[test]
    fn more_mantissa_bits_less_error() {
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let mut prev = f64::INFINITY;
        for mb in [3u32, 5, 7, 9] {
            let c = BfpCodec::new(16, mb);
            let q = c.quantize(&x);
            let err: f64 = x
                .iter()
                .zip(&q)
                .map(|(a, b)| ((a - b) as f64).abs())
                .sum();
            assert!(err <= prev, "mb {mb}: {err} > {prev}");
            prev = err;
        }
    }

    #[test]
    fn exp2i_edges() {
        assert_eq!(exp2i(0), 1.0);
        assert_eq!(exp2i(-126), f32::MIN_POSITIVE);
        assert_eq!(exp2i(10), 1024.0);
        assert!(exp2i(-140) > 0.0 || exp2i(-140) == 0.0); // subnormal path
        assert_eq!(exp2i(-133), 2.0f64.powi(-133) as f32);
    }

    #[test]
    fn prop_error_bound_any_magnitude() {
        let c = BfpCodec::bfp16();
        forall(&gens::vec_f32(16..=160, 30.0), 60, |x| {
            let q = c.quantize(x);
            x.iter().zip(&q).all(|(a, b)| {
                let blk_max = x
                    .iter()
                    .map(|v| v.abs())
                    .fold(0f32, f32::max);
                (a - b).abs() <= blk_max * 2.0f32.powi(-6) + 1e-30
            })
        });
    }

    #[test]
    fn fast_path_matches_integer_path_bitexact() {
        // adversarial magnitudes across the E = 8 fallback boundary,
        // subnormals, zeros, huge values, sign mixes
        let c = BfpCodec::bfp16();
        let mut rng = Rng::new(99);
        for trial in 0..500 {
            let x: Vec<f32> = (0..16)
                .map(|_| {
                    let kind = rng.below(6);
                    let v = match kind {
                        0 => 0.0,
                        1 => (rng.normal() as f32) * 1e-41, // subnormal
                        2 => (rng.normal() as f32) * f32::MIN_POSITIVE,
                        3 => (rng.normal() as f32)
                            * (rng.range_f64(-126.0, 127.0) as f32).exp2(),
                        4 => (rng.normal() as f32) * 1e37,
                        _ => rng.normal() as f32,
                    };
                    if rng.below(2) == 0 {
                        -v
                    } else {
                        v
                    }
                })
                .collect();
            // integer reference: encode+decode (pure integer datapath)
            let want = c.decode(&c.encode(&x), 16);
            // production path (fast float path where eligible)
            let got = c.quantize(&x);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "trial {trial} elem {i}: {g:e} vs {w:e} (x={:e})",
                    x[i]
                );
            }
        }
    }

    #[test]
    fn prop_quantize_preserves_length_and_finiteness() {
        let c = BfpCodec::bfp16();
        forall(&gens::vec_f32(1..=200, 30.0), 100, |x| {
            let q = c.quantize(x);
            q.len() == x.len() && q.iter().all(|v| v.is_finite())
        });
    }
}
