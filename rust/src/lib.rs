//! # ai-smartnic
//!
//! A production-quality reproduction of **"FPGA-based AI Smart NICs for
//! Scalable Distributed AI Training Systems"** (Ma, Georganas, Heinecke,
//! Boutros, Nurvitadhi — Intel, 2022).
//!
//! The paper offloads the all-reduce of data-parallel DNN training from
//! worker CPUs to FPGA smart NICs that also compress gradients to block
//! floating point (BFP16) on the wire.  This crate rebuilds the entire
//! system as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator, the
//!   smart-NIC datapath (ring all-reduce + BFP codec), the unified
//!   cluster simulator, the Sec. IV-C analytical model, and every
//!   experiment harness (Figs. 2a/2b/4a/4b, Table I).
//! * **L2 (python/compile/model.py, build-time)** — the 20-layer MLP
//!   fwd/bwd as layerwise JAX entry points, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/, build-time)** — Pallas kernels: the
//!   MXU-tiled matmul, the BFP compress/decompress datapath, and the NIC
//!   FP32 adder.
//!
//! ## Simulation architecture: one typed-event engine
//!
//! Everything dynamic runs as typed events on a single calendar-queue
//! executive ([`netsim::engine::Sim`] — an index-arena of compact
//! [`cluster::Event`]s ordered by a bucketed wheel with heap overflow,
//! dispatched by [`netsim::engine::World::handle`]'s match loop) over
//! one shared resource world ([`netsim::fabric::Fabric`]: per-node Tx
//! links, PCIe lanes, FPGA adders, host comm cores, plus a
//! topology-shaped cut-through interconnect — one flat crossbar or an
//! oversubscribed leaf–spine fabric, per
//! [`netsim::topology::Topology`]):
//!
//! * [`cluster::collective`] — the NIC ring datapath (PCIe fetch → FP32
//!   adder → Tx → switch → writeback, segment-pipelined), NIC-offloaded
//!   binomial/Rabenseifner rounds, and host/MPI software schemes, all as
//!   events contending FIFO for the fabric;
//! * [`cluster::job`] — the event-driven trainer: the Fig. 3b layerwise
//!   schedule posting *non-blocking* all-reduces that execute concurrently
//!   with backward compute and with each other;
//! * [`cluster::scenario`] — multi-tenant runs: several training jobs on
//!   one switch fabric, per-layer algorithm selection, and straggler /
//!   degraded-link injection that hits every in-flight collective;
//! * [`coordinator::unified`] — the single-job iteration entry point on
//!   that engine.
//!
//! The original serialized pipeline (one ring at a time, max-plus
//! composed) is retained as the compatibility path —
//! [`nic::simulate_ring_allreduce`] and [`coordinator::simulate`] — since
//! the Sec. IV-C closed form assumes exactly those semantics; experiment
//! E6 ([`analytic::validate`]) holds model, serialized path and unified
//! engine together within the paper's 3% at the paper's operating points.
//!
//! Python never runs at training time: the Rust runtime loads the AOT
//! artifacts through PJRT (`runtime`) and drives them from the training
//! loop (`coordinator::trainer`).
//!
//! New contributors: `docs/ARCHITECTURE.md` walks the module map, the
//! schedule → reserve → release event lifecycle, the five collective
//! plan families and the closed-form pairings; `docs/BENCHMARKS.md`
//! documents the three CI benchmark artifacts and their gates.
//!
//! ## Quickstart: one training job on the unified engine
//!
//! ```
//! use ai_smartnic::analytic::model::SystemKind;
//! use ai_smartnic::cluster::{run_scenario, ClusterSpec, JobSpec};
//! use ai_smartnic::sysconfig::{SystemParams, Workload};
//!
//! // a 6-node smart-NIC cluster (flat crossbar), one 2-layer job
//! let sys = SystemParams::smartnic_40g();
//! let w = Workload { layers: 2, hidden: 256, batch_per_node: 32 };
//! let spec = ClusterSpec::new(sys, 6).with_job(JobSpec::new(
//!     "j0",
//!     SystemKind::SmartNic { bfp: true },
//!     w,
//!     (0..6).collect(),
//! ));
//! let out = run_scenario(&spec);
//! assert_eq!(out.jobs[0].ar_count, 2); // one all-reduce per layer
//! assert!(out.jobs[0].duration > 0.0);
//! assert!(out.events > 0 && out.peak_queue_depth > 0);
//! ```

// Lint policy (docs/INVARIANTS.md, "Correctness tooling"): any `unsafe`
// an unsafe fn touches must be an explicit block, every unsafe block and
// impl carries a `// SAFETY:` comment, and float (in-)equality is only
// written where exactness is proven (and locally allowed).  The
// project's own determinism lints live in `smartnic-lint`
// (rust/src/bin/lint.rs).
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::float_cmp)]
#![warn(clippy::undocumented_unsafe_blocks)]

// `unsafe` is confined to two modules: `netsim` (the engine's
// shared-state window executive) and `cluster` (its `PartitionedWorld`
// impl).  Every other subtree forbids it outright.
#[forbid(unsafe_code)]
pub mod analytic;
#[forbid(unsafe_code)]
pub mod benchkit;
#[forbid(unsafe_code)]
pub mod bfp;
pub mod cluster;
#[forbid(unsafe_code)]
pub mod collective;
#[forbid(unsafe_code)]
pub mod coordinator;
#[forbid(unsafe_code)]
pub mod experiments;
pub mod netsim;
#[forbid(unsafe_code)]
pub mod nic;
#[forbid(unsafe_code)]
pub mod prop;
#[forbid(unsafe_code)]
pub mod runtime;
#[forbid(unsafe_code)]
pub mod sysconfig;
#[forbid(unsafe_code)]
pub mod trace;
#[forbid(unsafe_code)]
pub mod util;
