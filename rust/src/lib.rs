//! # ai-smartnic
//!
//! A production-quality reproduction of **"FPGA-based AI Smart NICs for
//! Scalable Distributed AI Training Systems"** (Ma, Georganas, Heinecke,
//! Boutros, Nurvitadhi — Intel, 2022).
//!
//! The paper offloads the all-reduce of data-parallel DNN training from
//! worker CPUs to FPGA smart NICs that also compress gradients to block
//! floating point (BFP16) on the wire.  This crate rebuilds the entire
//! system as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: worker
//!   orchestration, the Fig. 3b layerwise overlap schedule, the smart-NIC
//!   datapath (ring all-reduce + BFP codec), a discrete-event simulator of
//!   the 6→32-node cluster, the Sec. IV-C analytical model, and every
//!   experiment harness (Figs. 2a/2b/4a/4b, Table I).
//! * **L2 (python/compile/model.py, build-time)** — the 20-layer MLP
//!   fwd/bwd as layerwise JAX entry points, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/, build-time)** — Pallas kernels: the
//!   MXU-tiled matmul, the BFP compress/decompress datapath, and the NIC
//!   FP32 adder.
//!
//! Python never runs at training time: the Rust runtime loads the AOT
//! artifacts through PJRT (`runtime`) and drives them from the training
//! loop (`coordinator::trainer`).

pub mod analytic;
pub mod benchkit;
pub mod bfp;
pub mod collective;
pub mod coordinator;
pub mod netsim;
pub mod nic;
pub mod prop;
pub mod runtime;
pub mod sysconfig;
pub mod trace;
pub mod util;
pub mod experiments;
