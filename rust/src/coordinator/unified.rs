//! One training iteration on the unified cluster engine.
//!
//! Same inputs and outputs as [`simulate_iteration`] (the serialized
//! compatibility path), but communication is executed by the event engine:
//! each layer's all-reduce is posted non-blocking and runs concurrently
//! with later layers' compute *and* with the job's other in-flight
//! all-reduces, sharing the fabric's links, PCIe lanes and adders.
//!
//! Relationship between the engines:
//! * a single uncontended ring performs identical arithmetic in both, so
//!   when all-reduces never queue (the paper's B=1792 operating point)
//!   the two agree to float precision;
//! * when all-reduces do queue, the serialized path processes them one at
//!   a time while the unified engine lets them share resources FIFO.
//!   Both are work-conserving on the bottleneck resource, so per-iteration
//!   times stay within a few percent wherever a resource saturates, and
//!   the unified engine is (correctly) faster where the serialized path's
//!   one-ring-at-a-time assumption wasted pipeline opportunity.
//!
//! [`simulate_iteration`]: super::simulate_iteration

use super::simulate::SimOutput;
use crate::analytic::model::{layer_times, IterationBreakdown, SystemKind};
use crate::cluster::{run_scenario_on, ClusterSpec, EngineKind, JobSpec};
use crate::sysconfig::{ClusterFaults, SystemParams, Workload};

/// Simulate one training iteration of `w` on `n` nodes under `kind`,
/// executing all communication on the unified event engine.
pub fn simulate_iteration_unified(
    kind: SystemKind,
    sys: &SystemParams,
    w: &Workload,
    n: usize,
) -> SimOutput {
    simulate_iteration_unified_faulty(kind, sys, w, n, &ClusterFaults::none())
}

/// [`simulate_iteration_unified`] with cluster-level fault injection.
pub fn simulate_iteration_unified_faulty(
    kind: SystemKind,
    sys: &SystemParams,
    w: &Workload,
    n: usize,
    faults: &ClusterFaults,
) -> SimOutput {
    simulate_iteration_unified_on(kind, sys, w, n, faults, EngineKind::Typed)
}

/// [`simulate_iteration_unified_faulty`] on an explicit engine backend —
/// the cross-engine equivalence suite (`rust/tests/engine_equiv.rs`)
/// pins the typed engine to the boxed-closure baseline at the paper's
/// E6 operating points through this entry.
pub fn simulate_iteration_unified_on(
    kind: SystemKind,
    sys: &SystemParams,
    w: &Workload,
    n: usize,
    faults: &ClusterFaults,
    engine: EngineKind,
) -> SimOutput {
    let spec = ClusterSpec::new(*sys, n)
        .with_faults(faults.clone())
        .with_job(JobSpec::new("j0", kind, *w, (0..n).collect()));
    let out = run_scenario_on(&spec, engine);
    let job = &out.jobs[0];

    let lt = layer_times(kind, sys, w, n);
    let l = w.layers as f64;
    let fwd = lt.t_f * l;
    let bwd = lt.t_b * l;
    let upd = lt.t_u * l;
    let t_total = job.duration;
    let breakdown = IterationBreakdown {
        t_fwd: fwd,
        t_bwd: bwd,
        t_update: upd,
        t_exposed_ar: (t_total - fwd - bwd - upd).max(0.0),
        t_total,
        t_ar_raw: job.mean_ar * l,
    };
    SimOutput {
        breakdown,
        trace: out.trace,
        t_ar_layer: job.mean_ar,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Scheme;
    use crate::coordinator::simulate_iteration;
    use crate::util::stats::rel_err;

    #[test]
    fn e6_parity_at_paper_operating_point() {
        // B=1792 at 6 nodes: every all-reduce is hidden behind the next
        // layer's backward, so at most one is in flight and the unified
        // engine must reproduce the serialized path within the paper's 3%
        let sys = SystemParams::smartnic_40g();
        let w = Workload::paper_mlp(1792);
        for bfp in [false, true] {
            let kind = SystemKind::SmartNic { bfp };
            let ser = simulate_iteration(kind, &sys, &w, 6).breakdown.t_total;
            let uni = simulate_iteration_unified(kind, &sys, &w, 6)
                .breakdown
                .t_total;
            let err = rel_err(ser, uni);
            assert!(
                err < 0.03,
                "bfp={bfp}: serialized {ser} unified {uni} err {:.2}%",
                err * 100.0
            );
        }
    }

    #[test]
    fn e6_parity_when_ethernet_saturates() {
        // B=448 raw FP32: the Tx links saturate, and a saturated FIFO
        // resource is work-conserving under either engine — the iteration
        // times must again agree within 3%
        let sys = SystemParams::smartnic_40g();
        let w = Workload::paper_mlp(448);
        let kind = SystemKind::SmartNic { bfp: false };
        let ser = simulate_iteration(kind, &sys, &w, 6).breakdown.t_total;
        let uni = simulate_iteration_unified(kind, &sys, &w, 6)
            .breakdown
            .t_total;
        let err = rel_err(ser, uni);
        assert!(
            err < 0.03,
            "serialized {ser} unified {uni} err {:.2}%",
            err * 100.0
        );
    }

    #[test]
    fn concurrency_only_ever_helps() {
        // wherever no single resource saturates (B=448 + BFP: PCIe and
        // adder both have headroom between posts), overlapping all-reduces
        // pipeline latency the serialized path exposes — the unified time
        // may only be faster, and not implausibly so
        let sys = SystemParams::smartnic_40g();
        let w = Workload::paper_mlp(448);
        for bfp in [false, true] {
            let kind = SystemKind::SmartNic { bfp };
            let ser = simulate_iteration(kind, &sys, &w, 6).breakdown.t_total;
            let uni = simulate_iteration_unified(kind, &sys, &w, 6)
                .breakdown
                .t_total;
            assert!(
                uni <= ser * 1.03,
                "bfp={bfp}: unified {uni} slower than serialized {ser}"
            );
            assert!(
                uni >= ser * 0.75,
                "bfp={bfp}: unified {uni} implausibly fast vs {ser}"
            );
        }
    }

    #[test]
    fn overlapped_baseline_parity() {
        let sys = SystemParams::baseline_100g();
        let w = Workload::paper_mlp(1792);
        let kind = SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 };
        let ser = simulate_iteration(kind, &sys, &w, 6).breakdown.t_total;
        let uni = simulate_iteration_unified(kind, &sys, &w, 6)
            .breakdown
            .t_total;
        let err = rel_err(ser, uni);
        assert!(err < 0.02, "serialized {ser} unified {uni} err {:.2}%", err * 100.0);
    }

    #[test]
    fn concurrent_all_reduces_are_visible() {
        // B=448 raw: AR latency (≈5.7 ms) exceeds the compute between
        // posts (≈3.1 ms), so at least two rings must be in flight
        let sys = SystemParams::smartnic_40g();
        let w = Workload::paper_mlp(448);
        let out = simulate_iteration_unified(SystemKind::SmartNic { bfp: false }, &sys, &w, 6);
        assert!(
            out.trace.max_concurrent("ar") >= 2,
            "expected overlapping all-reduces, got {}",
            out.trace.max_concurrent("ar")
        );
    }

    #[test]
    fn serialized_engine_never_overlaps() {
        // the compatibility path keeps its one-ring-at-a-time semantics
        let sys = SystemParams::smartnic_40g();
        let w = Workload::paper_mlp(448);
        let out = simulate_iteration(SystemKind::SmartNic { bfp: false }, &sys, &w, 6);
        assert!(out.trace.max_concurrent("ar") <= 1);
    }

    #[test]
    fn unified_fault_injection_slows_iteration() {
        let sys = SystemParams::smartnic_40g();
        let w = Workload::paper_mlp(448);
        let kind = SystemKind::SmartNic { bfp: false };
        let healthy = simulate_iteration_unified(kind, &sys, &w, 6)
            .breakdown
            .t_total;
        let faults = ClusterFaults::none().with_degraded_link(2, 0.25);
        let degraded = simulate_iteration_unified_faulty(kind, &sys, &w, 6, &faults)
            .breakdown
            .t_total;
        assert!(
            degraded > healthy * 1.5,
            "degraded {degraded} vs healthy {healthy}"
        );
    }
}
