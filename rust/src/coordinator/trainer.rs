//! The real multi-worker training runtime.
//!
//! Workers are logical data-parallel ranks; each executes the AOT-compiled
//! layerwise fwd/bwd/update artifacts through PJRT, and per-layer weight
//! gradients flow through the *real* ring all-reduce
//! (`collective::data::ring_allreduce`) with optional BFP16 wire
//! quantization — the full numeric path of the paper's system, end to end.
//!
//! PJRT executables are not Send, so ranks execute round-robin on the
//! coordinator thread (deterministic; on this 1-core testbed that is also
//! the fastest schedule).  Weights stay bit-identical across ranks by
//! construction (identical init + identical reduced gradients), which the
//! trainer asserts every step.

use crate::bfp::BfpCodec;
use crate::collective::data::ring_allreduce;
use crate::runtime::{Engine, Tensor};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::time::Instant;

/// Gradient exchange backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArBackend {
    /// lossless FP32 ring all-reduce (baseline and plain smart NIC)
    Fp32,
    /// smart NIC with BFP16 wire compression
    Bfp16,
}

impl ArBackend {
    fn codec(&self) -> Option<BfpCodec> {
        match self {
            ArBackend::Fp32 => None,
            ArBackend::Bfp16 => Some(BfpCodec::bfp16()),
        }
    }
}

/// Weight-update rule (paper Sec. I cites both SGD and Adam [3]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Optimizer {
    #[default]
    Sgd,
    Adam,
}

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub layers: usize,
    pub hidden: usize,
    pub batch_per_worker: usize,
    pub workers: usize,
    pub lr: f32,
    pub seed: u64,
    pub backend: ArBackend,
    pub optimizer: Optimizer,
}

impl TrainerConfig {
    pub fn validate(&self) -> Result<()> {
        if self.layers < 2 {
            return Err(anyhow!("need >= 2 layers (hidden + linear output)"));
        }
        if self.workers < 1 {
            return Err(anyhow!("need >= 1 worker"));
        }
        Ok(())
    }
}

/// Per-step statistics.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub step: usize,
    pub loss: f64,
    /// wall-clock split of the step
    pub t_fwd: f64,
    pub t_bwd: f64,
    pub t_allreduce: f64,
    pub t_update: f64,
    /// bytes that crossed the (virtual) wire per node this step
    pub wire_bytes_per_node: f64,
}

struct WorkerData {
    /// fixed synthetic mini-batch (tiny-corpus regime)
    x: Tensor,
    target: Tensor,
}

/// The coordinator-owned trainer.
pub struct Trainer {
    pub cfg: TrainerConfig,
    engine: Engine,
    /// shared (replicated) parameters — identical across ranks
    ws: Vec<Tensor>,
    bs: Vec<Tensor>,
    adam: Option<AdamState>,
    workers: Vec<WorkerData>,
    step_no: usize,
    names: Names,
}

struct Names {
    fwd: String,
    fwd_linear: String,
    bwd: String,
    bwd_linear: String,
    loss: String,
    sgd: String,
    sgd_vec: String,
    adam: String,
    adam_vec: String,
}

/// Adam first/second-moment state (per layer, weights + biases).
struct AdamState {
    mw: Vec<Tensor>,
    vw: Vec<Tensor>,
    mb: Vec<Tensor>,
    vb: Vec<Tensor>,
}

impl Trainer {
    /// Build a trainer over the artifact directory.  Requires artifacts
    /// for the (hidden, batch_per_worker) pair to exist in the manifest.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>, cfg: TrainerConfig) -> Result<Trainer> {
        cfg.validate()?;
        let engine = Engine::open(artifact_dir)?;
        let (m, b) = (cfg.hidden, cfg.batch_per_worker);
        let names = Names {
            fwd: format!("layer_fwd_m{m}_b{b}"),
            fwd_linear: format!("layer_fwd_linear_m{m}_b{b}"),
            bwd: format!("layer_bwd_m{m}_b{b}"),
            bwd_linear: format!("layer_bwd_linear_m{m}_b{b}"),
            loss: format!("mse_loss_grad_m{m}_b{b}"),
            sgd: format!("sgd_update_m{m}"),
            sgd_vec: format!("sgd_update_vec_m{m}"),
            adam: format!("adam_update_m{m}"),
            adam_vec: format!("adam_update_vec_m{m}"),
        };
        // fail fast if any artifact is missing
        let mut required = vec![
            &names.fwd,
            &names.fwd_linear,
            &names.bwd,
            &names.bwd_linear,
            &names.loss,
            &names.sgd,
            &names.sgd_vec,
        ];
        if cfg.optimizer == Optimizer::Adam {
            required.push(&names.adam);
            required.push(&names.adam_vec);
        }
        for n in required {
            engine.manifest.get(n)?;
        }

        let mut rng = Rng::new(cfg.seed);
        let scale = (2.0 / m as f64).sqrt() as f32;
        let ws: Vec<Tensor> = (0..cfg.layers)
            .map(|_| Tensor::randn(&[m, m], scale, &mut rng))
            .collect();
        let bs: Vec<Tensor> = (0..cfg.layers).map(|_| Tensor::zeros(&[1, m])).collect();

        // fixed synthetic regression task: targets from a random linear
        // teacher of the inputs (+ noise), one fixed batch per worker
        let teacher = Tensor::randn(&[m, m], (1.0 / m as f64).sqrt() as f32, &mut rng);
        let workers = (0..cfg.workers)
            .map(|wi| {
                let mut wrng = rng.fork(wi as u64);
                let x = Tensor::randn(&[b, m], 1.0, &mut wrng);
                let mut target = Tensor::zeros(&[b, m]);
                // target = x @ teacher + 0.01*noise (host-side, init only)
                for r in 0..b {
                    for c in 0..m {
                        let mut acc = 0f32;
                        for k in 0..m {
                            acc += x.data[r * m + k] * teacher.data[k * m + c];
                        }
                        target.data[r * m + c] = acc + 0.01 * wrng.normal() as f32;
                    }
                }
                WorkerData { x, target }
            })
            .collect();

        let adam = (cfg.optimizer == Optimizer::Adam).then(|| AdamState {
            mw: (0..cfg.layers).map(|_| Tensor::zeros(&[m, m])).collect(),
            vw: (0..cfg.layers).map(|_| Tensor::zeros(&[m, m])).collect(),
            mb: (0..cfg.layers).map(|_| Tensor::zeros(&[1, m])).collect(),
            vb: (0..cfg.layers).map(|_| Tensor::zeros(&[1, m])).collect(),
        });

        Ok(Trainer {
            cfg,
            engine,
            ws,
            bs,
            adam,
            workers,
            step_no: 0,
            names,
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Current parameter L2 norm (for monitoring).
    pub fn weight_norm(&self) -> f64 {
        self.ws.iter().map(|w| w.norm().powi(2)).sum::<f64>().sqrt()
    }

    /// Serialize replicated model state (bit-exact: f32s as u32 bit
    /// patterns) + step counter to a JSON checkpoint.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        use crate::util::json::Json;
        let enc = |t: &Tensor| {
            Json::obj(vec![
                (
                    "shape",
                    Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
                ),
                (
                    "bits",
                    Json::Arr(
                        t.data
                            .iter()
                            .map(|v| Json::Num(v.to_bits() as f64))
                            .collect(),
                    ),
                ),
            ])
        };
        let j = Json::obj(vec![
            ("format", Json::Num(1.0)),
            ("step", Json::Num(self.step_no as f64)),
            ("layers", Json::Num(self.cfg.layers as f64)),
            ("hidden", Json::Num(self.cfg.hidden as f64)),
            ("ws", Json::Arr(self.ws.iter().map(enc).collect())),
            ("bs", Json::Arr(self.bs.iter().map(enc).collect())),
        ]);
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, j.to_string())?;
        Ok(())
    }

    /// Restore model state from a checkpoint written by `save_checkpoint`.
    /// The trainer must have been constructed with the same (layers,
    /// hidden) config; worker data is regenerated from the config seed.
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        use crate::util::json::Json;
        let text = std::fs::read_to_string(&path)?;
        let j = Json::parse(&text).map_err(|e| anyhow!("checkpoint: {e}"))?;
        let layers = j.get("layers").and_then(|v| v.as_usize());
        let hidden = j.get("hidden").and_then(|v| v.as_usize());
        if layers != Some(self.cfg.layers) || hidden != Some(self.cfg.hidden) {
            return Err(anyhow!(
                "checkpoint shape ({layers:?}, {hidden:?}) != config ({}, {})",
                self.cfg.layers,
                self.cfg.hidden
            ));
        }
        let dec = |v: &Json| -> Result<Tensor> {
            let shape = v
                .get("shape")
                .and_then(|s| s.num_vec(|x| x as usize))
                .ok_or_else(|| anyhow!("bad tensor shape"))?;
            let data = v
                .get("bits")
                .and_then(|b| b.num_vec(|x| f32::from_bits(x as u32)))
                .ok_or_else(|| anyhow!("bad tensor bits"))?;
            Ok(Tensor::new(shape, data))
        };
        let ws = j
            .get("ws")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("missing ws"))?
            .iter()
            .map(dec)
            .collect::<Result<Vec<_>>>()?;
        let bs = j
            .get("bs")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("missing bs"))?
            .iter()
            .map(dec)
            .collect::<Result<Vec<_>>>()?;
        self.ws = ws;
        self.bs = bs;
        self.step_no = j.get("step").and_then(|v| v.as_usize()).unwrap_or(0);
        Ok(())
    }

    pub fn step_count(&self) -> usize {
        self.step_no
    }

    /// Run one synchronous data-parallel training step; returns stats.
    pub fn step(&mut self) -> Result<StepStats> {
        let l = self.cfg.layers;
        let n = self.cfg.workers;
        let m = self.cfg.hidden;
        let codec = self.cfg.backend.codec();

        let mut t_fwd = 0.0;
        let mut t_bwd = 0.0;
        let mut t_ar = 0.0;
        let mut t_upd = 0.0;
        let mut wire = 0.0f64;

        // ---- forward + loss, per worker -----------------------------
        let t0 = Instant::now();
        // acts[w][i] = input to layer i; zs[w][i] = pre-activation
        let mut acts: Vec<Vec<Tensor>> = Vec::with_capacity(n);
        let mut zs: Vec<Vec<Tensor>> = Vec::with_capacity(n);
        let mut dys: Vec<Tensor> = Vec::with_capacity(n);
        let mut loss_sum = 0f64;
        for wd in &self.workers {
            let mut a = vec![wd.x.clone()];
            let mut z = Vec::with_capacity(l - 1);
            for i in 0..l - 1 {
                let out = self.engine.run(
                    &self.names.fwd,
                    &[a.last().unwrap(), &self.ws[i], &bias_vec(&self.bs[i])],
                )?;
                let [y, zz]: [Tensor; 2] = out
                    .try_into()
                    .map_err(|_| anyhow!("layer_fwd arity"))?;
                a.push(y);
                z.push(zz);
            }
            let out = self.engine.run(
                &self.names.fwd_linear,
                &[a.last().unwrap(), &self.ws[l - 1], &bias_vec(&self.bs[l - 1])],
            )?;
            let y = out.into_iter().next().unwrap();
            let lg = self.engine.run(&self.names.loss, &[&y, &wd.target])?;
            let mut it = lg.into_iter();
            let loss = it.next().unwrap();
            let dy = it.next().unwrap();
            loss_sum += loss.data[0] as f64;
            acts.push(a);
            zs.push(z);
            dys.push(dy);
        }
        t_fwd += t0.elapsed().as_secs_f64();

        // ---- backward, layer by layer, with per-layer all-reduce ----
        // (the Fig. 3b order: bwd of layer i, then AR of its gradients)
        let mut dws: Vec<Vec<Option<Tensor>>> = (0..n).map(|_| vec![None; l]).collect();
        let mut dbs: Vec<Vec<Option<Tensor>>> = (0..n).map(|_| vec![None; l]).collect();
        for i in (0..l).rev() {
            let tb = Instant::now();
            for wk in 0..n {
                let (dx, dw, db) = if i == l - 1 {
                    let out = self.engine.run(
                        &self.names.bwd_linear,
                        &[&acts[wk][i], &self.ws[i], &dys[wk]],
                    )?;
                    let mut it = out.into_iter();
                    (
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                    )
                } else {
                    let out = self.engine.run(
                        &self.names.bwd,
                        &[&acts[wk][i], &zs[wk][i], &self.ws[i], &dys[wk]],
                    )?;
                    let mut it = out.into_iter();
                    (
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                    )
                };
                dys[wk] = dx;
                dws[wk][i] = Some(dw);
                dbs[wk][i] = Some(db);
            }
            t_bwd += tb.elapsed().as_secs_f64();

            // all-reduce this layer's gradients across workers (weights
            // through the wire codec; biases are tiny and ride along raw)
            let ta = Instant::now();
            let mut wbufs: Vec<Vec<f32>> = (0..n)
                .map(|wk| dws[wk][i].as_ref().unwrap().data.clone())
                .collect();
            wire += ring_allreduce(&mut wbufs, codec.as_ref());
            let mut bbufs: Vec<Vec<f32>> = (0..n)
                .map(|wk| dbs[wk][i].as_ref().unwrap().data.clone())
                .collect();
            wire += ring_allreduce(&mut bbufs, None);
            for wk in 0..n {
                dws[wk][i].as_mut().unwrap().data = wbufs[wk].clone();
                dbs[wk][i].as_mut().unwrap().data = bbufs[wk].clone();
            }
            t_ar += ta.elapsed().as_secs_f64();
        }

        // ---- weight update (identical on every rank; computed once) --
        let tu = Instant::now();
        let lr_eff = Tensor::scalar(self.cfg.lr / n as f32); // mean gradient
        let t_step = (self.step_no + 1) as i32;
        let b1t = Tensor::scalar(0.9f32.powi(t_step));
        let b2t = Tensor::scalar(0.999f32.powi(t_step));
        for i in 0..l {
            let dw = dws[0][i].take().unwrap();
            let db = dbs[0][i].take().unwrap();
            let db2 = Tensor::new(vec![1, m], db.data);
            match &mut self.adam {
                None => {
                    let out =
                        self.engine.run(&self.names.sgd, &[&self.ws[i], &dw, &lr_eff])?;
                    self.ws[i] = out.into_iter().next().unwrap();
                    let out = self
                        .engine
                        .run(&self.names.sgd_vec, &[&self.bs[i], &db2, &lr_eff])?;
                    self.bs[i] = out.into_iter().next().unwrap();
                }
                Some(st) => {
                    let out = self.engine.run(
                        &self.names.adam,
                        &[&self.ws[i], &dw, &st.mw[i], &st.vw[i], &lr_eff, &b1t, &b2t],
                    )?;
                    let mut it = out.into_iter();
                    self.ws[i] = it.next().unwrap();
                    st.mw[i] = it.next().unwrap();
                    st.vw[i] = it.next().unwrap();
                    let out = self.engine.run(
                        &self.names.adam_vec,
                        &[&self.bs[i], &db2, &st.mb[i], &st.vb[i], &lr_eff, &b1t, &b2t],
                    )?;
                    let mut it = out.into_iter();
                    self.bs[i] = it.next().unwrap();
                    st.mb[i] = it.next().unwrap();
                    st.vb[i] = it.next().unwrap();
                }
            }
        }
        t_upd += tu.elapsed().as_secs_f64();

        self.step_no += 1;
        Ok(StepStats {
            step: self.step_no,
            loss: loss_sum / n as f64,
            t_fwd,
            t_bwd,
            t_allreduce: t_ar,
            t_update: t_upd,
            wire_bytes_per_node: wire,
        })
    }

    /// Train for `steps` steps, returning the loss curve.
    pub fn train(&mut self, steps: usize, log_every: usize) -> Result<Vec<StepStats>> {
        let mut out = Vec::with_capacity(steps);
        for s in 0..steps {
            let st = self.step()?;
            if log_every > 0 && (s % log_every == 0 || s + 1 == steps) {
                crate::log_info!(
                    "step {:>4}  loss {:.6}  (fwd {:.0}ms bwd {:.0}ms ar {:.0}ms upd {:.0}ms, wire {:.1} MB/node)",
                    st.step,
                    st.loss,
                    st.t_fwd * 1e3,
                    st.t_bwd * 1e3,
                    st.t_allreduce * 1e3,
                    st.t_update * 1e3,
                    st.wire_bytes_per_node / 1e6
                );
            }
            out.push(st);
        }
        Ok(out)
    }
}

/// Bias tensors are stored (1, M) for the SGD artifact but the fwd/bwd
/// artifacts take shape (M,): reshape view.
fn bias_vec(b: &Tensor) -> Tensor {
    Tensor::new(vec![b.len()], b.data.clone())
}
