//! Serialized execution of the paper's Fig. 3b training trace — the
//! compatibility path for the E6 closed-form validation.
//!
//! The worker lane runs forward → backward layer by layer; after each
//! layer's backward, a non-blocking all-reduce request goes to the NIC
//! lane (or to the host comm cores for the baselines); the worker
//! continues with the next layer's backward and the previous layer's
//! weight update, blocking only when the corresponding all-reduce has not
//! finished — exactly the synchronization structure the paper describes.
//! The NIC processes all-reduces in order (one ring at a time), which is
//! also the assumption baked into the Sec. IV-C closed form, so E6 checks
//! the two agree within the paper's 3%.
//!
//! For true concurrency — several all-reduces in flight sharing PCIe,
//! links and adders, multiple jobs on one fabric — use
//! [`super::unified::simulate_iteration_unified`] and the `cluster`
//! scenario layer, which execute everything as events on one calendar
//! queue and are themselves held to this path within 3% at the paper's
//! operating points.
//!
//! Unlike the closed form in `analytic::model`, the all-reduce time here
//! comes from the chunk-level NIC DES (`nic::simulate_ring_allreduce`),
//! which includes PCIe, adder and hop-latency effects.

use crate::analytic::model::{layer_times, IterationBreakdown, SystemKind};
use crate::bfp::BfpCodec;
use crate::nic::{simulate_ring_allreduce, NicConfig};
use crate::sysconfig::{SystemParams, Workload};
use crate::trace::Trace;

/// Simulation output: breakdown + full execution trace.
#[derive(Clone, Debug)]
pub struct SimOutput {
    pub breakdown: IterationBreakdown,
    pub trace: Trace,
    /// all-reduce time for one layer as simulated (NIC DES or host model)
    pub t_ar_layer: f64,
}

/// Simulate one training iteration of `w` on `n` nodes under `kind`.
pub fn simulate_iteration(
    kind: SystemKind,
    sys: &SystemParams,
    w: &Workload,
    n: usize,
) -> SimOutput {
    // per-layer compute/update times from the shared compute model
    let lt = layer_times(kind, sys, w, n);
    // all-reduce time: for the smart NIC, replace the closed form with the
    // chunk-level DES
    let t_ar = match kind {
        SystemKind::SmartNic { bfp } => {
            let cfg = NicConfig::new(*sys, if bfp { Some(BfpCodec::bfp16()) } else { None });
            // the DES already starts at t = nic_request_overhead
            simulate_ring_allreduce(&cfg, n, w.grad_elems_per_layer()).t_total
        }
        _ => lt.t_ar,
    };

    let l = w.layers;
    let mut trace = Trace::new();
    let mut t = 0.0f64;

    // forward pass
    for i in 0..l {
        trace.add("worker", &format!("fwd[{i}]"), t, t + lt.t_f);
        t += lt.t_f;
    }

    if !matches!(
        kind,
        SystemKind::BaselineOverlapped { .. } | SystemKind::SmartNic { .. }
    ) {
        // naive: bwd, blocking AR, update — all serial per layer
        for i in (0..l).rev() {
            trace.add("worker", &format!("bwd[{i}]"), t, t + lt.t_b);
            t += lt.t_b;
            trace.add("comm", &format!("ar[{i}]"), t, t + t_ar);
            t += t_ar;
            trace.add("worker", &format!("upd[{i}]"), t, t + lt.t_u);
            t += lt.t_u;
        }
        let breakdown = finish(&trace, lt.t_f, lt.t_b, lt.t_u, t_ar, l, t);
        return SimOutput {
            breakdown,
            trace,
            t_ar_layer: t_ar,
        };
    }

    // overlapped schedule (Fig. 3b)
    let comm_lane = if matches!(kind, SystemKind::SmartNic { .. }) {
        "nic"
    } else {
        "comm-cores"
    };
    // backward of the last layer
    trace.add("worker", &format!("bwd[{}]", l - 1), t, t + lt.t_b);
    t += lt.t_b;
    let mut nic_free = 0.0f64;
    // segments: AR of layer i overlaps worker work (next bwd + pending
    // update), worker blocks on AR i at segment end
    for i in (0..l).rev() {
        let ar_start = t.max(nic_free);
        let ar_done = ar_start + t_ar;
        trace.add(comm_lane, &format!("ar[{i}]"), ar_start, ar_done);
        nic_free = ar_done;
        // worker work during this segment
        if i == l - 1 {
            if l >= 2 {
                trace.add("worker", &format!("bwd[{}]", l - 2), t, t + lt.t_b);
                t += lt.t_b;
            }
        } else if i >= 1 {
            trace.add("worker", &format!("upd[{}]", i + 1), t, t + lt.t_u);
            t += lt.t_u;
            if i >= 1 {
                trace.add("worker", &format!("bwd[{}]", i - 1), t, t + lt.t_b);
                t += lt.t_b;
            }
        } else {
            // during AR of layer 0 the worker updates layer 1
            if l >= 2 {
                trace.add("worker", "upd[1]", t, t + lt.t_u);
                t += lt.t_u;
            }
        }
        if ar_done > t {
            trace.add("worker", &format!("wait-ar[{i}]"), t, ar_done);
            t = ar_done;
        }
    }
    // final update of layer 0
    trace.add("worker", "upd[0]", t, t + lt.t_u);
    t += lt.t_u;

    let breakdown = finish(&trace, lt.t_f, lt.t_b, lt.t_u, t_ar, l, t);
    SimOutput {
        breakdown,
        trace,
        t_ar_layer: t_ar,
    }
}

fn finish(
    trace: &Trace,
    t_f: f64,
    t_b: f64,
    t_u: f64,
    t_ar: f64,
    l: usize,
    t_total: f64,
) -> IterationBreakdown {
    debug_assert!(trace.check_no_lane_overlap().is_ok());
    let fwd = t_f * l as f64;
    let bwd = t_b * l as f64;
    let upd = t_u * l as f64;
    IterationBreakdown {
        t_fwd: fwd,
        t_bwd: bwd,
        t_update: upd,
        t_exposed_ar: (t_total - fwd - bwd - upd).max(0.0),
        t_total,
        t_ar_raw: t_ar * l as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::model::iteration;
    use crate::collective::Scheme;
    use crate::util::stats::rel_err;

    fn w(b: usize) -> Workload {
        Workload::paper_mlp(b)
    }

    #[test]
    fn trace_has_no_lane_overlap() {
        for kind in [
            SystemKind::BaselineNaive { scheme: Scheme::Ring },
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            SystemKind::SmartNic { bfp: false },
            SystemKind::SmartNic { bfp: true },
        ] {
            let sys = match kind {
                SystemKind::SmartNic { .. } => SystemParams::smartnic_40g(),
                _ => SystemParams::baseline_100g(),
            };
            let out = simulate_iteration(kind, &sys, &w(448), 6);
            out.trace.check_no_lane_overlap().unwrap();
            assert!(out.breakdown.t_total > 0.0);
        }
    }

    #[test]
    fn sim_matches_analytic_within_3pct_smartnic() {
        // E6: full-iteration agreement at paper scale
        let sys = SystemParams::smartnic_40g();
        for n in [3usize, 4, 5, 6, 8] {
            for bfp in [false, true] {
                for b in [448usize, 1792] {
                    let kind = SystemKind::SmartNic { bfp };
                    let sim = simulate_iteration(kind, &sys, &w(b), n).breakdown;
                    let ana = iteration(kind, &sys, &w(b), n);
                    let err = rel_err(ana.t_total, sim.t_total);
                    assert!(
                        err < 0.03,
                        "n={n} bfp={bfp} B={b}: ana {} sim {} err {:.2}%",
                        ana.t_total,
                        sim.t_total,
                        err * 100.0
                    );
                }
            }
        }
    }

    #[test]
    fn sim_matches_analytic_baselines() {
        let sys = SystemParams::baseline_100g();
        for kind in [
            SystemKind::BaselineNaive { scheme: Scheme::Ring },
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
        ] {
            let sim = simulate_iteration(kind, &sys, &w(1792), 6).breakdown;
            let ana = iteration(kind, &sys, &w(1792), 6);
            let err = rel_err(ana.t_total, sim.t_total);
            assert!(err < 0.01, "{kind:?}: err {:.2}%", err * 100.0);
        }
    }

    #[test]
    fn nic_lane_is_serial() {
        let sys = SystemParams::smartnic_40g();
        let out = simulate_iteration(SystemKind::SmartNic { bfp: false }, &sys, &w(448), 6);
        // 20 AR spans on the nic lane, no overlap (checked), total busy =
        // 20 * t_ar_layer
        let busy = out.trace.lane_busy("nic");
        assert!((busy - 20.0 * out.t_ar_layer).abs() / busy < 1e-9);
    }

    #[test]
    fn exposed_ar_much_smaller_when_overlapped() {
        let sys = SystemParams::baseline_100g();
        let naive =
            simulate_iteration(SystemKind::BaselineNaive { scheme: Scheme::Ring }, &sys, &w(1792), 6);
        let over = simulate_iteration(
            SystemKind::BaselineOverlapped { scheme: Scheme::Ring, comm_cores: 2 },
            &sys,
            &w(1792),
            6,
        );
        assert!(naive.breakdown.t_exposed_ar > 5.0 * over.breakdown.t_exposed_ar);
    }

    #[test]
    fn single_layer_workload() {
        let sys = SystemParams::smartnic_40g();
        let wl = Workload {
            layers: 1,
            hidden: 512,
            batch_per_node: 64,
        };
        let out = simulate_iteration(SystemKind::SmartNic { bfp: true }, &sys, &wl, 4);
        out.trace.check_no_lane_overlap().unwrap();
        assert!(out.breakdown.t_total > 0.0);
    }
}
