//! L3 coordinator — the paper's system contribution.
//!
//! * [`simulate`] — dynamic (event-driven) execution of the Fig. 3b
//!   layerwise schedule over the modeled cluster: backward compute on the
//!   workers overlapped with per-layer non-blocking all-reduces on the
//!   smart NICs (or host comm cores for the baselines).  Produces
//!   iteration breakdowns and execution traces; the Sec. IV-C closed form
//!   is validated against it.
//! * [`trainer`] — the *real* training runtime: workers execute the AOT
//!   compiled fwd/bwd/update artifacts through PJRT, gradients flow
//!   through the real ring all-reduce with real BFP wire quantization.

pub mod simulate;
pub mod trainer;

pub use simulate::{simulate_iteration, SimOutput};
pub use trainer::{ArBackend, Optimizer, StepStats, Trainer, TrainerConfig};
