//! L3 coordinator — the paper's system contribution.
//!
//! * [`unified`] — one training iteration on the unified cluster engine:
//!   compute events and non-blocking all-reduce collectives share a
//!   single calendar queue, so a layer's all-reduce runs concurrently
//!   with later layers' compute and with other in-flight all-reduces.
//!   This is the engine behind `cluster` multi-job scenarios.
//! * [`simulate`] — the serialized compatibility path: the Fig. 3b
//!   schedule composed from one-ring-at-a-time NIC timings (and
//!   closed-form host all-reduce costs).  The Sec. IV-C closed form is
//!   validated against this path (E6), and the unified engine is held to
//!   it within the paper's 3% at the paper's operating points.
//! * [`trainer`] — the *real* training runtime: workers execute the AOT
//!   compiled fwd/bwd/update artifacts through PJRT, gradients flow
//!   through the real ring all-reduce with real BFP wire quantization.

pub mod simulate;
pub mod trainer;
pub mod unified;

pub use simulate::{simulate_iteration, SimOutput};
pub use trainer::{ArBackend, Optimizer, StepStats, Trainer, TrainerConfig};
pub use unified::{
    simulate_iteration_unified, simulate_iteration_unified_faulty, simulate_iteration_unified_on,
};
