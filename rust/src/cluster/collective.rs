//! Event-driven collectives on the shared fabric.
//!
//! Three executors, all posting typed [`Event`]s on the one cluster
//! clock (each pipeline stage below is one [`Event`] variant, dispatched
//! back into this module by [`ClusterState`]'s
//! [`World::handle`](crate::netsim::engine::World::handle) match loop):
//!
//! * **Ring** — the NIC's native segment-pipelined ring all-reduce.  Per
//!   segment: PCIe fetch → (Tx serialize → switch → receive) per hop →
//!   FP32 reduce (reduce-scatter phase) or store-and-forward (allgather
//!   phase) → PCIe writeback of final copies.  The arithmetic per segment
//!   is identical to `nic::simulate_ring_allreduce`; the difference is
//!   that resources are the *shared* fabric servers, so concurrent rings
//!   queue-delay each other instead of executing in a vacuum.
//! * **Planned** — a sequence of composable [`Phase`]s executed with a
//!   barrier between phases.  [`Phase::Rounds`] runs barrier-synchronized
//!   rounds of point-to-point transfers through the Tx/switch/adder path
//!   (binomial, Rabenseifner, and the planner's hierarchical
//!   reduce-in-leaf → ring-across-spine → broadcast plans);
//!   [`Phase::SwitchReduce`] streams the gradient through the switch
//!   tier's per-egress-port aggregation engines (NetReduce-style,
//!   segment-pipelined with the engine-table window as the flow control).
//!   Plans come from [`crate::cluster::planner`]; a plan that degenerates
//!   to the ring (or must fall back because the switch cannot reduce)
//!   executes the *exact* native ring path.
//! * **Host rounds** — software/MPI schemes decomposed by
//!   [`scheme_rounds`] into per-step rounds served on each node's
//!   normalized comm-core server; an uncontended run reproduces the
//!   closed-form `allreduce_time` exactly.

use super::planner::{self, PlanKind};
use super::{
    job, ClusterSim, ClusterState, CollectiveAlgo, CollectiveId, CollectiveKind, Event, JobId,
    NodeId,
};
use crate::collective::timing::{scheme_rounds, HostRoundPlan};
use crate::netsim::fabric::HopOutcome;
use crate::netsim::topology::Ring;
use crate::netsim::Time;
use crate::nic::SegmentPlan;
use crate::sysconfig::SystemParams;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// One point-to-point transfer inside a NIC round (local rank indices).
#[derive(Clone, Copy, Debug)]
pub struct RoundOp {
    pub src: usize,
    pub dst: usize,
    /// host-side payload bytes (compressed on the wire by the job's codec)
    pub bytes: f64,
    /// f32 adds at the destination (0.0 = pure copy)
    pub reduce_elems: f64,
}

/// One barrier-synchronized stage of a collective plan
/// ([`crate::cluster::planner`] builds them, the planned executor runs
/// them in order with a barrier between consecutive phases).
#[derive(Clone, Debug)]
pub enum Phase {
    /// Barrier-synchronized rounds of point-to-point NIC transfers
    /// between local ranks.  The executor DMA-fetches the full payload
    /// once before the plan's first `Rounds` phase and writes it back
    /// once after the last phase.
    Rounds(Vec<Vec<RoundOp>>),
    /// NetReduce-style in-switch reduction of the whole vector: every
    /// member streams `bytes` up in segments, each leaf `group`'s
    /// contributions fold at that leaf's aggregation engine, the spine
    /// engine folds the per-leaf aggregates, and the reduced stream
    /// multicasts back down.  `groups` holds local rank indices grouped
    /// by leaf (every member exactly once).
    SwitchReduce {
        bytes: f64,
        elems: f64,
        groups: Vec<Vec<usize>>,
    },
    /// Switch-multicast replication of the whole payload from a single
    /// root — the dual of `SwitchReduce` with the folds removed: the
    /// root (local rank `groups[0][0]`) streams `bytes` up in segments
    /// and the switch tier replicates each segment to every *other*
    /// member (finite-table windowed flow control, one up leg, fan-out
    /// on the egress ports).  `groups` holds local rank indices grouped
    /// by leaf, every member exactly once.
    SwitchMulticast { bytes: f64, groups: Vec<Vec<usize>> },
}

impl Phase {
    /// A phase with nothing to do (skipped by the executor and dropped at
    /// plan-construction time).
    pub fn is_empty(&self) -> bool {
        match self {
            Phase::Rounds(rounds) => rounds.iter().all(|ops| ops.is_empty()),
            Phase::SwitchReduce { .. } | Phase::SwitchMulticast { .. } => false,
        }
    }

    /// Total wire bytes this phase moves (Tx sends, plus the up+down legs
    /// of an in-switch pass; one up leg plus `members − 1` replicated
    /// egress copies for a multicast pass), after compression by
    /// `wire_ratio`.
    pub fn wire_bytes(&self, wire_ratio: f64) -> f64 {
        match self {
            Phase::Rounds(rounds) => {
                rounds.iter().flatten().map(|op| op.bytes / wire_ratio).sum()
            }
            Phase::SwitchReduce { bytes, groups, .. } => {
                let members: usize = groups.iter().map(Vec::len).sum();
                2.0 * members as f64 * bytes / wire_ratio
            }
            Phase::SwitchMulticast { bytes, groups } => {
                let members: usize = groups.iter().map(Vec::len).sum();
                members as f64 * bytes / wire_ratio
            }
        }
    }

    /// Genuine f32 adds the phase performs — NIC adders for rounds; for
    /// an in-switch pass, (mᵍ−1)·E per leaf group plus (G−1)·E across
    /// groups (the engines' table write-ins are bandwidth, not adds).
    /// A multicast pass replicates and folds nothing.
    pub fn reduced_elems(&self) -> f64 {
        match self {
            Phase::Rounds(rounds) => {
                rounds.iter().flatten().map(|op| op.reduce_elems).sum()
            }
            Phase::SwitchReduce { elems, groups, .. } => {
                let local: f64 = groups.iter().map(|g| g.len() as f64 - 1.0).sum();
                (local + groups.len() as f64 - 1.0) * elems
            }
            Phase::SwitchMulticast { .. } => 0.0,
        }
    }
}

/// Per-algorithm execution state.
enum AlgoState {
    /// single-rank no-op: completes instantly
    Noop,
    Ring(RingState),
    Planned(PlannedState),
    Host(HostState),
}

struct RingState {
    plan: SegmentPlan,
    /// wire bytes per segment (after compression)
    wire_seg: f64,
    /// closed-form DMA-queue cursor, one entry per local rank: first
    /// fetch starts at `fetch_base` and each segment drains in
    /// `fetch_step` seconds (see [`RingState::fetch_time`]).  Replaces
    /// the old `[rank][chunk][segment]` table, whose O(n²·segs) memory
    /// made 16k+-node rings unbuildable.
    fetch_base: Vec<Time>,
    fetch_step: Vec<f64>,
    /// PCIe to-device latency added to every fetch completion
    fetch_latency: Time,
    /// final-copy writebacks outstanding; atomic so partition workers
    /// may decrement concurrently on a parallel run
    pending_writebacks: AtomicUsize,
    /// bit pattern of the latest writeback completion time (`f64`
    /// to-bits order is monotone for non-negative floats, so an atomic
    /// max over bits is a max over times)
    last_writeback: AtomicU64,
}

impl RingState {
    /// When segment `seg` of `chunk` lands in local rank `j`'s input
    /// FIFO.  Rank `j` DMA-fetches its chunks in ring-consumption order
    /// `[j, j-1, ..., j-(n-1)] (mod n)` — its own step-0 send chunk
    /// first, then each received chunk — one segment every `fetch_step`
    /// seconds behind a single FIFO DMA queue, so the whole table is
    /// this closed form.
    fn fetch_time(&self, n: usize, j: usize, chunk: usize, seg: usize) -> Time {
        let pos = (j + n - chunk) % n;
        let queued = (pos * self.plan.segs_per_chunk + seg + 1) as f64;
        self.fetch_base[j] + queued * self.fetch_step[j] + self.fetch_latency
    }
}

/// Progress of a planned (phase-list) collective.
struct PlannedState {
    phases: Vec<Phase>,
    /// host-side DMA fetch per local rank before the first `Rounds` phase
    /// (uniform for all-reduce; a broadcast fetches at the root only, an
    /// allgather fetches each rank's shard, …).  Zero entries skip the
    /// transfer entirely.
    fetch_bytes: Vec<f64>,
    /// host-side DMA writeback per local rank after the last phase (a
    /// broadcast writes back at the non-roots, a reduce-scatter writes
    /// back each owner's shard, …)
    wb_bytes: Vec<f64>,
    phase_idx: usize,
    fetch_pending: usize,
    wb_pending: usize,
    /// progress within the current [`Phase::Rounds`]
    round: usize,
    op_pending: usize,
    /// progress within the current [`Phase::SwitchReduce`]
    sw: Option<SwitchProgress>,
}

/// Live state of one in-switch pass (segment pipeline): reduction mode
/// folds every member's stream toward the root's engine and multicasts
/// the result; multicast mode replicates the root's stream to every
/// other member without folding.
struct SwitchProgress {
    /// replication (multicast) mode: the fold stages are skipped and the
    /// root is the only sender
    mcast: bool,
    seg_bytes: f64,
    wire_seg: f64,
    seg_elems: f64,
    segs: usize,
    /// aggregation-table flow control: max segments in flight at once
    window: usize,
    /// fetch each segment over PCIe (phase 0 owns the host copy)
    fetch: bool,
    /// write each segment back over PCIe (last phase delivers to host)
    writeback: bool,
    /// global node id whose egress engine roots the aggregation
    root: usize,
    /// local rank -> leaf-group index
    group_of: Vec<usize>,
    /// leaf id of each group
    group_leaves: Vec<usize>,
    /// all member local ranks, flattened in group order
    members: Vec<usize>,
    next_seg: usize,
    inflight: usize,
    done: usize,
    /// [segment][group] -> contributions not yet folded at the leaf engine
    group_pending: Vec<Vec<usize>>,
    /// [segment] -> leaf aggregates not yet folded at the spine engine
    spine_pending: Vec<usize>,
    /// [segment] -> member deliveries (incl. writeback) outstanding
    rank_pending: Vec<usize>,
}

struct HostState {
    plan: HostRoundPlan,
    eff_bw: f64,
    step_cost: f64,
    current_round: usize,
    round_pending: usize,
}

/// How the switching tier's admission control classified one flow under
/// multi-tenant aggregation-table pressure.  Admission is *per flow*: a
/// denied flow runs its job's exact host/NIC plan while other flows — of
/// this job or others — keep their in-switch slots.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TenancyOutcome {
    /// the flow never asked for switch-tier state (NIC/host algorithms,
    /// `n ≤ 1`, non-all-reduce kinds, incapable fabrics)
    NotRequested,
    /// admitted: the flow's job holds `granted_bytes` of aggregation
    /// table (its pipeline window) until the flow completes
    Admitted {
        /// table bytes granted to the job's reservation (a whole number
        /// of this flow's segments)
        granted_bytes: f64,
    },
    /// denied after a competing tenant evicted this job's warm slot —
    /// the flow fell back to the job's host/NIC plan
    Evicted,
    /// denied on first contact (table full of active tenants, or the
    /// achievable share is below one segment) — per-flow fallback to the
    /// job's host/NIC plan
    Fallback,
}

/// One posted collective: public bookkeeping + private executor state.
pub struct Collective {
    pub id: CollectiveId,
    pub job: JobId,
    pub layer: usize,
    pub algo: CollectiveAlgo,
    /// which collective pattern this operation implements (all-reduce,
    /// broadcast, allgather, reduce-scatter, all-to-all)
    pub kind: CollectiveKind,
    pub ranks: Vec<NodeId>,
    pub elems: usize,
    /// when the worker posted the (non-blocking) request
    pub t_post: Time,
    /// completion: all ranks hold the reduced gradient in host memory
    pub t_done: Option<Time>,
    /// analytic wire-byte accounting per rank
    pub wire_bytes_per_rank: f64,
    /// the executor has begun (reserved fabric resources).  NIC-path
    /// collectives flip this when [`Event::CollectiveStart`] fires; host
    /// and no-op collectives begin at post.  A *started* collective of a
    /// preempted job drains to completion on the fabric.
    pub started: bool,
    /// the owning job was preempted inside the driver-request window
    /// (posted, not yet started): the descriptor never reaches the
    /// datapath, nothing was reserved, and the conservation ledger
    /// excludes it ([`scenario`]'s audit, `docs/INVARIANTS.md`)
    pub aborted: bool,
    /// the switch tier's admission decision for this flow (decided at
    /// post time against the live [`planner::TenancyLoad`])
    pub tenancy: TenancyOutcome,
    state: AlgoState,
}

impl Collective {
    pub fn duration(&self) -> Option<f64> {
        self.t_done.map(|d| d - self.t_post)
    }

    /// The exactly-once reduction ledger (`docs/INVARIANTS.md`,
    /// `reduce-conservation`): f32 elements this collective's executor
    /// must push through `(node adders, switch aggregation engines)` by
    /// completion.  Ring: every reduce-scatter step folds one segment on
    /// every rank — `(n−1)·n·segs·seg_elems` adder elements.  Planned
    /// rounds: exactly the ops' `reduce_elems`.  In-switch passes count
    /// engine *bandwidth* (table write-ins included): every member
    /// streams the full vector through its leaf engine, and a spanning
    /// pass additionally folds each group's aggregate at the spine.
    /// Host/noop collectives fold nothing on either pool.
    #[must_use]
    pub fn expected_reduce_served(&self) -> (f64, f64) {
        let n = self.ranks.len() as f64;
        match &self.state {
            AlgoState::Noop | AlgoState::Host(_) => (0.0, 0.0),
            AlgoState::Ring(r) => {
                let segs = r.plan.segs_per_chunk as f64;
                ((n - 1.0) * n * segs * r.plan.seg_elems, 0.0)
            }
            AlgoState::Planned(p) => {
                let mut adders = 0.0;
                let mut engines = 0.0;
                for phase in &p.phases {
                    match phase {
                        Phase::Rounds(rounds) => {
                            adders +=
                                rounds.iter().flatten().map(|op| op.reduce_elems).sum::<f64>();
                        }
                        Phase::SwitchReduce { elems, groups, .. } => {
                            let members: usize = groups.iter().map(Vec::len).sum();
                            engines += members as f64 * elems;
                            if groups.len() > 1 {
                                engines += groups.len() as f64 * elems;
                            }
                        }
                        // replication moves bytes, folds nothing: its
                        // ledger is expected_mcast_deliveries
                        Phase::SwitchMulticast { .. } => {}
                    }
                }
                (adders, engines)
            }
        }
    }

    /// The replication ledger (`docs/INVARIANTS.md`,
    /// `multicast-conservation`): member-segment copies the switch tier
    /// must egress in multicast mode by completion — `(members − 1)` per
    /// segment for every [`Phase::SwitchMulticast`] (the root already
    /// holds the payload), zero for every other executor.  Replication is
    /// *not* reduction, so neither reduce ledger can see these copies;
    /// `segment_bytes` must be the NIC segment size the executor
    /// segmented the phase with.
    #[must_use]
    pub fn expected_mcast_deliveries(&self, segment_bytes: f64) -> f64 {
        match &self.state {
            AlgoState::Planned(p) => p
                .phases
                .iter()
                .map(|ph| match ph {
                    Phase::SwitchMulticast { bytes, groups } => {
                        let members: usize = groups.iter().map(Vec::len).sum();
                        let segs = (bytes / segment_bytes).ceil().max(1.0);
                        (members as f64 - 1.0) * segs
                    }
                    _ => 0.0,
                })
                .sum(),
            _ => 0.0,
        }
    }

    fn ring_mut(&mut self) -> &mut RingState {
        match &mut self.state {
            AlgoState::Ring(r) => r,
            _ => unreachable!("collective {} is not a ring", self.id),
        }
    }

    fn planned_ref(&self) -> &PlannedState {
        match &self.state {
            AlgoState::Planned(p) => p,
            _ => unreachable!("collective {} is not plan-based", self.id),
        }
    }

    fn planned_mut(&mut self) -> &mut PlannedState {
        match &mut self.state {
            AlgoState::Planned(p) => p,
            _ => unreachable!("collective {} is not plan-based", self.id),
        }
    }

    fn host_mut(&mut self) -> &mut HostState {
        match &mut self.state {
            AlgoState::Host(h) => h,
            _ => unreachable!("collective {} is not host-based", self.id),
        }
    }
}

/// Build the native segment-pipelined ring state — the single constructor
/// shared by `NicRing` and every planner fallback, so a fallback executes
/// *exactly* the ring path.
fn ring_state(sys: &SystemParams, n: usize, elems: usize, wire_ratio: f64) -> (AlgoState, f64) {
    let plan = SegmentPlan::new(sys.nic.segment_bytes, n, elems);
    let wire_seg = plan.seg_bytes / wire_ratio;
    let segs = plan.segs_per_chunk;
    let ring = Ring::new(n);
    (
        AlgoState::Ring(RingState {
            plan,
            wire_seg,
            fetch_base: Vec::new(),
            fetch_step: Vec::new(),
            fetch_latency: 0.0,
            pending_writebacks: AtomicUsize::new(n * n * segs),
            last_writeback: AtomicU64::new(0),
        }),
        ring.allreduce_steps() as f64 * segs as f64 * wire_seg,
    )
}

/// Build the planned-executor state from a phase list (empty phases are
/// dropped so phase barriers never stall on nothing).  `fetch_bytes` /
/// `wb_bytes` are the per-local-rank DMA volumes around the plan — see
/// [`dma_profile`] for the per-kind shapes.
fn planned_state(
    phases: Vec<Phase>,
    n: usize,
    wire_ratio: f64,
    fetch_bytes: Vec<f64>,
    wb_bytes: Vec<f64>,
) -> (AlgoState, f64) {
    assert_eq!(fetch_bytes.len(), n, "one fetch volume per rank");
    assert_eq!(wb_bytes.len(), n, "one writeback volume per rank");
    let phases: Vec<Phase> = phases.into_iter().filter(|p| !p.is_empty()).collect();
    let wire_total: f64 = phases.iter().map(|p| p.wire_bytes(wire_ratio)).sum();
    (
        AlgoState::Planned(PlannedState {
            phases,
            fetch_bytes,
            wb_bytes,
            phase_idx: 0,
            fetch_pending: 0,
            wb_pending: 0,
            round: 0,
            op_pending: 0,
            sw: None,
        }),
        wire_total / n as f64,
    )
}

/// Per-local-rank DMA volumes around a planned collective of payload
/// `bytes`: what each rank's host must push to the NIC before the plan
/// and pull back after it.  All-reduce moves the full payload both ways
/// on every rank; the other kinds are asymmetric — exactly the per-kind
/// accounting [`crate::cluster::planner::rounds_cost`] prices.
fn dma_profile(kind: CollectiveKind, n: usize, bytes: f64) -> (Vec<f64>, Vec<f64>) {
    let shard = bytes / n as f64;
    match kind {
        CollectiveKind::AllReduce | CollectiveKind::AllToAll => {
            (vec![bytes; n], vec![bytes; n])
        }
        CollectiveKind::Broadcast => {
            // the root (local rank 0) sources the payload; every other
            // rank only receives it
            let mut fetch = vec![0.0; n];
            fetch[0] = bytes;
            let mut wb = vec![bytes; n];
            wb[0] = 0.0;
            (fetch, wb)
        }
        CollectiveKind::Allgather => (vec![shard; n], vec![bytes; n]),
        CollectiveKind::ReduceScatter => (vec![bytes; n], vec![shard; n]),
    }
}

/// Post layer `layer`'s collective for `job` at the current virtual time
/// (the layer's [`CollectiveKind`] — all-reduce unless the spec says
/// otherwise — executed by the layer's algorithm preference).
/// Non-blocking: the executor's events interleave with everything else on
/// the clock.  Returns the collective id the worker can wait on.
pub fn post(sim: &mut ClusterSim, st: &mut ClusterState, job: JobId, layer: usize) -> CollectiveId {
    let now = sim.now();
    let spec = &st.jobs[job].spec;
    let ranks = spec.ranks.clone();
    let elems = spec.workload.grad_elems_per_layer();
    let algo = spec.layer_algos[layer];
    let kind = spec.layer_kinds[layer];
    let wire_ratio = st.jobs[job].wire_ratio;
    let n = ranks.len();
    // the NIC datapath pads to whole ring chunks (Sec. IV-C); the host
    // software path moves the raw gradient
    let padded_bytes = elems.div_ceil(n.max(1)).max(1) as f64 * 4.0 * n as f64;
    let raw_bytes = elems as f64 * 4.0;

    let cid = st.collectives.len();
    let mut tenancy = TenancyOutcome::NotRequested;
    let (state, wire_bytes_per_rank) = if n <= 1 {
        (AlgoState::Noop, 0.0)
    } else if kind != CollectiveKind::AllReduce {
        // every non-all-reduce kind runs on the planned executor; the
        // algorithm is a plan-family preference the kind-aware planner
        // resolves (with the documented fallbacks)
        assert!(
            !matches!(algo, CollectiveAlgo::Host(_)),
            "the host executor implements only all-reduce (layer {layer} asked for {})",
            kind.name()
        );
        let plan = planner::plan_collective_for_algo(
            &st.sys,
            &st.fabric.topology,
            &ranks,
            elems,
            wire_ratio,
            kind,
            algo,
        );
        let (fetch, wb) = dma_profile(kind, n, plan.payload_bytes);
        planned_state(plan.phases, n, wire_ratio, fetch, wb)
    } else {
        match algo {
            CollectiveAlgo::NicRing => ring_state(&st.sys, n, elems, wire_ratio),
            CollectiveAlgo::NicBinomial => planned_state(
                vec![Phase::Rounds(binomial_rounds(n, padded_bytes, elems as f64))],
                n,
                wire_ratio,
                vec![padded_bytes; n],
                vec![padded_bytes; n],
            ),
            CollectiveAlgo::NicRabenseifner => planned_state(
                vec![Phase::Rounds(rabenseifner_rounds(n, padded_bytes, elems as f64))],
                n,
                wire_ratio,
                vec![padded_bytes; n],
                vec![padded_bytes; n],
            ),
            CollectiveAlgo::NicHierarchical
            | CollectiveAlgo::SwitchReduce
            | CollectiveAlgo::Auto => {
                // price the candidate families against the switch tier's
                // *current* table/engine/PFC load, not the idle fabric
                let load = planner::TenancyLoad::observed(&st.fabric, job as u32);
                let plan = planner::plan_for_algo_with(
                    &st.sys,
                    &st.fabric.topology,
                    &ranks,
                    elems,
                    wire_ratio,
                    algo,
                    load,
                );
                if plan.kind == PlanKind::InSwitch {
                    // in-switch won under load: claim table bytes for the
                    // job's pipeline window before committing to the plan
                    let bytes = plan.payload_bytes;
                    let segs = (bytes / st.sys.nic.segment_bytes).ceil().max(1.0);
                    let seg = bytes / segs;
                    let cap = st.sys.switch.reduce_table_bytes;
                    let want = seg * segs.min((cap / seg).floor()).max(1.0);
                    let granted = st
                        .fabric
                        .table_mut()
                        .expect("in-switch plan on a fabric without an aggregation table")
                        .request(job as u32, want, seg);
                    if granted >= seg {
                        tenancy = TenancyOutcome::Admitted {
                            granted_bytes: granted,
                        };
                        planned_state(
                            plan.phases,
                            n,
                            wire_ratio,
                            vec![bytes; n],
                            vec![bytes; n],
                        )
                    } else {
                        // a shared slot too small for this flow's segment
                        // counts as a denial; drop the refcount we took
                        if granted > 0.0 {
                            st.fabric.table_mut().unwrap().release(job as u32);
                        }
                        tenancy = denial_outcome(st, job as u32);
                        ring_state(&st.sys, n, elems, wire_ratio)
                    }
                } else {
                    if algo == CollectiveAlgo::SwitchReduce
                        && st.fabric.switch_reduce_capable()
                    {
                        // the family was demanded on a capable fabric but
                        // the planner priced it out under current load —
                        // a per-flow denial, not a planning gap
                        tenancy = denial_outcome(st, job as u32);
                    }
                    if plan.kind == PlanKind::Ring {
                        // degenerate or fallback plan: the exact native ring
                        ring_state(&st.sys, n, elems, wire_ratio)
                    } else {
                        let payload = plan.payload_bytes;
                        planned_state(
                            plan.phases,
                            n,
                            wire_ratio,
                            vec![payload; n],
                            vec![payload; n],
                        )
                    }
                }
            }
            CollectiveAlgo::Host(scheme) => {
                let env = st.jobs[job].host_env;
                let plan = scheme_rounds(scheme, n, raw_bytes, &env);
                (
                    AlgoState::Host(HostState {
                        plan,
                        eff_bw: env.effective_bw(),
                        step_cost: env.step_cost(),
                        current_round: 0,
                        round_pending: 0,
                    }),
                    plan.rounds as f64 * plan.bytes_per_round,
                )
            }
        }
    };

    // classify before dispatching so no borrow of the collective is held
    // across the &mut state calls below
    let class: u8 = match &state {
        AlgoState::Noop => 0,
        AlgoState::Ring(_) | AlgoState::Planned(_) => 1,
        AlgoState::Host(_) => 2,
    };
    st.collectives.push(Collective {
        id: cid,
        job,
        layer,
        algo,
        kind,
        ranks,
        elems,
        t_post: now,
        t_done: None,
        wire_bytes_per_rank,
        // NIC-path executors start when CollectiveStart fires; no-op and
        // host collectives begin right here at post
        started: class != 1,
        aborted: false,
        tenancy,
        state,
    });
    match class {
        0 => complete(sim, st, cid),
        1 => {
            // driver hands the descriptor to the NIC after a fixed overhead
            let overhead = st.sys.nic_request_overhead;
            sim.schedule(overhead, Event::CollectiveStart { cid: cid as u32 });
        }
        _ => begin_host_round(sim, st, cid, 0),
    }
    cid
}

/// Classify a switch-tier denial: [`TenancyOutcome::Evicted`] when a
/// competitor displaced this job's warm slot since its last flow,
/// [`TenancyOutcome::Fallback`] for a plain full-table miss.
fn denial_outcome(st: &mut ClusterState, job: u32) -> TenancyOutcome {
    let evicted = st
        .fabric
        .table_mut()
        .is_some_and(|t| t.take_eviction_debt(job));
    if evicted {
        TenancyOutcome::Evicted
    } else {
        TenancyOutcome::Fallback
    }
}

/// Drop the aggregation-table refcount an [`TenancyOutcome::Admitted`]
/// flow holds.  Idle slots stay resident (sticky) until a competitor
/// evicts them, so a job's next flow re-admits for free.
fn release_table(st: &mut ClusterState, cid: CollectiveId) {
    if let TenancyOutcome::Admitted { .. } = st.collectives[cid].tenancy {
        let job = st.collectives[cid].job as u32;
        st.fabric
            .table_mut()
            .expect("admitted flow on a fabric without an aggregation table")
            .release(job);
    }
}

/// [`Event::CollectiveStart`]: the NIC driver's request overhead elapsed —
/// enter the executor matching the collective's algorithm state.
pub(super) fn on_start(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    if st.collectives[cid].aborted {
        // the owning job was preempted inside the driver-request window:
        // the descriptor never reaches the datapath — but the table share
        // claimed at post time must still come back
        release_table(st, cid);
        return;
    }
    st.collectives[cid].started = true;
    // classify first so no borrow of the collective is held across the
    // &mut state calls below
    let is_ring = matches!(&st.collectives[cid].state, AlgoState::Ring(_));
    if is_ring {
        start_ring(sim, st, cid);
    } else {
        assert!(
            matches!(&st.collectives[cid].state, AlgoState::Planned(_)),
            "start event on a non-NIC collective {cid}"
        );
        start_planned(sim, st, cid);
    }
}

/// [`Event::CollectiveComplete`]: a latency-only tail elapsed.
pub(super) fn on_complete(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    complete(sim, st, cid);
}

/// Mark `cid` complete at the current time, record its trace span, and
/// wake its job's worker if it is blocked on this collective.
fn complete(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    release_table(st, cid);
    let now = sim.now();
    st.collectives[cid].t_done = Some(now);
    let (jid, layer, t_post) = {
        let c = &st.collectives[cid];
        (c.job, c.layer, c.t_post)
    };
    if now > t_post {
        let lane = st.jobs[jid].comm_lane.clone();
        st.trace.add(&lane, &format!("ar[{layer}]"), t_post, now);
    }
    job::on_collective_done(sim, st, cid);
}

// ---------------------------------------------------------------------
// Ring executor (segment-pipelined, identical arithmetic to the
// serialized `nic::simulate_ring_allreduce`)
// ---------------------------------------------------------------------

fn start_ring(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let now = sim.now();
    let (ranks, plan) = {
        let c = &st.collectives[cid];
        let r = match &c.state {
            AlgoState::Ring(r) => r,
            _ => unreachable!(),
        };
        (c.ranks.clone(), r.plan)
    };
    let n = ranks.len();
    let ring = Ring::new(n);
    let segs = plan.segs_per_chunk;

    // Queue every PCIe fetch now, in the order the schedule consumes
    // chunks (chunk sent at step 0 first, then received chunks' local
    // counterparts) — the same DMA queue order as the serialized path,
    // reserved in bulk so one uniform-segment closed form replaces the
    // per-segment table.
    {
        let r = st.collectives[cid].ring_mut();
        r.fetch_base = Vec::with_capacity(n);
        r.fetch_step = Vec::with_capacity(n);
    }
    for &node in &ranks {
        let dev = &mut st.fabric.nodes[node].pcie.to_device;
        let base = now.max(dev.server.busy_until());
        let _ = dev.server.serve(now, (n * segs) as f64 * plan.seg_bytes);
        let step = plan.seg_bytes / dev.server.rate;
        let latency = dev.latency;
        let r = st.collectives[cid].ring_mut();
        r.fetch_base.push(base);
        r.fetch_step.push(step);
        r.fetch_latency = latency;
    }

    // Step-0 sends fire as each segment of the first chunk — rank
    // `local`'s own chunk, position 0 in its fetch order — lands in the
    // input FIFO.
    for (local, &node) in ranks.iter().enumerate() {
        let chunk0 = ring.send_chunk(local, 0);
        for seg in 0..segs {
            let t = match &st.collectives[cid].state {
                AlgoState::Ring(r) => r.fetch_time(n, local, chunk0, seg),
                _ => unreachable!(),
            };
            sim.schedule_at(
                t,
                Event::RingSend {
                    cid: cid as u32,
                    step: 0,
                    rank: local as u32,
                    seg: seg as u32,
                    node: node as u32,
                },
            );
        }
    }
}

/// Local rank `i`'s copy of segment `seg` for ring step `step` is ready in
/// its Tx path: serialize onto the uplink and switch it to the successor.
pub(super) fn ring_send(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    step: usize,
    i: usize,
    seg: usize,
) {
    let now = sim.now();
    let (src, dst, j, wire_seg) = {
        let c = &st.collectives[cid];
        let ring = Ring::new(c.ranks.len());
        let j = ring.next(i);
        let r = match &c.state {
            AlgoState::Ring(r) => r,
            _ => unreachable!(),
        };
        (c.ranks[i], c.ranks[j], j, r.wire_seg)
    };
    // The sender's half of the hop only: an intra-leaf segment delivers
    // directly, a cross-leaf one surfaces at the spine and the receiving
    // leaf times the downlink half when `RingXArrive` fires there.
    match st.fabric.hop_split(src, dst, now, wire_seg) {
        HopOutcome::Delivered(arrive) => sim.schedule_at(
            arrive,
            Event::RingRecv {
                cid: cid as u32,
                step: step as u32,
                rank: j as u32,
                seg: seg as u32,
                node: dst as u32,
            },
        ),
        HopOutcome::AtSpine(at_spine) => sim.schedule_at(
            at_spine,
            Event::RingXArrive {
                cid: cid as u32,
                step: step as u32,
                rank: j as u32,
                seg: seg as u32,
                node: dst as u32,
            },
        ),
    }
}

/// [`Event::RingXArrive`]: a cross-leaf segment for local rank `j` (on
/// `node`) reached the spine — reserve the receiving leaf's downlink
/// bundle and cut through to the destination port.
pub(super) fn ring_xarrive(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    step: usize,
    j: usize,
    seg: usize,
    node: NodeId,
) {
    let now = sim.now();
    let wire_seg = {
        let c = &st.collectives[cid];
        match &c.state {
            AlgoState::Ring(r) => r.wire_seg,
            _ => unreachable!(),
        }
    };
    let arrive = st.fabric.hop_deliver(node, now, wire_seg);
    sim.schedule_at(
        arrive,
        Event::RingRecv {
            cid: cid as u32,
            step: step as u32,
            rank: j as u32,
            seg: seg as u32,
            node: node as u32,
        },
    );
}

/// Segment `seg` of ring step `step` arrived at local rank `j`.
pub(super) fn ring_recv(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    step: usize,
    j: usize,
    seg: usize,
) {
    let now = sim.now();
    let (reduce_phase, local_ready, node) = {
        let c = &st.collectives[cid];
        let n = c.ranks.len();
        let ring = Ring::new(n);
        let reduce_phase = step < ring.reduce_scatter_steps();
        let local_ready = if reduce_phase {
            let r = match &c.state {
                AlgoState::Ring(r) => r,
                _ => unreachable!(),
            };
            r.fetch_time(n, j, ring.recv_chunk(j, step), seg)
        } else {
            0.0
        };
        (reduce_phase, local_ready, c.ranks[j])
    };
    if reduce_phase {
        // join with the local fetched copy, then reduce on the adder
        if local_ready > now {
            sim.schedule_at(
                local_ready,
                Event::RingReduce {
                    cid: cid as u32,
                    step: step as u32,
                    rank: j as u32,
                    seg: seg as u32,
                    node: node as u32,
                },
            );
        } else {
            ring_reduce(sim, st, cid, step, j, seg);
        }
    } else {
        // allgather: store & forward without waiting for the writeback
        ring_segment_final(sim, st, cid, step, j, seg);
    }
}

/// Both inputs of the reduce are present at local rank `j`: occupy the
/// FP32 adder.
pub(super) fn ring_reduce(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    step: usize,
    j: usize,
    seg: usize,
) {
    let now = sim.now();
    let (node, seg_elems) = {
        let c = &st.collectives[cid];
        let r = match &c.state {
            AlgoState::Ring(r) => r,
            _ => unreachable!(),
        };
        (c.ranks[j], r.plan.seg_elems)
    };
    let done = st.fabric.nodes[node].adder.serve(now, seg_elems);
    sim.schedule_at(
        done,
        Event::RingFinal {
            cid: cid as u32,
            step: step as u32,
            rank: j as u32,
            seg: seg as u32,
            node: node as u32,
        },
    );
}

/// Local rank `j`'s copy of this segment is final for `step`: write it
/// back to the host if it is a final copy, and forward it on the next
/// step if the ring continues.
pub(super) fn ring_segment_final(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    step: usize,
    j: usize,
    seg: usize,
) {
    let now = sim.now();
    let (node, seg_bytes, rs_steps, total_steps) = {
        let c = &st.collectives[cid];
        let ring = Ring::new(c.ranks.len());
        let r = match &c.state {
            AlgoState::Ring(r) => r,
            _ => unreachable!(),
        };
        (
            c.ranks[j],
            r.plan.seg_bytes,
            ring.reduce_scatter_steps(),
            ring.allreduce_steps(),
        )
    };
    if step >= rs_steps - 1 {
        let wb = st.fabric.nodes[node].pcie.to_host.transmit(now, seg_bytes);
        sim.schedule_at(wb, Event::RingWritebackDone { cid: cid as u32, node: node as u32 });
    }
    if step + 1 < total_steps {
        ring_send(sim, st, cid, step + 1, j, seg);
    }
}

/// [`Event::RingWritebackDone`]: count one final-copy writeback.  The
/// counters are atomic so every leaf partition's writebacks fold in
/// concurrently on a parallel run; the rank that retires the last one
/// observes the true maximum completion time (the `AcqRel` decrement's
/// release sequence orders all earlier `fetch_max` calls before the
/// final load) and posts the global completion event at it.
///
/// *Which* partition wins the countdown race varies with thread
/// interleaving, but the emitted `(t_done, CollectiveComplete{cid})`
/// pair does not, and the barrier merge orders same-time events by
/// [`PartitionedWorld::merge_key`] — not by emitting partition — so the
/// coordinator's execution order is identical across thread counts.
/// The zero-delay emission (`t_done` can equal `now`) is the
/// coordinator carve-out documented on the `PartitionedWorld` contract.
///
/// [`PartitionedWorld::merge_key`]: crate::netsim::engine::PartitionedWorld::merge_key
pub(super) fn ring_writeback_done(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let now = sim.now();
    let r = match &st.collectives[cid].state {
        AlgoState::Ring(r) => r,
        _ => unreachable!("collective {cid} is not a ring"),
    };
    r.last_writeback.fetch_max(now.to_bits(), Ordering::AcqRel);
    if r.pending_writebacks.fetch_sub(1, Ordering::AcqRel) == 1 {
        let t_done = f64::from_bits(r.last_writeback.load(Ordering::Acquire));
        sim.schedule_at(t_done, Event::CollectiveComplete { cid: cid as u32 });
    }
}

// ---------------------------------------------------------------------
// Planned executor: composable phases with a barrier between them
// (binomial / Rabenseifner / hierarchical / in-switch plans)
// ---------------------------------------------------------------------

fn start_planned(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let now = sim.now();
    let (ranks, fetches, first_is_switch) = {
        let c = &st.collectives[cid];
        let p = c.planned_ref();
        (
            c.ranks.clone(),
            p.fetch_bytes.clone(),
            matches!(
                p.phases.first(),
                Some(Phase::SwitchReduce { .. } | Phase::SwitchMulticast { .. })
            ),
        )
    };
    if first_is_switch {
        // the in-switch pass pipelines its own per-segment DMA fetches
        begin_phase(sim, st, cid);
        return;
    }
    // per-rank DMA fetch before the first rounds phase (zero-volume ranks
    // — e.g. a broadcast's receivers — have nothing to move)
    let pending = fetches.iter().filter(|b| **b > 0.0).count();
    if pending == 0 {
        begin_phase(sim, st, cid);
        return;
    }
    st.collectives[cid].planned_mut().fetch_pending = pending;
    for (local, &node) in ranks.iter().enumerate() {
        if fetches[local] > 0.0 {
            let done = st.fabric.nodes[node].pcie.to_device.transmit(now, fetches[local]);
            sim.schedule_at(done, Event::PlannedFetchDone { cid: cid as u32 });
        }
    }
}

pub(super) fn planned_fetch_done(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let p = st.collectives[cid].planned_mut();
    p.fetch_pending -= 1;
    if p.fetch_pending == 0 {
        begin_phase(sim, st, cid);
    }
}

/// Enter the current phase (or finish the plan when none are left).
fn begin_phase(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    #[derive(PartialEq)]
    enum Entry {
        Rounds,
        Reduce,
        Multicast,
    }
    let entry = {
        let p = st.collectives[cid].planned_ref();
        p.phases.get(p.phase_idx).map(|ph| match ph {
            Phase::Rounds(_) => Entry::Rounds,
            Phase::SwitchReduce { .. } => Entry::Reduce,
            Phase::SwitchMulticast { .. } => Entry::Multicast,
        })
    };
    match entry {
        None => finish_planned(sim, st, cid),
        Some(Entry::Rounds) => {
            st.collectives[cid].planned_mut().round = 0;
            begin_planned_round(sim, st, cid, 0);
        }
        Some(Entry::Reduce) => start_switch_phase(sim, st, cid),
        Some(Entry::Multicast) => start_mcast_phase(sim, st, cid),
    }
}

fn advance_phase(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    st.collectives[cid].planned_mut().phase_idx += 1;
    begin_phase(sim, st, cid);
}

/// All phases done: write the payload back unless the plan ended with an
/// in-switch pass (which delivered per segment).
fn finish_planned(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let now = sim.now();
    let (ranks, wbs, switch_tail) = {
        let c = &st.collectives[cid];
        let p = c.planned_ref();
        (
            c.ranks.clone(),
            p.wb_bytes.clone(),
            matches!(
                p.phases.last(),
                Some(Phase::SwitchReduce { .. } | Phase::SwitchMulticast { .. })
            ),
        )
    };
    if switch_tail {
        complete(sim, st, cid);
        return;
    }
    let pending = wbs.iter().filter(|b| **b > 0.0).count();
    if pending == 0 {
        complete(sim, st, cid);
        return;
    }
    st.collectives[cid].planned_mut().wb_pending = pending;
    for (local, &node) in ranks.iter().enumerate() {
        if wbs[local] > 0.0 {
            let wb = st.fabric.nodes[node].pcie.to_host.transmit(now, wbs[local]);
            sim.schedule_at(wb, Event::PlannedWbDone { cid: cid as u32 });
        }
    }
}

pub(super) fn planned_wb_done(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let p = st.collectives[cid].planned_mut();
    p.wb_pending -= 1;
    if p.wb_pending == 0 {
        complete(sim, st, cid);
    }
}

fn begin_planned_round(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    round: usize,
) {
    let now = sim.now();
    let (ops, ranks, wire_ratio) = {
        let c = &st.collectives[cid];
        let p = c.planned_ref();
        let rounds = match &p.phases[p.phase_idx] {
            Phase::Rounds(r) => r,
            _ => unreachable!("round in a non-rounds phase"),
        };
        (rounds[round].clone(), c.ranks.clone(), st.jobs[c.job].wire_ratio)
    };
    {
        let p = st.collectives[cid].planned_mut();
        p.round = round;
        p.op_pending = ops.len();
    }
    if ops.is_empty() {
        planned_round_barrier(sim, st, cid);
        return;
    }
    for op in ops {
        let wire = op.bytes / wire_ratio;
        let arrive = st.fabric.hop(ranks[op.src], ranks[op.dst], now, wire);
        sim.schedule_at(
            arrive,
            Event::PlannedOpArrive {
                cid: cid as u32,
                dst: ranks[op.dst] as u32,
                reduce_elems: op.reduce_elems,
            },
        );
    }
}

/// A round op's payload arrived at node `dst`: occupy `dst`'s adder when
/// the op reduces, then count the op done.
pub(super) fn planned_op_arrive(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    dst: NodeId,
    reduce_elems: f64,
) {
    if reduce_elems > 0.0 {
        let done = st.fabric.nodes[dst].adder.serve(sim.now(), reduce_elems);
        sim.schedule_at(done, Event::PlannedOpDone { cid: cid as u32 });
    } else {
        // always via the event queue: the arrival runs on `dst`'s leaf
        // partition, the round barrier on the coordinator.  The zero
        // delay is legal only because PlannedOpDone routes to the
        // coordinator — the carve-out on the PartitionedWorld contract.
        sim.schedule_at(sim.now(), Event::PlannedOpDone { cid: cid as u32 });
    }
}

pub(super) fn planned_op_done(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let p = st.collectives[cid].planned_mut();
    p.op_pending -= 1;
    if p.op_pending == 0 {
        planned_round_barrier(sim, st, cid);
    }
}

fn planned_round_barrier(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let (next, n_rounds) = {
        let p = st.collectives[cid].planned_ref();
        let rounds = match &p.phases[p.phase_idx] {
            Phase::Rounds(r) => r,
            _ => unreachable!("barrier in a non-rounds phase"),
        };
        (p.round + 1, rounds.len())
    };
    if next < n_rounds {
        begin_planned_round(sim, st, cid, next);
    } else {
        advance_phase(sim, st, cid);
    }
}

// ---------------------------------------------------------------------
// In-switch reduction executor (NetReduce-style segment pipeline)
// ---------------------------------------------------------------------

fn start_switch_phase(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let (bytes, elems, groups, idx, n_phases, wire_ratio, n) = {
        let c = &st.collectives[cid];
        let p = c.planned_ref();
        let (bytes, elems, groups) = match &p.phases[p.phase_idx] {
            Phase::SwitchReduce { bytes, elems, groups } => (*bytes, *elems, groups.clone()),
            _ => unreachable!("switch start in a non-switch phase"),
        };
        (
            bytes,
            elems,
            groups,
            p.phase_idx,
            p.phases.len(),
            st.jobs[c.job].wire_ratio,
            c.ranks.len(),
        )
    };
    assert!(
        st.fabric.switch_reduce_capable(),
        "in-switch plan on a fabric without reduction engines (planner fallback bug)"
    );
    let segs = (bytes / st.sys.nic.segment_bytes).ceil().max(1.0) as usize;
    let seg_bytes = bytes / segs as f64;
    let seg_elems = elems / segs as f64;
    let wire_seg = seg_bytes / wire_ratio;
    // the pipeline window is the flow's granted table share; flows that
    // never went through admission (directly-constructed planned states)
    // keep the legacy whole-table window
    let window = match st.collectives[cid].tenancy {
        TenancyOutcome::Admitted { granted_bytes } => {
            let w = (granted_bytes / seg_bytes).floor() as usize;
            assert!(w >= 1, "admitted flow's granted table share is below one segment");
            w
        }
        TenancyOutcome::NotRequested => {
            let w = (st.sys.switch.reduce_table_bytes / seg_bytes).floor() as usize;
            assert!(w >= 1, "aggregation table smaller than one segment (planner fallback bug)");
            w
        }
        TenancyOutcome::Evicted | TenancyOutcome::Fallback => {
            unreachable!("denied flow {cid} reached the in-switch executor")
        }
    };
    let window = window.min(segs);
    let mut group_of = vec![usize::MAX; n];
    for (g, grp) in groups.iter().enumerate() {
        for &local in grp {
            group_of[local] = g;
        }
    }
    let ranks = &st.collectives[cid].ranks;
    let group_leaves: Vec<usize> = groups
        .iter()
        .map(|grp| st.fabric.topology.leaf_of(ranks[grp[0]]))
        .collect();
    let root = ranks[groups[0][0]];
    let members: Vec<usize> = groups.iter().flatten().copied().collect();
    let member_count = members.len();
    let per_group: Vec<usize> = groups.iter().map(Vec::len).collect();
    let n_groups = groups.len();
    st.collectives[cid].planned_mut().sw = Some(SwitchProgress {
        mcast: false,
        seg_bytes,
        wire_seg,
        seg_elems,
        segs,
        window,
        fetch: idx == 0,
        writeback: idx + 1 == n_phases,
        root,
        group_of,
        group_leaves,
        members,
        next_seg: 0,
        inflight: 0,
        done: 0,
        group_pending: (0..segs).map(|_| per_group.clone()).collect(),
        spine_pending: vec![n_groups; segs],
        rank_pending: vec![member_count; segs],
    });
    for _ in 0..window {
        switch_launch_next(sim, st, cid);
    }
}

/// Launch the next segment if a table slot is free: queue every member's
/// PCIe fetch (or contribute directly when the data is already on-NIC).
fn switch_launch_next(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let now = sim.now();
    let launch = {
        let p = st.collectives[cid].planned_mut();
        let sw = p.sw.as_mut().expect("no in-switch pass active");
        if sw.next_seg >= sw.segs || sw.inflight >= sw.window {
            None
        } else {
            let seg = sw.next_seg;
            sw.next_seg += 1;
            sw.inflight += 1;
            Some((seg, sw.fetch, sw.seg_bytes, sw.members.clone()))
        }
    };
    let Some((seg, fetch, seg_bytes, members)) = launch else {
        return;
    };
    for local in members {
        if fetch {
            let node = st.collectives[cid].ranks[local];
            let done = st.fabric.nodes[node].pcie.to_device.transmit(now, seg_bytes);
            sim.schedule_at(
                done,
                Event::SwitchContribute {
                    cid: cid as u32,
                    seg: seg as u32,
                    rank: local as u32,
                },
            );
        } else {
            switch_contribute(sim, st, cid, seg, local);
        }
    }
}

/// One member's copy of `seg` is on its NIC: Tx-serialize it and fold it
/// into the local aggregation engine.
pub(super) fn switch_contribute(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    seg: usize,
    local: usize,
) {
    let now = sim.now();
    let (src, root, wire_seg, seg_elems, g) = {
        let c = &st.collectives[cid];
        let sw = c.planned_ref().sw.as_ref().expect("no in-switch pass active");
        (c.ranks[local], sw.root, sw.wire_seg, sw.seg_elems, sw.group_of[local])
    };
    let folded = st.fabric.reduce_fold_local(src, root, now, wire_seg, seg_elems);
    sim.schedule_at(
        folded,
        Event::SwitchFoldDone {
            cid: cid as u32,
            seg: seg as u32,
            group: g as u32,
        },
    );
}

/// A contribution folded at group `g`'s leaf engine; when the group is
/// complete, ship the aggregate to the spine (or multicast directly when
/// the whole collective sits behind one switch).
pub(super) fn switch_fold_done(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    seg: usize,
    g: usize,
) {
    let now = sim.now();
    let remaining = {
        let sw = st.collectives[cid].planned_mut().sw.as_mut().unwrap();
        sw.group_pending[seg][g] -= 1;
        sw.group_pending[seg][g]
    };
    if remaining > 0 {
        return;
    }
    let (spanning, leaf, root, wire_seg, seg_elems) = {
        let sw = st.collectives[cid].planned_ref().sw.as_ref().unwrap();
        (
            sw.group_leaves.len() > 1,
            sw.group_leaves[g],
            sw.root,
            sw.wire_seg,
            sw.seg_elems,
        )
    };
    if !spanning {
        // the completed aggregate drains through the root engine's
        // occupancy server — two tenants folding through one engine
        // genuinely serialize here, one slot per segment
        let drained = st.fabric.reduce_engine_occupancy(root, now, wire_seg);
        sim.schedule_at(
            drained,
            Event::SwitchMulticast { cid: cid as u32, seg: seg as u32, group: g as u32 },
        );
        return;
    }
    let at_spine =
        st.fabric.reduce_fold_spine(cid as u32, leaf, root, now, wire_seg, seg_elems);
    sim.schedule_at(at_spine, Event::SwitchSpineDone { cid: cid as u32, seg: seg as u32 });
}

/// A leaf aggregate folded at the spine engine; when all leaves are in,
/// multicast one copy down every leaf's bundle.
pub(super) fn switch_spine_done(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    seg: usize,
) {
    let now = sim.now();
    let remaining = {
        let sw = st.collectives[cid].planned_mut().sw.as_mut().unwrap();
        sw.spine_pending[seg] -= 1;
        sw.spine_pending[seg]
    };
    if remaining > 0 {
        return;
    }
    let (leaves, wire_seg, root) = {
        let sw = st.collectives[cid].planned_ref().sw.as_ref().unwrap();
        (sw.group_leaves.clone(), sw.wire_seg, sw.root)
    };
    // one occupancy-server slot per segment at the spine engine: tenants
    // sharing the root egress serialize their drained aggregates
    let drained = st.fabric.reduce_engine_occupancy(root, now, wire_seg);
    for (g, leaf) in leaves.into_iter().enumerate() {
        let at_leaf = st.fabric.reduce_downlink(leaf, drained, wire_seg);
        sim.schedule_at(
            at_leaf,
            Event::SwitchMulticast {
                cid: cid as u32,
                seg: seg as u32,
                group: g as u32,
            },
        );
    }
}

/// The reduced segment reached group `g`'s leaf switch: final egress to
/// every member of the group.
pub(super) fn switch_multicast(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    seg: usize,
    g: usize,
) {
    let now = sim.now();
    let (members, wire_seg) = {
        let c = &st.collectives[cid];
        let p = c.planned_ref();
        let groups = match &p.phases[p.phase_idx] {
            Phase::SwitchReduce { groups, .. } => groups,
            _ => unreachable!("multicast in a non-switch phase"),
        };
        (groups[g].clone(), p.sw.as_ref().unwrap().wire_seg)
    };
    for local in members {
        let dst = st.collectives[cid].ranks[local];
        let at_nic = st.fabric.reduce_deliver(dst, now, wire_seg);
        sim.schedule_at(
            at_nic,
            Event::SwitchDelivered {
                cid: cid as u32,
                seg: seg as u32,
                rank: local as u32,
            },
        );
    }
}

/// The reduced segment reached a member's NIC: DMA it to the host when
/// this pass owns the writeback.
pub(super) fn switch_delivered(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    seg: usize,
    local: usize,
) {
    let now = sim.now();
    let (writeback, seg_bytes, node) = {
        let c = &st.collectives[cid];
        let sw = c.planned_ref().sw.as_ref().unwrap();
        (sw.writeback, sw.seg_bytes, c.ranks[local])
    };
    if writeback {
        let wb = st.fabric.nodes[node].pcie.to_host.transmit(now, seg_bytes);
        sim.schedule_at(wb, Event::SwitchRankDone { cid: cid as u32, seg: seg as u32 });
    } else {
        switch_rank_done(sim, st, cid, seg);
    }
}

/// Segment bookkeeping (both switch modes): free the table slot when
/// every member is served, then launch the next queued segment or finish
/// the phase.
pub(super) fn switch_rank_done(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    seg: usize,
) {
    let outcome = {
        let sw = st.collectives[cid].planned_mut().sw.as_mut().unwrap();
        sw.rank_pending[seg] -= 1;
        if sw.rank_pending[seg] > 0 {
            None
        } else {
            sw.inflight -= 1;
            sw.done += 1;
            Some((sw.done == sw.segs, sw.mcast))
        }
    };
    match outcome {
        None => {}
        Some((false, false)) => switch_launch_next(sim, st, cid),
        Some((false, true)) => mcast_launch_next(sim, st, cid),
        Some((true, _)) => advance_phase(sim, st, cid),
    }
}

// ---------------------------------------------------------------------
// Switch-multicast executor (replication mode: the dual of the
// reduction pipeline with the folds removed — root streams up, the
// switch tier fans each segment out to every other member)
// ---------------------------------------------------------------------

fn start_mcast_phase(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let (bytes, groups, idx, n_phases, wire_ratio, n) = {
        let c = &st.collectives[cid];
        let p = c.planned_ref();
        let (bytes, groups) = match &p.phases[p.phase_idx] {
            Phase::SwitchMulticast { bytes, groups } => (*bytes, groups.clone()),
            _ => unreachable!("multicast start in a non-multicast phase"),
        };
        (
            bytes,
            groups,
            p.phase_idx,
            p.phases.len(),
            st.jobs[c.job].wire_ratio,
            c.ranks.len(),
        )
    };
    assert!(
        st.fabric.switch_reduce_capable(),
        "switch-multicast plan on a fabric without replication engines (planner fallback bug)"
    );
    let segs = (bytes / st.sys.nic.segment_bytes).ceil().max(1.0) as usize;
    let seg_bytes = bytes / segs as f64;
    let wire_seg = seg_bytes / wire_ratio;
    let window = (st.sys.switch.reduce_table_bytes / seg_bytes).floor() as usize;
    assert!(window >= 1, "replication table smaller than one segment (planner fallback bug)");
    let window = window.min(segs);
    let mut group_of = vec![usize::MAX; n];
    for (g, grp) in groups.iter().enumerate() {
        for &local in grp {
            group_of[local] = g;
        }
    }
    let ranks = &st.collectives[cid].ranks;
    let group_leaves: Vec<usize> = groups
        .iter()
        .map(|grp| st.fabric.topology.leaf_of(ranks[grp[0]]))
        .collect();
    let root = ranks[groups[0][0]];
    let members: Vec<usize> = groups.iter().flatten().copied().collect();
    // the root already holds the payload: every segment is delivered to
    // the other members only
    let fanout = members.len() - 1;
    st.collectives[cid].planned_mut().sw = Some(SwitchProgress {
        mcast: true,
        seg_bytes,
        wire_seg,
        seg_elems: 0.0,
        segs,
        window,
        fetch: idx == 0,
        writeback: idx + 1 == n_phases,
        root,
        group_of,
        group_leaves,
        members,
        next_seg: 0,
        inflight: 0,
        done: 0,
        // replication folds nothing: the reduction countdowns stay empty
        group_pending: Vec::new(),
        spine_pending: Vec::new(),
        rank_pending: vec![fanout; segs],
    });
    for _ in 0..window {
        mcast_launch_next(sim, st, cid);
    }
}

/// Launch the next segment if a table slot is free: DMA-fetch it at the
/// root (or send directly when a preceding phase left it on the NIC).
fn mcast_launch_next(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let now = sim.now();
    let launch = {
        let p = st.collectives[cid].planned_mut();
        let sw = p.sw.as_mut().expect("no multicast pass active");
        if sw.next_seg >= sw.segs || sw.inflight >= sw.window {
            None
        } else {
            let seg = sw.next_seg;
            sw.next_seg += 1;
            sw.inflight += 1;
            Some((seg, sw.fetch, sw.seg_bytes, sw.root))
        }
    };
    let Some((seg, fetch, seg_bytes, root)) = launch else {
        return;
    };
    if fetch {
        let done = st.fabric.nodes[root].pcie.to_device.transmit(now, seg_bytes);
        sim.schedule_at(done, Event::McastUp { cid: cid as u32, seg: seg as u32 });
    } else {
        mcast_up(sim, st, cid, seg);
    }
}

/// [`Event::McastUp`]: the root's copy of `seg` is on its NIC — Tx-
/// serialize it toward the switch tier, then cross the spine when the
/// members span leaves (or go straight to leaf delivery when they don't).
pub(super) fn mcast_up(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId, seg: usize) {
    let now = sim.now();
    let (root, wire_seg, spanning, root_leaf) = {
        let c = &st.collectives[cid];
        let sw = c.planned_ref().sw.as_ref().expect("no multicast pass active");
        (sw.root, sw.wire_seg, sw.group_leaves.len() > 1, sw.group_leaves[0])
    };
    let at_switch = st.fabric.nodes[root].tx.transmit(now, wire_seg);
    if spanning {
        let at_spine = st.fabric.mcast_to_spine(root_leaf, at_switch, wire_seg);
        sim.schedule_at(at_spine, Event::McastSpine { cid: cid as u32, seg: seg as u32 });
    } else {
        sim.schedule_at(
            at_switch,
            Event::McastLeaf { cid: cid as u32, seg: seg as u32, group: 0 },
        );
    }
}

/// [`Event::McastSpine`]: the segment reached the spine replication
/// point — one copy down every member leaf's bundle.
pub(super) fn mcast_spine(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    seg: usize,
) {
    let now = sim.now();
    let (leaves, wire_seg) = {
        let sw = st.collectives[cid].planned_ref().sw.as_ref().unwrap();
        (sw.group_leaves.clone(), sw.wire_seg)
    };
    for (g, leaf) in leaves.into_iter().enumerate() {
        let at_leaf = st.fabric.reduce_downlink(leaf, now, wire_seg);
        sim.schedule_at(
            at_leaf,
            Event::McastLeaf {
                cid: cid as u32,
                seg: seg as u32,
                group: g as u32,
            },
        );
    }
}

/// [`Event::McastLeaf`]: the segment reached group `g`'s leaf switch —
/// replicated final egress to every member of the group except the root
/// (which already holds the payload), each copy counted into the
/// multicast conservation ledger.
pub(super) fn mcast_leaf(
    sim: &mut ClusterSim,
    st: &mut ClusterState,
    cid: CollectiveId,
    seg: usize,
    g: usize,
) {
    let now = sim.now();
    let (members, wire_seg, root) = {
        let c = &st.collectives[cid];
        let p = c.planned_ref();
        let groups = match &p.phases[p.phase_idx] {
            Phase::SwitchMulticast { groups, .. } => groups,
            _ => unreachable!("multicast delivery in a non-multicast phase"),
        };
        let sw = p.sw.as_ref().unwrap();
        (groups[g].clone(), sw.wire_seg, sw.root)
    };
    for local in members {
        let dst = st.collectives[cid].ranks[local];
        if dst == root {
            continue;
        }
        let at_nic = st.fabric.mcast_deliver(dst, now, wire_seg);
        sim.schedule_at(
            at_nic,
            Event::SwitchDelivered {
                cid: cid as u32,
                seg: seg as u32,
                rank: local as u32,
            },
        );
    }
}

/// Binomial reduce-to-root + broadcast as rounds of local-rank transfers.
pub fn binomial_rounds(n: usize, bytes: f64, elems: f64) -> Vec<Vec<RoundOp>> {
    let mut reduce_rounds: Vec<Vec<RoundOp>> = Vec::new();
    let mut k = 1usize;
    while k < n {
        let mut ops = Vec::new();
        let mut dst = 0usize;
        while dst + k < n {
            ops.push(RoundOp {
                src: dst + k,
                dst,
                bytes,
                reduce_elems: elems,
            });
            dst += 2 * k;
        }
        reduce_rounds.push(ops);
        k *= 2;
    }
    let mut rounds = reduce_rounds.clone();
    for r in reduce_rounds.iter().rev() {
        rounds.push(
            r.iter()
                .map(|op| RoundOp {
                    src: op.dst,
                    dst: op.src,
                    bytes,
                    reduce_elems: 0.0,
                })
                .collect(),
        );
    }
    rounds
}

/// Rabenseifner recursive halving/doubling as rounds, with surplus ranks
/// folded in/out for non-powers-of-two (mirrors
/// `collective::algorithms::rabenseifner_allreduce`).
pub fn rabenseifner_rounds(n: usize, bytes: f64, elems: f64) -> Vec<Vec<RoundOp>> {
    let p = if n.is_power_of_two() {
        n
    } else {
        1usize << (usize::BITS - 1 - n.leading_zeros())
    };
    let r = n - p;
    let active: Vec<usize> = (0..r).map(|i| 2 * i).chain(2 * r..n).collect();
    let mut rounds: Vec<Vec<RoundOp>> = Vec::new();
    if r > 0 {
        rounds.push(
            (0..r)
                .map(|i| RoundOp {
                    src: 2 * i + 1,
                    dst: 2 * i,
                    bytes,
                    reduce_elems: elems,
                })
                .collect(),
        );
    }
    // recursive halving reduce-scatter
    let mut dist = p / 2;
    let mut vol = bytes / 2.0;
    let mut vol_elems = elems / 2.0;
    while dist >= 1 {
        let mut ops = Vec::new();
        for v in 0..p {
            let peer = v ^ dist;
            if peer < v {
                continue;
            }
            ops.push(RoundOp {
                src: active[v],
                dst: active[peer],
                bytes: vol,
                reduce_elems: vol_elems,
            });
            ops.push(RoundOp {
                src: active[peer],
                dst: active[v],
                bytes: vol,
                reduce_elems: vol_elems,
            });
        }
        rounds.push(ops);
        dist /= 2;
        vol /= 2.0;
        vol_elems /= 2.0;
    }
    // recursive doubling allgather
    let mut dist = 1usize;
    let mut vol = bytes / p as f64;
    while dist < p {
        let mut ops = Vec::new();
        for v in 0..p {
            let peer = v ^ dist;
            if peer < v {
                continue;
            }
            ops.push(RoundOp {
                src: active[v],
                dst: active[peer],
                bytes: vol,
                reduce_elems: 0.0,
            });
            ops.push(RoundOp {
                src: active[peer],
                dst: active[v],
                bytes: vol,
                reduce_elems: 0.0,
            });
        }
        rounds.push(ops);
        dist *= 2;
        vol *= 2.0;
    }
    if r > 0 {
        rounds.push(
            (0..r)
                .map(|i| RoundOp {
                    src: 2 * i,
                    dst: 2 * i + 1,
                    bytes,
                    reduce_elems: 0.0,
                })
                .collect(),
        );
    }
    rounds
}

/// Binomial-tree broadcast as rounds: the reverse of the binomial gather
/// tree, so round `r` doubles the set of ranks holding the payload.  The
/// root is local rank 0; `n - 1` full-payload transfers over
/// `ceil(log2 n)` rounds.
pub fn broadcast_binomial_rounds(n: usize, bytes: f64) -> Vec<Vec<RoundOp>> {
    let mut gather: Vec<Vec<RoundOp>> = Vec::new();
    let mut k = 1usize;
    while k < n {
        let mut ops = Vec::new();
        let mut dst = 0usize;
        while dst + k < n {
            ops.push(RoundOp {
                src: dst,
                dst: dst + k,
                bytes,
                reduce_elems: 0.0,
            });
            dst += 2 * k;
        }
        gather.push(ops);
        k *= 2;
    }
    gather.reverse();
    gather
}

/// Ring allgather as rounds: `n - 1` rounds in which every rank forwards
/// a shard of `bytes / n` to its successor, so each rank's shard walks
/// the whole ring.
pub fn allgather_ring_rounds(n: usize, bytes: f64) -> Vec<Vec<RoundOp>> {
    let shard = bytes / n as f64;
    (0..n.saturating_sub(1))
        .map(|_| {
            (0..n)
                .map(|i| RoundOp {
                    src: i,
                    dst: (i + 1) % n,
                    bytes: shard,
                    reduce_elems: 0.0,
                })
                .collect()
        })
        .collect()
}

/// Ring reduce-scatter as rounds: `n - 1` rounds, each forwarding a
/// partially-reduced shard of `bytes / n` to the successor, which folds
/// `elems / n` elements into its accumulator.
pub fn reduce_scatter_ring_rounds(n: usize, bytes: f64, elems: f64) -> Vec<Vec<RoundOp>> {
    let shard = bytes / n as f64;
    let shard_elems = elems / n as f64;
    (0..n.saturating_sub(1))
        .map(|_| {
            (0..n)
                .map(|i| RoundOp {
                    src: i,
                    dst: (i + 1) % n,
                    bytes: shard,
                    reduce_elems: shard_elems,
                })
                .collect()
        })
        .collect()
}

/// Pairwise-exchange all-to-all as rounds: round `r ∈ 1..n` has every
/// rank `i` send its `bytes / n` block for peer `(i + r) % n`, so every
/// ordered pair exchanges exactly once.
pub fn all_to_all_rounds(n: usize, bytes: f64) -> Vec<Vec<RoundOp>> {
    let block = bytes / n as f64;
    (1..n)
        .map(|r| {
            (0..n)
                .map(|i| RoundOp {
                    src: i,
                    dst: (i + r) % n,
                    bytes: block,
                    reduce_elems: 0.0,
                })
                .collect()
        })
        .collect()
}

// ---------------------------------------------------------------------
// Host (software/MPI) round executor
// ---------------------------------------------------------------------

fn begin_host_round(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId, round: usize) {
    let now = sim.now();
    let (ranks, work_secs, step_cost, n_rounds, extra) = {
        let c = &st.collectives[cid];
        let h = match &c.state {
            AlgoState::Host(h) => h,
            _ => unreachable!(),
        };
        (
            c.ranks.clone(),
            h.plan.bytes_per_round / h.eff_bw,
            h.step_cost,
            h.plan.rounds,
            h.plan.extra_step_costs,
        )
    };
    if round >= n_rounds {
        // latency-only tail (e.g. the pipelined tree's fill steps)
        let tail = extra as f64 * step_cost;
        if tail > 0.0 {
            sim.schedule(tail, Event::CollectiveComplete { cid: cid as u32 });
        } else {
            complete(sim, st, cid);
        }
        return;
    }
    {
        let h = st.collectives[cid].host_mut();
        h.current_round = round;
        h.round_pending = ranks.len();
    }
    for &node in &ranks {
        // the per-step software cost occupies the comm core (an MPI
        // progress thread spins through matching and the network hop, it
        // does not yield), so it is served — not just waited out.  An
        // uncontended run still reproduces the closed form exactly, while
        // concurrent collectives cannot hide each other's step overhead
        // on a shared core, matching the closed form's serial-round
        // assumption.
        let served = st.fabric.nodes[node].comm.serve(now, work_secs + step_cost);
        sim.schedule_at(served, Event::HostRoundDone { cid: cid as u32 });
    }
}

pub(super) fn host_round_done(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let (pending, round) = {
        let h = st.collectives[cid].host_mut();
        h.round_pending -= 1;
        (h.round_pending, h.current_round)
    };
    if pending == 0 {
        begin_host_round(sim, st, cid, round + 1);
    }
}

#[cfg(test)]
// exact float equalities are deliberate: byte/element bookkeeping is
// exact arithmetic the tests pin bit-for-bit
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;

    fn total_bytes(rounds: &[Vec<RoundOp>]) -> f64 {
        rounds.iter().flatten().map(|op| op.bytes).sum()
    }

    #[test]
    fn binomial_round_structure() {
        for n in [2usize, 3, 4, 5, 6, 7, 8, 12] {
            let rounds = binomial_rounds(n, 1024.0, 256.0);
            let lg = (n as f64).log2().ceil() as usize;
            assert_eq!(rounds.len(), 2 * lg, "n={n}");
            // reduce half carries (n-1) transfers total, broadcast mirrors
            let transfers: usize = rounds.iter().map(|r| r.len()).sum();
            assert_eq!(transfers, 2 * (n - 1), "n={n}");
            // every reduce op reduces; every broadcast op copies
            for (i, r) in rounds.iter().enumerate() {
                for op in r {
                    assert!(op.src < n && op.dst < n);
                    if i < lg {
                        assert!(op.reduce_elems > 0.0);
                    } else {
                        assert_eq!(op.reduce_elems, 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn rabenseifner_volume_is_bandwidth_optimal() {
        // per-phase wire volume per active rank: (1 - 1/p) * bytes, the
        // recursive-halving optimum
        let bytes = 4096.0;
        for n in [2usize, 4, 8, 16] {
            let rounds = rabenseifner_rounds(n, bytes, 1024.0);
            let lg = (n as f64).log2().ceil() as usize;
            assert_eq!(rounds.len(), 2 * lg, "n={n}");
            let total = total_bytes(&rounds);
            let want = 2.0 * n as f64 * (1.0 - 1.0 / n as f64) * bytes;
            assert!((total - want).abs() < 1e-9, "n={n}: {total} vs {want}");
        }
    }

    #[test]
    fn rabenseifner_nonpow2_folds() {
        let bytes = 1024.0;
        for n in [3usize, 5, 6, 7, 12] {
            let rounds = rabenseifner_rounds(n, bytes, 256.0);
            let p = 1usize << (usize::BITS - 1 - n.leading_zeros());
            let lg = (p as f64).log2() as usize;
            // fold + 2 lg(p) + unfold
            assert_eq!(rounds.len(), 2 * lg + 2, "n={n}");
            // fold round moves full payloads from the surplus ranks
            assert_eq!(rounds[0].len(), n - p);
            for op in &rounds[0] {
                assert_eq!(op.bytes, bytes);
                assert!(op.reduce_elems > 0.0);
            }
            // unfold round mirrors it without reducing
            let last = rounds.last().unwrap();
            assert_eq!(last.len(), n - p);
            for op in last {
                assert_eq!(op.reduce_elems, 0.0);
            }
        }
    }

    #[test]
    fn rabenseifner_ops_stay_in_range() {
        for n in 2..=17usize {
            for rounds in [
                rabenseifner_rounds(n, 512.0, 128.0),
                binomial_rounds(n, 512.0, 128.0),
                broadcast_binomial_rounds(n, 512.0),
                allgather_ring_rounds(n, 512.0),
                reduce_scatter_ring_rounds(n, 512.0, 128.0),
                all_to_all_rounds(n, 512.0),
            ] {
                for op in rounds.iter().flatten() {
                    assert!(op.src < n && op.dst < n && op.src != op.dst, "n={n} {op:?}");
                }
            }
        }
    }

    #[test]
    fn broadcast_rounds_double_coverage() {
        for n in [2usize, 3, 4, 5, 6, 7, 8, 13] {
            let rounds = broadcast_binomial_rounds(n, 2048.0);
            let lg = (n as f64).log2().ceil() as usize;
            assert_eq!(rounds.len(), lg, "n={n}");
            let transfers: usize = rounds.iter().map(|r| r.len()).sum();
            assert_eq!(transfers, n - 1, "n={n}");
            // simulate: a rank may only send once it holds the payload,
            // and every rank ends up holding it exactly once
            let mut holds = vec![false; n];
            holds[0] = true;
            for r in &rounds {
                let snapshot = holds.clone();
                for op in r {
                    assert!(snapshot[op.src], "n={n}: rank {} sent before receiving", op.src);
                    assert!(!holds[op.dst], "n={n}: rank {} received twice", op.dst);
                    assert_eq!(op.bytes, 2048.0);
                    assert_eq!(op.reduce_elems, 0.0);
                    holds[op.dst] = true;
                }
            }
            assert!(holds.iter().all(|&h| h), "n={n}");
        }
    }

    #[test]
    fn allgather_ring_walks_every_shard_everywhere() {
        for n in [2usize, 3, 5, 8] {
            let rounds = allgather_ring_rounds(n, 4096.0);
            assert_eq!(rounds.len(), n - 1);
            // track shard ownership: have[i] = set of shards rank i holds,
            // ring forwarding passes the shard received last round
            let mut have: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
            for r in &rounds {
                assert_eq!(r.len(), n);
                let latest: Vec<usize> = have.iter().map(|h| *h.last().unwrap()).collect();
                for op in r {
                    assert_eq!(op.dst, (op.src + 1) % n);
                    assert!((op.bytes - 4096.0 / n as f64).abs() < 1e-12);
                    have[op.dst].push(latest[op.src]);
                }
            }
            for (i, h) in have.iter().enumerate() {
                let mut s = h.clone();
                s.sort_unstable();
                s.dedup();
                assert_eq!(s.len(), n, "rank {i} missing shards: {h:?}");
            }
        }
    }

    #[test]
    fn reduce_scatter_ring_reduces_each_shard_n_minus_1_times() {
        for n in [2usize, 4, 6] {
            let rounds = reduce_scatter_ring_rounds(n, 4096.0, 1024.0);
            assert_eq!(rounds.len(), n - 1);
            let adds: f64 = rounds.iter().flatten().map(|op| op.reduce_elems).sum();
            // each of the n shards of elems/n is folded (n-1) times
            let want = (n - 1) as f64 * 1024.0;
            assert!((adds - want).abs() < 1e-9, "n={n}: {adds} vs {want}");
        }
    }

    #[test]
    fn all_to_all_covers_every_ordered_pair_once() {
        for n in [2usize, 3, 5, 8] {
            let rounds = all_to_all_rounds(n, 4096.0);
            assert_eq!(rounds.len(), n - 1);
            let mut seen = vec![vec![0usize; n]; n];
            for op in rounds.iter().flatten() {
                assert!((op.bytes - 4096.0 / n as f64).abs() < 1e-12);
                assert_eq!(op.reduce_elems, 0.0);
                seen[op.src][op.dst] += 1;
            }
            for (i, row) in seen.iter().enumerate() {
                for (j, &c) in row.iter().enumerate() {
                    assert_eq!(c, usize::from(i != j), "pair ({i},{j})");
                }
            }
        }
    }
}
