//! The event-driven trainer: one training job's compute lane as events.
//!
//! A job's worker executes the paper's Fig. 3b schedule as a task list —
//! forward layers, then backward from the top with a *non-blocking*
//! all-reduce posted after each layer's backward, interleaved weight
//! updates, and a block point before each update that needs its reduced
//! gradient.  Because the all-reduces are real event-driven collectives on
//! the shared fabric (not glued-in closed-form durations), a posted AR
//! executes concurrently with later compute, with the job's other
//! in-flight ARs, and with every other job on the cluster.
//!
//! Compute durations come from the same calibrated model as the
//! serialized path (`analytic::model::layer_times`), so any timing
//! difference between the two engines is attributable purely to how
//! communication is executed.

use super::{
    collective, ClusterSim, ClusterState, CollectiveAlgo, CollectiveId, CollectiveKind, Event,
    JobId, NodeId,
};
use crate::analytic::model::{layer_times, LayerTimes, SystemKind};
use crate::bfp::BfpCodec;
use crate::collective::timing::HostNet;
use crate::netsim::Time;
use crate::sysconfig::{SystemParams, Workload};

/// Description of one training job to run on the cluster.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    pub kind: SystemKind,
    pub workload: Workload,
    /// physical nodes this job's workers run on (one rank per node);
    /// different jobs may share nodes — that is what multi-tenancy means
    pub ranks: Vec<NodeId>,
    /// virtual time the job's iteration starts
    pub start_at: Time,
    /// all-reduce algorithm per layer (index = layer)
    pub layer_algos: Vec<CollectiveAlgo>,
    /// collective pattern per layer (index = layer); all-reduce for a
    /// gradient exchange, but a layer may instead be an MoE all-to-all,
    /// a weight broadcast, etc.
    pub layer_kinds: Vec<CollectiveKind>,
}

impl JobSpec {
    /// A job with the kind's natural algorithm on every layer: the NIC
    /// ring for smart-NIC systems, the host scheme for the baselines.
    pub fn new(name: &str, kind: SystemKind, workload: Workload, ranks: Vec<NodeId>) -> Self {
        assert!(workload.layers >= 1, "job needs at least one layer");
        assert!(!ranks.is_empty(), "job needs at least one rank");
        let default_algo = match kind {
            SystemKind::SmartNic { .. } => CollectiveAlgo::NicRing,
            SystemKind::BaselineNaive { scheme }
            | SystemKind::BaselineOverlapped { scheme, .. } => CollectiveAlgo::Host(scheme),
        };
        Self {
            name: name.to_string(),
            kind,
            workload,
            ranks,
            start_at: 0.0,
            layer_algos: vec![default_algo; workload.layers],
            layer_kinds: vec![CollectiveKind::AllReduce; workload.layers],
        }
    }

    pub fn starting_at(mut self, t: Time) -> Self {
        assert!(t >= 0.0 && t.is_finite());
        self.start_at = t;
        self
    }

    /// Override the all-reduce algorithm layer by layer.
    pub fn with_layer_algos(mut self, algos: Vec<CollectiveAlgo>) -> Self {
        assert_eq!(
            algos.len(),
            self.workload.layers,
            "need one algorithm per layer"
        );
        self.layer_algos = algos;
        self
    }

    /// Let the topology-aware planner pick every layer's algorithm from
    /// the fabric shape, placement and message size.
    pub fn with_auto_planner(mut self) -> Self {
        self.layer_algos = vec![CollectiveAlgo::Auto; self.workload.layers];
        self
    }

    /// Override the collective pattern layer by layer (e.g. an MoE
    /// iteration interleaving all-to-all with all-reduce, or an
    /// inference replica set broadcasting weights).
    pub fn with_layer_kinds(mut self, kinds: Vec<CollectiveKind>) -> Self {
        assert_eq!(kinds.len(), self.workload.layers, "need one kind per layer");
        self.layer_kinds = kinds;
        self
    }
}

/// One step of the worker lane.
#[derive(Clone, Debug)]
pub enum WorkerTask {
    /// occupy the worker for `dur` seconds (fwd/bwd/upd)
    Compute { dur: f64, label: String },
    /// fire layer `layer`'s non-blocking all-reduce (zero virtual time)
    PostAr { layer: usize },
    /// block until layer `layer`'s all-reduce has completed
    WaitAr { layer: usize },
}

/// Live state of one job inside the cluster simulation.
pub struct JobRuntime {
    pub spec: JobSpec,
    pub lt: LayerTimes,
    /// wire compression ratio of this job's gradients (1.0 = raw FP32)
    pub wire_ratio: f64,
    /// software all-reduce environment for Host(...) collectives
    pub host_env: HostNet,
    pub tasks: Vec<WorkerTask>,
    pub next_task: usize,
    pub blocked_on: Option<CollectiveId>,
    pub block_started: Time,
    pub ar_of_layer: Vec<Option<CollectiveId>>,
    pub t_done: Option<Time>,
    pub worker_lane: String,
    pub comm_lane: String,
    /// placement generation: bumped by every preempt/restart so wakes
    /// scheduled against an older placement are dropped on dispatch
    pub epoch: u32,
    /// training iterations this job runs before it departs (1 on the
    /// static scenario paths; the arrival trace sets more)
    pub iters_total: usize,
    /// iterations completed so far — a restart replays the current
    /// iteration from this checkpoint, never re-counting finished ones
    pub iters_done: usize,
}

impl JobRuntime {
    pub fn new(spec: JobSpec, sys: &SystemParams) -> Self {
        let n = spec.ranks.len();
        let lt = layer_times(spec.kind, sys, &spec.workload, n);
        let wire_ratio = match spec.kind {
            SystemKind::SmartNic { bfp: true } => BfpCodec::bfp16().compression_ratio(),
            _ => 1.0,
        };
        let host_bw_cap = match spec.kind {
            SystemKind::BaselineOverlapped { comm_cores, .. } => {
                sys.worker.host_comm_bw(Some(comm_cores), n)
            }
            _ => sys.worker.host_comm_bw(None, n),
        };
        let host_env = HostNet {
            net: sys.net,
            step_overhead: sys.host_step_overhead,
            comm_bw_cap: host_bw_cap,
        };
        let overlap = !matches!(spec.kind, SystemKind::BaselineNaive { .. });
        let tasks = compile_tasks(&lt, spec.workload.layers, overlap);
        let comm_suffix = match spec.kind {
            SystemKind::SmartNic { .. } => "nic",
            _ => "comm",
        };
        let layers = spec.workload.layers;
        let worker_lane = format!("{}/worker", spec.name);
        let comm_lane = format!("{}/{comm_suffix}", spec.name);
        Self {
            spec,
            lt,
            wire_ratio,
            host_env,
            tasks,
            next_task: 0,
            blocked_on: None,
            block_started: 0.0,
            ar_of_layer: vec![None; layers],
            t_done: None,
            worker_lane,
            comm_lane,
            epoch: 0,
            iters_total: 1,
            iters_done: 0,
        }
    }

    /// Rebuild this runtime for a new placement (gang scheduling or an
    /// elastic resize): recompute the layer times, wire ratio, host
    /// environment and task list for `ranks`, resetting the worker to the
    /// top of the iteration.  The placement generation and iteration
    /// checkpoint survive — a restarted job replays only its current
    /// iteration.
    pub fn reconfigure(&mut self, ranks: Vec<NodeId>, sys: &SystemParams) {
        let mut spec = self.spec.clone();
        spec.ranks = ranks;
        let epoch = self.epoch;
        let iters_total = self.iters_total;
        let iters_done = self.iters_done;
        *self = JobRuntime::new(spec, sys);
        self.epoch = epoch;
        self.iters_total = iters_total;
        self.iters_done = iters_done;
    }
}

/// Compile the Fig. 3b schedule into worker tasks.  `overlap = false`
/// serializes bwd → blocking AR → upd per layer (the naive baseline);
/// otherwise the worker posts each AR right after the layer's backward
/// and only blocks where the serialized path blocks, so the two engines
/// agree whenever all-reduces do not actually queue.
fn compile_tasks(lt: &LayerTimes, layers: usize, overlap: bool) -> Vec<WorkerTask> {
    let l = layers;
    let mut tasks = Vec::new();
    let compute = |dur: f64, label: String| WorkerTask::Compute { dur, label };
    for i in 0..l {
        tasks.push(compute(lt.t_f, format!("fwd[{i}]")));
    }
    if !overlap || l == 1 {
        for i in (0..l).rev() {
            tasks.push(compute(lt.t_b, format!("bwd[{i}]")));
            tasks.push(WorkerTask::PostAr { layer: i });
            tasks.push(WorkerTask::WaitAr { layer: i });
            tasks.push(compute(lt.t_u, format!("upd[{i}]")));
        }
        return tasks;
    }
    // overlapped: bwd[l-1], bwd[l-2] posted back to back, then per
    // segment i: (upd[i+1], bwd[i-1]) while AR[i] is in flight
    tasks.push(compute(lt.t_b, format!("bwd[{}]", l - 1)));
    tasks.push(WorkerTask::PostAr { layer: l - 1 });
    tasks.push(compute(lt.t_b, format!("bwd[{}]", l - 2)));
    tasks.push(WorkerTask::PostAr { layer: l - 2 });
    tasks.push(WorkerTask::WaitAr { layer: l - 1 });
    for i in (1..=l.saturating_sub(2)).rev() {
        tasks.push(compute(lt.t_u, format!("upd[{}]", i + 1)));
        tasks.push(compute(lt.t_b, format!("bwd[{}]", i - 1)));
        tasks.push(WorkerTask::PostAr { layer: i - 1 });
        tasks.push(WorkerTask::WaitAr { layer: i });
    }
    tasks.push(compute(lt.t_u, "upd[1]".to_string()));
    tasks.push(WorkerTask::WaitAr { layer: 0 });
    tasks.push(compute(lt.t_u, "upd[0]".to_string()));
    tasks
}

/// Advance `jid`'s worker from its current task until it blocks, starts a
/// compute span, or finishes the iteration.  Invoked at the job's start
/// time and again at every event that frees the worker.
pub fn run_worker(sim: &mut ClusterSim, st: &mut ClusterState, jid: JobId) {
    let now = sim.now();
    if st.jobs[jid].t_done.is_some() {
        return;
    }
    loop {
        let idx = st.jobs[jid].next_task;
        if idx >= st.jobs[jid].tasks.len() {
            st.jobs[jid].iters_done += 1;
            if st.jobs[jid].iters_done < st.jobs[jid].iters_total {
                // iteration boundary = the checkpoint: restart the task
                // list and let the scheduler apply any pending elastic
                // resize (no collectives are in flight here — the Fig. 3b
                // schedule waits on every posted AR before its last update)
                st.jobs[jid].next_task = 0;
                for slot in st.jobs[jid].ar_of_layer.iter_mut() {
                    *slot = None;
                }
                if st.sched.is_some() {
                    super::sched::on_iteration_boundary(sim, st, jid);
                }
                continue;
            }
            st.jobs[jid].t_done = Some(now);
            if st.sched.is_some() {
                sim.schedule_at(now, Event::JobDepart { job: jid as u32 });
            }
            return;
        }
        let task = st.jobs[jid].tasks[idx].clone();
        match task {
            WorkerTask::Compute { dur, label } => {
                st.jobs[jid].next_task = idx + 1;
                let lane = st.jobs[jid].worker_lane.clone();
                st.trace.add(&lane, &label, now, now + dur);
                let epoch = st.jobs[jid].epoch;
                sim.schedule_at(now + dur, Event::JobWake { job: jid as u32, epoch });
                return;
            }
            WorkerTask::PostAr { layer } => {
                st.jobs[jid].next_task = idx + 1;
                let cid = collective::post(sim, st, jid, layer);
                st.jobs[jid].ar_of_layer[layer] = Some(cid);
            }
            WorkerTask::WaitAr { layer } => {
                let cid = st.jobs[jid].ar_of_layer[layer]
                    .expect("schedule bug: WaitAr before PostAr");
                if st.collectives[cid].t_done.is_some() {
                    st.jobs[jid].next_task = idx + 1;
                } else {
                    st.jobs[jid].blocked_on = Some(cid);
                    st.jobs[jid].block_started = now;
                    return;
                }
            }
        }
    }
}

/// Called by the collective layer when `cid` completes: if the owning
/// job's worker is parked on it, record the wait and resume.
pub fn on_collective_done(sim: &mut ClusterSim, st: &mut ClusterState, cid: CollectiveId) {
    let now = sim.now();
    let jid = st.collectives[cid].job;
    if st.jobs[jid].blocked_on != Some(cid) {
        return;
    }
    st.jobs[jid].blocked_on = None;
    let layer = st.collectives[cid].layer;
    let started = st.jobs[jid].block_started;
    if now > started {
        let lane = st.jobs[jid].worker_lane.clone();
        st.trace.add(&lane, &format!("wait-ar[{layer}]"), started, now);
    }
    st.jobs[jid].next_task += 1; // consume the WaitAr
    run_worker(sim, st, jid);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Scheme;

    fn lt() -> LayerTimes {
        LayerTimes {
            t_f: 1.0,
            t_b: 2.0,
            t_ar: 0.0,
            t_u: 0.5,
            layers: 4,
        }
    }

    fn labels(tasks: &[WorkerTask]) -> Vec<String> {
        tasks
            .iter()
            .map(|t| match t {
                WorkerTask::Compute { label, .. } => label.clone(),
                WorkerTask::PostAr { layer } => format!("post[{layer}]"),
                WorkerTask::WaitAr { layer } => format!("wait[{layer}]"),
            })
            .collect()
    }

    #[test]
    fn overlapped_schedule_matches_fig3b() {
        let tasks = compile_tasks(&lt(), 4, true);
        assert_eq!(
            labels(&tasks),
            vec![
                "fwd[0]", "fwd[1]", "fwd[2]", "fwd[3]", // forward pass
                "bwd[3]", "post[3]", "bwd[2]", "post[2]", "wait[3]", // top segment
                "upd[3]", "bwd[1]", "post[1]", "wait[2]", // segment 2
                "upd[2]", "bwd[0]", "post[0]", "wait[1]", // segment 1
                "upd[1]", "wait[0]", "upd[0]", // tail
            ]
        );
    }

    #[test]
    fn naive_schedule_serializes() {
        let tasks = compile_tasks(&lt(), 2, false);
        assert_eq!(
            labels(&tasks),
            vec![
                "fwd[0]", "fwd[1]", "bwd[1]", "post[1]", "wait[1]", "upd[1]", "bwd[0]",
                "post[0]", "wait[0]", "upd[0]",
            ]
        );
    }

    #[test]
    fn single_layer_schedule() {
        let mut l1 = lt();
        l1.layers = 1;
        let tasks = compile_tasks(&l1, 1, true);
        assert_eq!(
            labels(&tasks),
            vec!["fwd[0]", "bwd[0]", "post[0]", "wait[0]", "upd[0]"]
        );
    }

    #[test]
    fn default_algos_follow_kind() {
        let w = Workload::paper_mlp(448);
        let nic = JobSpec::new("a", SystemKind::SmartNic { bfp: true }, w, vec![0, 1]);
        assert!(nic.layer_algos.iter().all(|a| *a == CollectiveAlgo::NicRing));
        let base = JobSpec::new(
            "b",
            SystemKind::BaselineNaive { scheme: Scheme::Ring },
            w,
            vec![0, 1],
        );
        assert!(base
            .layer_algos
            .iter()
            .all(|a| *a == CollectiveAlgo::Host(Scheme::Ring)));
    }

    #[test]
    #[should_panic(expected = "one algorithm per layer")]
    fn wrong_algo_count_panics() {
        let w = Workload::paper_mlp(448);
        let _ = JobSpec::new("a", SystemKind::SmartNic { bfp: false }, w, vec![0, 1])
            .with_layer_algos(vec![CollectiveAlgo::NicRing]);
    }
}
