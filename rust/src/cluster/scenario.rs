//! Multi-job cluster scenarios: N training jobs on one switch fabric.
//!
//! `run_scenario` builds the shared [`Fabric`] for the spec's
//! [`Topology`] (flat crossbar by default, leaf–spine via
//! [`ClusterSpec::with_topology`]), compiles every job's worker schedule,
//! seeds the calendar queue with the jobs' start events and runs the
//! clock dry.  Jobs that share nodes contend for those nodes' Tx links,
//! PCIe, adders and comm cores; all jobs contend for switch egress ports
//! and — on a leaf–spine fabric — for the oversubscribed leaf uplinks.
//! Straggler / degraded-link injection lives in the fabric, so a fault
//! degrades every in-flight collective of every job that touches the
//! faulty node — not just a single ring.

use super::collective::TenancyOutcome;
use super::job::{JobRuntime, JobSpec};
use super::{ClusterSim, ClusterState, Event};
use crate::netsim::audit::{AuditReport, AuditViolation};
use crate::netsim::engine::{EngineKind, PartitionStats, Sim};
use crate::netsim::fabric::Fabric;
use crate::netsim::topology::Topology;
use crate::netsim::Time;
use crate::sysconfig::{ClusterFaults, SystemParams};
use crate::trace::Trace;

/// A cluster plus the jobs to run on it.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub sys: SystemParams,
    pub topology: Topology,
    pub faults: ClusterFaults,
    pub jobs: Vec<JobSpec>,
}

impl ClusterSpec {
    /// A flat (single non-blocking crossbar) cluster of `nodes` nodes.
    pub fn new(sys: SystemParams, nodes: usize) -> Self {
        Self {
            sys,
            topology: Topology::flat(nodes),
            faults: ClusterFaults::none(),
            jobs: Vec::new(),
        }
    }

    /// Replace the interconnect shape (e.g. an oversubscribed leaf–spine).
    pub fn with_topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }

    pub fn with_job(mut self, job: JobSpec) -> Self {
        self.jobs.push(job);
        self
    }

    pub fn with_faults(mut self, faults: ClusterFaults) -> Self {
        self.faults = faults;
        self
    }

    /// Total physical nodes on the fabric.
    pub fn nodes(&self) -> usize {
        self.topology.nodes()
    }
}

/// Per-job outcome of a scenario run.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub name: String,
    pub kind: String,
    pub t_start: Time,
    pub t_end: Time,
    pub duration: f64,
    /// completed all-reduces
    pub ar_count: usize,
    /// mean all-reduce latency, post → completion
    pub mean_ar: f64,
    /// maximum number of this job's all-reduces in flight at once
    pub max_inflight: usize,
    /// worker time spent blocked on unfinished all-reduces
    pub exposed_wait: f64,
    /// switch-tier admission tally over this job's collectives
    pub tenancy: TenancyStats,
}

/// Everything a scenario run produces.
pub struct ScenarioOutput {
    pub trace: Trace,
    pub jobs: Vec<JobResult>,
    pub makespan: Time,
    pub events: u64,
    pub eth_util: f64,
    pub pcie_util: f64,
    pub adder_util: f64,
    /// switch egress-port utilization, one entry per node
    pub port_util: Vec<f64>,
    /// high-water mark of the engine's pending-event count
    pub peak_queue_depth: usize,
    /// per-partition load of a parallel run (entry 0 is the coordinator,
    /// entries 1.. the leaf partitions); empty on sequential engines.
    /// Surfaces parallel load imbalance from the CLI without a profiler.
    pub partitions: Vec<PartitionStats>,
    /// invariant-audit report of an [`EngineKind::Checked`] run (engine
    /// dispatch checks plus the post-quiescence conservation audit);
    /// `None` on unchecked engines.
    pub audit: Option<AuditReport>,
    /// switch-tier admission tally across every collective of the run
    pub tenancy: TenancyStats,
}

/// How the switch tier's per-flow admission control classified the run's
/// collectives.  `requested` counts flows that asked for in-switch state
/// (`requested = admitted + evicted + fallback` — the partition the
/// tenancy property suite pins); flows that never asked (NIC/host
/// algorithms, incapable fabrics) are not counted.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenancyStats {
    /// flows that went through switch-tier admission
    pub requested: usize,
    /// flows granted an aggregation-table share
    pub admitted: usize,
    /// flows denied after a competitor displaced their job's warm slot
    pub evicted: usize,
    /// flows denied on first contact (per-flow host/NIC fallback)
    pub fallback: usize,
    /// sticky-idle slots displaced inside the allocator over the run
    pub table_evictions: u64,
}

/// What a budget-capped run (see [`run_scenario_capped`]) produces: how
/// far virtual time advanced, how many events that took, and how the
/// work spread across partitions.  No per-job results — capped runs stop
/// mid-flight, so jobs are generally unfinished.
pub struct CappedRun {
    pub virtual_s: f64,
    pub events: u64,
    pub partitions: Vec<PartitionStats>,
}

/// Run `spec` to completion on the unified engine.  Fully deterministic:
/// identical specs produce identical traces.
pub fn run_scenario(spec: &ClusterSpec) -> ScenarioOutput {
    run_scenario_on(spec, EngineKind::Typed)
}

/// Validate `spec`, build the shared fabric and seed the job start
/// events.  Common front half of [`run_scenario_on`] and
/// [`run_scenario_capped`].
fn init(spec: &ClusterSpec, engine: EngineKind) -> (ClusterSim, ClusterState) {
    let nodes = spec.nodes();
    assert!(nodes >= 1, "cluster needs at least one node");
    assert!(!spec.jobs.is_empty(), "scenario needs at least one job");
    for &(node, _) in spec.faults.degraded_links.iter().chain(&spec.faults.stragglers) {
        assert!(
            node < nodes,
            "fault on node {node} but the fabric has only {nodes} nodes"
        );
    }
    for j in &spec.jobs {
        let mut seen = vec![false; nodes];
        for &r in &j.ranks {
            assert!(r < nodes, "job '{}': rank {r} outside the fabric", j.name);
            assert!(!seen[r], "job '{}': duplicate rank {r}", j.name);
            seen[r] = true;
        }
    }

    let state = ClusterState {
        sys: spec.sys,
        fabric: Fabric::with_topology(&spec.sys, spec.topology, &spec.faults),
        trace: Trace::new(),
        jobs: spec
            .jobs
            .iter()
            .map(|j| JobRuntime::new(j.clone(), &spec.sys))
            .collect(),
        collectives: Vec::new(),
        sched: None,
    };
    let mut sim: ClusterSim = Sim::with_engine(engine);
    for (jid, j) in spec.jobs.iter().enumerate() {
        sim.schedule_at(j.start_at, Event::JobWake { job: jid as u32, epoch: 0 });
    }
    (sim, state)
}

/// Drain the calendar on the backend `engine` selects: the parallel
/// executive fans a leaf-partitioned copy of the queue across worker
/// threads, every other kind drains sequentially.
pub(super) fn drive(sim: &mut ClusterSim, state: &mut ClusterState, engine: EngineKind) {
    match engine {
        EngineKind::Parallel { threads } => {
            sim.run_parallel(state, threads);
        }
        // audited runs take the same executive their thread count selects
        // (0 = the sequential dispatch loop), with the audit hooks armed
        // by `Sim::with_engine`
        EngineKind::Checked { threads } if threads > 0 => {
            sim.run_parallel(state, threads);
        }
        _ => {
            sim.run(state);
        }
    }
}

/// Post-quiescence half of the [`EngineKind::Checked`] audit
/// (`docs/INVARIANTS.md`): every collective completed, each gradient
/// element was folded exactly once per peer on the pool that owns it
/// (node adders vs. switch aggregation engines), every switch-multicast
/// phase delivered exactly `members − 1` replicated copies per segment
/// (replication is counted, never folded), and no fabric server
/// holds reserved capacity past the final event time beyond its own
/// longest single drain (a cut-through reservation legitimately outlives
/// its delivery event by at most that much).
///
/// Churn carve-out: a collective whose job was preempted inside the
/// driver-request window is marked `aborted` — it never started, never
/// reserved fabric resources and folds nothing, so it is excluded from
/// both the completion check and the expected-fold sums.  *Started*
/// collectives of preempted jobs drain to completion and are accounted
/// in full.
pub(super) fn audit_conservation(state: &ClusterState, end: Time, report: &mut AuditReport) {
    let mut adders = 0.0;
    let mut engines = 0.0;
    let mut mcast = 0.0;
    for c in &state.collectives {
        if c.aborted {
            continue;
        }
        if c.t_done.is_none() {
            report.record(AuditViolation::UnfinishedCollective { cid: c.id });
        }
        let (a, e) = c.expected_reduce_served();
        adders += a;
        engines += e;
        mcast += c.expected_mcast_deliveries(state.sys.nic.segment_bytes);
    }
    let tol = |expected: f64| 1e-6 * expected.max(1.0);
    let served_adders = state.fabric.adders_served();
    if (served_adders - adders).abs() > tol(adders) {
        report.record(AuditViolation::ReduceConservation {
            expected: adders,
            actual: served_adders,
            pool: 0,
        });
    }
    let served_engines = state.fabric.reduce_engines_served();
    if (served_engines - engines).abs() > tol(engines) {
        report.record(AuditViolation::ReduceConservation {
            expected: engines,
            actual: served_engines,
            pool: 1,
        });
    }
    // replication ledger: multicast copies are counted, not folded — a
    // copy landing in either reduce ledger (or vanishing) surfaces here
    let delivered_mcast = state.fabric.mcast_delivered();
    if (delivered_mcast - mcast).abs() > tol(mcast) {
        report.record(AuditViolation::MulticastConservation {
            expected: mcast,
            actual: delivered_mcast,
        });
    }
    for s in state.fabric.servers() {
        let slack = s.max_service() + 1e-9 * end.abs().max(1.0);
        if s.busy_until() > end + slack {
            report.record(AuditViolation::LeakedReservation {
                busy_until: s.busy_until(),
                end,
            });
        }
    }
    // tenancy ledger: the aggregation table may never hold more bytes
    // than it has, and no two tenants may hold overlapping slot ranges
    if let Some(table) = state.fabric.table() {
        let capacity = table.capacity();
        let reserved: f64 = table.slots().iter().map(|s| s.len).sum();
        let mut spans: Vec<(f64, f64)> =
            table.slots().iter().map(|s| (s.offset, s.offset + s.len)).collect();
        spans.sort_by(|a, b| a.0.total_cmp(&b.0));
        let overlapping = spans.windows(2).any(|w| w[0].1 > w[1].0 + 1e-6);
        if overlapping || reserved > capacity + tol(capacity) {
            report.record(AuditViolation::TableOvercommit {
                reserved,
                capacity,
                overlapping,
            });
        }
    }
    // PFC ledger: pause edges recorded within one priority class (one
    // collective) must stay acyclic — a cycle is a deadlocked reduction
    // tree — and the configured duty cycle must leave forward progress
    if state.fabric.pfc_duty() <= 0.0 {
        report.record(AuditViolation::PauseDeadlock { cid: u32::MAX, cycle_len: 0 });
    }
    let mut edges = state.fabric.pause_edges().to_vec();
    edges.sort_unstable();
    let mut i = 0;
    while i < edges.len() {
        let cid = edges[i].0;
        let mut j = i;
        while j < edges.len() && edges[j].0 == cid {
            j += 1;
        }
        if let Some(cycle_len) = directed_cycle(&edges[i..j]) {
            report.record(AuditViolation::PauseDeadlock { cid, cycle_len });
        }
        i = j;
    }
}

/// Length (in edges) of some directed cycle among one priority class's
/// pause edges, or `None` when the class is acyclic.  Edges are
/// `(cid, from_leaf, to_leaf)` with a shared `cid`.
fn directed_cycle(edges: &[(u32, usize, usize)]) -> Option<u32> {
    let n = edges.iter().map(|&(_, a, b)| a.max(b) + 1).max().unwrap_or(0);
    let mut adj = vec![Vec::new(); n];
    for &(_, a, b) in edges {
        adj[a].push(b);
    }
    // three-color DFS; `depth` sizes the back-edge cycle
    fn dfs(u: usize, adj: &[Vec<usize>], color: &mut [u8], depth: &mut [u32]) -> Option<u32> {
        color[u] = 1;
        for &v in &adj[u] {
            match color[v] {
                0 => {
                    depth[v] = depth[u] + 1;
                    if let Some(len) = dfs(v, adj, color, depth) {
                        return Some(len);
                    }
                }
                1 => return Some(depth[u] + 1 - depth[v]),
                _ => {}
            }
        }
        color[u] = 2;
        None
    }
    let mut color = vec![0u8; n];
    let mut depth = vec![0u32; n];
    (0..n).find_map(|s| {
        if color[s] == 0 {
            depth[s] = 0;
            dfs(s, &adj, &mut color, &mut depth)
        } else {
            None
        }
    })
}

/// [`run_scenario`] on an explicit engine backend: the typed calendar
/// engine in production, the leaf-partitioned parallel executive
/// (`EngineKind::Parallel`), or — under the `testing` feature — the
/// boxed-closure baseline that `smartnic engine-bench` and the
/// cross-engine equivalence suite (`rust/tests/engine_equiv.rs`)
/// measure it against.  All backends execute the same virtual-time
/// trajectory, so their outputs agree to float precision.
pub fn run_scenario_on(spec: &ClusterSpec, engine: EngineKind) -> ScenarioOutput {
    let nodes = spec.nodes();
    let (mut sim, mut state) = init(spec, engine);
    drive(&mut sim, &mut state, engine);
    let audit = sim.take_audit_report().map(|mut report| {
        audit_conservation(&state, sim.now(), &mut report);
        report
    });

    let makespan = state.trace.makespan();
    let job_tenancy = |jid: usize| {
        let mut t = TenancyStats::default();
        for c in state.collectives.iter().filter(|c| c.job == jid) {
            match c.tenancy {
                TenancyOutcome::NotRequested => {}
                TenancyOutcome::Admitted { .. } => t.admitted += 1,
                TenancyOutcome::Evicted => t.evicted += 1,
                TenancyOutcome::Fallback => t.fallback += 1,
            }
        }
        t.requested = t.admitted + t.evicted + t.fallback;
        t
    };
    let jobs: Vec<JobResult> = state
        .jobs
        .iter()
        .enumerate()
        .map(|(jid, j)| {
            let t_end = j
                .t_done
                .unwrap_or_else(|| panic!("job '{}' never finished (deadlock?)", j.spec.name));
            JobResult {
                name: j.spec.name.clone(),
                kind: j.spec.kind.name(),
                t_start: j.spec.start_at,
                t_end,
                duration: t_end - j.spec.start_at,
                ar_count: state
                    .collectives
                    .iter()
                    .filter(|c| c.job == jid && c.t_done.is_some())
                    .count(),
                mean_ar: state.mean_ar_duration(jid),
                max_inflight: state.max_inflight(jid),
                exposed_wait: state.trace.lane_time_in(&j.worker_lane, "wait-ar"),
                tenancy: job_tenancy(jid),
            }
        })
        .collect();
    let port_util = (0..nodes)
        .map(|p| state.fabric.port_utilization(p, makespan))
        .collect();
    let mut tenancy = TenancyStats::default();
    for c in &state.collectives {
        match c.tenancy {
            TenancyOutcome::NotRequested => {}
            TenancyOutcome::Admitted { .. } => tenancy.admitted += 1,
            TenancyOutcome::Evicted => tenancy.evicted += 1,
            TenancyOutcome::Fallback => tenancy.fallback += 1,
        }
    }
    tenancy.requested = tenancy.admitted + tenancy.evicted + tenancy.fallback;
    tenancy.table_evictions = state.fabric.table().map_or(0, |t| t.evictions());
    ScenarioOutput {
        jobs,
        makespan,
        events: sim.events_run(),
        eth_util: state.fabric.mean_eth_util(makespan),
        pcie_util: state.fabric.mean_pcie_util(makespan),
        adder_util: state.fabric.mean_adder_util(makespan),
        port_util,
        peak_queue_depth: sim.peak_pending(),
        partitions: sim.partition_stats().to_vec(),
        audit,
        tenancy,
        trace: state.trace,
    }
}

/// Run `spec` for at most `max_events` events and report how far virtual
/// time got.  This is the honest way to benchmark node counts (16k–64k)
/// whose full runs would take 10^10+ events: both engines burn the same
/// budget and events/sec is comparable, but no job-completion claims are
/// made.  Panics if `max_events` is 0.
pub fn run_scenario_capped(spec: &ClusterSpec, engine: EngineKind, max_events: u64) -> CappedRun {
    assert!(max_events > 0, "capped run needs a positive event budget");
    let (mut sim, mut state) = init(spec, engine);
    sim.set_event_budget(Some(max_events));
    drive(&mut sim, &mut state, engine);
    CappedRun {
        virtual_s: sim.now(),
        events: sim.events_run(),
        partitions: sim.partition_stats().to_vec(),
    }
}

#[cfg(test)]
// exact float equalities are deliberate: determinism tests pin
// bit-identical virtual times across engines
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;
    use crate::analytic::model::{iteration, SystemKind};
    use crate::collective::Scheme;
    use crate::sysconfig::Workload;
    use crate::util::stats::rel_err;

    #[test]
    fn single_smartnic_job_completes() {
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 4,
            hidden: 512,
            batch_per_node: 64,
        };
        let spec = ClusterSpec::new(sys, 3).with_job(JobSpec::new(
            "j0",
            SystemKind::SmartNic { bfp: true },
            w,
            vec![0, 1, 2],
        ));
        let out = run_scenario(&spec);
        assert_eq!(out.jobs.len(), 1);
        let j = &out.jobs[0];
        assert!(j.duration > 0.0 && j.duration.is_finite());
        assert_eq!(j.ar_count, 4);
        assert!(j.mean_ar > 0.0);
        assert!(out.events > 0);
        out.trace.check_lane_serial("j0/worker").unwrap();
    }

    #[test]
    fn naive_baseline_reproduces_closed_form_exactly() {
        // the naive schedule serializes everything and the event-driven
        // host rounds sum to the closed form, so the unified engine must
        // land on the analytic total to float precision
        let sys = SystemParams::baseline_100g();
        let w = Workload::paper_mlp(1792);
        let kind = SystemKind::BaselineNaive { scheme: Scheme::Ring };
        let spec = ClusterSpec::new(sys, 6)
            .with_job(JobSpec::new("base", kind, w, (0..6).collect()));
        let out = run_scenario(&spec);
        let ana = iteration(kind, &sys, &w, 6);
        let err = rel_err(ana.t_total, out.jobs[0].duration);
        assert!(
            err < 1e-9,
            "unified {} vs closed form {} ({:.2e})",
            out.jobs[0].duration,
            ana.t_total,
            err
        );
    }

    #[test]
    fn delayed_job_starts_late() {
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 2,
            hidden: 256,
            batch_per_node: 32,
        };
        let spec = ClusterSpec::new(sys, 2)
            .with_job(
                JobSpec::new("late", SystemKind::SmartNic { bfp: false }, w, vec![0, 1])
                    .starting_at(1.0),
            );
        let out = run_scenario(&spec);
        assert!(out.jobs[0].t_start == 1.0);
        assert!(out.jobs[0].t_end > 1.0);
    }

    #[test]
    fn leaf_spine_strided_ring_pays_the_oversubscription_penalty() {
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 2,
            hidden: 1024,
            batch_per_node: 64,
        };
        let kind = SystemKind::SmartNic { bfp: false };
        // 2 leaves x 4 nodes, 4:1 tapered: the uplink bundle carries
        // exactly one port's worth — enough for a contiguous ring's single
        // crossing flow per leaf, 4x short for the strided ring
        let topo = Topology::leaf_spine(2, 4, 4.0);
        let flat = run_scenario(&ClusterSpec::new(sys, 8).with_job(JobSpec::new(
            "flat",
            kind,
            w,
            (0..8).collect(),
        )));
        let contiguous = run_scenario(
            &ClusterSpec::new(sys, 8).with_topology(topo).with_job(JobSpec::new(
                "contig",
                kind,
                w,
                topo.contiguous_ranks(8),
            )),
        );
        let strided = run_scenario(
            &ClusterSpec::new(sys, 8).with_topology(topo).with_job(JobSpec::new(
                "strided",
                kind,
                w,
                topo.strided_ranks(8),
            )),
        );
        // placement decides whether the ring sees the spine at all: the
        // strided ring crosses the 4:1 uplinks on every edge
        assert!(
            strided.jobs[0].duration > contiguous.jobs[0].duration * 1.5,
            "strided {} vs contiguous {}",
            strided.jobs[0].duration,
            contiguous.jobs[0].duration
        );
        // a contiguous ring's one crossing flow per leaf fits the bundle
        // exactly: it pays only extra spine latency — within a few
        // percent of flat
        assert!(
            contiguous.jobs[0].duration < flat.jobs[0].duration * 1.10,
            "contiguous {} vs flat {}",
            contiguous.jobs[0].duration,
            flat.jobs[0].duration
        );
    }

    #[test]
    fn parallel_engine_matches_sequential_on_leaf_spine() {
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 2,
            hidden: 256,
            batch_per_node: 32,
        };
        let topo = Topology::leaf_spine(2, 4, 4.0);
        let spec = ClusterSpec::new(sys, 8).with_topology(topo).with_job(JobSpec::new(
            "par",
            SystemKind::SmartNic { bfp: true },
            w,
            topo.contiguous_ranks(8),
        ));
        let seq = run_scenario(&spec);
        let par = run_scenario_on(&spec, EngineKind::Parallel { threads: 2 });
        assert_eq!(seq.events, par.events);
        let err = rel_err(seq.makespan, par.makespan);
        assert!(err < 1e-9, "parallel {} vs sequential {}", par.makespan, seq.makespan);
        // sequential runs report no partitions; parallel reports the
        // coordinator plus one entry per leaf, accounting for every event
        assert!(seq.partitions.is_empty());
        assert_eq!(par.partitions.len(), 3);
        let total: u64 = par.partitions.iter().map(|p| p.events).sum();
        assert_eq!(total, par.events);
    }

    #[test]
    fn capped_run_respects_the_event_budget() {
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 4,
            hidden: 512,
            batch_per_node: 64,
        };
        let spec = ClusterSpec::new(sys, 3).with_job(JobSpec::new(
            "cap",
            SystemKind::SmartNic { bfp: true },
            w,
            vec![0, 1, 2],
        ));
        let full = run_scenario(&spec);
        let capped = run_scenario_capped(&spec, EngineKind::Typed, 20);
        assert!(capped.events <= full.events);
        assert!(capped.events >= 20, "budget is a floor for stopping, not a skip");
        assert!(capped.virtual_s <= full.makespan);
    }

    #[test]
    fn checked_engine_is_bit_identical_and_audit_clean() {
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 2,
            hidden: 256,
            batch_per_node: 32,
        };
        let topo = Topology::leaf_spine(2, 4, 4.0);
        let spec = ClusterSpec::new(sys, 8).with_topology(topo).with_job(JobSpec::new(
            "chk",
            SystemKind::SmartNic { bfp: true },
            w,
            topo.contiguous_ranks(8),
        ));
        let plain = run_scenario(&spec);
        assert!(plain.audit.is_none(), "unchecked engines carry no audit report");
        for threads in [0usize, 1, 2, 4] {
            let checked = run_scenario_on(&spec, EngineKind::Checked { threads });
            assert_eq!(plain.events, checked.events, "threads={threads}");
            let err = rel_err(plain.makespan, checked.makespan);
            assert!(
                err < 1e-9,
                "threads={threads}: checked {} vs typed {}",
                checked.makespan,
                plain.makespan
            );
            let report = checked.audit.expect("checked run must report");
            assert!(report.is_clean(), "threads={threads}: {}", report.summary());
            assert_eq!(report.events_checked(), plain.events);
        }
    }

    #[test]
    fn checked_ring_is_clean_when_segments_do_not_divide_nodes() {
        // regression for the writeback countdown (`pending_writebacks` =
        // n·n·segs): at a segment count that divides neither into nor by
        // the node count, a missed final writeback would leave the
        // collective unfinished and surface as a structured
        // `UnfinishedCollective` — the audit must instead come back clean
        // and bit-identical across executives
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 1,
            hidden: 1250,
            batch_per_node: 8,
        };
        let n = 6;
        let plan =
            crate::nic::SegmentPlan::new(sys.nic.segment_bytes, n, w.grad_elems_per_layer());
        let segs = plan.segs_per_chunk;
        assert!(
            segs % n != 0 && n % segs != 0,
            "combo must be non-dividing (n={n}, segs={segs})"
        );
        let spec = ClusterSpec::new(sys, n).with_job(JobSpec::new(
            "odd",
            SystemKind::SmartNic { bfp: false },
            w,
            (0..n).collect(),
        ));
        let plain = run_scenario(&spec);
        for threads in [0usize, 2] {
            let checked = run_scenario_on(&spec, EngineKind::Checked { threads });
            assert_eq!(plain.events, checked.events);
            assert!(rel_err(plain.makespan, checked.makespan) < 1e-9);
            let report = checked.audit.expect("checked run must report");
            assert!(report.is_clean(), "threads={threads}: {}", report.summary());
        }
    }

    /// Run `spec` on the plain typed engine and hand back the quiesced
    /// sim + state for the negative conservation tests to tamper with.
    fn run_state(spec: &ClusterSpec) -> (ClusterSim, ClusterState) {
        let (mut sim, mut state) = init(spec, EngineKind::Typed);
        drive(&mut sim, &mut state, EngineKind::Typed);
        (sim, state)
    }

    fn small_ring_spec() -> ClusterSpec {
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 1,
            hidden: 128,
            batch_per_node: 8,
        };
        ClusterSpec::new(sys, 3).with_job(JobSpec::new(
            "neg",
            SystemKind::SmartNic { bfp: false },
            w,
            vec![0, 1, 2],
        ))
    }

    #[test]
    fn conservation_audit_is_clean_at_quiescence() {
        let (sim, state) = run_state(&small_ring_spec());
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        assert!(report.is_clean(), "{}", report.summary());
    }

    #[test]
    fn unfinished_collective_yields_structured_violation() {
        let (sim, mut state) = run_state(&small_ring_spec());
        state.collectives[0].t_done = None;
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        assert!(report
            .violations()
            .iter()
            .any(|v| matches!(v, AuditViolation::UnfinishedCollective { cid: 0 })));
    }

    #[test]
    fn overfolded_adder_yields_structured_violation() {
        let (sim, mut state) = run_state(&small_ring_spec());
        // fold elements that no collective accounts for
        let _ = state.fabric.nodes[0].adder.serve(0.0, 1e6);
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        let v = report
            .violations()
            .iter()
            .find(|v| matches!(v, AuditViolation::ReduceConservation { pool: 0, .. }))
            .expect("adder-pool conservation violation");
        match v {
            AuditViolation::ReduceConservation { expected, actual, .. } => {
                assert!(actual > expected);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn unaccounted_switch_fold_yields_structured_violation() {
        use crate::sysconfig::SwitchParams;
        let mut spec = small_ring_spec();
        spec.sys = spec.sys.with_switch_reduction(SwitchParams {
            reduce_flops: 1e9,
            reduce_table_bytes: 16.0 * 1024.0 * 1024.0,
        });
        let (sim, mut state) = run_state(&spec);
        // the ring never touches the switch engines: any served elements
        // there are unaccounted
        let _ = state.fabric.reduce_fold_local(0, 0, 0.0, 1024.0, 256.0);
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        assert!(report
            .violations()
            .iter()
            .any(|v| matches!(v, AuditViolation::ReduceConservation { pool: 1, .. })));
    }

    #[test]
    fn leaked_reservation_yields_structured_violation() {
        let (sim, mut state) = run_state(&small_ring_spec());
        let end = sim.now();
        // reserve capacity starting far past quiescence: more than one
        // drain time beyond the final event
        let _ = state.fabric.nodes[0].tx.server.serve(2.0 * end + 1.0, 1.0);
        let mut report = AuditReport::new();
        audit_conservation(&state, end, &mut report);
        assert!(report
            .violations()
            .iter()
            .any(|v| matches!(v, AuditViolation::LeakedReservation { .. })));
    }

    /// One-layer job running collective pattern `kind` on `nodes` flat
    /// nodes — scaffold for the per-kind forged-violation tests below.
    fn kind_spec(kind: super::super::CollectiveKind, nodes: usize) -> ClusterSpec {
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 1,
            hidden: 128,
            batch_per_node: 8,
        };
        ClusterSpec::new(sys, nodes).with_job(
            JobSpec::new("kneg", SystemKind::SmartNic { bfp: false }, w, (0..nodes).collect())
                .with_layer_kinds(vec![kind]),
        )
    }

    #[test]
    fn forged_multicast_delivery_yields_structured_violation() {
        use super::super::{CollectiveAlgo, CollectiveKind};
        use crate::sysconfig::SwitchParams;
        // broadcast through the switch's replication engines: the run is
        // clean, then one forged copy nobody posted breaks the ledger
        let mut spec = kind_spec(CollectiveKind::Broadcast, 4);
        spec.sys = spec.sys.with_switch_reduction(SwitchParams {
            reduce_flops: 1e9,
            reduce_table_bytes: 16.0 * 1024.0 * 1024.0,
        });
        spec.jobs[0] = spec.jobs[0]
            .clone()
            .with_layer_algos(vec![CollectiveAlgo::SwitchReduce]);
        let (sim, mut state) = run_state(&spec);
        assert!(
            state.fabric.mcast_delivered() > 0.0,
            "broadcast must exercise replication mode"
        );
        let mut clean = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut clean);
        assert!(clean.is_clean(), "{}", clean.summary());
        let _ = state.fabric.mcast_deliver(0, 0.0, 64.0);
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        assert!(report
            .violations()
            .iter()
            .any(|v| matches!(v, AuditViolation::MulticastConservation { .. })));
    }

    #[test]
    fn forged_allgather_fold_yields_structured_violation() {
        use super::super::CollectiveKind;
        // allgather moves shards without folding anything: any adder
        // element at all is unaccounted
        let (sim, mut state) = run_state(&kind_spec(CollectiveKind::Allgather, 3));
        let mut clean = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut clean);
        assert!(clean.is_clean(), "{}", clean.summary());
        let _ = state.fabric.nodes[1].adder.serve(0.0, 1e6);
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        assert!(report
            .violations()
            .iter()
            .any(|v| matches!(v, AuditViolation::ReduceConservation { pool: 0, .. })));
    }

    #[test]
    fn vanished_reduce_scatter_yields_structured_violation() {
        use super::super::CollectiveKind;
        // the clean pass doubles as the reduce-scatter fold ledger check:
        // (n−1)·elems adds, exactly once per element into its owner
        let (sim, mut state) = run_state(&kind_spec(CollectiveKind::ReduceScatter, 3));
        let mut clean = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut clean);
        assert!(clean.is_clean(), "{}", clean.summary());
        state.collectives[0].t_done = None;
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        assert!(report
            .violations()
            .iter()
            .any(|v| matches!(v, AuditViolation::UnfinishedCollective { cid: 0 })));
    }

    #[test]
    fn leaked_all_to_all_reservation_yields_structured_violation() {
        use super::super::CollectiveKind;
        let (sim, mut state) = run_state(&kind_spec(CollectiveKind::AllToAll, 4));
        let end = sim.now();
        let mut clean = AuditReport::new();
        audit_conservation(&state, end, &mut clean);
        assert!(clean.is_clean(), "{}", clean.summary());
        let _ = state.fabric.nodes[2].tx.server.serve(2.0 * end + 1.0, 1.0);
        let mut report = AuditReport::new();
        audit_conservation(&state, end, &mut report);
        assert!(report
            .violations()
            .iter()
            .any(|v| matches!(v, AuditViolation::LeakedReservation { .. })));
    }

    /// One-layer all-reduce forced through the switch tier on a
    /// reduction-capable fabric — scaffold for the forged tenancy
    /// negatives below.
    fn inswitch_spec() -> ClusterSpec {
        use super::super::CollectiveAlgo;
        use crate::sysconfig::SwitchParams;
        let sys = SystemParams::smartnic_40g().with_switch_reduction(SwitchParams {
            reduce_flops: 1e9,
            reduce_table_bytes: 16.0 * 1024.0 * 1024.0,
        });
        let w = Workload {
            layers: 1,
            hidden: 128,
            batch_per_node: 8,
        };
        ClusterSpec::new(sys, 3).with_job(
            JobSpec::new("tneg", SystemKind::SmartNic { bfp: false }, w, vec![0, 1, 2])
                .with_layer_algos(vec![CollectiveAlgo::SwitchReduce]),
        )
    }

    #[test]
    fn forged_table_overcommit_yields_structured_violation() {
        use crate::netsim::switch::TableReservation;
        let (sim, mut state) = run_state(&inswitch_spec());
        assert!(
            matches!(state.collectives[0].tenancy, TenancyOutcome::Admitted { .. }),
            "a solo tenant must be admitted"
        );
        let mut clean = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut clean);
        assert!(clean.is_clean(), "{}", clean.summary());
        // forge a second tenant squatting on the whole table: its slot
        // overlaps the first job's sticky one and oversubscribes capacity
        let capacity = state.fabric.table().unwrap().capacity();
        state.fabric.table_mut().unwrap().force_reservation(TableReservation {
            job: 99,
            offset: 0.0,
            len: capacity,
            active_flows: 1,
            idle_seq: 0,
        });
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        let v = report
            .violations()
            .iter()
            .find(|v| matches!(v, AuditViolation::TableOvercommit { .. }))
            .expect("table-overcommit violation");
        match v {
            AuditViolation::TableOvercommit { reserved, capacity: cap, overlapping } => {
                assert!(*overlapping, "forged slot must overlap the resident one");
                assert!(reserved > cap);
                assert_eq!(v.kind(), "table-overcommit");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn forged_pause_cycle_yields_structured_violation() {
        use super::super::CollectiveAlgo;
        use crate::sysconfig::{PfcParams, SwitchParams};
        let sys = SystemParams::smartnic_40g()
            .with_switch_reduction(SwitchParams {
                reduce_flops: 1e9,
                reduce_table_bytes: 16.0 * 1024.0 * 1024.0,
            })
            .with_pfc(PfcParams {
                pause_rate: 200.0,
                pause_window: 1.0e-3,
            });
        let w = Workload {
            layers: 1,
            hidden: 256,
            batch_per_node: 8,
        };
        let topo = Topology::leaf_spine(2, 4, 4.0);
        let spec = ClusterSpec::new(sys, 8).with_topology(topo).with_job(
            JobSpec::new("pfc", SystemKind::SmartNic { bfp: false }, w, topo.contiguous_ranks(8))
                .with_layer_algos(vec![CollectiveAlgo::SwitchReduce]),
        );
        let (sim, mut state) = run_state(&spec);
        // the genuine fold-spine edges form a star into the root leaf —
        // acyclic by construction, so the audit is clean
        assert!(
            !state.fabric.pause_edges().is_empty(),
            "a paused spanning fold must record pause edges"
        );
        let mut clean = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut clean);
        assert!(clean.is_clean(), "{}", clean.summary());
        // forge the reverse edge: a 2-cycle within one priority class
        let &(cid, from, to) = &state.fabric.pause_edges()[0];
        state.fabric.record_pause_edge(cid, to, from);
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        let v = report
            .violations()
            .iter()
            .find(|v| matches!(v, AuditViolation::PauseDeadlock { .. }))
            .expect("pause-deadlock violation");
        match v {
            AuditViolation::PauseDeadlock { cid: c, cycle_len } => {
                assert_eq!(*c, cid);
                assert_eq!(*cycle_len, 2);
                assert_eq!(v.kind(), "pause-deadlock-free");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn pause_storm_yields_structured_violation() {
        use crate::sysconfig::PfcParams;
        let mut spec = small_ring_spec();
        // rate · window = 2 ⇒ duty = −1: a saturated pause storm
        spec.sys = spec.sys.with_pfc(PfcParams {
            pause_rate: 2000.0,
            pause_window: 1.0e-3,
        });
        // don't drive — a stormed tier makes no forward progress; audit
        // the freshly-built state directly
        let (sim, state) = init(&spec, EngineKind::Typed);
        let mut report = AuditReport::new();
        audit_conservation(&state, sim.now(), &mut report);
        assert!(report.violations().iter().any(|v| matches!(
            v,
            AuditViolation::PauseDeadlock { cid: u32::MAX, cycle_len: 0 }
        )));
    }

    #[test]
    #[should_panic(expected = "duplicate rank")]
    fn duplicate_ranks_rejected() {
        let sys = SystemParams::smartnic_40g();
        let w = Workload {
            layers: 1,
            hidden: 64,
            batch_per_node: 8,
        };
        let spec = ClusterSpec::new(sys, 2).with_job(JobSpec::new(
            "bad",
            SystemKind::SmartNic { bfp: false },
            w,
            vec![0, 0],
        ));
        let _ = run_scenario(&spec);
    }
}
