//! Topology-aware collective planner.
//!
//! Given a [`Topology`], a placement (the job's physical ranks), and a
//! message size, the planner prices every plan it can build with the
//! closed forms in [`crate::analytic::model`] and returns the cheapest as
//! a list of composable [`Phase`]s for the unified engine:
//!
//! * **Ring** — the NIC's native segment-pipelined ring, derated by the
//!   placement's leaf-uplink contention factor ([`ring_uplink_factor`]):
//!   a strided placement on a tapered spine pays ~the oversubscription
//!   factor on the wire term, the penalty PR 2's sweep measured.
//! * **Binomial / Rabenseifner** — the round-based NIC offloads, priced
//!   per round by the worst reservation-stage load on this topology
//!   ([`rounds_cost`]).
//! * **Hierarchical** — ring reduce-scatter inside each leaf, ring
//!   all-reduce of each rank's shard across the leaves (m concurrent
//!   l-rings over the spine), ring allgather inside the leaf.  Crosses
//!   the spine with 2(l−1)/l · S/m per rank instead of the strided
//!   ring's 2(n−1)/n · S — the placement-aware plan that undercuts the
//!   tapering penalty.  Requires equal-size leaf groups.
//! * **InSwitch** — NetReduce-style switch-resident reduction
//!   ([`Phase::SwitchReduce`]), available when the fabric's switch tier
//!   has aggregation engines and a table that holds at least one
//!   segment; otherwise the planner falls back to the exact ring path.
//!
//! Two invariants are property-tested (`rust/tests/planner.rs`): the
//! chosen plan is never predicted slower than any fixed single-scheme
//! plan, and every plan reduces each gradient element exactly once per
//! peer ((n−1)·E genuine adds).

use super::collective::{
    all_to_all_rounds, allgather_ring_rounds, binomial_rounds, broadcast_binomial_rounds,
    rabenseifner_rounds, reduce_scatter_ring_rounds, Phase, RoundOp,
};
use super::{CollectiveAlgo, CollectiveKind};
use crate::analytic::model::{
    hierarchical_ar_time_elems, inswitch_ar_time_contended, nic_ring_ar_time_elems,
    switch_multicast_time_elems,
};
use crate::netsim::fabric::Fabric;
use crate::netsim::topology::Topology;
use crate::sysconfig::SystemParams;

/// The tenancy conditions an in-switch plan is priced against: how many
/// jobs currently hold aggregation-table slots, how many table bytes
/// *this* job could actually obtain (its own slot, or free + evictable
/// bytes), and the switching tier's PFC pause duty cycle.  [`idle`] is
/// the no-contention load every legacy entry point prices with — one
/// tenant, unlimited table, full duty — which reproduces the solo closed
/// form bit-for-bit.
///
/// [`idle`]: TenancyLoad::idle
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenancyLoad {
    /// concurrent tenants sharing the switch tier, this job included
    pub tenants: usize,
    /// table bytes obtainable by this job (clamped to the switch's
    /// capacity at pricing time; `INFINITY` = the full table)
    pub table_bytes: f64,
    /// PFC pause duty cycle (1.0 = PFC off)
    pub pause_duty: f64,
}

impl TenancyLoad {
    /// No contention: one tenant, the whole table, PFC off.
    #[must_use]
    pub fn idle() -> Self {
        Self { tenants: 1, table_bytes: f64::INFINITY, pause_duty: 1.0 }
    }

    /// Snapshot the *current* tenancy of `fabric` as seen by `job`: the
    /// jobs holding table slots (plus this one, if it doesn't already),
    /// the bytes this job could obtain right now, and the fabric's pause
    /// duty.  This is what threads live contention into
    /// [`candidates_with`] at admission time.
    #[must_use]
    pub fn observed(fabric: &Fabric, job: u32) -> Self {
        let (tenants, table_bytes) = match fabric.table() {
            Some(t) => {
                let holds = t.slots().iter().any(|s| s.job == job);
                (t.tenants() + usize::from(!holds), t.available_to(job))
            }
            None => (1, f64::INFINITY),
        };
        Self {
            tenants: tenants.max(1),
            table_bytes,
            pause_duty: fabric.pfc_duty(),
        }
    }
}

/// The families of plans the planner can build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanKind {
    /// native segment-pipelined NIC ring (executed by the ring executor)
    Ring,
    /// round-based binomial reduce + broadcast
    Binomial,
    /// round-based Rabenseifner halving/doubling
    Rabenseifner,
    /// reduce-scatter in leaf → shard all-reduce across the spine →
    /// allgather in leaf
    Hierarchical,
    /// NetReduce-style in-switch reduction
    InSwitch,
    /// round-based pairwise exchange (all-to-all)
    Pairwise,
    /// switch-resident replication: the multicast dual of in-switch
    /// reduction (broadcast)
    SwitchMulticast,
}

impl PlanKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlanKind::Ring => "ring",
            PlanKind::Binomial => "binomial",
            PlanKind::Rabenseifner => "rabenseifner",
            PlanKind::Hierarchical => "hierarchical",
            PlanKind::InSwitch => "in-switch",
            PlanKind::Pairwise => "pairwise",
            PlanKind::SwitchMulticast => "switch-multicast",
        }
    }
}

/// A priced, executable collective plan.
#[derive(Clone, Debug)]
pub struct Plan {
    pub kind: PlanKind,
    /// phases for the planned executor; empty for [`PlanKind::Ring`],
    /// which runs on the native ring datapath
    pub phases: Vec<Phase>,
    /// host-side DMA payload per rank (fetched before the first rounds
    /// phase, written back after the last; the ring path manages its own
    /// segment DMA)
    pub payload_bytes: f64,
    /// the planner's closed-form cost estimate (seconds)
    pub predicted: f64,
}

impl Plan {
    /// Genuine f32 adds the plan performs.  An all-reduce over `n` ranks
    /// must reduce every element exactly once per peer: (n−1)·E — the
    /// conservation invariant, and exactly what `scheme_rounds`' ring
    /// decomposition implies (n−1 reduce rounds × n ranks × E/n apiece).
    /// A [`PlanKind::Ring`] with phases is a ring-structured *rounds*
    /// plan (allgather / reduce-scatter) and is priced by its ops.
    pub fn reduced_elems(&self, n: usize, elems: usize) -> f64 {
        if self.kind == PlanKind::Ring && self.phases.is_empty() {
            // native ring: each rank reduces n−1 chunks of E/n
            return (n as f64 - 1.0) * elems as f64;
        }
        self.phases.iter().map(Phase::reduced_elems).sum()
    }
}

/// Local rank indices grouped by the leaf switch their node hangs off,
/// in order of first appearance (so group 0 contains local rank 0).
pub fn leaf_groups(topo: &Topology, ranks: &[usize]) -> Vec<Vec<usize>> {
    let mut order: Vec<usize> = Vec::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (local, &node) in ranks.iter().enumerate() {
        let leaf = topo.leaf_of(node);
        match order.iter().position(|&l| l == leaf) {
            Some(g) => groups[g].push(local),
            None => {
                order.push(leaf);
                groups.push(vec![local]);
            }
        }
    }
    groups
}

/// Leaf-uplink contention multiplier of a ring over this placement: per
/// pipelined ring step every rank forwards one chunk to its successor, so
/// a leaf whose `e` ring edges exit (or enter) it pushes `e` concurrent
/// chunks through a bundle provisioned for `m/oversub` ports —
/// max(1, e·oversub/m) slower than one port's serialization.
pub fn ring_uplink_factor(topo: &Topology, ranks: &[usize]) -> f64 {
    let k = ranks.len();
    if k <= 1 {
        return 1.0;
    }
    match *topo {
        Topology::Flat { .. } => 1.0,
        Topology::LeafSpine { leaves, nodes_per_leaf, oversubscription } => {
            let mut out = vec![0usize; leaves];
            let mut inc = vec![0usize; leaves];
            for i in 0..k {
                let (a, b) = (ranks[i], ranks[(i + 1) % k]);
                let (la, lb) = (topo.leaf_of(a), topo.leaf_of(b));
                if la != lb {
                    out[la] += 1;
                    inc[lb] += 1;
                }
            }
            let worst = out.iter().chain(inc.iter()).copied().max().unwrap_or(0) as f64;
            (worst * oversubscription / nodes_per_leaf as f64).max(1.0)
        }
    }
}

/// Closed-form cost of barrier-synchronized rounds on this topology: per
/// round, the worst reservation-stage load (any Tx link, leaf uplink or
/// downlink bundle, destination egress port) plus the route latency and
/// the worst destination-adder time, plus the plan-level DMA fetch /
/// writeback and the NIC request overhead.
///
/// The DMA term is split by direction because the collective family is
/// no longer symmetric: an all-reduce fetches and writes back the whole
/// payload, but an allgather fetches only each rank's shard (`S/n`)
/// while writing back the full vector, and a reduce-scatter is the
/// mirror image.  Pass the worst per-rank fetch and writeback volumes.
pub fn rounds_cost(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    rounds: &[Vec<RoundOp>],
    wire_ratio: f64,
    fetch_bytes: f64,
    wb_bytes: f64,
) -> f64 {
    let bw = sys.net.effective_bw();
    let lat = sys.net.hop_latency;
    let rho = sys.nic.add_flops;
    let n = ranks.len();
    let up_bw = topo.uplink_bw(bw);
    let l = topo.leaves();
    let max = |v: &[f64]| v.iter().fold(0.0f64, |a, &b| a.max(b));
    let mut t = sys.nic_request_overhead
        + (fetch_bytes / sys.nic.pcie_bw + sys.nic.pcie_latency)
        + (wb_bytes / sys.nic.pcie_bw + sys.nic.pcie_latency);
    for round in rounds {
        if round.is_empty() {
            continue;
        }
        let mut tx = vec![0.0f64; n];
        let mut eg = vec![0.0f64; n];
        let mut up = vec![0.0f64; l];
        let mut down = vec![0.0f64; l];
        let mut add = vec![0.0f64; n];
        let mut hops = 1usize;
        for op in round {
            let wire = op.bytes / wire_ratio;
            tx[op.src] += wire;
            eg[op.dst] += wire;
            let (ls, ld) = (topo.leaf_of(ranks[op.src]), topo.leaf_of(ranks[op.dst]));
            if ls != ld {
                up[ls] += wire;
                down[ld] += wire;
                hops = 3;
            }
            add[op.dst] += op.reduce_elems;
        }
        let wire_t = (max(&tx) / bw)
            .max(max(&eg) / bw)
            .max(max(&up) / up_bw)
            .max(max(&down) / up_bw);
        t += wire_t + hops as f64 * lat + max(&add) / rho;
    }
    t
}

/// Hierarchical phases for uniform leaf groups (`m` ranks in each of `l`
/// groups).  Volumes are exact f64 fractions of the raw gradient so the
/// plan reduces each element exactly once per peer.
pub fn hierarchical_phases(groups: &[Vec<usize>], bytes: f64, elems: f64) -> Vec<Phase> {
    let l = groups.len();
    let m = groups[0].len();
    debug_assert!(groups.iter().all(|g| g.len() == m), "groups must be uniform");
    let mut phases = Vec::new();
    let intra = |reduce: bool| -> Vec<Vec<RoundOp>> {
        (0..m.saturating_sub(1))
            .map(|_| {
                groups
                    .iter()
                    .flat_map(|grp| {
                        (0..m).map(move |j| RoundOp {
                            src: grp[j],
                            dst: grp[(j + 1) % m],
                            bytes: bytes / m as f64,
                            reduce_elems: if reduce { elems / m as f64 } else { 0.0 },
                        })
                    })
                    .collect()
            })
            .collect()
    };
    if m >= 2 {
        phases.push(Phase::Rounds(intra(true))); // reduce-scatter in leaf
    }
    if l >= 2 {
        // each rank's shard (S/m) ring-all-reduced across the leaves: m
        // concurrent rings of l, one spine crossing per member per round
        let c2 = bytes / (m * l) as f64;
        let e2 = elems / (m * l) as f64;
        let cross: Vec<Vec<RoundOp>> = (0..2 * (l - 1))
            .map(|r| {
                let reduce_elems = if r < l - 1 { e2 } else { 0.0 };
                (0..l)
                    .flat_map(|g| {
                        let next = (g + 1) % l;
                        (0..m).map(move |j| RoundOp {
                            src: groups[g][j],
                            dst: groups[next][j],
                            bytes: c2,
                            reduce_elems,
                        })
                    })
                    .collect()
            })
            .collect();
        phases.push(Phase::Rounds(cross));
    }
    if m >= 2 {
        phases.push(Phase::Rounds(intra(false))); // allgather in leaf
    }
    phases
}

/// Every plan the planner can price for this configuration (the ring is
/// always present; hierarchical needs uniform leaf groups on ≥ 2 leaves;
/// in-switch needs a reduction-capable switch tier).
pub fn candidates(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
) -> Vec<Plan> {
    candidates_with(sys, topo, ranks, elems, wire_ratio, TenancyLoad::idle())
}

/// [`candidates`] priced against a live [`TenancyLoad`]: the in-switch
/// plan's cost reflects the tenants already folding through the switch,
/// the table bytes this job could actually obtain, and PFC derating —
/// so the planner flips to NIC-ring / hierarchical past the occupancy
/// knee instead of letting in-switch win unconditionally.  The host/NIC
/// plans are load-independent (they use no switch-tier state).
pub fn candidates_with(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
    load: TenancyLoad,
) -> Vec<Plan> {
    let n = ranks.len();
    let raw = elems as f64 * 4.0;
    let padded = elems.div_ceil(n.max(1)).max(1) as f64 * 4.0 * n as f64;
    let groups = leaf_groups(topo, ranks);
    let l = groups.len();
    let m = groups[0].len();
    let uniform = groups.iter().all(|g| g.len() == m);
    // The closed forms price the spine by "group size over bundle": their
    // `oversub` must be the *effective* per-group tapering m·bw /
    // uplink_bw — equal to the fabric factor when groups fill their
    // leaves, and proportionally milder when a job only partially
    // occupies them (the bundle stays provisioned by nodes_per_leaf).
    let bw = sys.net.effective_bw();
    let oversub_eff = |grp_m: usize| grp_m as f64 * bw / topo.uplink_bw(bw);

    let mut out = vec![Plan {
        kind: PlanKind::Ring,
        phases: Vec::new(),
        payload_bytes: padded,
        predicted: nic_ring_ar_time_elems(
            sys,
            elems,
            n,
            wire_ratio,
            ring_uplink_factor(topo, ranks),
        ),
    }];
    if n >= 2 {
        let b_rounds = binomial_rounds(n, padded, elems as f64);
        let b_cost = rounds_cost(sys, topo, ranks, &b_rounds, wire_ratio, padded, padded);
        out.push(Plan {
            kind: PlanKind::Binomial,
            phases: vec![Phase::Rounds(b_rounds)],
            payload_bytes: padded,
            predicted: b_cost,
        });
        let r_rounds = rabenseifner_rounds(n, padded, elems as f64);
        let r_cost = rounds_cost(sys, topo, ranks, &r_rounds, wire_ratio, padded, padded);
        out.push(Plan {
            kind: PlanKind::Rabenseifner,
            phases: vec![Phase::Rounds(r_rounds)],
            payload_bytes: padded,
            predicted: r_cost,
        });
    }
    if uniform && l >= 2 {
        out.push(Plan {
            kind: PlanKind::Hierarchical,
            phases: hierarchical_phases(&groups, raw, elems as f64),
            payload_bytes: raw,
            predicted: hierarchical_ar_time_elems(sys, elems, m, l, oversub_eff(m), wire_ratio),
        });
    }
    if sys.switch.enabled() && n >= 2 {
        // ragged groups are priced by their worst leaf: the largest
        // group's fold is the pipeline's leaf-engine stage time, which is
        // exactly what bounds the executor's per-segment rate
        let m_max = groups.iter().map(Vec::len).max().unwrap_or(1);
        let predicted = inswitch_ar_time_contended(
            sys,
            elems,
            m_max,
            l,
            oversub_eff(m_max),
            wire_ratio,
            load.tenants,
            load.table_bytes.min(sys.switch.reduce_table_bytes),
            load.pause_duty,
        );
        if predicted.is_finite() {
            out.push(Plan {
                kind: PlanKind::InSwitch,
                phases: vec![Phase::SwitchReduce {
                    bytes: raw,
                    elems: elems as f64,
                    groups,
                }],
                payload_bytes: raw,
                predicted,
            });
        }
    }
    out
}

/// Pick the cheapest plan for this configuration.
pub fn plan(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
) -> Plan {
    plan_with(sys, topo, ranks, elems, wire_ratio, TenancyLoad::idle())
}

/// [`plan`] priced against a live [`TenancyLoad`].
pub fn plan_with(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
    load: TenancyLoad,
) -> Plan {
    candidates_with(sys, topo, ranks, elems, wire_ratio, load)
        .into_iter()
        .min_by(|a, b| a.predicted.total_cmp(&b.predicted))
        .expect("the ring candidate always exists")
}

/// A specific plan family, falling back to the exact native ring when the
/// requested family is unavailable here (no spine for a hierarchical
/// plan, or a switch tier that cannot reduce).
pub fn plan_fixed(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
    kind: PlanKind,
) -> Plan {
    plan_fixed_with(sys, topo, ranks, elems, wire_ratio, kind, TenancyLoad::idle())
}

/// [`plan_fixed`] priced against a live [`TenancyLoad`]: the requested
/// family still falls back to the exact native ring when unavailable —
/// which under load now includes an in-switch plan whose granted table
/// share can't hold one segment (the per-flow fallback path).
#[allow(clippy::too_many_arguments)]
pub fn plan_fixed_with(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
    kind: PlanKind,
    load: TenancyLoad,
) -> Plan {
    let mut cands = candidates_with(sys, topo, ranks, elems, wire_ratio, load);
    let idx = cands
        .iter()
        .position(|c| c.kind == kind)
        .unwrap_or_else(|| {
            cands
                .iter()
                .position(|c| c.kind == PlanKind::Ring)
                .expect("the ring candidate always exists")
        });
    cands.swap_remove(idx)
}

/// Resolve a planner-backed [`CollectiveAlgo`] into an executable plan.
pub fn plan_for_algo(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
    algo: CollectiveAlgo,
) -> Plan {
    plan_for_algo_with(sys, topo, ranks, elems, wire_ratio, algo, TenancyLoad::idle())
}

/// [`plan_for_algo`] priced against a live [`TenancyLoad`] — the
/// admission-time entry point: `cluster::collective::post` snapshots the
/// fabric's tenancy ([`TenancyLoad::observed`]) and resolves the
/// requested algorithm against it, so a late tenant is planned onto its
/// host/NIC path *per flow* when the switch is oversubscribed.
#[allow(clippy::too_many_arguments)]
pub fn plan_for_algo_with(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
    algo: CollectiveAlgo,
    load: TenancyLoad,
) -> Plan {
    match algo {
        CollectiveAlgo::Auto => plan_with(sys, topo, ranks, elems, wire_ratio, load),
        CollectiveAlgo::NicHierarchical => {
            plan_fixed_with(sys, topo, ranks, elems, wire_ratio, PlanKind::Hierarchical, load)
        }
        CollectiveAlgo::SwitchReduce => {
            plan_fixed_with(sys, topo, ranks, elems, wire_ratio, PlanKind::InSwitch, load)
        }
        other => unreachable!("planner invoked for fixed algorithm {other:?}"),
    }
}

/// Every plan the planner can price for this collective *kind*.
/// All-reduce keeps its five families ([`candidates`]); the other kinds
/// get their canonical host/NIC rounds plan plus — for broadcast — the
/// switch-multicast offload when the fabric's switch tier can replicate
/// (finite predicted cost: engines present, table holds ≥ 1 segment).
///
/// The host plan is always first, so an incapable switch falls back to
/// it bit-identically (mirroring the in-switch → ring fallback).
pub fn candidates_for(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
    kind: CollectiveKind,
) -> Vec<Plan> {
    if kind == CollectiveKind::AllReduce {
        return candidates(sys, topo, ranks, elems, wire_ratio);
    }
    let n = ranks.len();
    let raw = elems as f64 * 4.0;
    let padded = elems.div_ceil(n.max(1)).max(1) as f64 * 4.0 * n as f64;
    let shard = padded / n.max(1) as f64;
    let mut out = Vec::new();
    match kind {
        CollectiveKind::AllReduce => unreachable!(),
        CollectiveKind::Broadcast => {
            // root fetches the full payload once; every non-root writes
            // it back — no sharding, so no padding either
            let rounds = broadcast_binomial_rounds(n, raw);
            let cost = rounds_cost(sys, topo, ranks, &rounds, wire_ratio, raw, raw);
            out.push(Plan {
                kind: PlanKind::Binomial,
                phases: vec![Phase::Rounds(rounds)],
                payload_bytes: raw,
                predicted: cost,
            });
            if sys.switch.enabled() && n >= 2 {
                let groups = leaf_groups(topo, ranks);
                let l = groups.len();
                let m_max = groups.iter().map(Vec::len).max().unwrap_or(1);
                let bw = sys.net.effective_bw();
                let oversub_eff = m_max as f64 * bw / topo.uplink_bw(bw);
                let predicted =
                    switch_multicast_time_elems(sys, elems, m_max, l, oversub_eff, wire_ratio);
                if predicted.is_finite() {
                    out.push(Plan {
                        kind: PlanKind::SwitchMulticast,
                        phases: vec![Phase::SwitchMulticast { bytes: raw, groups }],
                        payload_bytes: raw,
                        predicted,
                    });
                }
            }
        }
        CollectiveKind::Allgather => {
            let rounds = allgather_ring_rounds(n, padded);
            let cost = rounds_cost(sys, topo, ranks, &rounds, wire_ratio, shard, padded);
            out.push(Plan {
                kind: PlanKind::Ring,
                phases: vec![Phase::Rounds(rounds)],
                payload_bytes: padded,
                predicted: cost,
            });
        }
        CollectiveKind::ReduceScatter => {
            let rounds = reduce_scatter_ring_rounds(n, padded, elems as f64);
            let cost = rounds_cost(sys, topo, ranks, &rounds, wire_ratio, padded, shard);
            out.push(Plan {
                kind: PlanKind::Ring,
                phases: vec![Phase::Rounds(rounds)],
                payload_bytes: padded,
                predicted: cost,
            });
        }
        CollectiveKind::AllToAll => {
            let rounds = all_to_all_rounds(n, padded);
            let cost = rounds_cost(sys, topo, ranks, &rounds, wire_ratio, padded, padded);
            out.push(Plan {
                kind: PlanKind::Pairwise,
                phases: vec![Phase::Rounds(rounds)],
                payload_bytes: padded,
                predicted: cost,
            });
        }
    }
    out
}

/// Pick the cheapest plan for this collective kind.
pub fn plan_collective(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
    kind: CollectiveKind,
) -> Plan {
    candidates_for(sys, topo, ranks, elems, wire_ratio, kind)
        .into_iter()
        .min_by(|a, b| a.predicted.total_cmp(&b.predicted))
        .expect("every kind has a host-path candidate")
}

/// Resolve an algorithm request for an arbitrary collective kind.
/// All-reduce routes through [`plan_for_algo`] unchanged; for the other
/// kinds, `SwitchReduce` asks for the switch offload (falling back
/// bit-identically to the host plan when the switch can't replicate or
/// the kind has no switch variant), `Auto` takes the cheapest, and any
/// NIC-path algorithm pins the canonical host/NIC rounds plan.
pub fn plan_collective_for_algo(
    sys: &SystemParams,
    topo: &Topology,
    ranks: &[usize],
    elems: usize,
    wire_ratio: f64,
    kind: CollectiveKind,
    algo: CollectiveAlgo,
) -> Plan {
    if kind == CollectiveKind::AllReduce {
        return plan_for_algo(sys, topo, ranks, elems, wire_ratio, algo);
    }
    let mut cands = candidates_for(sys, topo, ranks, elems, wire_ratio, kind);
    let idx = match algo {
        CollectiveAlgo::Auto => cands
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.predicted.total_cmp(&b.predicted))
            .map(|(i, _)| i)
            .expect("every kind has a host-path candidate"),
        CollectiveAlgo::SwitchReduce => cands
            .iter()
            .position(|c| c.kind == PlanKind::SwitchMulticast)
            .unwrap_or(0),
        _ => 0,
    };
    cands.swap_remove(idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysconfig::SwitchParams;

    const ELEMS: usize = 2048 * 2048;

    #[test]
    fn leaf_groups_follow_placement() {
        let topo = Topology::leaf_spine(2, 3, 4.0);
        let contig = leaf_groups(&topo, &topo.contiguous_ranks(6));
        assert_eq!(contig, vec![vec![0, 1, 2], vec![3, 4, 5]]);
        let strided = leaf_groups(&topo, &topo.strided_ranks(6));
        // strided: local ranks 0,2,4 land on leaf 0; 1,3,5 on leaf 1
        assert_eq!(strided, vec![vec![0, 2, 4], vec![1, 3, 5]]);
        let flat = leaf_groups(&Topology::flat(4), &[0, 1, 2, 3]);
        assert_eq!(flat.len(), 1);
    }

    #[test]
    fn uplink_factor_matches_placement() {
        let topo = Topology::leaf_spine(4, 8, 4.0);
        let n = 32;
        // contiguous: one exit edge per leaf -> the bundle absorbs it
        let f_contig = ring_uplink_factor(&topo, &topo.contiguous_ranks(n));
        assert_eq!(f_contig, 1.0);
        // strided: every edge crosses -> 8 exits share a 2-port bundle
        let f_strided = ring_uplink_factor(&topo, &topo.strided_ranks(n));
        assert_eq!(f_strided, 4.0);
        assert_eq!(ring_uplink_factor(&Topology::flat(n), &topo.contiguous_ranks(n)), 1.0);
    }

    #[test]
    fn planner_picks_ring_on_the_flat_crossbar() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::flat(8);
        let p = plan(&sys, &topo, &topo.contiguous_ranks(8), ELEMS, 1.0);
        assert_eq!(p.kind, PlanKind::Ring);
        assert!(p.phases.is_empty());
    }

    #[test]
    fn planner_undercuts_the_strided_ring_on_a_tapered_spine() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::leaf_spine(4, 8, 4.0);
        let ranks = topo.strided_ranks(32);
        let cands = candidates(&sys, &topo, &ranks, ELEMS, 1.0);
        let ring = cands.iter().find(|c| c.kind == PlanKind::Ring).unwrap();
        let hier = cands.iter().find(|c| c.kind == PlanKind::Hierarchical).unwrap();
        assert!(
            hier.predicted < ring.predicted * 0.8,
            "hierarchical {} vs strided ring {}",
            hier.predicted,
            ring.predicted
        );
        let chosen = plan(&sys, &topo, &ranks, ELEMS, 1.0);
        assert_ne!(chosen.kind, PlanKind::Ring, "planner kept the derated ring");
    }

    #[test]
    fn switch_plans_require_a_capable_fabric() {
        let topo = Topology::leaf_spine(2, 4, 4.0);
        let ranks = topo.contiguous_ranks(8);
        let plain = SystemParams::smartnic_40g();
        assert!(!candidates(&plain, &topo, &ranks, ELEMS, 1.0)
            .iter()
            .any(|c| c.kind == PlanKind::InSwitch));
        // forcing in-switch on a plain fabric falls back to the ring
        let fb = plan_fixed(&plain, &topo, &ranks, ELEMS, 1.0, PlanKind::InSwitch);
        assert_eq!(fb.kind, PlanKind::Ring);
        let netred = plain
            .with_switch_reduction(SwitchParams::netreduce(4, &plain.net));
        let cands = candidates(&netred, &topo, &ranks, ELEMS, 1.0);
        assert!(cands.iter().any(|c| c.kind == PlanKind::InSwitch));
    }

    #[test]
    // delegation identity is the point: idle load must not perturb a
    // single bit of the legacy pricing
    #[allow(clippy::float_cmp)]
    fn tenancy_load_prices_the_occupancy_knee() {
        use crate::netsim::fabric::Fabric;
        use crate::sysconfig::ClusterFaults;
        let base = SystemParams::smartnic_40g();
        let sys = base.with_switch_reduction(SwitchParams::netreduce(8, &base.net));
        let topo = Topology::leaf_spine(2, 4, 4.0);
        let ranks = topo.contiguous_ranks(8);
        // idle load is the legacy pricing, bit for bit, for every family
        let legacy = candidates(&sys, &topo, &ranks, ELEMS, 1.0);
        let idle = candidates_with(&sys, &topo, &ranks, ELEMS, 1.0, TenancyLoad::idle());
        assert_eq!(legacy.len(), idle.len());
        for (a, b) in legacy.iter().zip(&idle) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.predicted.to_bits(), b.predicted.to_bits());
        }
        // uncontended, in-switch wins on this shape
        let solo = plan_for_algo_with(
            &sys, &topo, &ranks, ELEMS, 1.0, CollectiveAlgo::Auto, TenancyLoad::idle(),
        );
        assert_eq!(solo.kind, PlanKind::InSwitch);
        // pile on tenants: past the knee the cheapest plan is not in-switch
        let mut flipped = None;
        for tenants in 2..=64 {
            let load = TenancyLoad {
                tenants,
                table_bytes: sys.switch.reduce_table_bytes,
                pause_duty: 1.0,
            };
            let p = plan_with(&sys, &topo, &ranks, ELEMS, 1.0, load);
            if p.kind != PlanKind::InSwitch {
                flipped = Some(tenants);
                break;
            }
        }
        let knee = flipped.expect("contention must eventually price in-switch out");
        assert!(knee >= 2, "knee at {knee}");
        // a granted share below one segment is a per-flow fallback even
        // when the family is forced
        let squeezed = TenancyLoad { tenants: 2, table_bytes: 1024.0, pause_duty: 1.0 };
        let fb = plan_for_algo_with(
            &sys, &topo, &ranks, ELEMS, 1.0, CollectiveAlgo::SwitchReduce, squeezed,
        );
        assert_eq!(fb.kind, PlanKind::Ring);
        // observed() snapshots a live fabric: empty table -> just this job
        let fabric = Fabric::with_topology(&sys, topo, &ClusterFaults::none());
        let seen = TenancyLoad::observed(&fabric, 0);
        assert_eq!(seen.tenants, 1);
        assert_eq!(seen.table_bytes, sys.switch.reduce_table_bytes);
        assert_eq!(seen.pause_duty, 1.0);
        // ... and counts a competing holder
        let mut fabric = fabric;
        let _ = fabric.table_mut().unwrap().request(9, 1024.0, 1024.0);
        let seen = TenancyLoad::observed(&fabric, 0);
        assert_eq!(seen.tenants, 2);
        assert_eq!(seen.table_bytes, sys.switch.reduce_table_bytes - 1024.0);
        // the holder itself sees its own slot and stays one tenant of two
        let held = TenancyLoad::observed(&fabric, 9);
        assert_eq!(held.tenants, 1);
        assert_eq!(held.table_bytes, 1024.0);
    }

    #[test]
    fn hierarchical_needs_uniform_groups() {
        let sys = SystemParams::smartnic_40g();
        let topo = Topology::leaf_spine(2, 4, 4.0);
        // 3 ranks on leaf 0, 2 on leaf 1: ragged -> no hierarchical plan
        let ranks = vec![0, 1, 2, 4, 5];
        assert!(!candidates(&sys, &topo, &ranks, ELEMS, 1.0)
            .iter()
            .any(|c| c.kind == PlanKind::Hierarchical));
        let fb = plan_fixed(&sys, &topo, &ranks, ELEMS, 1.0, PlanKind::Hierarchical);
        assert_eq!(fb.kind, PlanKind::Ring);
    }

    #[test]
    fn dma_split_keeps_rabenseifner_pinned_to_rs_plus_ag() {
        // All-reduce is exactly reduce-scatter + allgather: stitching the
        // ring reduce-scatter and ring allgather rounds into one plan
        // must price identically to summing the two standalone plans
        // minus the double-counted request overhead and the two
        // shard-sized DMA legs at the seam (an all-reduce keeps the
        // shards on the NIC between the halves).  This pins the
        // per-direction DMA split: symmetric (padded, padded) arguments
        // reproduce the pre-split all-reduce pricing bit-for-bit.
        let sys = SystemParams::smartnic_40g();
        for (topo, k) in [(Topology::flat(6), 6usize), (Topology::leaf_spine(2, 4, 4.0), 8)] {
            let ranks = topo.contiguous_ranks(k);
            let padded = ELEMS.div_ceil(k).max(1) as f64 * 4.0 * k as f64;
            let shard = padded / k as f64;
            let rs = reduce_scatter_ring_rounds(k, padded, ELEMS as f64);
            let ag = allgather_ring_rounds(k, padded);
            let rs_c = rounds_cost(&sys, &topo, &ranks, &rs, 1.0, padded, shard);
            let ag_c = rounds_cost(&sys, &topo, &ranks, &ag, 1.0, shard, padded);
            let mut both = rs.clone();
            both.extend(ag.iter().cloned());
            let ar_c = rounds_cost(&sys, &topo, &ranks, &both, 1.0, padded, padded);
            let seam = sys.nic_request_overhead
                + 2.0 * (shard / sys.nic.pcie_bw + sys.nic.pcie_latency);
            assert!(
                (rs_c + ag_c - seam - ar_c).abs() < 1e-12 * ar_c.abs().max(1.0),
                "rs {rs_c} + ag {ag_c} - seam {seam} != ar {ar_c}"
            );
        }
    }

    #[test]
    fn every_kind_plans_on_every_topology() {
        let sys = SystemParams::smartnic_40g()
            .with_switch_reduction(SwitchParams::netreduce(8, &SystemParams::smartnic_40g().net));
        for (topo, k) in [
            (Topology::flat(6), 6usize),
            (Topology::leaf_spine(3, 4, 4.0), 12),
        ] {
            let ranks = topo.contiguous_ranks(k);
            for kind in CollectiveKind::ALL {
                let p = plan_collective(&sys, &topo, &ranks, ELEMS, 1.0, kind);
                assert!(
                    p.predicted.is_finite() && p.predicted > 0.0,
                    "{} on {topo:?}: {}",
                    kind.name(),
                    p.predicted
                );
                if kind != CollectiveKind::AllReduce {
                    assert!(!p.phases.is_empty(), "{} plan has no phases", kind.name());
                }
            }
        }
    }

    #[test]
    fn broadcast_prefers_the_switch_and_falls_back_to_the_tree() {
        let topo = Topology::leaf_spine(4, 8, 4.0);
        let ranks = topo.contiguous_ranks(32);
        let plain = SystemParams::smartnic_40g();
        let netred =
            plain.with_switch_reduction(SwitchParams::netreduce(8, &plain.net));
        // a capable switch replicates at line rate: one payload up, one
        // down per member — cheaper than log2(n) full-payload tree hops
        let chosen =
            plan_collective(&netred, &topo, &ranks, ELEMS, 1.0, CollectiveKind::Broadcast);
        assert_eq!(chosen.kind, PlanKind::SwitchMulticast);
        // forcing the switch path on an incapable fabric falls back to
        // exactly the host binomial tree
        let forced = plan_collective_for_algo(
            &plain,
            &topo,
            &ranks,
            ELEMS,
            1.0,
            CollectiveKind::Broadcast,
            CollectiveAlgo::SwitchReduce,
        );
        let tree =
            plan_collective(&plain, &topo, &ranks, ELEMS, 1.0, CollectiveKind::Broadcast);
        assert_eq!(forced.kind, PlanKind::Binomial);
        assert_eq!(forced.predicted, tree.predicted);
    }

    #[test]
    fn every_candidate_conserves_the_reduction() {
        let sys = SystemParams::smartnic_40g()
            .with_switch_reduction(SwitchParams::netreduce(8, &SystemParams::smartnic_40g().net));
        for (topo, k) in [
            (Topology::flat(6), 6usize),
            (Topology::leaf_spine(3, 4, 4.0), 12),
            (Topology::leaf_spine(2, 4, 1.0), 8),
        ] {
            for ranks in [topo.contiguous_ranks(k), topo.strided_ranks(k)] {
                for c in candidates(&sys, &topo, &ranks, ELEMS, 1.0) {
                    let want = (k as f64 - 1.0) * ELEMS as f64;
                    let got = c.reduced_elems(k, ELEMS);
                    assert!(
                        (got - want).abs() <= want * 1e-9,
                        "{}: {} adds, want {}",
                        c.kind.name(),
                        got,
                        want
                    );
                }
            }
        }
    }
}
