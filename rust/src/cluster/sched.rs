//! Trace-driven gang scheduler: a multi-tenant cluster under churn.
//!
//! Where [`scenario`](super::scenario) runs a *static* co-location (every
//! job's placement fixed up front), this module grows the study into a
//! cluster scheduler: jobs arrive from a synthetic seeded trace (Poisson
//! arrivals, heavy-tailed gang sizes and iteration counts), a gang
//! scheduler places each arrival leaf-contiguously or fragmented under a
//! pluggable [`Policy`], elastic jobs grow/shrink at iteration
//! boundaries, and node failures preempt their occupants into a
//! checkpoint-restart cycle.  Everything is driven by the same unified
//! event engine — arrivals, placements, preemptions and restarts are
//! [`Event`] variants on the one calendar queue, so churn runs stay
//! bit-identical across `EngineKind`s and thread counts (pinned in
//! `rust/tests/engine_equiv.rs`).
//!
//! Determinism: every random choice (arrival gaps, gang sizes, iteration
//! counts, elastic ops, failure times) is precomputed from the trace seed
//! by [`synth_trace`] *before* the simulation starts; the scheduler
//! itself is a pure function of event order.  The per-node allocation
//! table and ready queue are index-addressed `Vec`s — no hash-order
//! iteration anywhere near the event path (`docs/INVARIANTS.md`).
//!
//! Preemption semantics ("checkpoint-restart"): a preempted job loses its
//! current iteration back to the last iteration boundary.  Its *started*
//! collectives drain to completion on the fabric (a real NIC cannot
//! recall a descriptor mid-flight — and, just as important, cancelling
//! them would make partition handlers' behavior depend on when a
//! same-time preempt executed, breaking parallel-engine bit-identity).
//! Collectives still inside the driver-request window are marked aborted
//! and excluded from the conservation ledger.

use super::job::{JobRuntime, JobSpec};
use super::scenario;
use super::{ClusterSim, ClusterState, Event, JobId, NodeId};
use crate::analytic::model::SystemKind;
use crate::netsim::audit::{AuditReport, AuditViolation};
use crate::netsim::engine::{EngineKind, PartitionStats, Sim};
use crate::netsim::fabric::Fabric;
use crate::netsim::topology::Topology;
use crate::netsim::Time;
use crate::sysconfig::{ClusterFaults, SystemParams, Workload};
use crate::trace::Trace;
use crate::util::rng::Rng;

/// Free marker in the per-node allocation table.
const FREE: u32 = u32::MAX;

/// Gang-placement policy of the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// first contiguous node-id run that fits; queue otherwise
    FirstFit,
    /// smallest contiguous run that fits (lowest start on ties); queue
    /// otherwise
    BestFit,
    /// contiguous first-fit, falling back to a leaf-striped scatter of
    /// whatever free nodes exist — a job fragments only when no
    /// contiguous hole could hold it
    FragAllowed,
    /// always leaf-striped scatter: the adversarial baseline that pins
    /// the fragmentation penalty (every gang pays spine crossings)
    Scatter,
}

impl Policy {
    /// Every policy, in the order the bench sweeps them.
    pub const ALL: [Policy; 4] =
        [Policy::FirstFit, Policy::BestFit, Policy::FragAllowed, Policy::Scatter];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::FirstFit => "first-fit",
            Policy::BestFit => "best-fit",
            Policy::FragAllowed => "frag-allowed",
            Policy::Scatter => "scatter",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "first-fit" => Some(Policy::FirstFit),
            "best-fit" => Some(Policy::BestFit),
            "frag-allowed" => Some(Policy::FragAllowed),
            "scatter" => Some(Policy::Scatter),
            _ => None,
        }
    }
}

/// One job in the arrival trace.
#[derive(Clone, Debug)]
pub struct TraceJob {
    pub name: String,
    /// virtual time the job enters the ready queue
    pub arrival: Time,
    /// ranks the gang scheduler must co-allocate (all-or-none)
    pub gang: usize,
    /// training iterations before the job departs
    pub iters: usize,
    pub workload: Workload,
    /// at most one elastic resize request over the job's lifetime
    pub elastic: Option<ElasticOp>,
}

/// An elastic join/leave request, applied at the job's next iteration
/// boundary (its checkpoint) if it is running, or to its queued demand
/// otherwise.
#[derive(Clone, Copy, Debug)]
pub struct ElasticOp {
    /// absolute virtual time the request arrives
    pub at: Time,
    /// true = grow by `delta` ranks (opportunistic — skipped when the
    /// fabric has no free nodes), false = shrink by `delta`
    pub grow: bool,
    pub delta: usize,
}

/// One injected node failure; the node repairs itself after the spec's
/// `repair_delay` and the occupant (if any) checkpoint-restarts after
/// `restart_delay`.
#[derive(Clone, Copy, Debug)]
pub struct Failure {
    pub at: Time,
    pub node: NodeId,
}

/// A full scheduler study: the fabric, the policy, and the precomputed
/// churn trace.  Everything random lives here, fixed before the first
/// event fires.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub sys: SystemParams,
    pub topology: Topology,
    /// static straggler / degraded-link injection (the fabric-level fault
    /// model churn rides on top of)
    pub faults: ClusterFaults,
    pub policy: Policy,
    pub jobs: Vec<TraceJob>,
    pub failures: Vec<Failure>,
    /// checkpoint-reload time between a preempt and re-entering the queue
    pub restart_delay: f64,
    /// time a failed node stays out of the allocatable pool
    pub repair_delay: f64,
}

/// Knobs of the synthetic trace generator ([`synth_trace`]).
#[derive(Clone, Debug)]
pub struct TraceGenConfig {
    pub jobs: usize,
    pub seed: u64,
    /// mean of the exponential inter-arrival gap (Poisson arrivals)
    pub mean_interarrival: f64,
    /// bounded-Pareto gang-size range (heavy tail, alpha 1.5)
    pub min_gang: usize,
    pub max_gang: usize,
    /// bounded-Pareto iteration-count cap (heavy tail, alpha 1.2)
    pub max_iters: usize,
    pub layers: usize,
    pub hidden: usize,
    pub batch_per_node: usize,
    /// fraction of jobs that file one elastic grow/shrink request
    pub elastic_fraction: f64,
    /// node failures injected over the trace horizon
    pub failures: usize,
    pub restart_delay: f64,
    pub repair_delay: f64,
}

impl Default for TraceGenConfig {
    fn default() -> Self {
        Self {
            jobs: 80,
            seed: 1,
            mean_interarrival: 0.02,
            min_gang: 2,
            max_gang: 16,
            max_iters: 6,
            layers: 2,
            hidden: 256,
            batch_per_node: 32,
            elastic_fraction: 0.25,
            failures: 3,
            restart_delay: 0.05,
            repair_delay: 0.2,
        }
    }
}

/// Bounded-Pareto sample on `[lo, hi]` — the heavy-tail workhorse for
/// gang sizes and iteration counts.
fn pareto_int(rng: &mut Rng, lo: usize, hi: usize, alpha: f64) -> usize {
    debug_assert!(lo >= 1 && hi >= lo);
    let u = rng.next_f64(); // [0, 1) => 1-u in (0, 1]
    let x = lo as f64 / (1.0 - u).powf(1.0 / alpha);
    (x.floor() as usize).clamp(lo, hi)
}

/// Generate a seeded churn trace on `topology`.  Each random stream
/// (arrivals, gangs, iteration counts, elastic ops, failures) is forked
/// independently from the seed, so changing one knob does not shift the
/// others.
pub fn synth_trace(
    sys: SystemParams,
    topology: Topology,
    policy: Policy,
    cfg: &TraceGenConfig,
) -> TraceSpec {
    let nodes = topology.nodes();
    assert!(cfg.jobs >= 1, "trace needs at least one job");
    assert!(
        cfg.min_gang >= 1 && cfg.min_gang <= cfg.max_gang && cfg.max_gang <= nodes,
        "gang range [{}, {}] must fit the {nodes}-node fabric",
        cfg.min_gang,
        cfg.max_gang
    );
    assert!(cfg.max_iters >= 1, "jobs need at least one iteration");
    assert!(
        cfg.mean_interarrival > 0.0 && cfg.mean_interarrival.is_finite(),
        "mean inter-arrival must be positive and finite"
    );
    assert!(
        cfg.restart_delay >= 0.0 && cfg.repair_delay >= 0.0,
        "churn delays must be non-negative"
    );
    let mut root = Rng::new(cfg.seed);
    let mut arrivals = root.fork(1);
    let mut gangs = root.fork(2);
    let mut iters = root.fork(3);
    let mut elastic = root.fork(4);
    let mut failures = root.fork(5);

    let horizon = cfg.jobs as f64 * cfg.mean_interarrival;
    let mut t = 0.0;
    let jobs: Vec<TraceJob> = (0..cfg.jobs)
        .map(|i| {
            // exponential inter-arrival gap: -mean * ln(1 - U)
            t += -cfg.mean_interarrival * (1.0 - arrivals.next_f64()).ln();
            let gang = pareto_int(&mut gangs, cfg.min_gang, cfg.max_gang, 1.5);
            let n_iters = pareto_int(&mut iters, 1, cfg.max_iters, 1.2);
            let op = if elastic.next_f64() < cfg.elastic_fraction && gang >= 2 {
                Some(ElasticOp {
                    at: t + elastic.range_f64(0.5, 5.0) * cfg.mean_interarrival,
                    grow: elastic.next_f64() < 0.5,
                    delta: 1 + elastic.below((gang / 2) as u64) as usize,
                })
            } else {
                None
            };
            TraceJob {
                name: format!("job{i}"),
                arrival: t,
                gang,
                iters: n_iters,
                workload: Workload {
                    layers: cfg.layers,
                    hidden: cfg.hidden,
                    batch_per_node: cfg.batch_per_node,
                },
                elastic: op,
            }
        })
        .collect();
    let failures = (0..cfg.failures)
        .map(|_| Failure {
            at: failures.range_f64(0.1, 0.9) * horizon.max(cfg.mean_interarrival),
            node: failures.below(nodes as u64) as usize,
        })
        .collect();
    TraceSpec {
        sys,
        topology,
        faults: ClusterFaults::none(),
        policy,
        jobs,
        failures,
        restart_delay: cfg.restart_delay,
        repair_delay: cfg.repair_delay,
    }
}

/// Lifecycle phase of one traced job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum JobPhase {
    /// arrival event not fired yet
    Pending,
    /// in the ready queue (first arrival, or re-queued after a restart)
    Queued,
    /// gang placed, worker running
    Running,
    /// preempted; waiting out the checkpoint-reload delay
    Restarting,
    /// all iterations complete, gang released
    Done,
}

/// Scheduler-side bookkeeping for one traced job.
#[derive(Clone, Debug)]
struct SchedJob {
    /// current gang demand (elastic ops move it)
    gang: usize,
    /// iterations the trace demands — the conservation ledger checks the
    /// runtime completed exactly this many
    demand_iters: usize,
    arrival: Time,
    phase: JobPhase,
    /// nodes currently held (ascending; empty unless Running)
    nodes: Vec<NodeId>,
    first_placed: Option<Time>,
    completed: Option<Time>,
    /// this job ever ran on a non-contiguous placement
    frag_ever: bool,
    preemptions: u32,
    restarts: u32,
    /// elastic request parked until the next iteration boundary
    pending_resize: Option<(bool, usize)>,
}

/// One entry of the allocation journal ([`SchedState::log`]); the Vec
/// order is the commit order, so property tests can replay the whole
/// placement history.
#[derive(Clone, Debug)]
pub struct AllocEvent {
    pub t: Time,
    /// the affected job, or the failed/repaired node's own id for
    /// `NodeDown`/`NodeUp`
    pub job: usize,
    pub kind: AllocKind,
    /// nodes placed/released (ascending); the single node for
    /// `NodeDown`/`NodeUp`
    pub nodes: Vec<NodeId>,
}

/// What an [`AllocEvent`] records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocKind {
    /// a gang was committed, all-or-none; `frag` = not one contiguous run
    Place { frag: bool },
    /// a gang (or part of one, on an elastic shrink) was released
    Release,
    /// a node failed out of the allocatable pool
    NodeDown,
    /// a node repaired back into the pool
    NodeUp,
}

/// The gang scheduler's live state, owned by [`ClusterState::sched`] and
/// touched exclusively by coordinator events (see the `PartitionedWorld`
/// safety argument in `cluster/mod.rs`).
#[derive(Clone, Debug)]
pub struct SchedState {
    policy: Policy,
    /// node -> owning job, [`FREE`] when unallocated
    alloc: Vec<u32>,
    /// node -> failed and not yet repaired
    down: Vec<bool>,
    /// ready queue, FIFO with greedy in-order backfill
    queue: Vec<u32>,
    meta: Vec<SchedJob>,
    /// the committed allocation journal, in commit order
    pub log: Vec<AllocEvent>,
    nodes_per_leaf: usize,
    restart_delay: f64,
    repair_delay: f64,
}

impl SchedState {
    fn new(spec: &TraceSpec) -> Self {
        let nodes = spec.topology.nodes();
        let nodes_per_leaf = match spec.topology {
            Topology::Flat { nodes } => nodes.max(1),
            Topology::LeafSpine { nodes_per_leaf, .. } => nodes_per_leaf,
        };
        Self {
            policy: spec.policy,
            alloc: vec![FREE; nodes],
            down: vec![false; nodes],
            queue: Vec::new(),
            meta: spec
                .jobs
                .iter()
                .map(|j| SchedJob {
                    gang: j.gang,
                    demand_iters: j.iters,
                    arrival: j.arrival,
                    phase: JobPhase::Pending,
                    nodes: Vec::new(),
                    first_placed: None,
                    completed: None,
                    frag_ever: false,
                    preemptions: 0,
                    restarts: 0,
                    pending_resize: None,
                })
                .collect(),
            log: Vec::new(),
            nodes_per_leaf,
            restart_delay: spec.restart_delay,
            repair_delay: spec.repair_delay,
        }
    }
}

fn contiguous(nodes: &[NodeId]) -> bool {
    nodes.windows(2).all(|w| w[1] == w[0] + 1)
}

/// Maximal runs of consecutive free (and up) nodes, as `(start, len)`.
fn free_runs(alloc: &[u32], down: &[bool]) -> Vec<(usize, usize)> {
    let mut runs = Vec::new();
    let mut start = None;
    for i in 0..alloc.len() {
        let free = alloc[i] == FREE && !down[i];
        match (free, start) {
            (true, None) => start = Some(i),
            (false, Some(s)) => {
                runs.push((s, i - s));
                start = None;
            }
            _ => {}
        }
    }
    if let Some(s) = start {
        runs.push((s, alloc.len() - s));
    }
    runs
}

/// Leaf-striped pick of `g` free nodes: one node per leaf round-robin, so
/// the gang spreads across as many leaves as possible (the adversarial
/// anti-placement, and the frag-allowed fallback).
fn scatter_pick(alloc: &[u32], down: &[bool], nodes_per_leaf: usize, g: usize) -> Option<Vec<NodeId>> {
    let n = alloc.len();
    let leaves = n.div_ceil(nodes_per_leaf);
    let mut picked = Vec::with_capacity(g);
    for offset in 0..nodes_per_leaf {
        for leaf in 0..leaves {
            let node = leaf * nodes_per_leaf + offset;
            if node < n && alloc[node] == FREE && !down[node] {
                picked.push(node);
                if picked.len() == g {
                    picked.sort_unstable();
                    return Some(picked);
                }
            }
        }
    }
    None
}

/// The placement decision: `g` nodes under `policy`, or `None` (stay
/// queued).  Returns the node list (ascending) and whether the placement
/// is fragmented.  Pure function of the tables — the property suite
/// replays it offline.
fn find_nodes(
    policy: Policy,
    g: usize,
    alloc: &[u32],
    down: &[bool],
    nodes_per_leaf: usize,
) -> Option<(Vec<NodeId>, bool)> {
    debug_assert!(g >= 1);
    let runs = free_runs(alloc, down);
    let hole = match policy {
        Policy::FirstFit | Policy::FragAllowed => {
            runs.iter().find(|&&(_, len)| len >= g).map(|&(s, _)| s)
        }
        Policy::BestFit => runs
            .iter()
            .filter(|&&(_, len)| len >= g)
            .min_by_key(|&&(s, len)| (len, s))
            .map(|&(s, _)| s),
        Policy::Scatter => None,
    };
    if let Some(start) = hole {
        return Some(((start..start + g).collect(), false));
    }
    match policy {
        Policy::FragAllowed => {
            scatter_pick(alloc, down, nodes_per_leaf, g).map(|ns| (ns, true))
        }
        Policy::Scatter => scatter_pick(alloc, down, nodes_per_leaf, g).map(|ns| {
            let frag = !contiguous(&ns);
            (ns, frag)
        }),
        _ => None,
    }
}

fn sched(st: &mut ClusterState) -> &mut SchedState {
    st.sched.as_deref_mut().expect("scheduler event on a run without a scheduler")
}

/// Commit a placement: update the tables, journal it, rebuild the job's
/// runtime for the actual ranks, and wake the worker.
fn place_job(sim: &mut ClusterSim, st: &mut ClusterState, jid: JobId, nodes: Vec<NodeId>, frag: bool) {
    let now = sim.now();
    {
        let s = sched(st);
        s.queue.retain(|&q| q as usize != jid);
        for &n in &nodes {
            debug_assert!(s.alloc[n] == FREE && !s.down[n], "placing onto a busy node");
            s.alloc[n] = jid as u32;
        }
        let m = &mut s.meta[jid];
        m.phase = JobPhase::Running;
        m.nodes = nodes.clone();
        m.gang = nodes.len();
        m.frag_ever |= frag;
        if m.first_placed.is_none() {
            m.first_placed = Some(now);
        }
        s.log.push(AllocEvent { t: now, job: jid, kind: AllocKind::Place { frag }, nodes: nodes.clone() });
    }
    let sys = st.sys;
    st.jobs[jid].reconfigure(nodes, &sys);
    let epoch = st.jobs[jid].epoch;
    sim.schedule_at(now, Event::JobWake { job: jid as u32, epoch });
}

/// FIFO-with-backfill pass: repeatedly place the first queued job that
/// fits under the current tables, until none does.
fn try_place_queued(sim: &mut ClusterSim, st: &mut ClusterState) {
    loop {
        let placed = {
            let s = sched(st);
            let mut found = None;
            for &q in &s.queue {
                let jid = q as usize;
                let g = s.meta[jid].gang;
                if let Some((nodes, frag)) =
                    find_nodes(s.policy, g, &s.alloc, &s.down, s.nodes_per_leaf)
                {
                    found = Some((jid, nodes, frag));
                    break;
                }
            }
            found
        };
        let Some((jid, nodes, frag)) = placed else { return };
        place_job(sim, st, jid, nodes, frag);
    }
}

/// Release every node `jid` holds and journal it.  No-op on an empty
/// holding (e.g. depart racing a same-time preempt).
fn release_nodes(st: &mut ClusterState, jid: JobId, now: Time) {
    let s = sched(st);
    let nodes = std::mem::take(&mut s.meta[jid].nodes);
    if nodes.is_empty() {
        return;
    }
    for &n in &nodes {
        debug_assert_eq!(s.alloc[n], jid as u32, "releasing a node the job does not hold");
        s.alloc[n] = FREE;
    }
    s.log.push(AllocEvent { t: now, job: jid, kind: AllocKind::Release, nodes });
}

/// [`Event::JobArrive`]: the job enters the ready queue.
pub(super) fn on_job_arrive(sim: &mut ClusterSim, st: &mut ClusterState, jid: JobId) {
    {
        let s = sched(st);
        debug_assert_eq!(s.meta[jid].phase, JobPhase::Pending, "double arrival");
        s.meta[jid].phase = JobPhase::Queued;
        s.queue.push(jid as u32);
    }
    try_place_queued(sim, st);
}

/// [`Event::JobDepart`]: the worker finished its last iteration — release
/// the gang and give the freed nodes to the queue.
pub(super) fn on_job_depart(sim: &mut ClusterSim, st: &mut ClusterState, jid: JobId) {
    let now = sim.now();
    release_nodes(st, jid, now);
    {
        let s = sched(st);
        s.meta[jid].phase = JobPhase::Done;
        s.meta[jid].completed = Some(now);
    }
    try_place_queued(sim, st);
}

/// [`Event::JobPreempt`]: evict a running job.  The current iteration is
/// lost back to the checkpoint; started collectives drain, unstarted ones
/// are aborted (see the module docs), and the job re-queues after the
/// restart delay.
pub(super) fn on_job_preempt(sim: &mut ClusterSim, st: &mut ClusterState, jid: JobId) {
    let now = sim.now();
    let phase = sched(st).meta[jid].phase;
    if phase != JobPhase::Running || st.jobs[jid].t_done.is_some() {
        // already evicted by a same-time failure, or the job finished at
        // this very instant (its depart event will settle it)
        return;
    }
    release_nodes(st, jid, now);
    let restart_delay = {
        let s = sched(st);
        let m = &mut s.meta[jid];
        m.phase = JobPhase::Restarting;
        m.preemptions += 1;
        s.restart_delay
    };
    // invalidate pending compute wakes and unblock the worker; in-flight
    // collectives keep draining and complete as orphans (their cid no
    // longer matches anything the job waits on)
    st.jobs[jid].epoch = st.jobs[jid].epoch.wrapping_add(1);
    st.jobs[jid].blocked_on = None;
    for c in st.collectives.iter_mut() {
        if c.job == jid && c.t_done.is_none() && !c.started {
            c.aborted = true;
        }
    }
    sim.schedule(restart_delay, Event::JobRestart { job: jid as u32 });
    // the eviction freed nodes — queued jobs may fit now
    try_place_queued(sim, st);
}

/// [`Event::JobRestart`]: the checkpoint is reloaded — re-enter the ready
/// queue (iteration progress survives; the interrupted iteration reruns).
pub(super) fn on_job_restart(sim: &mut ClusterSim, st: &mut ClusterState, jid: JobId) {
    {
        let s = sched(st);
        if s.meta[jid].phase != JobPhase::Restarting {
            return;
        }
        s.meta[jid].phase = JobPhase::Queued;
        s.meta[jid].restarts += 1;
        s.queue.push(jid as u32);
    }
    try_place_queued(sim, st);
}

/// [`Event::JobGrow`] / [`Event::JobShrink`]: an elastic resize request.
/// Running jobs park it until their next iteration boundary (the
/// checkpoint); queued/restarting jobs adjust their demand immediately.
pub(super) fn on_job_resize(
    _sim: &mut ClusterSim,
    st: &mut ClusterState,
    jid: JobId,
    grow: bool,
    delta: usize,
) {
    let s = sched(st);
    let total = s.alloc.len();
    let m = &mut s.meta[jid];
    match m.phase {
        JobPhase::Done => {}
        JobPhase::Running => m.pending_resize = Some((grow, delta)),
        JobPhase::Pending | JobPhase::Queued | JobPhase::Restarting => {
            m.gang = if grow {
                (m.gang + delta).min(total)
            } else {
                m.gang.saturating_sub(delta).max(1)
            };
        }
    }
}

/// Called by the worker between iterations: apply a parked elastic
/// resize.  Shrinks keep the ascending prefix of the held nodes; grows
/// opportunistically take free nodes in index order (none free — the
/// request is dropped).  The swap is journaled as Release + Place so the
/// property suite replays it like any other placement.
pub(crate) fn on_iteration_boundary(sim: &mut ClusterSim, st: &mut ClusterState, jid: JobId) {
    let now = sim.now();
    let resize = {
        let s = sched(st);
        s.meta[jid].pending_resize.take()
    };
    let Some((grow, delta)) = resize else { return };
    let new_nodes = {
        let s = sched(st);
        let cur = &s.meta[jid].nodes;
        if grow {
            // contiguous edge extension first: taking the nodes just past
            // the block's ends keeps a contiguous gang contiguous, so the
            // contiguous policies never fragment through growth
            let total = s.alloc.len();
            let mut extra: Vec<NodeId> = Vec::with_capacity(delta);
            let mut after = cur[cur.len() - 1] + 1;
            let mut before = cur[0];
            while extra.len() < delta {
                if after < total && s.alloc[after] == FREE && !s.down[after] {
                    extra.push(after);
                    after += 1;
                } else if before > 0 && s.alloc[before - 1] == FREE && !s.down[before - 1] {
                    before -= 1;
                    extra.push(before);
                } else {
                    break;
                }
            }
            // only the fragmentation-tolerant policies top up from
            // anywhere; first-fit/best-fit settle for the edge growth (or
            // drop the request entirely)
            if matches!(s.policy, Policy::FragAllowed | Policy::Scatter) {
                for i in 0..total {
                    if extra.len() >= delta {
                        break;
                    }
                    if s.alloc[i] == FREE && !s.down[i] && !extra.contains(&i) {
                        extra.push(i);
                    }
                }
            }
            if extra.is_empty() {
                return;
            }
            let mut ns = cur.clone();
            ns.extend(extra);
            ns.sort_unstable();
            ns
        } else {
            let keep = cur.len().saturating_sub(delta).max(1);
            if keep == cur.len() {
                return;
            }
            cur[..keep].to_vec()
        }
    };
    let frag = !contiguous(&new_nodes);
    release_nodes(st, jid, now);
    {
        let s = sched(st);
        for &n in &new_nodes {
            s.alloc[n] = jid as u32;
        }
        let m = &mut s.meta[jid];
        m.nodes = new_nodes.clone();
        m.gang = new_nodes.len();
        m.frag_ever |= frag;
        s.log.push(AllocEvent {
            t: now,
            job: jid,
            kind: AllocKind::Place { frag },
            nodes: new_nodes.clone(),
        });
    }
    let sys = st.sys;
    st.jobs[jid].reconfigure(new_nodes, &sys);
    // a shrink freed nodes — queued jobs may fit now
    try_place_queued(sim, st);
}

/// [`Event::NodeFail`]: take the node out of the pool, preempt its
/// occupant, and start the repair timer.
pub(super) fn on_node_fail(sim: &mut ClusterSim, st: &mut ClusterState, node: NodeId) {
    let now = sim.now();
    let (victim, repair_delay) = {
        let s = sched(st);
        s.down[node] = true;
        s.log.push(AllocEvent { t: now, job: node, kind: AllocKind::NodeDown, nodes: vec![node] });
        let v = if s.alloc[node] != FREE { Some(s.alloc[node] as usize) } else { None };
        (v, s.repair_delay)
    };
    sim.schedule(repair_delay, Event::NodeRepair { node: node as u32 });
    if let Some(jid) = victim {
        sim.schedule_at(now, Event::JobPreempt { job: jid as u32 });
    }
}

/// [`Event::NodeRepair`]: the node rejoins the pool.
pub(super) fn on_node_repair(sim: &mut ClusterSim, st: &mut ClusterState, node: NodeId) {
    let now = sim.now();
    {
        let s = sched(st);
        s.down[node] = false;
        s.log.push(AllocEvent { t: now, job: node, kind: AllocKind::NodeUp, nodes: vec![node] });
    }
    try_place_queued(sim, st);
}

/// Post-quiescence scheduler ledger (`docs/INVARIANTS.md`:
/// `leaked-allocation`, `job-conservation`): at quiescence every node
/// must be free — any residual assignment is a job that left without
/// releasing — and every arrived job must have completed exactly the
/// iterations its trace demanded (a checkpoint-restart that double-counts
/// an iteration, or a job that vanished, breaks this).
fn audit_sched(state: &ClusterState, report: &mut AuditReport) {
    let Some(s) = state.sched.as_deref() else { return };
    for (node, &owner) in s.alloc.iter().enumerate() {
        if owner != FREE {
            report.record(AuditViolation::LeakedAllocation { node, job: owner as usize });
        }
    }
    for (jid, m) in s.meta.iter().enumerate() {
        let done = state.jobs[jid].iters_done;
        if m.phase != JobPhase::Done || m.completed.is_none() || done != m.demand_iters {
            report.record(AuditViolation::JobConservation {
                job: jid,
                done,
                demand: m.demand_iters,
            });
        }
    }
}

/// Per-job outcome of a trace run.
#[derive(Clone, Debug)]
pub struct TraceJobResult {
    pub name: String,
    /// final gang size (elastic ops may have moved it)
    pub gang: usize,
    pub arrival: Time,
    pub first_placed: Time,
    pub completed: Time,
    /// job completion time: queueing wait + service, `completed - arrival`
    pub jct: f64,
    /// time from arrival to the first placement
    pub queue_wait: f64,
    /// the job ever ran on a fragmented (non-contiguous) placement
    pub frag: bool,
    pub preemptions: u32,
    pub restarts: u32,
    pub iters: usize,
}

/// Everything a trace run produces.
pub struct TraceOutput {
    pub jobs: Vec<TraceJobResult>,
    /// the committed allocation journal, for offline property replay
    pub log: Vec<AllocEvent>,
    /// last job completion time
    pub makespan: Time,
    pub events: u64,
    /// allocated node-seconds over `nodes * makespan`
    pub node_util: f64,
    /// fabric Ethernet utilization over the makespan
    pub eth_util: f64,
    /// collectives aborted inside the driver-request window by preempts
    pub aborted_collectives: usize,
    pub peak_queue_depth: usize,
    pub partitions: Vec<PartitionStats>,
    /// audit of an [`EngineKind::Checked`] run (engine invariants +
    /// conservation + the scheduler ledger); `None` otherwise
    pub audit: Option<AuditReport>,
    pub nodes: usize,
}

/// Validate `spec`, build the state and seed the churn events.
fn init(spec: &TraceSpec, engine: EngineKind) -> (ClusterSim, ClusterState) {
    let nodes = spec.topology.nodes();
    assert!(nodes >= 1, "cluster needs at least one node");
    assert!(!spec.jobs.is_empty(), "trace needs at least one job");
    assert!(
        spec.restart_delay >= 0.0
            && spec.restart_delay.is_finite()
            && spec.repair_delay >= 0.0
            && spec.repair_delay.is_finite(),
        "churn delays must be non-negative and finite"
    );
    for j in &spec.jobs {
        assert!(
            j.gang >= 1 && j.gang <= nodes,
            "job '{}': gang {} cannot fit the {nodes}-node fabric",
            j.name,
            j.gang
        );
        assert!(j.iters >= 1, "job '{}': needs at least one iteration", j.name);
        assert!(
            j.arrival >= 0.0 && j.arrival.is_finite(),
            "job '{}': arrival must be non-negative and finite",
            j.name
        );
    }
    for f in &spec.failures {
        assert!(f.node < nodes, "failure on node {} outside the {nodes}-node fabric", f.node);
        assert!(f.at >= 0.0 && f.at.is_finite(), "failure time must be non-negative and finite");
    }
    let jobs: Vec<JobRuntime> = spec
        .jobs
        .iter()
        .map(|tj| {
            // placeholder single-rank spec: the real gang is bound by the
            // scheduler at placement time via `reconfigure`
            let js = JobSpec::new(
                &tj.name,
                SystemKind::SmartNic { bfp: false },
                tj.workload,
                vec![0],
            );
            let mut rt = JobRuntime::new(js, &spec.sys);
            rt.iters_total = tj.iters;
            rt
        })
        .collect();
    let state = ClusterState {
        sys: spec.sys,
        fabric: Fabric::with_topology(&spec.sys, spec.topology, &spec.faults),
        trace: Trace::new(),
        jobs,
        collectives: Vec::new(),
        sched: Some(Box::new(SchedState::new(spec))),
    };
    let mut sim: ClusterSim = Sim::with_engine(engine);
    for (jid, tj) in spec.jobs.iter().enumerate() {
        sim.schedule_at(tj.arrival, Event::JobArrive { job: jid as u32 });
        if let Some(op) = &tj.elastic {
            let ev = if op.grow {
                Event::JobGrow { job: jid as u32, nodes: op.delta as u32 }
            } else {
                Event::JobShrink { job: jid as u32, nodes: op.delta as u32 }
            };
            sim.schedule_at(op.at.max(tj.arrival), ev);
        }
    }
    for f in &spec.failures {
        sim.schedule_at(f.at, Event::NodeFail { node: f.node as u32 });
    }
    (sim, state)
}

/// Run a churn trace to completion on `engine`.  Fully deterministic:
/// identical specs produce bit-identical outputs on every engine kind and
/// thread count (pinned in `rust/tests/engine_equiv.rs`).
pub fn run_trace(spec: &TraceSpec, engine: EngineKind) -> TraceOutput {
    let (mut sim, mut state) = init(spec, engine);
    scenario::drive(&mut sim, &mut state, engine);
    let audit = sim.take_audit_report().map(|mut report| {
        scenario::audit_conservation(&state, sim.now(), &mut report);
        audit_sched(&state, &mut report);
        report
    });

    let nodes = spec.topology.nodes();
    let sched_state = state.sched.take().expect("run_trace armed a scheduler");
    let jobs: Vec<TraceJobResult> = sched_state
        .meta
        .iter()
        .zip(&spec.jobs)
        .enumerate()
        .map(|(jid, (m, tj))| {
            let completed = m.completed.unwrap_or_else(|| {
                panic!("job '{}' never finished (scheduler deadlock?)", tj.name)
            });
            let first_placed = m.first_placed.expect("completed job was placed");
            TraceJobResult {
                name: tj.name.clone(),
                gang: m.gang,
                arrival: m.arrival,
                first_placed,
                completed,
                jct: completed - m.arrival,
                queue_wait: first_placed - m.arrival,
                frag: m.frag_ever,
                preemptions: m.preemptions,
                restarts: m.restarts,
                iters: state.jobs[jid].iters_done,
            }
        })
        .collect();
    let makespan = jobs.iter().map(|j| j.completed).fold(0.0, f64::max);

    // replay the journal for allocated node-seconds (utilization)
    let mut open: Vec<Option<(Time, usize)>> = vec![None; spec.jobs.len()];
    let mut node_seconds = 0.0;
    for ev in &sched_state.log {
        match ev.kind {
            AllocKind::Place { .. } => open[ev.job] = Some((ev.t, ev.nodes.len())),
            AllocKind::Release => {
                if let Some((t0, k)) = open[ev.job].take() {
                    node_seconds += (ev.t - t0) * k as f64;
                }
            }
            AllocKind::NodeDown | AllocKind::NodeUp => {}
        }
    }
    let node_util = if makespan > 0.0 { node_seconds / (nodes as f64 * makespan) } else { 0.0 };

    TraceOutput {
        log: sched_state.log,
        makespan,
        events: sim.events_run(),
        node_util,
        eth_util: state.fabric.mean_eth_util(makespan.max(1e-12)),
        aborted_collectives: state.collectives.iter().filter(|c| c.aborted).count(),
        peak_queue_depth: sim.peak_pending(),
        partitions: sim.partition_stats().to_vec(),
        audit,
        nodes,
        jobs,
    }
}

#[cfg(test)]
// exact float equalities are deliberate: determinism tests pin
// bit-identical virtual times across runs
#[allow(clippy::float_cmp)]
mod tests {
    use super::*;

    fn small_sys() -> (SystemParams, Topology) {
        (SystemParams::smartnic_40g(), Topology::leaf_spine(4, 4, 4.0))
    }

    fn tiny_trace(policy: Policy, failures: usize) -> TraceSpec {
        let (sys, topo) = small_sys();
        let cfg = TraceGenConfig {
            jobs: 12,
            seed: 7,
            mean_interarrival: 0.01,
            min_gang: 2,
            max_gang: 8,
            max_iters: 3,
            layers: 2,
            hidden: 64,
            batch_per_node: 8,
            elastic_fraction: 0.4,
            failures,
            restart_delay: 0.01,
            repair_delay: 0.05,
        };
        synth_trace(sys, topo, policy, &cfg)
    }

    #[test]
    fn synth_trace_is_seed_deterministic() {
        let a = tiny_trace(Policy::FirstFit, 2);
        let b = tiny_trace(Policy::FirstFit, 2);
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.gang, y.gang);
            assert_eq!(x.iters, y.iters);
        }
        for (x, y) in a.failures.iter().zip(&b.failures) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.node, y.node);
        }
    }

    #[test]
    fn first_fit_takes_first_hole() {
        let mut alloc = vec![FREE; 8];
        let down = vec![false; 8];
        alloc[0] = 0; // busy: holes are [1..4) len 3 and [4..8) len 4
        alloc[4] = FREE;
        alloc[1] = 9;
        // layout: [busy, busy, free, free, free, free, free, free]
        let (nodes, frag) = find_nodes(Policy::FirstFit, 3, &alloc, &down, 4).unwrap();
        assert_eq!(nodes, vec![2, 3, 4]);
        assert!(!frag);
    }

    #[test]
    fn best_fit_takes_smallest_hole() {
        // holes: [0..2) len 2, [3..8) len 5 — best fit for g=2 is the
        // first; first-fit would also take it, so split them with g=2 on
        // holes [0..3) len 3 and [4..6) len 2
        let mut alloc = vec![FREE; 8];
        let down = vec![false; 8];
        alloc[3] = 1;
        alloc[6] = 1;
        alloc[7] = 1;
        // holes: [0..3) len 3, [4..6) len 2
        let (ff, _) = find_nodes(Policy::FirstFit, 2, &alloc, &down, 4).unwrap();
        assert_eq!(ff, vec![0, 1]);
        let (bf, _) = find_nodes(Policy::BestFit, 2, &alloc, &down, 4).unwrap();
        assert_eq!(bf, vec![4, 5]);
    }

    #[test]
    fn frag_allowed_scatters_only_without_a_hole() {
        let mut alloc = vec![FREE; 8];
        let down = vec![false; 8];
        // kill any contiguous pair: busy every other node
        for i in [1, 3, 5, 7] {
            alloc[i] = 2;
        }
        let (nodes, frag) = find_nodes(Policy::FragAllowed, 2, &alloc, &down, 4).unwrap();
        assert!(frag, "no 2-hole exists, placement must be marked fragmented");
        assert_eq!(nodes.len(), 2);
        // with a hole available the same policy stays contiguous
        let alloc2 = vec![FREE; 8];
        let (nodes2, frag2) = find_nodes(Policy::FragAllowed, 2, &alloc2, &down, 4).unwrap();
        assert!(!frag2);
        assert_eq!(nodes2, vec![0, 1]);
    }

    #[test]
    fn scatter_stripes_across_leaves() {
        let alloc = vec![FREE; 8];
        let down = vec![false; 8];
        let (nodes, frag) = find_nodes(Policy::Scatter, 2, &alloc, &down, 4).unwrap();
        // 2 leaves of 4: round-robin picks node 0 (leaf 0) and node 4
        // (leaf 1)
        assert_eq!(nodes, vec![0, 4]);
        assert!(frag);
    }

    #[test]
    fn down_nodes_are_never_handed_out() {
        let alloc = vec![FREE; 8];
        let mut down = vec![false; 8];
        down[0] = true;
        down[4] = true;
        for policy in Policy::ALL {
            if let Some((nodes, _)) = find_nodes(policy, 3, &alloc, &down, 4) {
                assert!(!nodes.contains(&0) && !nodes.contains(&4), "{policy:?} used a down node");
            }
        }
    }

    #[test]
    fn churn_trace_completes_and_audits_clean() {
        let spec = tiny_trace(Policy::FragAllowed, 2);
        let out = run_trace(&spec, EngineKind::Checked { threads: 0 });
        assert_eq!(out.jobs.len(), spec.jobs.len());
        for j in &out.jobs {
            assert!(j.completed >= j.first_placed && j.first_placed >= j.arrival);
            assert!(j.jct > 0.0);
        }
        let report = out.audit.expect("checked run carries a report");
        assert!(report.is_clean(), "churn audit violations: {}", report.summary());
        assert!(out.events > 0 && out.makespan > 0.0);
        assert!(out.node_util > 0.0 && out.node_util <= 1.0 + 1e-9);
    }

    #[test]
    fn preemption_restarts_preserve_iteration_count() {
        let (sys, topo) = small_sys();
        let wl = Workload { layers: 2, hidden: 64, batch_per_node: 8 };
        // fail node 1 squarely inside the first forward pass: the
        // occupant is mid-compute, loses the iteration back to the
        // checkpoint, and restarts on a fresh contiguous hole
        let probe = JobRuntime::new(
            JobSpec::new("p", SystemKind::SmartNic { bfp: false }, wl, vec![0, 1, 2, 3]),
            &sys,
        );
        let spec = TraceSpec {
            sys,
            topology: topo,
            faults: ClusterFaults::none(),
            policy: Policy::FirstFit,
            jobs: vec![TraceJob {
                name: "victim".to_string(),
                arrival: 0.0,
                gang: 4,
                iters: 3,
                workload: wl,
                elastic: None,
            }],
            failures: vec![Failure { at: 0.5 * probe.lt.t_f, node: 1 }],
            restart_delay: 0.01,
            repair_delay: 0.02,
        };
        let out = run_trace(&spec, EngineKind::Checked { threads: 0 });
        let report = out.audit.expect("checked run carries a report");
        assert!(report.is_clean(), "churn audit violations: {}", report.summary());
        assert_eq!(out.jobs[0].preemptions, 1);
        assert_eq!(out.jobs[0].restarts, 1);
        assert_eq!(out.jobs[0].iters, 3, "restart must not lose or double-count iterations");
    }

    #[test]
    fn preempt_inside_request_window_aborts_cleanly() {
        let (sys, topo) = small_sys();
        let job = TraceJob {
            name: "solo".to_string(),
            arrival: 0.0,
            gang: 4,
            iters: 1,
            workload: Workload { layers: 1, hidden: 64, batch_per_node: 8 },
            elastic: None,
        };
        // with layers == 1 the worker posts its only AR after fwd + bwd;
        // compute that instant from the same model the runtime uses and
        // fail node 0 halfway through the driver-request window
        let probe = JobRuntime::new(
            JobSpec::new("p", SystemKind::SmartNic { bfp: false }, job.workload, vec![0, 1, 2, 3]),
            &sys,
        );
        let t_post = probe.lt.t_f + probe.lt.t_b;
        let spec = TraceSpec {
            sys,
            topology: topo,
            faults: ClusterFaults::none(),
            policy: Policy::FirstFit,
            jobs: vec![job],
            failures: vec![Failure { at: t_post + 0.5 * sys.nic_request_overhead, node: 0 }],
            restart_delay: 0.01,
            repair_delay: 0.02,
        };
        let out = run_trace(&spec, EngineKind::Checked { threads: 0 });
        assert_eq!(out.aborted_collectives, 1, "the posted AR must abort in the request window");
        assert_eq!(out.jobs[0].preemptions, 1);
        let report = out.audit.expect("checked run carries a report");
        assert!(
            report.is_clean(),
            "aborted collective must not trip the ledger: {}",
            report.summary()
        );
    }

    #[test]
    fn forged_leave_without_release_is_flagged() {
        let spec = tiny_trace(Policy::FirstFit, 1);
        let (mut sim, mut state) = init(&spec, EngineKind::Typed);
        scenario::drive(&mut sim, &mut state, EngineKind::Typed);
        // forge: job 0 "left" but node 3 was never handed back
        state.sched.as_deref_mut().unwrap().alloc[3] = 0;
        let mut report = AuditReport::new();
        audit_sched(&state, &mut report);
        assert!(report.violations().iter().any(|v| v.kind() == "leaked-allocation"));
    }

    #[test]
    fn forged_restart_double_count_is_flagged() {
        let spec = tiny_trace(Policy::FirstFit, 1);
        let (mut sim, mut state) = init(&spec, EngineKind::Typed);
        scenario::drive(&mut sim, &mut state, EngineKind::Typed);
        let mut report = AuditReport::new();
        audit_sched(&state, &mut report);
        assert!(report.is_clean(), "clean run must audit clean: {}", report.summary());
        // forge: a restart replayed a finished iteration and counted it twice
        state.jobs[0].iters_done += 1;
        let mut report = AuditReport::new();
        audit_sched(&state, &mut report);
        assert!(report.violations().iter().any(|v| v.kind() == "job-conservation"));
    }

    #[test]
    fn contiguous_policies_never_fragment() {
        for policy in [Policy::FirstFit, Policy::BestFit] {
            let out = run_trace(&tiny_trace(policy, 1), EngineKind::Typed);
            assert!(
                out.jobs.iter().all(|j| !j.frag),
                "{policy:?} produced a fragmented placement"
            );
        }
    }

    #[test]
    fn scatter_jct_dominates_contiguous() {
        // same trace, adversarial vs contiguous placement: spine
        // crossings + oversubscribed uplinks must cost wall-clock JCT
        let ff = run_trace(&tiny_trace(Policy::FirstFit, 0), EngineKind::Typed);
        let sc = run_trace(&tiny_trace(Policy::Scatter, 0), EngineKind::Typed);
        let mean = |o: &TraceOutput| {
            o.jobs.iter().map(|j| j.jct).sum::<f64>() / o.jobs.len() as f64
        };
        assert!(
            mean(&sc) > mean(&ff),
            "scatter mean JCT {} must exceed first-fit {}",
            mean(&sc),
            mean(&ff)
        );
    }
}
