//! The unified cluster engine: everything on one calendar queue.
//!
//! This is the crate's execution layer for *dynamic* simulation.  Where
//! `nic::simulate_ring_allreduce` and `coordinator::simulate_iteration`
//! run one collective / one job at a time on private servers (the
//! serialized compatibility path kept for the E6 closed-form validation),
//! here every activity in the cluster is a typed [`Event`] on a single
//! [`netsim::engine::Sim`] clock sharing one [`netsim::fabric::Fabric`]:
//!
//! * the smart-NIC ring datapath (PCIe fetch → adder → Tx → switch →
//!   writeback), segment-pipelined exactly like the serialized path but
//!   scheduled as events — so a layer's all-reduce executes *while* later
//!   layers compute and while other all-reduces are in flight, all
//!   contending FIFO for links, PCIe, adders and switch egress ports;
//! * NIC-offloaded binomial and Rabenseifner collectives (round-based),
//!   selectable per layer;
//! * host/MPI software all-reduces, decomposed by
//!   [`collective::timing::scheme_rounds`] into rounds on the nodes'
//!   comm-core servers;
//! * the event-driven trainer ([`job`]): forward/backward/update compute
//!   posting non-blocking all-reduces in the paper's Fig. 3b order;
//! * multi-job scenarios ([`scenario`]): several training jobs on one
//!   switch fabric, with straggler / degraded-link injection that affects
//!   every in-flight collective.
//!
//! [`netsim::engine::Sim`]: crate::netsim::engine::Sim
//! [`netsim::fabric::Fabric`]: crate::netsim::fabric::Fabric
//! [`collective::timing::scheme_rounds`]: crate::collective::timing::scheme_rounds

// Only this file's `unsafe impl PartitionedWorld` (below) may contain
// `unsafe` in the cluster subtree; the executors and drivers forbid it.
#[forbid(unsafe_code)]
pub mod collective;
#[forbid(unsafe_code)]
pub mod job;
#[forbid(unsafe_code)]
pub mod planner;
#[forbid(unsafe_code)]
pub mod scenario;
#[forbid(unsafe_code)]
pub mod sched;

use crate::collective::Scheme;
use crate::netsim::engine::{PartitionedWorld, Sim, World, GLOBAL_PARTITION};
use crate::netsim::fabric::Fabric;
use crate::netsim::Time;
use crate::sysconfig::SystemParams;
use crate::trace::Trace;

pub use crate::netsim::engine::{EngineKind, PartitionStats};
pub use crate::netsim::topology::Topology;
pub use collective::TenancyOutcome;
pub use job::{JobSpec, WorkerTask};
pub use scenario::{
    run_scenario, run_scenario_capped, run_scenario_on, CappedRun, ClusterSpec, JobResult,
    ScenarioOutput, TenancyStats,
};
pub use sched::{
    run_trace, synth_trace, AllocEvent, AllocKind, ElasticOp, Failure, Policy, TraceGenConfig,
    TraceJob, TraceJobResult, TraceOutput, TraceSpec,
};

/// Physical node index into the fabric.
pub type NodeId = usize;
/// Index into [`ClusterState::jobs`].
pub type JobId = usize;
/// Index into [`ClusterState::collectives`].
pub type CollectiveId = usize;

/// Which algorithm a collective runs — NIC-offloaded (on the FPGA
/// datapath), switch-resident, planner-selected, or host software (on the
/// comm cores).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveAlgo {
    /// segment-pipelined in-network ring (the NIC's native algorithm)
    NicRing,
    /// NIC-offloaded binomial reduce + broadcast (round-based)
    NicBinomial,
    /// NIC-offloaded Rabenseifner halving/doubling (round-based)
    NicRabenseifner,
    /// placement-aware hierarchical plan: ring reduce-scatter inside each
    /// leaf, ring all-reduce of the shards across the spine, allgather
    /// inside the leaf ([`planner`] builds the phases)
    NicHierarchical,
    /// NetReduce-style in-switch reduction on the fabric's aggregation
    /// engines; falls back to the exact NIC ring when the switch cannot
    /// reduce (no engines, or a table too small for one segment)
    SwitchReduce,
    /// let [`planner`] pick the cheapest plan for this topology,
    /// placement and message size
    Auto,
    /// host/MPI software scheme on the comm cores
    Host(Scheme),
}

impl CollectiveAlgo {
    pub fn name(&self) -> String {
        match self {
            CollectiveAlgo::NicRing => "nic-ring".to_string(),
            CollectiveAlgo::NicBinomial => "nic-binomial".to_string(),
            CollectiveAlgo::NicRabenseifner => "nic-rabenseifner".to_string(),
            CollectiveAlgo::NicHierarchical => "nic-hierarchical".to_string(),
            CollectiveAlgo::SwitchReduce => "switch-reduce".to_string(),
            CollectiveAlgo::Auto => "auto".to_string(),
            CollectiveAlgo::Host(s) => format!("host-{}", s.name()),
        }
    }
}

/// Which collective *pattern* an operation implements.  The executors and
/// the planner are kind-aware: all-reduce is the paper's original
/// workload, the other four open the MoE (all-to-all) and inference
/// weight-distribution (broadcast) workload families.  Reduction-style
/// kinds fold elements on adders / switch engines; movement-style kinds
/// (broadcast, allgather, all-to-all) only replicate or permute — the
/// conservation audit prices the two families differently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveKind {
    /// every rank ends with the sum of all ranks' payloads
    AllReduce,
    /// rank 0's payload is replicated to every other rank
    Broadcast,
    /// every rank's 1/n shard is delivered to all peers
    Allgather,
    /// each element is reduced exactly once, into its owning rank's shard
    ReduceScatter,
    /// every ordered (src, dst) pair exchanges its private 1/n block
    AllToAll,
}

impl CollectiveKind {
    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::AllReduce => "all-reduce",
            CollectiveKind::Broadcast => "broadcast",
            CollectiveKind::Allgather => "allgather",
            CollectiveKind::ReduceScatter => "reduce-scatter",
            CollectiveKind::AllToAll => "all-to-all",
        }
    }

    /// All five kinds, in bench/report order.
    pub const ALL: [CollectiveKind; 5] = [
        CollectiveKind::AllReduce,
        CollectiveKind::Broadcast,
        CollectiveKind::Allgather,
        CollectiveKind::ReduceScatter,
        CollectiveKind::AllToAll,
    ];
}

/// The world state threaded through every event: shared resources, job
/// runtimes, collective bookkeeping, and the execution trace.
pub struct ClusterState {
    pub sys: SystemParams,
    pub fabric: Fabric,
    pub trace: Trace,
    pub jobs: Vec<job::JobRuntime>,
    pub collectives: Vec<collective::Collective>,
    /// the gang scheduler of a trace-driven run ([`sched::run_trace`]);
    /// `None` on the static scenario paths, whose placements are fixed
    /// up front
    pub sched: Option<Box<sched::SchedState>>,
}

/// The executive type of the unified engine.
pub type ClusterSim = Sim<ClusterState>;

/// The typed event vocabulary of the unified cluster engine.
///
/// One variant per scheduler client step — the trainer's worker wake-ups
/// ([`cluster::job`]), the three collective executors' pipeline stages
/// ([`cluster::collective`]: the NIC ring, the planned phase executor
/// with its in-switch segment pipeline, and the host/MPI rounds) — each
/// dispatched by [`ClusterState`]'s [`World::handle`] match loop.  All
/// fields are `u32` indices into [`ClusterState`] bookkeeping (plus the
/// one `f64` payload a round op carries), so an [`Event`] is a compact
/// `Copy` value: the engine's arena stores it inline, with no per-event
/// allocation and no closure captures.
///
/// Every node-local variant (the ring pipeline stages and the planned
/// round arrivals) carries the *global* node id it executes on, so the
/// parallel engine's stateless [`PartitionedWorld::route`] can assign it
/// to the owning leaf partition from the event value alone.
///
/// [`cluster::job`]: crate::cluster::job
/// [`cluster::collective`]: crate::cluster::collective
#[derive(Clone, Copy, Debug)]
pub enum Event {
    /// (re)enter a job's worker loop (job start, or a compute span
    /// ended).  `epoch` is the job's placement generation: a wake whose
    /// epoch no longer matches the runtime's was scheduled before a
    /// preempt/restart and is dropped, so a stale compute continuation
    /// cannot advance a restarted task list
    JobWake { job: u32, epoch: u32 },
    /// scheduler: a job enters the cluster from the arrival trace
    JobArrive { job: u32 },
    /// scheduler: a job completed its final iteration — release its gang
    /// and try the queue
    JobDepart { job: u32 },
    /// scheduler: an elastic job asks for `nodes` more ranks (applied at
    /// its next iteration boundary — the checkpoint)
    JobGrow { job: u32, nodes: u32 },
    /// scheduler: an elastic job gives up `nodes` ranks (applied at its
    /// next iteration boundary)
    JobShrink { job: u32, nodes: u32 },
    /// scheduler: evict a running job (in-flight collectives drain; the
    /// current iteration is lost back to the checkpoint)
    JobPreempt { job: u32 },
    /// scheduler: a preempted job's checkpoint is reloaded — re-enter the
    /// ready queue
    JobRestart { job: u32 },
    /// scheduler: a fabric node fails — preempt its occupant and start
    /// the repair timer
    NodeFail { node: u32 },
    /// scheduler: a failed node is serviceable again
    NodeRepair { node: u32 },
    /// the NIC driver hands `cid`'s descriptor to the datapath (the
    /// fixed request overhead elapsed)
    CollectiveStart { cid: u32 },
    /// mark `cid` complete at the event time (host latency-only tail)
    CollectiveComplete { cid: u32 },
    /// ring: `rank`'s copy of `seg` (on `node`) is ready for `step` —
    /// serialize it to the successor
    RingSend { cid: u32, step: u32, rank: u32, seg: u32, node: u32 },
    /// ring: `seg` of `step` arrived at `rank` (on `node`)
    RingRecv { cid: u32, step: u32, rank: u32, seg: u32, node: u32 },
    /// ring: both reduce inputs present at `rank` — occupy `node`'s FP32
    /// adder
    RingReduce { cid: u32, step: u32, rank: u32, seg: u32, node: u32 },
    /// ring: `rank`'s copy of `seg` is final for `step` (reduce or
    /// store-and-forward done)
    RingFinal { cid: u32, step: u32, rank: u32, seg: u32, node: u32 },
    /// ring: a cross-leaf segment for `rank` (on `node`) reached the
    /// spine — the destination leaf times the downlink half of the hop
    /// (this is the spine crossing the parallel engine ships between
    /// partitions)
    RingXArrive { cid: u32, step: u32, rank: u32, seg: u32, node: u32 },
    /// ring: one final-copy PCIe writeback finished on `node`
    RingWritebackDone { cid: u32, node: u32 },
    /// planned: one rank's whole-payload DMA fetch finished
    PlannedFetchDone { cid: u32 },
    /// planned: a round op's payload arrived at node `dst` (the reduce,
    /// if any, follows on `dst`'s adder)
    PlannedOpArrive { cid: u32, dst: u32, reduce_elems: f64 },
    /// planned: one round op fully done (including its reduce)
    PlannedOpDone { cid: u32 },
    /// planned: one rank's final PCIe writeback finished
    PlannedWbDone { cid: u32 },
    /// in-switch: a member's copy of `seg` is on its NIC — fold it into
    /// the local aggregation engine
    SwitchContribute { cid: u32, seg: u32, rank: u32 },
    /// in-switch: one contribution folded at `group`'s leaf engine
    SwitchFoldDone { cid: u32, seg: u32, group: u32 },
    /// in-switch: one leaf aggregate folded at the spine engine
    SwitchSpineDone { cid: u32, seg: u32 },
    /// in-switch: the reduced `seg` reached `group`'s leaf switch
    SwitchMulticast { cid: u32, seg: u32, group: u32 },
    /// in-switch: the reduced `seg` reached a member's NIC
    SwitchDelivered { cid: u32, seg: u32, rank: u32 },
    /// in-switch: one member fully served for `seg` (incl. writeback)
    SwitchRankDone { cid: u32, seg: u32 },
    /// switch-multicast: the root's copy of `seg` reached its leaf switch
    /// — replicate it on the egress engines (and up the spine if the
    /// group spans leaves)
    McastUp { cid: u32, seg: u32 },
    /// switch-multicast: `seg` crossed the spine — fan it out to every
    /// member leaf's downlink
    McastSpine { cid: u32, seg: u32 },
    /// switch-multicast: `seg` reached `group`'s leaf switch — replicate
    /// to that leaf's members
    McastLeaf { cid: u32, seg: u32, group: u32 },
    /// host: one rank's software round drained on its comm-core server
    HostRoundDone { cid: u32 },
}

/// Widen a compact event index back to the bookkeeping index type.
fn ix(i: u32) -> usize {
    i as usize
}

impl World for ClusterState {
    type Event = Event;

    fn handle(sim: &mut ClusterSim, st: &mut ClusterState, event: Event) {
        match event {
            Event::JobWake { job, epoch } => {
                // placement-generation guard: drop wakes scheduled before
                // a preempt/restart invalidated this job's task list
                if st.jobs[ix(job)].epoch == epoch {
                    job::run_worker(sim, st, ix(job));
                }
            }
            Event::JobArrive { job } => sched::on_job_arrive(sim, st, ix(job)),
            Event::JobDepart { job } => sched::on_job_depart(sim, st, ix(job)),
            Event::JobGrow { job, nodes } => sched::on_job_resize(sim, st, ix(job), true, ix(nodes)),
            Event::JobShrink { job, nodes } => {
                sched::on_job_resize(sim, st, ix(job), false, ix(nodes));
            }
            Event::JobPreempt { job } => sched::on_job_preempt(sim, st, ix(job)),
            Event::JobRestart { job } => sched::on_job_restart(sim, st, ix(job)),
            Event::NodeFail { node } => sched::on_node_fail(sim, st, ix(node)),
            Event::NodeRepair { node } => sched::on_node_repair(sim, st, ix(node)),
            Event::CollectiveStart { cid } => collective::on_start(sim, st, ix(cid)),
            Event::CollectiveComplete { cid } => collective::on_complete(sim, st, ix(cid)),
            Event::RingSend { cid, step, rank, seg, .. } => {
                collective::ring_send(sim, st, ix(cid), ix(step), ix(rank), ix(seg));
            }
            Event::RingRecv { cid, step, rank, seg, .. } => {
                collective::ring_recv(sim, st, ix(cid), ix(step), ix(rank), ix(seg));
            }
            Event::RingReduce { cid, step, rank, seg, .. } => {
                collective::ring_reduce(sim, st, ix(cid), ix(step), ix(rank), ix(seg));
            }
            Event::RingFinal { cid, step, rank, seg, .. } => {
                collective::ring_segment_final(sim, st, ix(cid), ix(step), ix(rank), ix(seg));
            }
            Event::RingXArrive { cid, step, rank, seg, node } => {
                collective::ring_xarrive(sim, st, ix(cid), ix(step), ix(rank), ix(seg), ix(node));
            }
            Event::RingWritebackDone { cid, .. } => {
                collective::ring_writeback_done(sim, st, ix(cid));
            }
            Event::PlannedFetchDone { cid } => collective::planned_fetch_done(sim, st, ix(cid)),
            Event::PlannedOpArrive { cid, dst, reduce_elems } => {
                collective::planned_op_arrive(sim, st, ix(cid), ix(dst), reduce_elems);
            }
            Event::PlannedOpDone { cid } => collective::planned_op_done(sim, st, ix(cid)),
            Event::PlannedWbDone { cid } => collective::planned_wb_done(sim, st, ix(cid)),
            Event::SwitchContribute { cid, seg, rank } => {
                collective::switch_contribute(sim, st, ix(cid), ix(seg), ix(rank));
            }
            Event::SwitchFoldDone { cid, seg, group } => {
                collective::switch_fold_done(sim, st, ix(cid), ix(seg), ix(group));
            }
            Event::SwitchSpineDone { cid, seg } => {
                collective::switch_spine_done(sim, st, ix(cid), ix(seg));
            }
            Event::SwitchMulticast { cid, seg, group } => {
                collective::switch_multicast(sim, st, ix(cid), ix(seg), ix(group));
            }
            Event::SwitchDelivered { cid, seg, rank } => {
                collective::switch_delivered(sim, st, ix(cid), ix(seg), ix(rank));
            }
            Event::SwitchRankDone { cid, seg } => {
                collective::switch_rank_done(sim, st, ix(cid), ix(seg));
            }
            Event::McastUp { cid, seg } => collective::mcast_up(sim, st, ix(cid), ix(seg)),
            Event::McastSpine { cid, seg } => collective::mcast_spine(sim, st, ix(cid), ix(seg)),
            Event::McastLeaf { cid, seg, group } => {
                collective::mcast_leaf(sim, st, ix(cid), ix(seg), ix(group));
            }
            Event::HostRoundDone { cid } => collective::host_round_done(sim, st, ix(cid)),
        }
    }
}

/// The cluster's partition routing table: one partition per leaf switch
/// (the whole cluster is one partition on a flat crossbar), captured from
/// the topology when a parallel run starts.
#[derive(Clone, Copy, Debug)]
pub struct PartitionMap {
    nodes_per_leaf: u32,
    leaves: u32,
}

// SAFETY: the `PartitionedWorld` routing contract holds —
//
// * `route` confines every node-local pipeline stage to the leaf
//   partition owning its `node`/`dst`; those handlers touch only that
//   node's servers (Tx, PCIe, adder, comm core), the leaf's
//   uplink/downlink bundles (`Fabric::hop_split` books uplink resources
//   of the *source* leaf only; `hop_deliver` the *destination* leaf's
//   only), and per-collective counters that are either per-rank slots or
//   atomics (`RingState::{pending_writebacks,last_writeback}`).
// * Every cross-leaf path re-enters another partition through
//   `RingXArrive` at >= one switch-hop latency, and every
//   coordinator-fanned chain re-enters at >= one PCIe latency — i.e. >=
//   `lookahead()`.
// * All remaining variants route to the coordinator; their zero-delay
//   emissions (`RingWritebackDone` completion, zero-reduce
//   `PlannedOpDone`) are the documented coordinator carve-out.
// * The scheduler's churn vocabulary (`JobArrive`/`JobDepart`/
//   `JobGrow`/`JobShrink`/`JobPreempt`/`JobRestart`/`NodeFail`/
//   `NodeRepair`) is coordinator-only on both ends: the events route to
//   `GLOBAL_PARTITION`, they are emitted exclusively by other
//   coordinator events (the trace seed and the scheduler's own
//   handlers), and every per-node table they mutate (`SchedState`) is
//   read by coordinator events alone.  Partition handlers never observe
//   scheduler state — a preempted job's in-flight collectives *drain to
//   completion* rather than being cancelled, precisely so no partition
//   handler's behavior can depend on when a same-time preempt executed.
unsafe impl PartitionedWorld for ClusterState {
    type Map = PartitionMap;

    fn partition_map(&self) -> PartitionMap {
        match self.fabric.topology {
            Topology::Flat { nodes } => PartitionMap {
                nodes_per_leaf: (nodes as u32).max(1),
                leaves: 1,
            },
            Topology::LeafSpine { leaves, nodes_per_leaf, .. } => PartitionMap {
                nodes_per_leaf: nodes_per_leaf as u32,
                leaves: leaves as u32,
            },
        }
    }

    fn partition_count(map: &PartitionMap) -> usize {
        map.leaves as usize
    }

    /// Node-local pipeline stages belong to the leaf owning their node;
    /// everything else (job control, collective barriers, host rounds,
    /// the in-switch executor's spine-coupled stages — the multicast
    /// replication pipeline included) runs globally on the coordinator.
    fn route(map: &PartitionMap, event: &Event) -> u32 {
        match event {
            Event::RingSend { node, .. }
            | Event::RingRecv { node, .. }
            | Event::RingReduce { node, .. }
            | Event::RingFinal { node, .. }
            | Event::RingXArrive { node, .. }
            | Event::RingWritebackDone { node, .. } => node / map.nodes_per_leaf,
            Event::PlannedOpArrive { dst, .. } => dst / map.nodes_per_leaf,
            _ => GLOBAL_PARTITION,
        }
    }

    /// Conservative lookahead: every path from one partition (or the
    /// coordinator) into another pays at least one switch hop latency
    /// (spine crossings, planned-round deliveries) or one PCIe latency
    /// (the ring's step-0 DMA fetches issued at collective start), so the
    /// minimum of the two bounds how far a partition may safely run ahead.
    /// Partition-to-*coordinator* emissions are exempt (the carve-out in
    /// the [`PartitionedWorld`] contract): `ring_writeback_done` and
    /// zero-reduce `planned_op_arrive` post completion events at the
    /// current time, which is legal because the coordinator executes them
    /// at the window barrier and their downstream effects re-enter
    /// partitions only through chains at least one lookahead long.
    fn lookahead(&self) -> Time {
        self.sys.net.hop_latency.min(self.sys.nic.pcie_latency)
    }

    /// Thread-independent barrier tie-break: the variant tag plus every
    /// identifying index, packed so that any two same-time deferred
    /// events which are *not* interchangeable (identical handler effect)
    /// compare differently no matter which worker emitted them.  The one
    /// emission whose carrier genuinely races is the ring's
    /// `CollectiveComplete`, posted by whichever rank retires the last
    /// writeback — its key depends only on `cid`.
    fn merge_key(_map: &PartitionMap, event: &Event) -> u128 {
        // tag(8) | cid(24) | f1(32) | f2(32) | f3(32): cid is an index
        // into `ClusterState::collectives` (nowhere near 2^24), and every
        // per-event index (rank, node, step, seg, group) fits u32.
        const fn pack(tag: u8, cid: u32, f1: u32, f2: u32, f3: u32) -> u128 {
            ((tag as u128) << 120)
                | (((cid & 0x00ff_ffff) as u128) << 96)
                | ((f1 as u128) << 64)
                | ((f2 as u128) << 32)
                | (f3 as u128)
        }
        match *event {
            Event::JobWake { job, epoch } => pack(0, 0, job, epoch, 0),
            Event::CollectiveStart { cid } => pack(1, cid, 0, 0, 0),
            Event::CollectiveComplete { cid } => pack(2, cid, 0, 0, 0),
            Event::RingSend { cid, step, rank, seg, .. } => pack(3, cid, step, rank, seg),
            Event::RingRecv { cid, step, rank, seg, .. } => pack(4, cid, step, rank, seg),
            Event::RingReduce { cid, step, rank, seg, .. } => pack(5, cid, step, rank, seg),
            Event::RingFinal { cid, step, rank, seg, .. } => pack(6, cid, step, rank, seg),
            Event::RingXArrive { cid, step, rank, seg, .. } => pack(7, cid, step, rank, seg),
            Event::RingWritebackDone { cid, node } => pack(8, cid, node, 0, 0),
            Event::PlannedFetchDone { cid } => pack(9, cid, 0, 0, 0),
            Event::PlannedOpArrive { cid, dst, reduce_elems } => {
                let bits = reduce_elems.to_bits();
                pack(10, cid, dst, (bits >> 32) as u32, bits as u32)
            }
            Event::PlannedOpDone { cid } => pack(11, cid, 0, 0, 0),
            Event::PlannedWbDone { cid } => pack(12, cid, 0, 0, 0),
            Event::SwitchContribute { cid, seg, rank } => pack(13, cid, seg, rank, 0),
            Event::SwitchFoldDone { cid, seg, group } => pack(14, cid, seg, group, 0),
            Event::SwitchSpineDone { cid, seg } => pack(15, cid, seg, 0, 0),
            Event::SwitchMulticast { cid, seg, group } => pack(16, cid, seg, group, 0),
            Event::SwitchDelivered { cid, seg, rank } => pack(17, cid, seg, rank, 0),
            Event::SwitchRankDone { cid, seg } => pack(18, cid, seg, 0, 0),
            Event::HostRoundDone { cid } => pack(19, cid, 0, 0, 0),
            Event::JobArrive { job } => pack(20, 0, job, 0, 0),
            Event::JobDepart { job } => pack(21, 0, job, 0, 0),
            Event::JobGrow { job, nodes } => pack(22, 0, job, nodes, 0),
            Event::JobShrink { job, nodes } => pack(23, 0, job, nodes, 0),
            Event::JobPreempt { job } => pack(24, 0, job, 0, 0),
            Event::JobRestart { job } => pack(25, 0, job, 0, 0),
            Event::NodeFail { node } => pack(26, 0, node, 0, 0),
            Event::NodeRepair { node } => pack(27, 0, node, 0, 0),
            Event::McastUp { cid, seg } => pack(28, cid, seg, 0, 0),
            Event::McastSpine { cid, seg } => pack(29, cid, seg, 0, 0),
            Event::McastLeaf { cid, seg, group } => pack(30, cid, seg, group, 0),
        }
    }
}

impl ClusterState {
    /// One job's collective records, in the order they were posted (ARs
    /// may *complete* out of post order — sort by `t_done` if completion
    /// order matters).
    pub fn job_collectives(&self, job: JobId) -> Vec<&collective::Collective> {
        self.collectives.iter().filter(|c| c.job == job).collect()
    }

    /// Mean duration (post → done) of a job's completed collectives.
    pub fn mean_ar_duration(&self, job: JobId) -> f64 {
        let durs: Vec<f64> = self
            .collectives
            .iter()
            .filter(|c| c.job == job)
            .filter_map(|c| c.t_done.map(|d| d - c.t_post))
            .collect();
        if durs.is_empty() {
            0.0
        } else {
            durs.iter().sum::<f64>() / durs.len() as f64
        }
    }

    /// Maximum number of this job's collectives simultaneously in flight.
    pub fn max_inflight(&self, job: JobId) -> usize {
        crate::trace::max_overlap(
            self.collectives
                .iter()
                .filter(|c| c.job == job)
                .filter_map(|c| c.t_done.map(|done| (c.t_post, done))),
        )
    }
}
