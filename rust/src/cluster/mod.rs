//! The unified cluster engine: everything on one calendar queue.
//!
//! This is the crate's execution layer for *dynamic* simulation.  Where
//! `nic::simulate_ring_allreduce` and `coordinator::simulate_iteration`
//! run one collective / one job at a time on private servers (the
//! serialized compatibility path kept for the E6 closed-form validation),
//! here every activity in the cluster is an event on a single
//! [`netsim::engine::Sim`] clock sharing one [`netsim::fabric::Fabric`]:
//!
//! * the smart-NIC ring datapath (PCIe fetch → adder → Tx → switch →
//!   writeback), segment-pipelined exactly like the serialized path but
//!   scheduled as events — so a layer's all-reduce executes *while* later
//!   layers compute and while other all-reduces are in flight, all
//!   contending FIFO for links, PCIe, adders and switch egress ports;
//! * NIC-offloaded binomial and Rabenseifner collectives (round-based),
//!   selectable per layer;
//! * host/MPI software all-reduces, decomposed by
//!   [`collective::timing::scheme_rounds`] into rounds on the nodes'
//!   comm-core servers;
//! * the event-driven trainer ([`job`]): forward/backward/update compute
//!   posting non-blocking all-reduces in the paper's Fig. 3b order;
//! * multi-job scenarios ([`scenario`]): several training jobs on one
//!   switch fabric, with straggler / degraded-link injection that affects
//!   every in-flight collective.
//!
//! [`netsim::engine::Sim`]: crate::netsim::engine::Sim
//! [`netsim::fabric::Fabric`]: crate::netsim::fabric::Fabric
//! [`collective::timing::scheme_rounds`]: crate::collective::timing::scheme_rounds

pub mod collective;
pub mod job;
pub mod planner;
pub mod scenario;

use crate::collective::Scheme;
use crate::netsim::engine::Sim;
use crate::netsim::fabric::Fabric;
use crate::sysconfig::SystemParams;
use crate::trace::Trace;

pub use crate::netsim::topology::Topology;
pub use job::{JobSpec, WorkerTask};
pub use scenario::{run_scenario, ClusterSpec, JobResult, ScenarioOutput};

/// Physical node index into the fabric.
pub type NodeId = usize;
/// Index into [`ClusterState::jobs`].
pub type JobId = usize;
/// Index into [`ClusterState::collectives`].
pub type CollectiveId = usize;

/// Which algorithm a collective runs — NIC-offloaded (on the FPGA
/// datapath), switch-resident, planner-selected, or host software (on the
/// comm cores).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveAlgo {
    /// segment-pipelined in-network ring (the NIC's native algorithm)
    NicRing,
    /// NIC-offloaded binomial reduce + broadcast (round-based)
    NicBinomial,
    /// NIC-offloaded Rabenseifner halving/doubling (round-based)
    NicRabenseifner,
    /// placement-aware hierarchical plan: ring reduce-scatter inside each
    /// leaf, ring all-reduce of the shards across the spine, allgather
    /// inside the leaf ([`planner`] builds the phases)
    NicHierarchical,
    /// NetReduce-style in-switch reduction on the fabric's aggregation
    /// engines; falls back to the exact NIC ring when the switch cannot
    /// reduce (no engines, or a table too small for one segment)
    SwitchReduce,
    /// let [`planner`] pick the cheapest plan for this topology,
    /// placement and message size
    Auto,
    /// host/MPI software scheme on the comm cores
    Host(Scheme),
}

impl CollectiveAlgo {
    pub fn name(&self) -> String {
        match self {
            CollectiveAlgo::NicRing => "nic-ring".to_string(),
            CollectiveAlgo::NicBinomial => "nic-binomial".to_string(),
            CollectiveAlgo::NicRabenseifner => "nic-rabenseifner".to_string(),
            CollectiveAlgo::NicHierarchical => "nic-hierarchical".to_string(),
            CollectiveAlgo::SwitchReduce => "switch-reduce".to_string(),
            CollectiveAlgo::Auto => "auto".to_string(),
            CollectiveAlgo::Host(s) => format!("host-{}", s.name()),
        }
    }
}

/// The world state threaded through every event: shared resources, job
/// runtimes, collective bookkeeping, and the execution trace.
pub struct ClusterState {
    pub sys: SystemParams,
    pub fabric: Fabric,
    pub trace: Trace,
    pub jobs: Vec<job::JobRuntime>,
    pub collectives: Vec<collective::Collective>,
}

/// The event type of the unified engine.
pub type ClusterSim = Sim<ClusterState>;

impl ClusterState {
    /// One job's collective records, in the order they were posted (ARs
    /// may *complete* out of post order — sort by `t_done` if completion
    /// order matters).
    pub fn job_collectives(&self, job: JobId) -> Vec<&collective::Collective> {
        self.collectives.iter().filter(|c| c.job == job).collect()
    }

    /// Mean duration (post → done) of a job's completed collectives.
    pub fn mean_ar_duration(&self, job: JobId) -> f64 {
        let durs: Vec<f64> = self
            .collectives
            .iter()
            .filter(|c| c.job == job)
            .filter_map(|c| c.t_done.map(|d| d - c.t_post))
            .collect();
        if durs.is_empty() {
            0.0
        } else {
            durs.iter().sum::<f64>() / durs.len() as f64
        }
    }

    /// Maximum number of this job's collectives simultaneously in flight.
    pub fn max_inflight(&self, job: JobId) -> usize {
        crate::trace::max_overlap(
            self.collectives
                .iter()
                .filter(|c| c.job == job)
                .filter_map(|c| c.t_done.map(|done| (c.t_post, done))),
        )
    }
}
