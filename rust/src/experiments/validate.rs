//! E6 — model validation: the Sec. IV-C closed form vs the DES over a
//! configuration grid (the paper's "within 3% of real measurements").

use crate::analytic::model::{iteration, SystemKind};
use crate::analytic::validate::{sweep, ArValidation};
use crate::coordinator::simulate_iteration;
use crate::sysconfig::{SystemParams, Workload};
use crate::util::json::Json;
use crate::util::stats::{rel_err, summarize};
use crate::util::table::{fnum, Table};

#[derive(Clone, Debug)]
pub struct IterValidation {
    pub system: String,
    pub nodes: usize,
    pub batch: usize,
    pub t_model: f64,
    pub t_sim: f64,
    pub rel_err: f64,
}

/// Full-iteration validation across systems, node counts and batches.
pub fn run_iteration_grid() -> Vec<IterValidation> {
    let mut out = Vec::new();
    for bfp in [false, true] {
        for &n in &[2usize, 3, 4, 5, 6, 8, 12, 16, 24, 32] {
            for &b in &[448usize, 1792] {
                let kind = SystemKind::SmartNic { bfp };
                let sys = SystemParams::smartnic_40g();
                let w = Workload::paper_mlp(b);
                let t_model = iteration(kind, &sys, &w, n).t_total;
                let t_sim = simulate_iteration(kind, &sys, &w, n).breakdown.t_total;
                out.push(IterValidation {
                    system: kind.name(),
                    nodes: n,
                    batch: b,
                    t_model,
                    t_sim,
                    rel_err: rel_err(t_model, t_sim),
                });
            }
        }
    }
    out
}

pub fn print_iteration(rows: &[IterValidation]) {
    let mut t = Table::new(&["system", "nodes", "batch", "model (ms)", "sim (ms)", "err"])
        .with_title("E6 — analytical model vs DES, full training iteration");
    for r in rows {
        t.row(&[
            r.system.clone(),
            r.nodes.to_string(),
            r.batch.to_string(),
            fnum(r.t_model * 1e3, 2),
            fnum(r.t_sim * 1e3, 2),
            format!("{:.2}%", r.rel_err * 100.0),
        ]);
    }
    t.print();
    let errs: Vec<f64> = rows.iter().map(|r| r.rel_err).collect();
    let s = summarize(&errs);
    println!(
        "error: mean {:.2}%, median {:.2}%, max {:.2}%  (paper: within 3%)\n",
        s.mean * 100.0,
        s.median * 100.0,
        s.max * 100.0
    );
}

/// All-reduce-level validation sweep.
pub fn run_ar_grid() -> Vec<ArValidation> {
    let sys = SystemParams::smartnic_40g();
    sweep(
        &sys,
        &[2, 3, 4, 6, 8, 16, 32],
        &[1 << 18, 2048 * 2048, 1 << 24],
    )
}

pub fn print_ar(rows: &[ArValidation]) {
    let mut t = Table::new(&["nodes", "elems", "bfp", "analytic (ms)", "sim (ms)", "err"])
        .with_title("E6 — Sec. IV-C T_AR vs chunk-level NIC DES");
    for r in rows {
        t.row(&[
            r.nodes.to_string(),
            r.elems.to_string(),
            r.bfp.to_string(),
            fnum(r.t_analytic * 1e3, 3),
            fnum(r.t_sim * 1e3, 3),
            format!("{:.2}%", r.rel_err * 100.0),
        ]);
    }
    t.print();
    println!();
}

pub fn to_json(rows: &[IterValidation]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("system", Json::Str(r.system.clone())),
                    ("nodes", Json::Num(r.nodes as f64)),
                    ("batch", Json::Num(r.batch as f64)),
                    ("t_model", Json::Num(r.t_model)),
                    ("t_sim", Json::Num(r.t_sim)),
                    ("rel_err", Json::Num(r.rel_err)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_grid_within_3pct() {
        let rows = run_iteration_grid();
        assert!(rows.len() >= 40);
        for r in &rows {
            assert!(
                r.rel_err < 0.03,
                "{} n={} B={}: {:.2}%",
                r.system,
                r.nodes,
                r.batch,
                r.rel_err * 100.0
            );
        }
    }

    #[test]
    fn ar_grid_mostly_within_5pct() {
        // small tensors are latency-dominated; the paper-scale and larger
        // ones must be tight
        let rows = run_ar_grid();
        for r in rows.iter().filter(|r| r.elems >= 2048 * 2048) {
            assert!(
                r.rel_err < 0.05,
                "n={} elems={} bfp={}: {:.1}%",
                r.nodes,
                r.elems,
                r.bfp,
                r.rel_err * 100.0
            );
        }
    }
}
