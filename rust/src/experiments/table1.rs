//! E3 — Table I: FPGA resource breakdown of the AI smart NIC, plus the
//! Sec. V-A 100/400 Gbps scaling claims.

use crate::nic::resources::{lanes_at, Breakdown, Resources};
use crate::util::json::Json;
use crate::util::table::Table;

fn fmt(r: &Resources) -> [String; 3] {
    [
        format!("{} ({:.1}%)", group_digits(r.alms), r.pct_alms()),
        format!("{} ({:.1}%)", group_digits(r.m20ks), r.pct_m20ks()),
        format!("{} ({:.1}%)", r.dsps, r.pct_dsps()),
    ]
}

fn group_digits(v: u32) -> String {
    let s = v.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

pub fn print_at(eth_gbps: f64) {
    let b = Breakdown::at(eth_gbps);
    let mut t = Table::new(&["component", "ALMs", "M20Ks", "DSPs"]).with_title(&format!(
        "Table I — FPGA resource breakdown @ {eth_gbps:.0} Gbps ({} SIMD lanes), Arria 10 GX 1150",
        lanes_at(eth_gbps)
    ));
    for (name, r) in [
        ("OPAE + IKL Shim", &b.shim),
        ("All-Reduce", &b.allreduce),
        ("BFP Compression", &b.bfp),
        ("Total", &b.total()),
    ] {
        let f = fmt(r);
        t.row(&[name.to_string(), f[0].clone(), f[1].clone(), f[2].clone()]);
    }
    t.print();
    let ai = b.ai_only();
    println!(
        "AI-specific additions only: {:.1}% logic, {:.1}% RAM, {:.1}% DSP{}\n",
        ai.pct_alms(),
        ai.pct_m20ks(),
        ai.pct_dsps(),
        if eth_gbps >= 400.0 {
            "  (paper claim: <2%, <9%, <5%)"
        } else if (eth_gbps - 40.0).abs() < 1.0 {
            "  (paper: 1.2%, 6.1%, 0.5%)"
        } else {
            ""
        }
    );
}

pub fn run_all() {
    for g in [40.0, 100.0, 400.0] {
        print_at(g);
    }
}

pub fn to_json() -> Json {
    Json::Arr(
        [40.0, 100.0, 400.0]
            .iter()
            .map(|&g| {
                let b = Breakdown::at(g);
                let row = |r: &Resources| {
                    Json::obj(vec![
                        ("alms", Json::Num(r.alms as f64)),
                        ("m20ks", Json::Num(r.m20ks as f64)),
                        ("dsps", Json::Num(r.dsps as f64)),
                    ])
                };
                Json::obj(vec![
                    ("eth_gbps", Json::Num(g)),
                    ("lanes", Json::Num(lanes_at(g) as f64)),
                    ("shim", row(&b.shim)),
                    ("allreduce", row(&b.allreduce)),
                    ("bfp", row(&b.bfp)),
                    ("total", row(&b.total())),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_has_three_speeds() {
        let j = to_json();
        assert_eq!(j.as_arr().unwrap().len(), 3);
        assert_eq!(
            j.idx(0).unwrap().get("total").unwrap().get("alms").unwrap().as_i64(),
            Some(69_570)
        );
    }

    #[test]
    fn digit_grouping() {
        assert_eq!(group_digits(64_480), "64,480");
        assert_eq!(group_digits(534), "534");
        assert_eq!(group_digits(1_000_000), "1,000,000");
    }
}
