//! E7 — hierarchical scaling sweep (Sec. V: "1.6x at 6 nodes measured,
//! 2.5x at 32 nodes predicted"), beyond the paper's prototype.
//!
//! Two parts:
//!
//! * **Flat sweep** — for every node count the full training iteration
//!   runs on the unified event engine (flat crossbar) *and* through the
//!   Sec. IV-C closed form, for the overlapped host baseline, the smart
//!   NIC, and the smart NIC with BFP.  The two paths must agree — the
//!   cross-validation that extends the paper's "within 3%" claim from the
//!   6-node prototype to 512 nodes.  (The BFP point is the exception by
//!   design: its all-reduce is PCIe-bound, and overlapped collectives
//!   genuinely pipeline the two PCIe directions better than the closed
//!   form's serial-AR assumption — the unified engine may only be
//!   *faster* there, and the sweep records by how much.)
//! * **Oversubscription penalty** — the same collectives routed over a
//!   leaf–spine fabric with strided placement, where every ring-neighbor
//!   edge crosses the oversubscribed spine: the per-scheme slowdown
//!   relative to the flat crossbar quantifies what the paper's
//!   contention-freedom claim is worth once the fabric is tapered.
//!
//! `smartnic scale` prints both tables and writes the machine-readable
//! `BENCH_scaling.json` so the repo tracks a perf trajectory over time.

use crate::analytic::model::{iteration, SystemKind};
use crate::cluster::{run_scenario, ClusterSpec, CollectiveAlgo, JobSpec, Topology};
use crate::collective::Scheme;
use crate::coordinator::simulate_iteration_unified;
use crate::sysconfig::{SystemParams, Workload};
use crate::util::json::Json;
use crate::util::stats::rel_err;
use crate::util::table::{fnum, Table};

/// Systems compared at every point, in column order.
pub const SYSTEMS: [&str; 3] = ["baseline", "smartnic", "smartnic+bfp"];

/// Tolerance of the unified-engine vs closed-form cross-validation for
/// the baseline and raw smart-NIC columns (the paper's 3% plus margin for
/// pipeline fill/drain effects at depth).
pub const VALIDATE_TOL: f64 = 0.05;

/// Sweep parameters.
#[derive(Clone, Debug)]
pub struct ScalingConfig {
    /// node counts for the flat sweep
    pub nodes: Vec<usize>,
    /// mini-batch per node (448 = the paper's communication-bound point)
    pub batch: usize,
    /// leaf switches for the leaf–spine runs
    pub leaves: usize,
    /// leaf uplink oversubscription factor for the leaf–spine runs
    pub oversubscription: f64,
}

impl Default for ScalingConfig {
    fn default() -> Self {
        Self {
            nodes: vec![6, 12, 32, 64, 128, 512],
            batch: 448,
            leaves: 4,
            oversubscription: 4.0,
        }
    }
}

fn variants() -> [(SystemKind, SystemParams); 3] {
    [
        (
            SystemKind::BaselineOverlapped {
                scheme: Scheme::Ring,
                comm_cores: 2,
            },
            SystemParams::baseline_100g(),
        ),
        (
            SystemKind::SmartNic { bfp: false },
            SystemParams::smartnic_40g(),
        ),
        (
            SystemKind::SmartNic { bfp: true },
            SystemParams::smartnic_40g(),
        ),
    ]
}

/// One node count of the flat sweep: iteration times per system from both
/// engines, with their relative deviation.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub nodes: usize,
    /// closed-form iteration time (s) per system ([`SYSTEMS`] order)
    pub model: [f64; 3],
    /// unified-engine iteration time (s) per system
    pub unified: [f64; 3],
    /// rel_err(model, unified) per system
    pub err: [f64; 3],
}

impl SweepPoint {
    /// Closed-form speedup of system `i` over the baseline column.
    pub fn model_speedup(&self, i: usize) -> f64 {
        self.model[0] / self.model[i]
    }

    /// Unified-engine speedup of system `i` over the baseline column.
    pub fn unified_speedup(&self, i: usize) -> f64 {
        self.unified[0] / self.unified[i]
    }
}

/// One (node count, scheme) cell of the oversubscription study.
#[derive(Clone, Debug)]
pub struct OversubPoint {
    pub nodes: usize,
    pub scheme: &'static str,
    /// mean all-reduce latency on the flat crossbar (s)
    pub flat: f64,
    /// same collective on the leaf–spine fabric, strided placement (s)
    pub spanning: f64,
}

impl OversubPoint {
    /// Slowdown of the spine-crossing run relative to the flat crossbar.
    pub fn penalty(&self) -> f64 {
        self.spanning / self.flat
    }
}

/// Worst unified-vs-model deviation across the validated columns
/// (baseline + raw smart NIC; BFP is exempt by design — see module docs).
/// The single source for both the printed PASS/FAIL and the CLI exit code.
pub fn worst_err(points: &[SweepPoint]) -> f64 {
    points
        .iter()
        .flat_map(|p| [p.err[0], p.err[1]])
        .fold(0.0, f64::max)
}

/// Run the flat sweep: unified engine vs closed form at every node count.
pub fn run_sweep(cfg: &ScalingConfig) -> Vec<SweepPoint> {
    let w = Workload::paper_mlp(cfg.batch);
    cfg.nodes
        .iter()
        .map(|&n| {
            let mut model = [0.0; 3];
            let mut unified = [0.0; 3];
            let mut err = [0.0; 3];
            for (i, (kind, sys)) in variants().into_iter().enumerate() {
                model[i] = iteration(kind, &sys, &w, n).t_total;
                unified[i] = simulate_iteration_unified(kind, &sys, &w, n)
                    .breakdown
                    .t_total;
                err[i] = rel_err(model[i], unified[i]);
            }
            SweepPoint {
                nodes: n,
                model,
                unified,
                err,
            }
        })
        .collect()
}

const SCHEMES: [(&str, CollectiveAlgo); 3] = [
    ("nic-ring", CollectiveAlgo::NicRing),
    ("nic-binomial", CollectiveAlgo::NicBinomial),
    ("nic-rabenseifner", CollectiveAlgo::NicRabenseifner),
];

/// Mean all-reduce latency of a single paper-sized collective under
/// `algo` on the given topology and placement.
fn one_collective_ar(topology: Topology, ranks: Vec<usize>, algo: CollectiveAlgo) -> f64 {
    let sys = SystemParams::smartnic_40g();
    let w = Workload {
        layers: 1,
        hidden: 2048,
        batch_per_node: 64,
    };
    let spec = ClusterSpec::new(sys, topology.nodes())
        .with_topology(topology)
        .with_job(
            JobSpec::new("ar", SystemKind::SmartNic { bfp: false }, w, ranks)
                .with_layer_algos(vec![algo]),
        );
    run_scenario(&spec).jobs[0].mean_ar
}

/// Node counts of `cfg` that fit the leaf–spine shape (divisible across
/// the leaves, at least two nodes per leaf).
pub fn oversub_nodes(cfg: &ScalingConfig) -> Vec<usize> {
    cfg.nodes
        .iter()
        .copied()
        .filter(|&n| cfg.leaves >= 2 && n % cfg.leaves == 0 && n / cfg.leaves >= 2)
        .collect()
}

/// Run the oversubscription study: per scheme, flat vs spine-crossing.
pub fn run_oversub(cfg: &ScalingConfig) -> Vec<OversubPoint> {
    let mut out = Vec::new();
    for n in oversub_nodes(cfg) {
        let topo = Topology::leaf_spine(cfg.leaves, n / cfg.leaves, cfg.oversubscription);
        for (name, algo) in SCHEMES {
            let flat = one_collective_ar(Topology::flat(n), (0..n).collect(), algo);
            let spanning = one_collective_ar(topo, topo.strided_ranks(n), algo);
            out.push(OversubPoint {
                nodes: n,
                scheme: name,
                flat,
                spanning,
            });
        }
    }
    out
}

pub fn print_sweep(points: &[SweepPoint], cfg: &ScalingConfig) {
    let mut t = Table::new(&[
        "nodes",
        "base m/u (ms)",
        "nic m/u (ms)",
        "bfp m/u (ms)",
        "speedup nic m/u",
        "speedup bfp m/u",
        "err b/n/bfp",
    ])
    .with_title(&format!(
        "scaling sweep — closed form (m) vs unified engine (u), B={}/node, flat crossbar",
        cfg.batch
    ));
    for p in points {
        t.row(&[
            p.nodes.to_string(),
            format!("{} / {}", fnum(p.model[0] * 1e3, 1), fnum(p.unified[0] * 1e3, 1)),
            format!("{} / {}", fnum(p.model[1] * 1e3, 1), fnum(p.unified[1] * 1e3, 1)),
            format!("{} / {}", fnum(p.model[2] * 1e3, 1), fnum(p.unified[2] * 1e3, 1)),
            format!(
                "{} / {}",
                fnum(p.model_speedup(1), 2),
                fnum(p.unified_speedup(1), 2)
            ),
            format!(
                "{} / {}",
                fnum(p.model_speedup(2), 2),
                fnum(p.unified_speedup(2), 2)
            ),
            format!(
                "{:.1}% {:.1}% {:.1}%",
                p.err[0] * 100.0,
                p.err[1] * 100.0,
                p.err[2] * 100.0
            ),
        ]);
    }
    t.print();
    let worst = worst_err(points);
    println!(
        "cross-validation (baseline + smartnic): worst deviation {:.1}% — {}",
        worst * 100.0,
        if worst < VALIDATE_TOL { "PASS" } else { "FAIL" }
    );
}

pub fn print_oversub(points: &[OversubPoint], cfg: &ScalingConfig) {
    if points.is_empty() {
        return;
    }
    let mut t = Table::new(&["nodes", "scheme", "flat AR (ms)", "spanning AR (ms)", "penalty"])
        .with_title(&format!(
            "oversubscription penalty — {} leaves, {}:1 tapered, strided placement",
            cfg.leaves, cfg.oversubscription
        ));
    for p in points {
        t.row(&[
            p.nodes.to_string(),
            p.scheme.to_string(),
            fnum(p.flat * 1e3, 2),
            fnum(p.spanning * 1e3, 2),
            format!("x{}", fnum(p.penalty(), 2)),
        ]);
    }
    t.print();
    println!(
        "a spine-crossing ring loses its contention-freedom: each leaf's uplink carries every\n\
         resident rank's traffic, so the pipelined schedule queues by ~the tapering factor\n"
    );
}

/// Serialize the whole study to the `BENCH_scaling.json` schema.
pub fn to_json(cfg: &ScalingConfig, sweep: &[SweepPoint], oversub: &[OversubPoint]) -> Json {
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("batch", Json::Num(cfg.batch as f64)),
                ("leaves", Json::Num(cfg.leaves as f64)),
                ("oversubscription", Json::Num(cfg.oversubscription)),
                ("validate_tol", Json::Num(VALIDATE_TOL)),
            ]),
        ),
        (
            "sweep",
            Json::Arr(
                sweep
                    .iter()
                    .map(|p| {
                        let per_system = |vals: &[f64; 3]| {
                            Json::obj(
                                SYSTEMS
                                    .iter()
                                    .zip(vals)
                                    .map(|(name, v)| (*name, Json::Num(*v)))
                                    .collect(),
                            )
                        };
                        Json::obj(vec![
                            ("nodes", Json::Num(p.nodes as f64)),
                            ("model_s", per_system(&p.model)),
                            ("unified_s", per_system(&p.unified)),
                            ("rel_err", per_system(&p.err)),
                            (
                                "speedup_vs_baseline",
                                Json::obj(vec![
                                    ("model_nic", Json::Num(p.model_speedup(1))),
                                    ("model_bfp", Json::Num(p.model_speedup(2))),
                                    ("unified_nic", Json::Num(p.unified_speedup(1))),
                                    ("unified_bfp", Json::Num(p.unified_speedup(2))),
                                ]),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "oversubscription_penalty",
            Json::Arr(
                oversub
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("nodes", Json::Num(p.nodes as f64)),
                            ("scheme", Json::Str(p.scheme.to_string())),
                            ("flat_ar_s", Json::Num(p.flat)),
                            ("spanning_ar_s", Json::Num(p.spanning)),
                            ("penalty", Json::Num(p.penalty())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write the study to `path` (the repo convention is `BENCH_scaling.json`
/// in the working directory, uploaded as a CI artifact).
pub fn write_bench(
    path: &str,
    cfg: &ScalingConfig,
    sweep: &[SweepPoint],
    oversub: &[OversubPoint],
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, sweep, oversub).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(nodes: Vec<usize>) -> ScalingConfig {
        ScalingConfig {
            nodes,
            ..ScalingConfig::default()
        }
    }

    #[test]
    fn acceptance_32_nodes_speedup_matches_model_within_5pct() {
        // the paper's headline prediction: ~2.5x smartnic-vs-baseline at
        // 32 nodes (Sec. V).  The unified event engine must land on the
        // closed form's speedup within 5% on a flat 32-node topology.
        let pts = run_sweep(&small_cfg(vec![32]));
        let p = &pts[0];
        assert!(p.err[0] < VALIDATE_TOL, "baseline err {:.1}%", p.err[0] * 100.0);
        assert!(p.err[1] < VALIDATE_TOL, "smartnic err {:.1}%", p.err[1] * 100.0);
        let (m, u) = (p.model_speedup(1), p.unified_speedup(1));
        assert!(
            (u - m).abs() / m < 0.05,
            "speedup parity: model {m:.2}x unified {u:.2}x"
        );
        assert!((2.1..2.8).contains(&m), "expected ~2.5x, got {m:.2}x");
        // BFP's closed form stays PCIe-bound and conservative: the event
        // engine pipelines the two PCIe directions and may only be faster
        // (up to the usual 5% model slack)
        assert!(p.unified[2] <= p.model[2] * 1.05, "bfp slower than model");
        assert!(p.unified[2] >= p.model[2] * 0.5, "bfp implausibly fast");
        let bfp = p.model_speedup(2);
        assert!((2.0..3.7).contains(&bfp), "bfp speedup {bfp:.2}x");
    }

    #[test]
    fn sweep_validates_at_the_prototype_size_too() {
        let pts = run_sweep(&small_cfg(vec![6]));
        let p = &pts[0];
        assert!(p.err[0] < VALIDATE_TOL && p.err[1] < VALIDATE_TOL, "{:?}", p.err);
        // gains grow with scale: 6-node speedup below the 32-node one
        let pts32 = run_sweep(&small_cfg(vec![32]));
        assert!(p.model_speedup(1) < pts32[0].model_speedup(1));
    }

    #[test]
    fn oversub_penalty_hits_the_ring_hardest_where_it_was_optimal() {
        let cfg = ScalingConfig {
            nodes: vec![12],
            leaves: 4,
            oversubscription: 4.0,
            ..ScalingConfig::default()
        };
        let pts = run_oversub(&cfg);
        assert_eq!(pts.len(), SCHEMES.len());
        for p in &pts {
            assert!(p.flat > 0.0 && p.spanning.is_finite());
            // crossing the spine never speeds a collective up
            assert!(p.penalty() > 0.95, "{}: penalty {}", p.scheme, p.penalty());
        }
        let ring = pts.iter().find(|p| p.scheme == "nic-ring").unwrap();
        assert!(
            (2.0..5.0).contains(&ring.penalty()),
            "ring penalty x{:.2} under 4:1 tapering",
            ring.penalty()
        );
    }

    #[test]
    fn non_blocking_spine_is_nearly_free_for_the_ring() {
        let cfg = ScalingConfig {
            nodes: vec![12],
            leaves: 4,
            oversubscription: 1.0,
            ..ScalingConfig::default()
        };
        let pts = run_oversub(&cfg);
        let ring = pts.iter().find(|p| p.scheme == "nic-ring").unwrap();
        assert!(
            ring.penalty() < 1.3,
            "full-bisection spine penalty x{:.2}",
            ring.penalty()
        );
    }

    #[test]
    fn oversub_nodes_respects_leaf_shape() {
        let cfg = ScalingConfig {
            nodes: vec![6, 12, 32, 511],
            leaves: 4,
            ..ScalingConfig::default()
        };
        assert_eq!(oversub_nodes(&cfg), vec![12, 32]);
    }

    #[test]
    fn bench_json_schema() {
        let cfg = small_cfg(vec![6]);
        let sweep = run_sweep(&cfg);
        let oversub: Vec<OversubPoint> = Vec::new();
        let j = to_json(&cfg, &sweep, &oversub);
        let first = j.get("sweep").unwrap().idx(0).unwrap();
        assert_eq!(first.get("nodes").unwrap().as_usize(), Some(6));
        for sys in SYSTEMS {
            assert!(first.get("model_s").unwrap().get(sys).unwrap().as_f64().unwrap() > 0.0);
            assert!(first.get("unified_s").unwrap().get(sys).unwrap().as_f64().unwrap() > 0.0);
        }
        let sp = first.get("speedup_vs_baseline").unwrap();
        assert!(sp.get("model_nic").unwrap().as_f64().unwrap() > 1.0);
        // round-trips through the parser
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j);
    }
}
