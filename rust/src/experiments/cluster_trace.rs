//! E10 — cluster-scheduler trace study: gang placement policies under
//! churn.
//!
//! One seeded synthetic arrival trace (Poisson arrivals, heavy-tailed
//! gang sizes and iteration counts, elastic resizes, node failures) runs
//! to completion once per [`Policy`] on the same leaf–spine fabric, so
//! every policy sees the identical offered load and the only varying
//! factor is where gangs land.  Per policy the study reports p50/p99 job
//! completion time and queue wait, makespan, allocated-node utilization,
//! fabric Ethernet utilization, and how many jobs ever ran fragmented.
//!
//! The headline number is the *fragmentation penalty*: mean JCT of the
//! always-scatter policy over contiguous first-fit.  Scatter forces
//! every collective across the oversubscribed spine, so on a healthy
//! model the ratio is strictly above 1 — the bench fails if it is not
//! ([`FRAG_GAP_MIN`]), and warns below the [`FRAG_GAP_TARGET`] trend
//! level.
//!
//! Two more gates ride along: an audited churn run
//! ([`EngineKind::Checked`]) must report zero violations — the runtime
//! invariant auditor plus the conservation ledger, including the
//! scheduler's own `leaked-allocation` / `job-conservation` checks — and
//! a same-seed re-run must reproduce p50/p99 JCT bit-for-bit
//! (`gates.determinism_pass`).
//!
//! `smartnic cluster-trace` prints the table and writes
//! `BENCH_cluster.json` (schema documented in `docs/BENCHMARKS.md`,
//! pinned by `rust/tests/bench_schema.rs`).

use crate::cluster::{run_trace, synth_trace, EngineKind, Policy, Topology, TraceGenConfig};
use crate::experiments::planner::planner_system;
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::util::table::{fnum, Table};
use std::time::Instant;

/// Hard floor of the fragmentation-penalty gate: scatter placement must
/// cost strictly more mean JCT than contiguous first-fit.  The sim is
/// deterministic, so any ratio at or below 1.0 means spine crossings
/// have stopped costing anything — a modeling regression, not noise.
pub const FRAG_GAP_MIN: f64 = 1.0;

/// Trend target for the fragmentation penalty (warn-only below): the
/// level a 4:1-oversubscribed spine is expected to extract from
/// all-scatter placement on the default trace.
pub const FRAG_GAP_TARGET: f64 = 1.05;

/// Policy whose trace is re-run for the audit and determinism gates —
/// the fragmented-fallback scheduler exercises every churn path
/// (contiguous placement, scatter fallback, preempt, restart, elastic).
pub const GATE_POLICY: Policy = Policy::FragAllowed;

/// Sweep parameters: fabric shape plus the synthetic-trace knobs
/// forwarded to [`synth_trace`].
#[derive(Clone, Debug)]
pub struct ClusterTraceConfig {
    pub nodes: usize,
    pub leaves: usize,
    pub oversubscription: f64,
    pub jobs: usize,
    pub seed: u64,
    pub mean_interarrival: f64,
    pub min_gang: usize,
    pub max_gang: usize,
    pub max_iters: usize,
    pub layers: usize,
    pub hidden: usize,
    pub batch_per_node: usize,
    pub elastic_fraction: f64,
    pub failures: usize,
    pub restart_delay: f64,
    pub repair_delay: f64,
    /// parallel worker threads for the sweep runs (0 = sequential typed)
    pub threads: usize,
}

impl Default for ClusterTraceConfig {
    fn default() -> Self {
        Self {
            nodes: 64,
            leaves: 8,
            oversubscription: 4.0,
            jobs: 80,
            seed: 7,
            mean_interarrival: 0.02,
            min_gang: 2,
            max_gang: 16,
            max_iters: 6,
            layers: 2,
            hidden: 256,
            batch_per_node: 32,
            elastic_fraction: 0.25,
            failures: 3,
            restart_delay: 0.05,
            repair_delay: 0.2,
            threads: 0,
        }
    }
}

/// One policy's run over the shared trace.
#[derive(Clone, Debug)]
pub struct TracePolicyPoint {
    pub policy: &'static str,
    pub jobs: usize,
    pub p50_jct: f64,
    pub p99_jct: f64,
    pub mean_jct: f64,
    pub p50_wait: f64,
    pub p99_wait: f64,
    pub makespan: f64,
    /// allocated node-seconds over `nodes * makespan`
    pub node_util: f64,
    /// fabric Ethernet utilization over the makespan
    pub eth_util: f64,
    /// jobs that ever ran on a fragmented placement
    pub frag_jobs: usize,
    pub preemptions: u64,
    pub restarts: u64,
    /// collectives aborted in the driver-request window by preempts
    pub aborted_collectives: usize,
    pub events: u64,
    pub peak_queue_depth: usize,
    pub wall_s: f64,
}

/// Result of the audited ([`EngineKind::Checked`]) churn run.
#[derive(Clone, Debug)]
pub struct AuditInfo {
    pub policy: &'static str,
    /// audited worker threads (0 = sequential audited run)
    pub threads: usize,
    pub violations: usize,
    pub events_checked: u64,
    pub events: u64,
    pub wall_s: f64,
}

fn topology(cfg: &ClusterTraceConfig) -> Topology {
    assert!(cfg.leaves >= 1, "need at least one leaf");
    assert!(
        cfg.nodes % cfg.leaves == 0,
        "nodes {} must divide evenly across {} leaves",
        cfg.nodes,
        cfg.leaves
    );
    if cfg.leaves == 1 {
        Topology::flat(cfg.nodes)
    } else {
        Topology::leaf_spine(cfg.leaves, cfg.nodes / cfg.leaves, cfg.oversubscription)
    }
}

fn gen_config(cfg: &ClusterTraceConfig) -> TraceGenConfig {
    TraceGenConfig {
        jobs: cfg.jobs,
        seed: cfg.seed,
        mean_interarrival: cfg.mean_interarrival,
        min_gang: cfg.min_gang,
        max_gang: cfg.max_gang,
        max_iters: cfg.max_iters,
        layers: cfg.layers,
        hidden: cfg.hidden,
        batch_per_node: cfg.batch_per_node,
        elastic_fraction: cfg.elastic_fraction,
        failures: cfg.failures,
        restart_delay: cfg.restart_delay,
        repair_delay: cfg.repair_delay,
    }
}

fn sweep_engine(cfg: &ClusterTraceConfig) -> EngineKind {
    if cfg.threads == 0 {
        EngineKind::Typed
    } else {
        EngineKind::Parallel { threads: cfg.threads }
    }
}

fn run_policy(cfg: &ClusterTraceConfig, policy: Policy, engine: EngineKind) -> TracePolicyPoint {
    let topo = topology(cfg);
    let sys = planner_system(cfg.leaves, cfg.nodes / cfg.leaves);
    let spec = synth_trace(sys, topo, policy, &gen_config(cfg));
    let t0 = Instant::now();
    let out = run_trace(&spec, engine);
    let wall = t0.elapsed().as_secs_f64();
    let jcts: Vec<f64> = out.jobs.iter().map(|j| j.jct).collect();
    let waits: Vec<f64> = out.jobs.iter().map(|j| j.queue_wait).collect();
    TracePolicyPoint {
        policy: policy.name(),
        jobs: out.jobs.len(),
        p50_jct: percentile(&jcts, 50.0),
        p99_jct: percentile(&jcts, 99.0),
        mean_jct: jcts.iter().sum::<f64>() / jcts.len().max(1) as f64,
        p50_wait: percentile(&waits, 50.0),
        p99_wait: percentile(&waits, 99.0),
        makespan: out.makespan,
        node_util: out.node_util,
        eth_util: out.eth_util,
        frag_jobs: out.jobs.iter().filter(|j| j.frag).count(),
        preemptions: out.jobs.iter().map(|j| j.preemptions as u64).sum(),
        restarts: out.jobs.iter().map(|j| j.restarts as u64).sum(),
        aborted_collectives: out.aborted_collectives,
        events: out.events,
        peak_queue_depth: out.peak_queue_depth,
        wall_s: wall,
    }
}

/// Run the shared trace once per [`Policy`] on the sweep engine.
pub fn run(cfg: &ClusterTraceConfig) -> Vec<TracePolicyPoint> {
    Policy::ALL.iter().map(|&p| run_policy(cfg, p, sweep_engine(cfg))).collect()
}

/// Re-run the [`GATE_POLICY`] trace under the checked executive: the
/// runtime invariant auditor plus the post-quiescence conservation
/// ledger (including the scheduler's churn invariants).  Any violation
/// fails the bench.
pub fn run_audited(cfg: &ClusterTraceConfig) -> AuditInfo {
    let topo = topology(cfg);
    let sys = planner_system(cfg.leaves, cfg.nodes / cfg.leaves);
    let spec = synth_trace(sys, topo, GATE_POLICY, &gen_config(cfg));
    let t0 = Instant::now();
    let out = run_trace(&spec, EngineKind::Checked { threads: cfg.threads });
    let wall = t0.elapsed().as_secs_f64();
    let report = out.audit.expect("checked run carries an audit report");
    AuditInfo {
        policy: GATE_POLICY.name(),
        threads: cfg.threads,
        violations: report.total() as usize,
        events_checked: report.events_checked(),
        events: out.events,
        wall_s: wall,
    }
}

/// Same-seed reproducibility gate: re-run the [`GATE_POLICY`] trace on
/// the sweep engine and bit-compare p50/p99 JCT and makespan against the
/// sweep's own point.  `None` when the sweep holds no such point — no
/// vacuous PASS.
pub fn check_determinism(cfg: &ClusterTraceConfig, points: &[TracePolicyPoint]) -> Option<bool> {
    let reference = points.iter().find(|p| p.policy == GATE_POLICY.name())?;
    let rerun = run_policy(cfg, GATE_POLICY, sweep_engine(cfg));
    Some(
        rerun.p50_jct.to_bits() == reference.p50_jct.to_bits()
            && rerun.p99_jct.to_bits() == reference.p99_jct.to_bits()
            && rerun.makespan.to_bits() == reference.makespan.to_bits()
            && rerun.events == reference.events,
    )
}

/// The fragmentation penalty: scatter mean JCT over first-fit mean JCT.
/// `None` when either policy is missing from the sweep — no vacuous
/// PASS.
pub fn frag_jct_gap(points: &[TracePolicyPoint]) -> Option<f64> {
    let mean = |name: &str| points.iter().find(|p| p.policy == name).map(|p| p.mean_jct);
    match (mean("scatter"), mean("first-fit")) {
        (Some(sc), Some(ff)) if ff > 0.0 => Some(sc / ff),
        _ => None,
    }
}

pub fn print(
    cfg: &ClusterTraceConfig,
    points: &[TracePolicyPoint],
    audit: Option<&AuditInfo>,
    determinism: Option<bool>,
) {
    let mut t = Table::new(&[
        "policy",
        "p50 jct",
        "p99 jct",
        "mean jct",
        "p50 wait",
        "makespan",
        "util",
        "eth util",
        "frag",
        "preempt",
        "events",
    ])
    .with_title(&format!(
        "cluster trace — {} jobs on {} nodes ({} leaves, {}:1), seed {}",
        cfg.jobs, cfg.nodes, cfg.leaves, cfg.oversubscription, cfg.seed
    ));
    for p in points {
        t.row(&[
            p.policy.to_string(),
            fnum(p.p50_jct, 4),
            fnum(p.p99_jct, 4),
            fnum(p.mean_jct, 4),
            fnum(p.p50_wait, 4),
            fnum(p.makespan, 4),
            format!("{:.1}%", p.node_util * 100.0),
            format!("{:.1}%", p.eth_util * 100.0),
            format!("{}/{}", p.frag_jobs, p.jobs),
            format!("{}", p.preemptions),
            p.events.to_string(),
        ]);
    }
    t.print();
    match frag_jct_gap(points) {
        Some(g) => println!(
            "fragmentation penalty (scatter/first-fit mean JCT): x{:.3} \
             (hard floor x{FRAG_GAP_MIN}, target x{FRAG_GAP_TARGET}) — {}",
            g,
            if g > FRAG_GAP_MIN && g >= FRAG_GAP_TARGET {
                "PASS"
            } else if g > FRAG_GAP_MIN {
                "WARN (below target, above floor)"
            } else {
                "FAIL"
            }
        ),
        None => println!("fragmentation penalty: not validated (scatter or first-fit missing)"),
    }
    match audit {
        Some(a) => println!(
            "audited churn run ({}, {} thread(s)): {} violation(s) over {} checked events — {}",
            a.policy,
            a.threads,
            a.violations,
            a.events_checked,
            if a.violations == 0 { "PASS" } else { "FAIL" }
        ),
        None => println!("audited churn run: not validated (skipped)"),
    }
    match determinism {
        Some(pass) => println!(
            "same-seed determinism ({}): p50/p99 JCT bit-identical — {}",
            GATE_POLICY.name(),
            if pass { "PASS" } else { "FAIL" }
        ),
        None => println!("same-seed determinism: not validated (no gate-policy point)"),
    }
}

/// Serialize the study to the `BENCH_cluster.json` schema (documented in
/// `docs/BENCHMARKS.md`, pinned by `rust/tests/bench_schema.rs`).
pub fn to_json(
    cfg: &ClusterTraceConfig,
    points: &[TracePolicyPoint],
    audit: Option<&AuditInfo>,
    determinism: Option<bool>,
) -> Json {
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("nodes", Json::Num(cfg.nodes as f64)),
                ("leaves", Json::Num(cfg.leaves as f64)),
                ("oversubscription", Json::Num(cfg.oversubscription)),
                ("jobs", Json::Num(cfg.jobs as f64)),
                ("seed", Json::Num(cfg.seed as f64)),
                ("mean_interarrival", Json::Num(cfg.mean_interarrival)),
                ("min_gang", Json::Num(cfg.min_gang as f64)),
                ("max_gang", Json::Num(cfg.max_gang as f64)),
                ("max_iters", Json::Num(cfg.max_iters as f64)),
                ("layers", Json::Num(cfg.layers as f64)),
                ("hidden", Json::Num(cfg.hidden as f64)),
                ("elastic_fraction", Json::Num(cfg.elastic_fraction)),
                ("failures", Json::Num(cfg.failures as f64)),
                ("threads", Json::Num(cfg.threads as f64)),
                ("frag_gap_min", Json::Num(FRAG_GAP_MIN)),
                ("frag_gap_target", Json::Num(FRAG_GAP_TARGET)),
            ]),
        ),
        (
            "policies",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("policy", Json::Str(p.policy.to_string())),
                            ("jobs", Json::Num(p.jobs as f64)),
                            ("p50_jct", Json::Num(p.p50_jct)),
                            ("p99_jct", Json::Num(p.p99_jct)),
                            ("mean_jct", Json::Num(p.mean_jct)),
                            ("p50_wait", Json::Num(p.p50_wait)),
                            ("p99_wait", Json::Num(p.p99_wait)),
                            ("makespan", Json::Num(p.makespan)),
                            ("node_util", Json::Num(p.node_util)),
                            ("eth_util", Json::Num(p.eth_util)),
                            ("frag_jobs", Json::Num(p.frag_jobs as f64)),
                            ("preemptions", Json::Num(p.preemptions as f64)),
                            ("restarts", Json::Num(p.restarts as f64)),
                            ("aborted_collectives", Json::Num(p.aborted_collectives as f64)),
                            ("events", Json::Num(p.events as f64)),
                            ("peak_queue_depth", Json::Num(p.peak_queue_depth as f64)),
                            ("wall_s", Json::Num(p.wall_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gates",
            Json::obj(vec![
                (
                    "frag_jct_gap",
                    match frag_jct_gap(points) {
                        Some(g) => Json::Num(g),
                        None => Json::Null,
                    },
                ),
                (
                    "frag_gap_pass",
                    match frag_jct_gap(points) {
                        Some(g) => Json::Bool(g > FRAG_GAP_MIN),
                        None => Json::Null,
                    },
                ),
                (
                    "frag_gap_target_pass",
                    match frag_jct_gap(points) {
                        Some(g) => Json::Bool(g >= FRAG_GAP_TARGET),
                        None => Json::Null,
                    },
                ),
                (
                    "audit_violations",
                    match audit {
                        Some(a) => Json::Num(a.violations as f64),
                        None => Json::Null,
                    },
                ),
                (
                    "audit_events_checked",
                    match audit {
                        Some(a) => Json::Num(a.events_checked as f64),
                        None => Json::Null,
                    },
                ),
                (
                    "audit_pass",
                    match audit {
                        Some(a) => Json::Bool(a.violations == 0),
                        None => Json::Null,
                    },
                ),
                (
                    "determinism_pass",
                    match determinism {
                        Some(pass) => Json::Bool(pass),
                        None => Json::Null,
                    },
                ),
                (
                    "total_preemptions",
                    Json::Num(points.iter().map(|p| p.preemptions).sum::<u64>() as f64),
                ),
                (
                    "all_jobs_completed",
                    Json::Bool(points.iter().all(|p| p.jobs > 0)),
                ),
            ]),
        ),
    ])
}

/// Write the study to `path` (repo convention: `BENCH_cluster.json`,
/// uploaded as a CI artifact).
pub fn write_bench(
    path: &str,
    cfg: &ClusterTraceConfig,
    points: &[TracePolicyPoint],
    audit: Option<&AuditInfo>,
    determinism: Option<bool>,
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, points, audit, determinism).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ClusterTraceConfig {
        ClusterTraceConfig {
            nodes: 16,
            leaves: 4,
            jobs: 10,
            max_gang: 8,
            max_iters: 3,
            hidden: 64,
            batch_per_node: 8,
            mean_interarrival: 0.01,
            failures: 1,
            restart_delay: 0.01,
            repair_delay: 0.05,
            ..ClusterTraceConfig::default()
        }
    }

    #[test]
    fn sweep_covers_every_policy() {
        let points = run(&tiny_cfg());
        assert_eq!(points.len(), Policy::ALL.len());
        for p in &points {
            assert_eq!(p.jobs, 10, "{}: lost jobs", p.policy);
            assert!(p.p50_jct > 0.0 && p.p99_jct >= p.p50_jct, "{}", p.policy);
            assert!(p.makespan > 0.0 && p.events > 0, "{}", p.policy);
            assert!(p.node_util > 0.0 && p.node_util <= 1.0 + 1e-9, "{}", p.policy);
        }
    }

    #[test]
    fn frag_gap_is_strictly_positive() {
        let points = run(&tiny_cfg());
        let gap = frag_jct_gap(&points).expect("both gate policies in the sweep");
        assert!(gap > FRAG_GAP_MIN, "scatter must cost JCT, got x{gap:.4}");
    }

    #[test]
    fn audited_run_is_clean() {
        let a = run_audited(&tiny_cfg());
        assert_eq!(a.violations, 0, "audited churn run must be clean");
        assert!(a.events_checked > 0, "auditor must have checked events");
    }

    #[test]
    fn determinism_gate_passes_on_same_seed() {
        let cfg = tiny_cfg();
        let points = run(&cfg);
        assert_eq!(check_determinism(&cfg, &points), Some(true));
        // no gate-policy point → the gate must refuse to report
        let rest: Vec<TracePolicyPoint> =
            points.iter().filter(|p| p.policy != GATE_POLICY.name()).cloned().collect();
        assert_eq!(check_determinism(&cfg, &rest), None);
    }

    #[test]
    fn gates_are_not_vacuous_on_partial_sweeps() {
        let points = run(&tiny_cfg());
        let no_scatter: Vec<TracePolicyPoint> =
            points.iter().filter(|p| p.policy != "scatter").cloned().collect();
        assert!(frag_jct_gap(&no_scatter).is_none());
        let j = to_json(&tiny_cfg(), &no_scatter, None, None);
        let gates = j.get("gates").unwrap();
        assert_eq!(gates.get("frag_jct_gap"), Some(&Json::Null));
        assert_eq!(gates.get("frag_gap_pass"), Some(&Json::Null));
        assert_eq!(gates.get("audit_pass"), Some(&Json::Null));
        assert_eq!(gates.get("determinism_pass"), Some(&Json::Null));
    }

    #[test]
    fn json_round_trips() {
        let cfg = tiny_cfg();
        let points = run(&cfg);
        let audit = run_audited(&cfg);
        let determinism = check_determinism(&cfg, &points);
        let j = to_json(&cfg, &points, Some(&audit), determinism);
        let parsed = Json::parse(&j.to_string_pretty()).expect("self-emitted JSON parses");
        assert_eq!(parsed, j);
    }
}
