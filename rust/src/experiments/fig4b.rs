//! E5 — Fig. 4b: performance scaling to 32 nodes (normalized to 1 node)
//! for B=448 and B=1792: baseline vs smart NIC vs smart NIC + BFP.
//!
//! Like the paper: "measured" points (the DES plays the prototype's role)
//! up to 6 nodes, analytical-model points beyond — and the two must agree
//! where they overlap.

use crate::analytic::model::{iteration, SystemKind};
use crate::collective::Scheme;
use crate::coordinator::simulate_iteration;
use crate::sysconfig::{SystemParams, Workload};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

pub const SIM_MAX_NODES: usize = 6; // the prototype's size

#[derive(Clone, Debug)]
pub struct Point {
    pub nodes: usize,
    /// normalized throughput from the DES ("measured"), <= 6 nodes
    pub sim: Option<f64>,
    /// normalized throughput from the analytical model
    pub model: f64,
}

#[derive(Clone, Debug)]
pub struct Series {
    pub system: String,
    pub points: Vec<Point>,
}

fn variants() -> [(&'static str, SystemKind, SystemParams); 3] {
    [
        (
            "baseline",
            SystemKind::BaselineOverlapped {
                scheme: Scheme::Ring,
                comm_cores: 2,
            },
            SystemParams::baseline_100g(),
        ),
        (
            "smartnic",
            SystemKind::SmartNic { bfp: false },
            SystemParams::smartnic_40g(),
        ),
        (
            "smartnic+bfp",
            SystemKind::SmartNic { bfp: true },
            SystemParams::smartnic_40g(),
        ),
    ]
}

pub fn run(node_counts: &[usize], batch: usize) -> Vec<Series> {
    let w = Workload::paper_mlp(batch);
    // common 1-worker reference for every curve (the paper normalizes to
    // "a system with only 1 worker", where NICs are irrelevant): plain
    // all-cores compute, no all-reduce
    let t1 = iteration(
        SystemKind::SmartNic { bfp: false },
        &SystemParams::smartnic_40g(),
        &w,
        1,
    )
    .t_total;
    variants()
        .into_iter()
        .map(|(name, kind, sys)| {
            let points = node_counts
                .iter()
                .map(|&n| {
                    let model = n as f64 * t1 / iteration(kind, &sys, &w, n).t_total;
                    let sim = (n <= SIM_MAX_NODES).then(|| {
                        n as f64 * t1 / simulate_iteration(kind, &sys, &w, n).breakdown.t_total
                    });
                    Point { nodes: n, sim, model }
                })
                .collect();
            Series {
                system: name.to_string(),
                points,
            }
        })
        .collect()
}

pub fn print(series: &[Series], batch: usize) {
    let nodes: Vec<usize> = series[0].points.iter().map(|p| p.nodes).collect();
    let mut headers = vec!["system".to_string()];
    headers.extend(nodes.iter().map(|n| format!("{n}n")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs).with_title(&format!(
        "Fig. 4b — normalized throughput vs nodes (B={batch}/node; sim<=6n shown as s/, model as m/)"
    ));
    let mut ideal = vec!["ideal".to_string()];
    ideal.extend(nodes.iter().map(|n| fnum(*n as f64, 1)));
    t.row(&ideal);
    for s in series {
        let mut row = vec![s.system.clone()];
        row.extend(s.points.iter().map(|p| match p.sim {
            Some(sv) => format!("s{} m{}", fnum(sv, 1), fnum(p.model, 1)),
            None => format!("m{}", fnum(p.model, 1)),
        }));
        t.row(&row);
    }
    t.print();
    // headline gains at the largest node count
    let last = nodes.len() - 1;
    let base = series[0].points[last].model;
    println!(
        "gain vs baseline at {} nodes: smartnic {:.1}x, smartnic+bfp {:.1}x\n",
        nodes[last],
        series[1].points[last].model / base,
        series[2].points[last].model / base,
    );
}

pub fn to_json(series: &[Series]) -> Json {
    Json::Arr(
        series
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("system", Json::Str(s.system.clone())),
                    (
                        "points",
                        Json::Arr(
                            s.points
                                .iter()
                                .map(|p| {
                                    Json::obj(vec![
                                        ("nodes", Json::Num(p.nodes as f64)),
                                        (
                                            "sim",
                                            p.sim.map(Json::Num).unwrap_or(Json::Null),
                                        ),
                                        ("model", Json::Num(p.model)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_err;

    #[test]
    fn sim_and_model_agree_where_both_exist() {
        // the paper's "within 3%" claim, at the prototype sizes
        for batch in [448usize, 1792] {
            let series = run(&[3, 4, 5, 6], batch);
            for s in &series {
                for p in &s.points {
                    let sim = p.sim.unwrap();
                    assert!(
                        rel_err(p.model, sim) < 0.03,
                        "{} n={} B={batch}: model {} sim {}",
                        s.system,
                        p.nodes,
                        p.model,
                        sim
                    );
                }
            }
        }
    }

    #[test]
    fn b448_gains_match_papers_range() {
        let series = run(&[1, 6, 32], 448);
        let base32 = series[0].points[2].model;
        let nic32 = series[1].points[2].model;
        let bfp32 = series[2].points[2].model;
        // paper: up to 1.8x (NIC) and 2.5x (NIC+BFP) at 32 nodes — our
        // calibration lands the same ordering with somewhat larger gains
        // (see EXPERIMENTS.md E5 for the deltas)
        assert!((1.3..2.6).contains(&(nic32 / base32)), "nic {:.2}", nic32 / base32);
        assert!((1.8..3.6).contains(&(bfp32 / base32)), "bfp {:.2}", bfp32 / base32);
        assert!(bfp32 > nic32);
    }

    #[test]
    fn b1792_near_ideal_for_smartnic() {
        // paper: at B=1792 the smart NIC achieves ~ideal scaling and BFP
        // adds nothing (compute-bound)
        let series = run(&[6, 32], 1792);
        let nic = &series[1];
        let bfp = &series[2];
        assert!(nic.points[0].model > 0.9 * 6.0, "{:?}", nic.points[0]);
        assert!(nic.points[1].model > 0.85 * 32.0, "{:?}", nic.points[1]);
        for (a, b) in nic.points.iter().zip(&bfp.points) {
            assert!(
                (a.model - b.model).abs() / a.model < 0.03,
                "bfp should not help at B=1792"
            );
        }
        // paper: NIC beats baseline ~1.1x at 6 nodes, ~1.4x at 32
        let g6 = nic.points[0].model / series[0].points[0].model;
        let g32 = nic.points[1].model / series[0].points[1].model;
        assert!((1.02..1.35).contains(&g6), "gain@6 {g6:.2}");
        assert!((1.15..1.8).contains(&g32), "gain@32 {g32:.2}");
        assert!(g32 > g6);
    }
}
