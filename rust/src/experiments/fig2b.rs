//! E2 — Fig. 2b: scaling of the overlapped host implementation for
//! different MPI all-reduce schemes vs ideal scaling (B=1792/node).

use crate::analytic::model::{iteration, SystemKind};
use crate::collective::Scheme;
use crate::sysconfig::{SystemParams, Workload};
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

#[derive(Clone, Debug)]
pub struct Series {
    pub scheme: Scheme,
    /// (nodes, normalized throughput) — normalized to the 1-node system
    pub points: Vec<(usize, f64)>,
}

pub fn run(node_counts: &[usize], batch: usize) -> Vec<Series> {
    let sys = SystemParams::baseline_100g();
    let w = Workload::paper_mlp(batch);
    let t1 = iteration(
        SystemKind::BaselineOverlapped {
            scheme: Scheme::Ring,
            comm_cores: 2,
        },
        &sys,
        &w,
        1,
    )
    .t_total;
    Scheme::ALL
        .iter()
        .map(|&scheme| {
            let points = node_counts
                .iter()
                .map(|&n| {
                    let kind = SystemKind::BaselineOverlapped {
                        scheme,
                        comm_cores: 2,
                    };
                    let t = iteration(kind, &sys, &w, n).t_total;
                    // throughput normalized to 1 node: (N·B/t) / (B/t1)
                    (n, n as f64 * t1 / t)
                })
                .collect();
            Series { scheme, points }
        })
        .collect()
}

pub fn print(series: &[Series]) {
    let nodes: Vec<usize> = series[0].points.iter().map(|p| p.0).collect();
    let mut headers = vec!["scheme".to_string()];
    headers.extend(nodes.iter().map(|n| format!("{n}n")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs).with_title(
        "Fig. 2b — normalized throughput vs nodes (overlapped host all-reduce, B=1792/node)",
    );
    let mut ideal = vec!["ideal".to_string()];
    ideal.extend(nodes.iter().map(|n| fnum(*n as f64, 2)));
    t.row(&ideal);
    for s in series {
        let mut row = vec![s.scheme.name().to_string()];
        row.extend(s.points.iter().map(|(_, v)| fnum(*v, 2)));
        t.row(&row);
    }
    t.print();
    println!();
}

pub fn to_json(series: &[Series]) -> Json {
    Json::Arr(
        series
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("scheme", Json::Str(s.scheme.name().to_string())),
                    (
                        "points",
                        Json::Arr(
                            s.points
                                .iter()
                                .map(|(n, v)| Json::arr_f64(&[*n as f64, *v]))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn by_name<'a>(series: &'a [Series], name: &str) -> &'a Series {
        series.iter().find(|s| s.scheme.name() == name).unwrap()
    }

    #[test]
    fn papers_ordering_holds() {
        let series = run(&[2, 4, 6, 8, 12, 16, 24], 1792);
        // ring / rabenseifner / default all similar and better than binomial
        for (i, &n) in [2usize, 4, 6, 8, 12, 16, 24].iter().enumerate() {
            let ring = by_name(&series, "ring").points[i].1;
            let rab = by_name(&series, "rabenseifner").points[i].1;
            let def = by_name(&series, "default").points[i].1;
            let bin = by_name(&series, "binomial").points[i].1;
            assert!(ring >= bin, "n={n}: ring {ring} < binomial {bin}");
            assert!(def >= bin, "n={n}");
            assert!((ring - rab).abs() / ring < 0.15, "n={n}: ring {ring} rab {rab}");
        }
    }

    #[test]
    fn gap_to_ideal_grows() {
        let series = run(&[2, 12, 24], 1792);
        let ring = by_name(&series, "ring");
        let eff: Vec<f64> = ring.points.iter().map(|(n, v)| v / *n as f64).collect();
        assert!(eff[0] > eff[1] - 1e-12);
        assert!(eff[1] >= eff[2] - 1e-12);
        // scales well at 12 nodes (>= 80% efficiency)
        assert!(eff[1] > 0.8, "12-node efficiency {}", eff[1]);
    }
}
