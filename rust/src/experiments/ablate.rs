//! Ablations over the design choices DESIGN.md calls out:
//!
//! * **segment size** — the NIC's chunk-pipelining granularity (the FIFO
//!   depth analogue): too coarse loses fetch/ring/writeback overlap, too
//!   fine pays per-segment latency;
//! * **comm cores** — the baseline's compute/communication core split
//!   (the paper: "2 cores ... yields the best performance. However, this
//!   balance ... is workload dependent");
//! * **α sensitivity** — achievable fraction of NIC line rate;
//! * **NIC line rate** — 40/100/400 Gbps variants of Sec. V-A.

use crate::analytic::model::{iteration, SystemKind};
use crate::bfp::BfpCodec;
use crate::collective::Scheme;
use crate::nic::{simulate_ring_allreduce, NicConfig};
use crate::sysconfig::{SystemParams, Workload};
use crate::util::table::{fnum, Table};

/// Segment-size sweep: returns (segment_bytes, t_allreduce).
pub fn segment_sweep(nodes: usize, elems: usize, bfp: bool) -> Vec<(f64, f64)> {
    [4.0 * 1024.0, 16.0 * 1024.0, 64.0 * 1024.0, 256.0 * 1024.0, 1024.0 * 1024.0, 4096.0 * 1024.0]
        .into_iter()
        .map(|seg| {
            let mut sys = SystemParams::smartnic_40g();
            sys.nic.segment_bytes = seg;
            let cfg = NicConfig::new(sys, if bfp { Some(BfpCodec::bfp16()) } else { None });
            (seg, simulate_ring_allreduce(&cfg, nodes, elems).t_total)
        })
        .collect()
}

/// Comm-core sweep for the overlapped baseline: (k, t_total).
pub fn comm_core_sweep(nodes: usize, batch: usize, max_k: usize) -> Vec<(usize, f64)> {
    let sys = SystemParams::baseline_100g();
    let w = Workload::paper_mlp(batch);
    (1..=max_k)
        .map(|k| {
            let kind = SystemKind::BaselineOverlapped {
                scheme: Scheme::Ring,
                comm_cores: k,
            };
            (k, iteration(kind, &sys, &w, nodes).t_total)
        })
        .collect()
}

/// α sensitivity of the smart NIC: (alpha, t_total).
pub fn alpha_sweep(nodes: usize, batch: usize, bfp: bool) -> Vec<(f64, f64)> {
    let w = Workload::paper_mlp(batch);
    [0.5, 0.7, 0.85, 0.95, 1.0]
        .into_iter()
        .map(|alpha| {
            let mut sys = SystemParams::smartnic_40g();
            sys.net.alpha = alpha;
            (
                alpha,
                iteration(SystemKind::SmartNic { bfp }, &sys, &w, nodes).t_total,
            )
        })
        .collect()
}

pub fn print_all() {
    println!("-- segment size (NIC pipelining granularity), 6 nodes, 2048^2 grad, +BFP --");
    let mut t = Table::new(&["segment", "t_allreduce (ms)"]);
    for (seg, tt) in segment_sweep(6, 2048 * 2048, true) {
        t.row(&[
            crate::util::units::fmt_bytes(seg),
            fnum(tt * 1e3, 3),
        ]);
    }
    t.print();

    println!("\n-- comm cores (baseline compute/comm split), 6 nodes --");
    let mut t = Table::new(&["k", "t_iter B=448 (ms)", "t_iter B=1792 (ms)"]);
    let s448 = comm_core_sweep(6, 448, 8);
    let s1792 = comm_core_sweep(6, 1792, 8);
    for (i, (k, t448)) in s448.iter().enumerate() {
        t.row(&[
            k.to_string(),
            fnum(t448 * 1e3, 1),
            fnum(s1792[i].1 * 1e3, 1),
        ]);
    }
    t.print();
    let best448 = s448.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap().0;
    let best1792 = s1792.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap().0;
    println!("best k: {best448} (B=448), {best1792} (B=1792) — paper found 2 for their workload");

    println!("\n-- alpha sensitivity (smart NIC, B=448, 6 nodes) --");
    let mut t = Table::new(&["alpha", "t_iter raw (ms)", "t_iter +BFP (ms)"]);
    let raw = alpha_sweep(6, 448, false);
    let comp = alpha_sweep(6, 448, true);
    for (i, (a, tr)) in raw.iter().enumerate() {
        t.row(&[fnum(*a, 2), fnum(tr * 1e3, 1), fnum(comp[i].1 * 1e3, 1)]);
    }
    t.print();
    println!("(BFP makes the system nearly alpha-insensitive: the wire stops mattering)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coarse_segments_lose_overlap() {
        let pts = segment_sweep(6, 2048 * 2048, true);
        let best = pts
            .iter()
            .map(|p| p.1)
            .fold(f64::INFINITY, f64::min);
        let coarsest = pts.last().unwrap().1;
        assert!(
            coarsest > best * 1.05,
            "whole-chunk segments should lose pipelining: {coarsest} vs {best}"
        );
    }

    #[test]
    fn comm_core_tradeoff_has_interior_shape() {
        // more comm cores help AR but steal compute: time is not
        // monotone increasing from k=1
        let pts = comm_core_sweep(6, 448, 8);
        let t1 = pts[0].1;
        let best = pts.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
        assert!(best.1 <= t1, "{pts:?}");
        // and at some point stealing cores hurts again
        let t8 = pts.last().unwrap().1;
        assert!(t8 > best.1, "{pts:?}");
    }

    #[test]
    fn bfp_flattens_alpha_sensitivity() {
        let raw = alpha_sweep(6, 448, false);
        let comp = alpha_sweep(6, 448, true);
        let spread = |pts: &[(f64, f64)]| {
            let lo = pts.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
            let hi = pts.iter().map(|p| p.1).fold(0.0, f64::max);
            hi / lo
        };
        assert!(spread(&raw) > spread(&comp), "raw {:?} comp {:?}", raw, comp);
    }
}
