//! Experiment harnesses: one module per paper table/figure (DESIGN.md §5).
//!
//! Each regenerates the paper artifact's rows/series, prints them as an
//! ASCII table, and returns structured results for the benches and for
//! `results/*.json` dumps.

pub mod ablate;
pub mod cluster_trace;
pub mod collectives;
pub mod engine_bench;
pub mod fig2a;
pub mod fig2b;
pub mod fig4a;
pub mod fig4b;
pub mod planner;
pub mod scaling;
pub mod table1;
pub mod tenancy;
pub mod validate;

use crate::util::json::Json;
use std::path::Path;

/// Write an experiment result JSON under `results/`.
pub fn write_result(name: &str, json: &Json) -> std::io::Result<std::path::PathBuf> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, json.to_string_pretty())?;
    Ok(path)
}
